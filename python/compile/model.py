# L2: the JAX model — a RoPE decoder-only transformer (GQA, SwiGLU, RMSNorm)
# whose decode step attends over a *PolarQuant-encoded* key cache via the L1
# Pallas kernels.  Lowered once by aot.py to HLO text; never imported at
# runtime.
#
# Graph contracts (shapes fixed per AOT bucket; see aot.py manifest):
#
#   prefill(tokens (B,T) i32, prompt_len (B,) i32, *weights)
#       -> logits_last (B,V), k_cache (L,B,Kv,T,dh) post-RoPE, v_cache (same)
#     Full-precision causal attention; key quantization of the prompt is the
#     coordinator's job (Rust encodes full groups, keeps the tail residual).
#
#   decode_step(tokens (B,), positions (B,), cache_len (B,), resid_len (B,),
#               theta_code, rho_code (L,B,Kv,S,dh/2) i32,
#               rho_z, rho_s, theta_z, theta_s (L,B,Kv,S/g,dh/2) f32,
#               v_cache (L,B,Kv,S,dh) f32,
#               resid_k, resid_v (L,B,Kv,R,dh) f32, *weights)
#       -> logits (B,V), new_k (L,B,Kv,dh) post-RoPE, new_v (L,B,Kv,dh)
#     Attention scores over the quantized region come from the PolarQuant
#     LUT kernel (polar_qk_pallas); the fp residual tail and the current
#     token are scored densely.  Softmax runs over the concatenation with
#     per-sequence length masks (cache_len is always a multiple of g).
#
# Weights are graph *inputs* (never constants): the Rust runtime keeps them
# resident as PjRtBuffers, so HLO text stays small and one artifact serves
# any checkpoint of the same config.

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.polar_qk import polar_qk_pallas
from compile.kernels.polar_quant import polar_encode_pallas

NEG_INF = -1e9


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + quantization hyper-parameters (DESIGN.md §7)."""

    name: str = "tiny"
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    ffn: int = 256
    rope_base: float = 10000.0
    # quantization
    group: int = 64        # tokens per quant group (g)
    r_bits: int = 4
    t_bits: int = 4
    resid: int = 64        # fp residual capacity (R) — one group

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def validate(self):
        assert self.n_heads % self.n_kv_heads == 0
        assert self.head_dim % 2 == 0
        assert self.resid >= self.group


CONFIGS = {
    "tiny": ModelConfig(),
    "small": ModelConfig(
        name="small", vocab=2048, d_model=256, n_layers=8, n_heads=8,
        n_kv_heads=2, head_dim=32, ffn=704, group=64, resid=64,
    ),
    # Llama-3.1-8B head geometry at reduced depth/width — used for the
    # kernel-latency experiments (Fig 3 / Table 4) where only the attention
    # geometry matters.
    "llama31-head": ModelConfig(
        name="llama31-head", vocab=1024, d_model=512, n_layers=2, n_heads=32,
        n_kv_heads=8, head_dim=128, ffn=1024, rope_base=500000.0,
        group=128, resid=128,
    ),
}


# --------------------------------------------------------------------------
# Weights
# --------------------------------------------------------------------------


def weight_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the canonical flattening order used by
    the .bin file, the manifest, and every graph's trailing inputs."""
    L, D, H, Kv, dh, F, V = (
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
        cfg.head_dim, cfg.ffn, cfg.vocab,
    )
    return [
        ("embed", (V, D)),
        ("wq", (L, D, H * dh)),
        ("wk", (L, D, Kv * dh)),
        ("bk", (L, Kv * dh)),
        ("wv", (L, D, Kv * dh)),
        ("wo", (L, H * dh, D)),
        ("w_gate", (L, D, F)),
        ("w_up", (L, D, F)),
        ("w_down", (L, F, D)),
        ("norm_attn", (L, D)),
        ("norm_mlp", (L, D)),
        ("norm_final", (D,)),
        ("lm_head", (D, V)),
    ]


def init_weights(cfg: ModelConfig, seed: int = 0, outlier_severity: float = 6.0,
                 outlier_frac: float = 0.0625) -> Dict[str, np.ndarray]:
    """Synthetic weights with the paper's key-cache outlier structure.

    A fraction of key channels get a large constant BIAS on ONE dim of
    their RoPE pair (Qwen2.5's attention-bias mechanism, which the paper
    singles out as the hardest case): post-RoPE those pairs trace the
    Figure-1(b) ring — consistent radius, smooth angle — while
    Cartesian-wise the channel magnitudes dwarf their peers across every
    token (Figure 1a), which is what breaks token-wise quantization.
    Mirrors `rust/src/model/weights.rs::synthetic`.
    """
    rng = np.random.default_rng(seed)
    w: Dict[str, np.ndarray] = {}
    for name, shape in weight_specs(cfg):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = 1.0 / math.sqrt(fan_in)
        if name.startswith("norm"):
            w[name] = np.ones(shape, dtype=np.float32)
        elif name == "bk":
            w[name] = np.zeros(shape, dtype=np.float32)
        else:
            w[name] = rng.normal(0.0, std, size=shape).astype(np.float32)
    # channel outliers in the key projection (pre-RoPE, per kv-head)
    dh = cfg.head_dim
    n_pairs = dh // 2
    n_out = max(1, int(n_pairs * outlier_frac))
    bk = w["bk"].reshape(cfg.n_layers, cfg.n_kv_heads, dh)
    if outlier_severity > 0.0:
        for l in range(cfg.n_layers):
            for h in range(cfg.n_kv_heads):
                pairs = rng.choice(n_pairs, size=n_out, replace=False)
                for j in pairs:
                    sign = 1.0 if rng.random() < 0.5 else -1.0
                    bk[l, h, 2 * j] = sign * outlier_severity
    w["bk"] = bk.reshape(cfg.n_layers, cfg.n_kv_heads * dh)
    return w


def flatten_weights(cfg: ModelConfig, w: Dict[str, np.ndarray]):
    return [w[name] for name, _ in weight_specs(cfg)]


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------


def rms_norm(x, gamma, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gamma


def rope_tables(cfg: ModelConfig, positions):
    """cos/sin (..., dh/2) for the given positions (adjacent-pair form)."""
    i = jnp.arange(cfg.head_dim // 2, dtype=jnp.float32)
    phi = cfg.rope_base ** (-2.0 * i / cfg.head_dim)
    ang = positions.astype(jnp.float32)[..., None] * phi
    return jnp.cos(ang), jnp.sin(ang)


def rope_rotate(x, cos, sin):
    """x (..., dh); cos/sin broadcastable to (..., dh/2)."""
    xe = x[..., 0::2]
    xo = x[..., 1::2]
    ye = xe * cos - xo * sin
    yo = xe * sin + xo * cos
    return jnp.stack([ye, yo], axis=-1).reshape(x.shape)


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


# --------------------------------------------------------------------------
# Decode step (the serving hot path)
# --------------------------------------------------------------------------


def _decode_attn_layer(cfg: ModelConfig, x, lw, cache, positions, cache_len, resid_len):
    """One layer's attention over quantized cache + fp residual + self.

    x: (B, D); lw: dict of this layer's weights; cache: dict of this
    layer's cache slices.  Returns (out (B, D), k_cur, v_cur (B,Kv,dh)).
    """
    B = x.shape[0]
    H, Kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Hq = cfg.q_per_kv
    S = cache["v"].shape[2]
    R = cache["resid_k"].shape[2]
    G = S // cfg.group

    q = (x @ lw["wq"]).reshape(B, H, dh)
    k = (x @ lw["wk"] + lw["bk"]).reshape(B, Kv, dh)
    v = (x @ lw["wv"]).reshape(B, Kv, dh)
    cos, sin = rope_tables(cfg, positions)  # (B, dh/2)
    q = rope_rotate(q, cos[:, None, :], sin[:, None, :])
    k = rope_rotate(k, cos[:, None, :], sin[:, None, :])

    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, Kv, Hq, dh).reshape(B * Kv, Hq, dh)

    # --- quantized region: PolarQuant LUT kernel (L1) ---
    sc_q = polar_qk_pallas(
        qg,
        cache["theta_code"].reshape(B * Kv, S, dh // 2),
        cache["rho_code"].reshape(B * Kv, S, dh // 2),
        cache["rho_z"].reshape(B * Kv, G, dh // 2),
        cache["rho_s"].reshape(B * Kv, G, dh // 2),
        cache["theta_z"].reshape(B * Kv, G, dh // 2),
        cache["theta_s"].reshape(B * Kv, G, dh // 2),
        cfg.group,
        cfg.t_bits,
    ).reshape(B, Kv, Hq, S) * scale
    pos_s = jnp.arange(S, dtype=jnp.int32)
    mask_q = pos_s[None, :] < cache_len[:, None]  # (B, S)
    sc_q = jnp.where(mask_q[:, None, None, :], sc_q, NEG_INF)

    # --- fp residual tail ---
    sc_r = jnp.einsum("bkhd,bkrd->bkhr", qg.reshape(B, Kv, Hq, dh), cache["resid_k"]) * scale
    pos_r = jnp.arange(R, dtype=jnp.int32)
    mask_r = pos_r[None, :] < resid_len[:, None]
    sc_r = jnp.where(mask_r[:, None, None, :], sc_r, NEG_INF)

    # --- current token (always attends to itself) ---
    sc_c = jnp.einsum("bkhd,bkd->bkh", qg.reshape(B, Kv, Hq, dh), k)[..., None] * scale

    scores = jnp.concatenate([sc_q, sc_r, sc_c], axis=-1)  # (B,Kv,Hq,S+R+1)
    w = jax.nn.softmax(scores, axis=-1)
    out = (
        jnp.einsum("bkhs,bksd->bkhd", w[..., :S], cache["v"])
        + jnp.einsum("bkhr,bkrd->bkhd", w[..., S : S + R], cache["resid_v"])
        + w[..., -1:] * v[:, :, None, :]
    )  # (B,Kv,Hq,dh)
    out = out.reshape(B, H * dh) @ lw["wo"]
    return out, k, v


def decode_step(cfg: ModelConfig, tokens, positions, cache_len, resid_len,
                theta_code, rho_code, rho_z, rho_s, theta_z, theta_s,
                v_cache, resid_k, resid_v, *weights):
    """Full-model decode step. See module docstring for the contract."""
    w = {name: arr for (name, _), arr in zip(weight_specs(cfg), weights)}
    x = w["embed"][tokens]  # (B, D)

    layer_w = {
        k: w[k]
        for k in ("wq", "wk", "bk", "wv", "wo", "w_gate", "w_up", "w_down", "norm_attn", "norm_mlp")
    }
    caches = {
        "theta_code": theta_code, "rho_code": rho_code,
        "rho_z": rho_z, "rho_s": rho_s, "theta_z": theta_z, "theta_s": theta_s,
        "v": v_cache, "resid_k": resid_k, "resid_v": resid_v,
    }

    def body(x, per_layer):
        lw, lc = per_layer
        h, k_cur, v_cur = _decode_attn_layer(
            cfg, rms_norm(x, lw["norm_attn"]), lw, lc, positions, cache_len, resid_len
        )
        x = x + h
        x = x + swiglu(rms_norm(x, lw["norm_mlp"]), lw["w_gate"], lw["w_up"], lw["w_down"])
        return x, (k_cur, v_cur)

    x, (new_k, new_v) = jax.lax.scan(body, x, (layer_w, caches))
    logits = rms_norm(x, w["norm_final"]) @ w["lm_head"]
    return logits, new_k, new_v


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------


def prefill(cfg: ModelConfig, tokens, prompt_len, *weights):
    """Full-precision causal prefill over right-padded prompts.

    Returns (logits at the last valid position (B,V),
             k_cache (L,B,Kv,T,dh) post-RoPE, v_cache (L,B,Kv,T,dh)).
    """
    w = {name: arr for (name, _), arr in zip(weight_specs(cfg), weights)}
    B, T = tokens.shape
    H, Kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Hq = cfg.q_per_kv
    x = w["embed"][tokens]  # (B, T, D)
    positions = jnp.arange(T, dtype=jnp.int32)
    cos, sin = rope_tables(cfg, positions)  # (T, dh/2)

    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    valid = positions[None, :] < prompt_len[:, None]  # (B, T)
    mask = causal[None, :, :] & valid[:, None, :]  # (B, Tq, Tk)
    scale = 1.0 / math.sqrt(dh)

    layer_w = {
        k: w[k]
        for k in ("wq", "wk", "bk", "wv", "wo", "w_gate", "w_up", "w_down", "norm_attn", "norm_mlp")
    }

    def body(x, lw):
        xn = rms_norm(x, lw["norm_attn"])
        q = (xn @ lw["wq"]).reshape(B, T, H, dh)
        k = (xn @ lw["wk"] + lw["bk"]).reshape(B, T, Kv, dh)
        v = (xn @ lw["wv"]).reshape(B, T, Kv, dh)
        q = rope_rotate(q, cos[None, :, None, :], sin[None, :, None, :])
        k = rope_rotate(k, cos[None, :, None, :], sin[None, :, None, :])
        qh = q.reshape(B, T, Kv, Hq, dh)
        sc = jnp.einsum("bikhd,bjkd->bkhij", qh, k) * scale  # (B,Kv,Hq,T,T)
        sc = jnp.where(mask[:, None, None, :, :], sc, NEG_INF)
        a = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bkhij,bjkd->bikhd", a, v).reshape(B, T, H * dh)
        x = x + o @ lw["wo"]
        x = x + swiglu(rms_norm(x, lw["norm_mlp"]), lw["w_gate"], lw["w_up"], lw["w_down"])
        # cache layout (B,Kv,T,dh)
        return x, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    x, (k_cache, v_cache) = jax.lax.scan(body, x, layer_w)
    x = rms_norm(x, w["norm_final"])
    last = jnp.clip(prompt_len - 1, 0, T - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]  # (B, D)
    logits = x_last @ w["lm_head"]
    return logits, k_cache, v_cache


# --------------------------------------------------------------------------
# Standalone graphs (bulk encoder; used by the coordinator for prompts and
# by integration tests as the XLA-side twin of the Rust encoder)
# --------------------------------------------------------------------------


def polar_encode_graph(cfg: ModelConfig, k):
    """k: (N, T, dh) post-RoPE -> polar codes + params via the L1 kernel."""
    return polar_encode_pallas(k, cfg.r_bits, cfg.t_bits, cfg.group)
