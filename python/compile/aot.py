# AOT pipeline: lower the L2 graphs to HLO **text** + emit weights and the
# artifact manifest the Rust runtime consumes.
#
# HLO text (NOT lowered.compiler_ir("hlo") / .serialize()): jax >= 0.5 emits
# HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
# rejects (`proto.id() <= INT_MAX`).  Going through
# mlir_module_to_xla_computation + as_hlo_text reassigns ids and round-trips
# cleanly (see /opt/xla-example/README.md).
#
# Outputs under --out (default ../artifacts):
#   manifest.json                 — config, weight table, graph table
#   weights_<cfg>.bin             — raw little-endian f32, manifest order
#   <graph>.hlo.txt               — one per (kind, shape bucket)
#
# Every graph input is recorded in the manifest with name/shape/dtype in
# exact positional order — the Rust side marshals literals from that table,
# never from guesswork.

import argparse
import dataclasses
import functools
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def weight_input_specs(cfg):
    return [(name, spec(shape)) for name, shape in M.weight_specs(cfg)]


def decode_input_specs(cfg, B, S):
    """Positional (name, ShapeDtypeStruct) list for a decode-step graph."""
    L, Kv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    dh2 = dh // 2
    G = S // cfg.group
    R = cfg.resid
    i32 = jnp.int32
    base = [
        ("tokens", spec((B,), i32)),
        ("positions", spec((B,), i32)),
        ("cache_len", spec((B,), i32)),
        ("resid_len", spec((B,), i32)),
        ("theta_code", spec((L, B, Kv, S, dh2), i32)),
        ("rho_code", spec((L, B, Kv, S, dh2), i32)),
        ("rho_z", spec((L, B, Kv, G, dh2))),
        ("rho_s", spec((L, B, Kv, G, dh2))),
        ("theta_z", spec((L, B, Kv, G, dh2))),
        ("theta_s", spec((L, B, Kv, G, dh2))),
        ("v_cache", spec((L, B, Kv, S, dh))),
        ("resid_k", spec((L, B, Kv, R, dh))),
        ("resid_v", spec((L, B, Kv, R, dh))),
    ]
    return base + weight_input_specs(cfg)


def prefill_input_specs(cfg, B, T):
    return [
        ("tokens", spec((B, T), jnp.int32)),
        ("prompt_len", spec((B,), jnp.int32)),
    ] + weight_input_specs(cfg)


def encode_input_specs(cfg, N, T):
    return [("k", spec((N, T, cfg.head_dim)))]


def lower_graph(fn, input_specs):
    return jax.jit(fn).lower(*[s for _, s in input_specs])


def graph_entry(name, kind, bucket, input_specs, outputs, fname):
    return {
        "name": name,
        "file": fname,
        "kind": kind,
        "bucket": bucket,
        "inputs": [
            {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
            for n, s in input_specs
        ],
        "outputs": outputs,
    }


def parse_buckets(text):
    """'1x256,4x256' -> [(1, 256), (4, 256)]"""
    out = []
    for part in text.split(","):
        if not part:
            continue
        b, s = part.lower().split("x")
        out.append((int(b), int(s)))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", default="tiny", choices=sorted(M.CONFIGS))
    ap.add_argument("--buckets", default="1x256,4x256,1x1024",
                    help="decode buckets BxS (S = quantized cache capacity)")
    ap.add_argument("--prefill-buckets", default="1x64,4x64,1x256",
                    help="prefill buckets BxT")
    ap.add_argument("--encode-buckets", default="2x64",
                    help="bulk-encode buckets NxT")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--outlier-severity", type=float, default=6.0)
    args = ap.parse_args()

    cfg = M.CONFIGS[args.config]
    cfg.validate()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    # ---- weights ----------------------------------------------------------
    w = M.init_weights(cfg, seed=args.seed, outlier_severity=args.outlier_severity)
    tensors, offset = [], 0
    wfile = out / f"weights_{cfg.name}.bin"
    with open(wfile, "wb") as f:
        for name, shape in M.weight_specs(cfg):
            arr = np.ascontiguousarray(w[name], dtype=np.float32)
            assert tuple(arr.shape) == tuple(shape)
            f.write(arr.tobytes())
            nbytes = arr.nbytes
            tensors.append(
                {"name": name, "shape": list(shape), "offset_bytes": offset,
                 "size_bytes": nbytes}
            )
            offset += nbytes

    graphs = []

    def emit(name, fn, input_specs, kind, bucket, outputs):
        lowered = lower_graph(fn, input_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out / fname).write_text(text)
        graphs.append(graph_entry(name, kind, bucket, input_specs, outputs, fname))
        print(f"  {fname}: {len(text)} chars")

    # ---- decode graphs ----------------------------------------------------
    for B, S in parse_buckets(args.buckets):
        assert S % cfg.group == 0, "cache capacity must be whole groups"
        name = f"decode_{cfg.name}_b{B}_s{S}"
        specs_ = decode_input_specs(cfg, B, S)
        fn = functools.partial(M.decode_step, cfg)
        outs = [
            {"name": "logits", "shape": [B, cfg.vocab], "dtype": "float32"},
            {"name": "new_k",
             "shape": [cfg.n_layers, B, cfg.n_kv_heads, cfg.head_dim],
             "dtype": "float32"},
            {"name": "new_v",
             "shape": [cfg.n_layers, B, cfg.n_kv_heads, cfg.head_dim],
             "dtype": "float32"},
        ]
        emit(name, fn, specs_, "decode", {"batch": B, "seq": S}, outs)

    # ---- prefill graphs ---------------------------------------------------
    for B, T in parse_buckets(args.prefill_buckets):
        name = f"prefill_{cfg.name}_b{B}_t{T}"
        specs_ = prefill_input_specs(cfg, B, T)
        fn = functools.partial(M.prefill, cfg)
        outs = [
            {"name": "logits", "shape": [B, cfg.vocab], "dtype": "float32"},
            {"name": "k_cache",
             "shape": [cfg.n_layers, B, cfg.n_kv_heads, T, cfg.head_dim],
             "dtype": "float32"},
            {"name": "v_cache",
             "shape": [cfg.n_layers, B, cfg.n_kv_heads, T, cfg.head_dim],
             "dtype": "float32"},
        ]
        emit(name, fn, specs_, "prefill", {"batch": B, "seq": T}, outs)

    # ---- bulk polar encoder ----------------------------------------------
    for N, T in parse_buckets(args.encode_buckets):
        assert T % cfg.group == 0
        name = f"encode_{cfg.name}_n{N}_t{T}"
        specs_ = encode_input_specs(cfg, N, T)
        fn = functools.partial(M.polar_encode_graph, cfg)
        dh2 = cfg.head_dim // 2
        G = T // cfg.group
        outs = [
            {"name": "rho_code", "shape": [N, T, dh2], "dtype": "int32"},
            {"name": "theta_code", "shape": [N, T, dh2], "dtype": "int32"},
            {"name": "rho_z", "shape": [N, G, dh2], "dtype": "float32"},
            {"name": "rho_s", "shape": [N, G, dh2], "dtype": "float32"},
            {"name": "theta_z", "shape": [N, G, dh2], "dtype": "float32"},
            {"name": "theta_s", "shape": [N, G, dh2], "dtype": "float32"},
        ]
        emit(name, fn, specs_, "encode", {"batch": N, "seq": T}, outs)

    manifest = {
        "config": dataclasses.asdict(cfg),
        "weights": {"file": wfile.name, "tensors": tensors,
                    "total_bytes": offset, "seed": args.seed},
        "graphs": graphs,
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out / 'manifest.json'} ({len(graphs)} graphs, "
          f"{offset / 1e6:.1f} MB weights)")


if __name__ == "__main__":
    main()
