# L1 Pallas kernel: token-wise value-cache quantization (KIVI's value
# path, used by PolarQuant for the Table 7 "+ value quant" configuration).
#
# Values have no channel outliers, so per-token min/max quantization is
# sufficient (paper §5.2 / Appendix D).  Grid tiles the token axis; the
# reduction is over the channel axis of each VMEM tile.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _v_encode_kernel(v_ref, code_ref, z_ref, s_ref, *, bits):
    v = v_ref[...]  # (1, tile, d)
    z = jnp.min(v, axis=-1, keepdims=True)  # (1, tile, 1)
    s = (jnp.max(v, axis=-1, keepdims=True) - z) / float(2**bits)
    s = jnp.maximum(s, 1e-8)
    code_ref[...] = jnp.clip(jnp.floor((v - z) / s), 0, 2**bits - 1).astype(jnp.int32)
    z_ref[...] = z[..., 0]
    s_ref[...] = s[..., 0]


def value_encode_pallas(v: jnp.ndarray, bits: int, tile: int = 64):
    """Token-wise quantization. v: (N, T, d), T % tile == 0."""
    N, T, d = v.shape
    assert T % tile == 0
    kernel = functools.partial(_v_encode_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(N, T // tile),
        in_specs=[pl.BlockSpec((1, tile, d), lambda n, t: (n, t, 0))],
        out_specs=(
            pl.BlockSpec((1, tile, d), lambda n, t: (n, t, 0)),
            pl.BlockSpec((1, tile), lambda n, t: (n, t)),
            pl.BlockSpec((1, tile), lambda n, t: (n, t)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((N, T, d), jnp.int32),
            jax.ShapeDtypeStruct((N, T), jnp.float32),
            jax.ShapeDtypeStruct((N, T), jnp.float32),
        ),
        interpret=True,
    )(v)


def _v_decode_kernel(code_ref, z_ref, s_ref, v_ref):
    code = code_ref[...].astype(jnp.float32)
    v_ref[...] = (code + 0.5) * s_ref[...][..., None] + z_ref[...][..., None]


def value_decode_pallas(code, z, s, tile: int = 64):
    """Inverse of value_encode_pallas."""
    N, T, d = code.shape
    return pl.pallas_call(
        _v_decode_kernel,
        grid=(N, T // tile),
        in_specs=[
            pl.BlockSpec((1, tile, d), lambda n, t: (n, t, 0)),
            pl.BlockSpec((1, tile), lambda n, t: (n, t)),
            pl.BlockSpec((1, tile), lambda n, t: (n, t)),
        ],
        out_specs=pl.BlockSpec((1, tile, d), lambda n, t: (n, t, 0)),
        out_shape=jax.ShapeDtypeStruct((N, T, d), jnp.float32),
        interpret=True,
    )(code, z, s)
