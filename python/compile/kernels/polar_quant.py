# L1 Pallas kernel: PolarQuant encoder (post-RoPE keys -> polar codes).
#
# Grid layout (TPU adaptation, DESIGN.md §2): one grid step per
# (sequence-group, flattened batch*kv-head).  Each step stages one
# (group, d) tile of keys HBM->VMEM, computes the polar transform on the
# VPU, reduces min/max over the token axis of the tile (a VMEM-local
# reduction — the group IS the tile, so quantization params never leave
# VMEM), quantizes, and writes codes + params back.
#
# VMEM budget per step (f32): group*d (keys) + 3*group*d/2 (rho/theta/
# scratch) + 4*d/2 (params) ~= 2.5*group*d*4 bytes; for group=128, d=128
# that is ~160 KiB — far under the ~16 MiB VMEM ceiling, leaving room for
# double buffering.
#
# interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
# custom-calls; the BlockSpec structure is still the real-TPU schedule.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(k_ref, rc_ref, tc_ref, rz_ref, rs_ref, tz_ref, ts_ref, *, r_bits, t_bits):
    k = k_ref[...]  # (1, group, d)
    x = k[..., 0::2]
    y = k[..., 1::2]
    rho = jnp.sqrt(x * x + y * y)  # (1, group, d/2)
    theta = jnp.arctan2(y, x) + jnp.pi

    def qparams(v, bits):
        z = jnp.min(v, axis=1, keepdims=True)  # (1, 1, d/2)
        s = (jnp.max(v, axis=1, keepdims=True) - z) / float(2**bits)
        s = jnp.maximum(s, 1e-8)
        return z, s

    rz, rs = qparams(rho, r_bits)
    tz, ts = qparams(theta, t_bits)
    rc = jnp.clip(jnp.floor((rho - rz) / rs), 0, 2**r_bits - 1).astype(jnp.int32)
    tc = jnp.clip(jnp.floor((theta - tz) / ts), 0, 2**t_bits - 1).astype(jnp.int32)
    rc_ref[...] = rc
    tc_ref[...] = tc
    rz_ref[...] = rz
    rs_ref[...] = rs
    tz_ref[...] = tz
    ts_ref[...] = ts


def polar_encode_pallas(k: jnp.ndarray, r_bits: int, t_bits: int, group: int):
    """Encode post-RoPE keys into polar codes, group-wise over tokens.

    k: (N, T, d) with T % group == 0 (N = flattened batch * kv-heads).
    Returns (rho_code, theta_code) int32 (N, T, d/2) and four f32 param
    arrays (N, T/group, d/2): rho_z, rho_s, theta_z, theta_s.
    """
    N, T, d = k.shape
    assert T % group == 0 and d % 2 == 0
    G = T // group
    dh = d // 2
    kernel = functools.partial(_encode_kernel, r_bits=r_bits, t_bits=t_bits)
    out_shapes = (
        jax.ShapeDtypeStruct((N, T, dh), jnp.int32),
        jax.ShapeDtypeStruct((N, T, dh), jnp.int32),
        jax.ShapeDtypeStruct((N, G, dh), jnp.float32),
        jax.ShapeDtypeStruct((N, G, dh), jnp.float32),
        jax.ShapeDtypeStruct((N, G, dh), jnp.float32),
        jax.ShapeDtypeStruct((N, G, dh), jnp.float32),
    )
    code_spec = pl.BlockSpec((1, group, dh), lambda n, g: (n, g, 0))
    param_spec = pl.BlockSpec((1, 1, dh), lambda n, g: (n, g, 0))
    return pl.pallas_call(
        kernel,
        grid=(N, G),
        in_specs=[pl.BlockSpec((1, group, d), lambda n, g: (n, g, 0))],
        out_specs=(code_spec, code_spec, param_spec, param_spec, param_spec, param_spec),
        out_shape=out_shapes,
        interpret=True,
    )(k)


def _decode_kernel(rc_ref, tc_ref, rz_ref, rs_ref, tz_ref, ts_ref, k_ref):
    rho = (rc_ref[...].astype(jnp.float32) + 0.5) * rs_ref[...] + rz_ref[...]
    # -pi undoes the atan2(+pi) storage shift (see ref.polar_decode)
    theta = (tc_ref[...].astype(jnp.float32) + 0.5) * ts_ref[...] + tz_ref[...] - jnp.pi
    x = rho * jnp.cos(theta)  # (1, group, d/2)
    y = rho * jnp.sin(theta)
    k_ref[...] = jnp.stack([x, y], axis=-1).reshape(k_ref.shape)


def polar_decode_pallas(rho_code, theta_code, rho_z, rho_s, theta_z, theta_s, group: int):
    """Inverse of polar_encode_pallas: codes -> Cartesian keys (N, T, d)."""
    N, T, dh = rho_code.shape
    G = T // group
    code_spec = pl.BlockSpec((1, group, dh), lambda n, g: (n, g, 0))
    param_spec = pl.BlockSpec((1, 1, dh), lambda n, g: (n, g, 0))
    return pl.pallas_call(
        _decode_kernel,
        grid=(N, G),
        in_specs=[code_spec, code_spec, param_spec, param_spec, param_spec, param_spec],
        out_specs=pl.BlockSpec((1, group, 2 * dh), lambda n, g: (n, g, 0)),
        out_shape=jax.ShapeDtypeStruct((N, T, 2 * dh), jnp.float32),
        interpret=True,
    )(rho_code, theta_code, rho_z, rho_s, theta_z, theta_s)
