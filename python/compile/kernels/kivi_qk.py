# L1 Pallas baseline: KIVI-style channel-wise dequantize-then-multiply QK.
#
# This is the comparator the paper beats (Fig. 3 / Table 4).  Same grid as
# polar_qk.py — (batch*kv-head, seq-group) — but the inner loop must fully
# dequantize the (group, d) key tile (one mul + one add per element) before
# a dense (group, d) x (d, Hq) matmul.  On real TPU the dequant runs on the
# VPU and the matmul on the MXU; the dequant traffic is the cost PolarQuant
# removes.

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kivi_encode_kernel(k_ref, code_ref, z_ref, s_ref, *, bits):
    k = k_ref[...]  # (1, group, d)
    z = jnp.min(k, axis=1, keepdims=True)
    s = (jnp.max(k, axis=1, keepdims=True) - z) / float(2**bits)
    s = jnp.maximum(s, 1e-8)
    code_ref[...] = jnp.clip(jnp.floor((k - z) / s), 0, 2**bits - 1).astype(jnp.int32)
    z_ref[...] = z
    s_ref[...] = s


def kivi_encode_pallas(k: jnp.ndarray, bits: int, group: int):
    """Channel-wise group quantization of keys. k: (N, T, d)."""
    N, T, d = k.shape
    assert T % group == 0
    G = T // group
    import functools

    kernel = functools.partial(_kivi_encode_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(N, G),
        in_specs=[pl.BlockSpec((1, group, d), lambda n, g: (n, g, 0))],
        out_specs=(
            pl.BlockSpec((1, group, d), lambda n, g: (n, g, 0)),
            pl.BlockSpec((1, 1, d), lambda n, g: (n, g, 0)),
            pl.BlockSpec((1, 1, d), lambda n, g: (n, g, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((N, T, d), jnp.int32),
            jax.ShapeDtypeStruct((N, G, d), jnp.float32),
            jax.ShapeDtypeStruct((N, G, d), jnp.float32),
        ),
        interpret=True,
    )(k)


def _kivi_qk_kernel(q_ref, code_ref, z_ref, s_ref, out_ref):
    q = q_ref[...][0]  # (Hq, d)
    code = code_ref[...][0].astype(jnp.float32)  # (group, d)
    k_hat = (code + 0.5) * s_ref[...][0] + z_ref[...][0]  # dequant EVERY element
    out_ref[...] = (k_hat @ q.T).T[None]  # (1, Hq, group)


def kivi_qk_pallas(q, code, z, s, group: int):
    """Dequantize-then-multiply QK scores (the baseline PolarQuant beats).

    q: (N, Hq, d); code: (N, T, d) int32; z, s: (N, T/group, d).
    Returns (N, Hq, T) f32.
    """
    N, Hq, d = q.shape
    T = code.shape[1]
    G = T // group
    return pl.pallas_call(
        _kivi_qk_kernel,
        grid=(N, G),
        in_specs=[
            pl.BlockSpec((1, Hq, d), lambda n, g: (n, 0, 0)),
            pl.BlockSpec((1, group, d), lambda n, g: (n, g, 0)),
            pl.BlockSpec((1, 1, d), lambda n, g: (n, g, 0)),
            pl.BlockSpec((1, 1, d), lambda n, g: (n, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, group), lambda n, g: (n, 0, g)),
        out_shape=jax.ShapeDtypeStruct((N, Hq, T), jnp.float32),
        interpret=True,
    )(q, code, z, s)
