# L1 Pallas kernel: PolarQuant accelerated query-key inner product
# (Appendix A of the paper) — fused LUT build + gather + scale + reduce.
#
# The paper's Triton kernel tiles the sequence with threadblocks and keeps
# a per-channel LUT in shared memory.  TPU re-think (DESIGN.md §2):
#
#   grid = (N, G)  with N = batch*kv-heads, G = seq/group token groups.
#   Each grid step:
#     1. stages the group's theta/rho quant params (4 x d/2 f32) and the
#        query block (Hq x d, all query heads sharing this kv head) into
#        VMEM,
#     2. builds the LUT on the fly:
#          LUT[h, j, c] = qx[h,j]*cos(th~(c;j)) + qy[h,j]*sin(th~(c;j))
#        shape (Hq, d/2, 2^t) — for Hq=4, d=128, t=4 that is 16 KiB, i.e.
#        register/VMEM-resident.  The build is a (Hq*d/2, 2) x (2, 2^t)
#        contraction -> MXU-eligible on real hardware,
#     3. gathers LUT entries by the group's theta codes (VPU gather),
#        dequantizes rho inline, multiplies and reduces over channel
#        pairs -> a (Hq, group) tile of attention scores.
#
#   Per-step VMEM: codes 2*group*d/2 i32 + V-of-next-stage none here +
#   LUT + params ~= 80 KiB at group=128, d=128, Hq=4 — double-bufferable.
#
# The matmul the paper replaces would be (group x d) @ (d x Hq) per step;
# the LUT path does (d/2 x 2 x 2^t) mults once + group*d/2 gathers+mults,
# cutting multiply count roughly in half and removing the dequant
# (cos/sin/mul) entirely from the inner loop — the same arithmetic-
# intensity argument as the Triton kernel.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qk_kernel(q_ref, tc_ref, rc_ref, rz_ref, rs_ref, tz_ref, ts_ref, out_ref, *, t_bits):
    q = q_ref[...]  # (1, Hq, d)
    hq, d = q.shape[1], q.shape[2]
    qx = q[0, :, 0::2]  # (Hq, d/2)
    qy = q[0, :, 1::2]
    ts = ts_ref[...][0, 0]  # (d/2,)
    tz = tz_ref[...][0, 0]
    # LUT build: th~(j, c) = (c + 1/2) * ts[j] + tz[j]
    c = jnp.arange(2**t_bits, dtype=jnp.float32) + 0.5  # (C,)
    # -pi undoes the atan2(+pi) storage shift (see ref.polar_decode)
    th = c[None, :] * ts[:, None] + tz[:, None] - jnp.pi  # (d/2, C)
    cos_t, sin_t = jnp.cos(th), jnp.sin(th)
    lut = qx[:, :, None] * cos_t[None] + qy[:, :, None] * sin_t[None]  # (Hq, d/2, C)

    tc = tc_ref[...][0]  # (group, d/2) int32
    rc = rc_ref[...][0]
    rho = (rc.astype(jnp.float32) + 0.5) * rs_ref[...][0, 0][None, :] + rz_ref[...][0, 0][None, :]

    # gather: part[h, n, j] = lut[h, j, tc[n, j]]
    part = jnp.take_along_axis(
        jnp.broadcast_to(lut[:, None], (hq, tc.shape[0], lut.shape[1], lut.shape[2])),
        tc[None, :, :, None],
        axis=-1,
    )[..., 0]  # (Hq, group, d/2)
    out_ref[...] = (part * rho[None]).sum(-1)[None]  # (1, Hq, group)


def polar_qk_pallas(q, theta_code, rho_code, rho_z, rho_s, theta_z, theta_s, group: int, t_bits: int):
    """Fused dequant + QK scores against a polar-encoded key cache.

    q:          (N, Hq, d)    — decode-step queries, Hq = q-heads per kv-head
    theta_code: (N, T, d/2)   int32
    rho_code:   (N, T, d/2)   int32
    *_z, *_s:   (N, T/group, d/2) f32
    Returns scores (N, Hq, T) f32 (unscaled; caller applies 1/sqrt(d)).
    """
    N, Hq, d = q.shape
    T = theta_code.shape[1]
    dh = d // 2
    G = T // group
    kernel = functools.partial(_qk_kernel, t_bits=t_bits)
    code_spec = pl.BlockSpec((1, group, dh), lambda n, g: (n, g, 0))
    param_spec = pl.BlockSpec((1, 1, dh), lambda n, g: (n, g, 0))
    return pl.pallas_call(
        kernel,
        grid=(N, G),
        in_specs=[
            pl.BlockSpec((1, Hq, d), lambda n, g: (n, 0, 0)),
            code_spec,
            code_spec,
            param_spec,
            param_spec,
            param_spec,
            param_spec,
        ],
        out_specs=pl.BlockSpec((1, Hq, group), lambda n, g: (n, 0, g)),
        out_shape=jax.ShapeDtypeStruct((N, Hq, T), jnp.float32),
        interpret=True,
    )(q, theta_code, rho_code, rho_z, rho_s, theta_z, theta_s)
