# Pure-jnp correctness oracles for every kernel in this package.
#
# These functions define the *numerics contract* of the whole repo:
#   * the Pallas kernels (polar_quant.py, polar_qk.py, kivi_qk.py,
#     value_quant.py) must match them exactly (same op order, fp32),
#   * the Rust quantization library (rust/src/quant/) re-implements the
#     same formulas and is cross-checked against goldens generated from
#     here (python/tests/test_goldens.py writes them, rust tests read).
#
# Conventions (see DESIGN.md §5):
#   * keys are post-RoPE; a "pair" j couples dims (2j, 2j+1),
#   * group-wise quantization groups **tokens** (size g) per channel(-pair),
#   * asymmetric quant: code = clamp(floor((x - z)/s), 0, 2^b - 1),
#     dequant x~ = (code + 1/2) * s + z, with z = min, s = (max-min)/2^b.
#     (The paper's printed zero-point formula is a typo — its Figure-4
#     reference code uses the minimum, which is what we implement.)

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, base: float = 10000.0) -> jnp.ndarray:
    """Per-pair angular frequencies phi_i = base^(-2i/d), i < d/2."""
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)
    return base ** (-2.0 * i / head_dim)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, base: float = 10000.0):
    """Rotate pairs (2j, 2j+1) of the trailing dim by pos * phi_j.

    x: (..., T, d), positions: (T,) int32.  Uses the *adjacent-pair*
    (matrix-multiplication) formulation of Eq. 1, which is the one the
    polar transformation is defined over.
    """
    d = x.shape[-1]
    phi = rope_freqs(d, base)  # (d/2,)
    ang = positions.astype(jnp.float32)[:, None] * phi[None, :]  # (T, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xe = x[..., 0::2]
    xo = x[..., 1::2]
    ye = xe * cos - xo * sin
    yo = xe * sin + xo * cos
    return jnp.stack([ye, yo], axis=-1).reshape(x.shape)


# --------------------------------------------------------------------------
# Asymmetric min/max quantization helpers
# --------------------------------------------------------------------------


def _qparams(x: jnp.ndarray, bits: int, axis):
    """Zero-point (min) and scale over `axis`; s floored to avoid div-by-0."""
    z = jnp.min(x, axis=axis, keepdims=True)
    mx = jnp.max(x, axis=axis, keepdims=True)
    s = (mx - z) / float(2**bits)
    s = jnp.maximum(s, 1e-8)
    return z, s


def _quantize(x, z, s, bits: int):
    code = jnp.floor((x - z) / s)
    return jnp.clip(code, 0, 2**bits - 1).astype(jnp.int32)


def _dequantize(code, z, s):
    return (code.astype(jnp.float32) + 0.5) * s + z


# --------------------------------------------------------------------------
# PolarQuant (the paper's contribution)
# --------------------------------------------------------------------------


def polar_transform(k: jnp.ndarray):
    """(..., T, d) -> rho, theta with shapes (..., T, d/2).

    theta = atan2(y, x) + pi in (0, 2*pi).
    """
    x = k[..., 0::2]
    y = k[..., 1::2]
    rho = jnp.sqrt(x * x + y * y)
    theta = jnp.arctan2(y, x) + jnp.pi
    return rho, theta


def polar_encode(k: jnp.ndarray, r_bits: int, t_bits: int, group: int):
    """Quantize post-RoPE keys in polar coordinates, group-wise over tokens.

    k: (T, d) with T % group == 0 (the serving engine keeps a residual fp
    buffer for the tail; only full groups are ever encoded).

    Returns dict of:
      rho_code, theta_code: (T, d/2) int32
      rho_z, rho_s, theta_z, theta_s: (T/group, d/2) f32
    """
    T, d = k.shape
    assert T % group == 0, "only full groups are encoded"
    rho, theta = polar_transform(k)  # (T, d/2)
    G = T // group
    rho_g = rho.reshape(G, group, d // 2)
    th_g = theta.reshape(G, group, d // 2)
    rz, rs = _qparams(rho_g, r_bits, axis=1)  # (G, 1, d/2)
    tz, ts = _qparams(th_g, t_bits, axis=1)
    rc = _quantize(rho_g, rz, rs, r_bits).reshape(T, d // 2)
    tc = _quantize(th_g, tz, ts, t_bits).reshape(T, d // 2)
    return {
        "rho_code": rc,
        "theta_code": tc,
        "rho_z": rz[:, 0, :],
        "rho_s": rs[:, 0, :],
        "theta_z": tz[:, 0, :],
        "theta_s": ts[:, 0, :],
    }


def polar_decode(enc: dict, group: int):
    """Dequantize back to Cartesian keys (T, d)."""
    rc, tc = enc["rho_code"], enc["theta_code"]
    T, dh = rc.shape
    rz = jnp.repeat(enc["rho_z"], group, axis=0)  # (T, d/2)
    rs = jnp.repeat(enc["rho_s"], group, axis=0)
    tz = jnp.repeat(enc["theta_z"], group, axis=0)
    ts = jnp.repeat(enc["theta_s"], group, axis=0)
    rho = _dequantize(rc, rz, rs)
    # theta was stored shifted by +pi (range (0, 2pi)); undo the shift when
    # mapping back to Cartesian.  (The paper's decode formula omits the -pi,
    # which would negate every reconstructed key — an inconsistency in the
    # text; its Figure-4 reference code bakes the shift into `tmn`.)
    theta = _dequantize(tc, tz, ts) - jnp.pi
    x = rho * jnp.cos(theta)
    y = rho * jnp.sin(theta)
    return jnp.stack([x, y], axis=-1).reshape(T, 2 * dh)


def polar_qk_scores(q: jnp.ndarray, enc: dict, group: int):
    """Reference fused dequant+QK: q (d,) x encoded keys -> scores (T,).

    Mathematically identical to q @ polar_decode(enc).T; written via
    dequantization so the LUT kernel can be compared against it.
    """
    k_hat = polar_decode(enc, group)  # (T, d)
    return k_hat @ q


def polar_qk_scores_lut(q: jnp.ndarray, enc: dict, group: int, t_bits: int):
    """Explicit-LUT evaluation (what the accelerated kernel computes).

    Builds, per token-group and channel-pair, the 2^t-entry table
    LUT[g, j, c] = q[2j] cos(th~(c)) + q[2j+1] sin(th~(c)) and gathers.
    """
    rc, tc = enc["rho_code"], enc["theta_code"]
    T, dh = rc.shape
    G = T // group
    qx, qy = q[0::2], q[1::2]  # (d/2,)
    c = jnp.arange(2**t_bits, dtype=jnp.float32) + 0.5  # (C,)
    # th~(g, j, c) = c * ts[g, j] + tz[g, j] - pi (undo the storage shift)
    th = (
        c[None, None, :] * enc["theta_s"][:, :, None]
        + enc["theta_z"][:, :, None]
        - jnp.pi
    )
    lut = qx[None, :, None] * jnp.cos(th) + qy[None, :, None] * jnp.sin(th)  # (G, d/2, C)
    tcg = tc.reshape(G, group, dh)
    part = jnp.take_along_axis(
        jnp.broadcast_to(lut[:, None, :, :], (G, group, dh, lut.shape[-1])),
        tcg[..., None],
        axis=-1,
    )[..., 0]  # (G, group, d/2)
    rho = _dequantize(
        rc.reshape(G, group, dh),
        enc["rho_z"][:, None, :],
        enc["rho_s"][:, None, :],
    )
    return (part * rho).sum(-1).reshape(T)


# --------------------------------------------------------------------------
# KIVI baseline: channel-wise (per-channel over token groups) key quant
# --------------------------------------------------------------------------


def kivi_encode(k: jnp.ndarray, bits: int, group: int):
    """Channel-wise asymmetric quant: params per (token-group, channel)."""
    T, d = k.shape
    assert T % group == 0
    G = T // group
    kg = k.reshape(G, group, d)
    z, s = _qparams(kg, bits, axis=1)
    code = _quantize(kg, z, s, bits).reshape(T, d)
    return {"code": code, "z": z[:, 0, :], "s": s[:, 0, :]}


def kivi_decode(enc: dict, group: int):
    z = jnp.repeat(enc["z"], group, axis=0)
    s = jnp.repeat(enc["s"], group, axis=0)
    return _dequantize(enc["code"], z, s)


def kivi_qk_scores(q: jnp.ndarray, enc: dict, group: int):
    return kivi_decode(enc, group) @ q


# --------------------------------------------------------------------------
# Token-wise baselines (Int-N, ZipCache) and value quantization
# --------------------------------------------------------------------------


def int_encode(x: jnp.ndarray, bits: int):
    """Token-wise quant: params per token over channels. x: (T, d)."""
    z, s = _qparams(x, bits, axis=-1)
    code = _quantize(x, z, s, bits)
    return {"code": code, "z": z[..., 0], "s": s[..., 0]}


def int_decode(enc: dict):
    return _dequantize(enc["code"], enc["z"][..., None], enc["s"][..., None])


def zipcache_encode(k: jnp.ndarray, bits: int):
    """Channel-separable token-wise: normalize channels by sqrt(max |.|)."""
    norm = jnp.sqrt(jnp.maximum(jnp.max(jnp.abs(k), axis=0), 1e-8))  # (d,)
    kn = k / norm[None, :]
    enc = int_encode(kn, bits)
    enc["channel_norm"] = norm
    return enc


def zipcache_decode(enc: dict):
    return int_decode(enc) * enc["channel_norm"][None, :]


def value_encode(v: jnp.ndarray, bits: int):
    """Token-wise value quant (KIVI's value path)."""
    return int_encode(v, bits)


value_decode = int_decode


# --------------------------------------------------------------------------
# Attention (decode step) over a quantized key cache — the L2 contract
# --------------------------------------------------------------------------


def attn_decode_ref(q, enc, v, group, *, residual_k=None, residual_v=None, scale=None):
    """Single-head decode attention: q (d,), quantized keys (T tokens),
    fp values v (T, d), optional fp residual tail. Returns (d,) output."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
    scores = polar_qk_scores(q, enc, group) * scale  # (T,)
    if residual_k is not None:
        scores_r = (residual_k @ q) * scale
        scores = jnp.concatenate([scores, scores_r])
        v = jnp.concatenate([v, residual_v], axis=0)
    w = jax.nn.softmax(scores)
    return w @ v
