# Golden-file generator: canonical inputs/outputs of every ref.py quantizer
# serialized as raw little-endian tensors + a JSON index.  The Rust quant
# library (rust/src/quant/) re-implements the same numerics and its unit
# tests replay these files bit-for-bit (f32 tolerance) — the cross-language
# contract that keeps L1/L2/L3 in agreement.
#
# Usage: python -m compile.goldens --out ../artifacts/goldens

import argparse
import json
import pathlib

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref


def outlier_keys(rng, t, d, severity=8.0):
    k = rng.standard_normal((t, d)).astype(np.float32)
    for j in rng.choice(d // 2, size=max(1, d // 16), replace=False):
        k[:, 2 * j] += severity
    pos = np.arange(t, dtype=np.int32)
    return np.asarray(ref.apply_rope(jnp.asarray(k), jnp.asarray(pos)))


class Writer:
    def __init__(self, out: pathlib.Path):
        self.out = out
        self.out.mkdir(parents=True, exist_ok=True)
        self.cases = []

    def add(self, case: str, params: dict, tensors: dict):
        entry = {"name": case, "params": params, "tensors": []}
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            if arr.dtype in (np.int64, np.int32):
                arr = arr.astype(np.int32)
                dtype = "i32"
            else:
                arr = arr.astype(np.float32)
                dtype = "f32"
            fname = f"{case}__{name}.bin"
            (self.out / fname).write_bytes(np.ascontiguousarray(arr).tobytes())
            entry["tensors"].append(
                {"name": name, "file": fname, "shape": list(arr.shape), "dtype": dtype}
            )
        self.cases.append(entry)

    def finish(self):
        (self.out / "index.json").write_text(json.dumps({"cases": self.cases}, indent=1))
        print(f"wrote {len(self.cases)} golden cases to {self.out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/goldens")
    args = ap.parse_args()
    w = Writer(pathlib.Path(args.out))
    rng = np.random.default_rng(1234)

    # ---- rope -------------------------------------------------------------
    x = rng.standard_normal((12, 16)).astype(np.float32)
    pos = np.arange(5, 17, dtype=np.int32)
    got = ref.apply_rope(jnp.asarray(x), jnp.asarray(pos), base=10000.0)
    w.add("rope", {"base": 10000.0}, {"x": x, "positions": pos, "out": got})

    # ---- polar encode/decode/qk across bit mixes ---------------------------
    for r_bits, t_bits, group in [(4, 4, 16), (3, 3, 16), (5, 3, 8), (2, 4, 32)]:
        t, d = 2 * group, 32
        k = outlier_keys(rng, t, d)
        enc = ref.polar_encode(jnp.asarray(k), r_bits, t_bits, group)
        k_hat = ref.polar_decode(enc, group)
        q = rng.standard_normal(d).astype(np.float32)
        scores = ref.polar_qk_scores(jnp.asarray(q), enc, group)
        lut_scores = ref.polar_qk_scores_lut(jnp.asarray(q), enc, group, t_bits)
        w.add(
            f"polar_r{r_bits}t{t_bits}g{group}",
            {"r_bits": r_bits, "t_bits": t_bits, "group": group},
            {
                "k": k, "q": q,
                "rho_code": enc["rho_code"], "theta_code": enc["theta_code"],
                "rho_z": enc["rho_z"], "rho_s": enc["rho_s"],
                "theta_z": enc["theta_z"], "theta_s": enc["theta_s"],
                "k_hat": k_hat, "scores": scores, "lut_scores": lut_scores,
            },
        )

    # ---- kivi ---------------------------------------------------------------
    for bits, group in [(4, 16), (2, 16)]:
        t, d = 3 * group, 32
        k = outlier_keys(rng, t, d)
        enc = ref.kivi_encode(jnp.asarray(k), bits, group)
        q = rng.standard_normal(d).astype(np.float32)
        w.add(
            f"kivi_b{bits}g{group}",
            {"bits": bits, "group": group},
            {
                "k": k, "q": q, "code": enc["code"], "z": enc["z"], "s": enc["s"],
                "k_hat": ref.kivi_decode(enc, group),
                "scores": ref.kivi_qk_scores(jnp.asarray(q), enc, group),
            },
        )

    # ---- token-wise int / zipcache / value ---------------------------------
    t, d = 24, 32
    k = outlier_keys(rng, t, d)
    for bits in (3, 4):
        enc = ref.int_encode(jnp.asarray(k), bits)
        w.add(
            f"int_b{bits}", {"bits": bits},
            {"k": k, "code": enc["code"], "z": enc["z"], "s": enc["s"],
             "k_hat": ref.int_decode(enc)},
        )
    enc = ref.zipcache_encode(jnp.asarray(k), 4)
    w.add(
        "zipcache_b4", {"bits": 4},
        {"k": k, "code": enc["code"], "z": enc["z"], "s": enc["s"],
         "channel_norm": enc["channel_norm"], "k_hat": ref.zipcache_decode(enc)},
    )
    v = rng.standard_normal((t, d)).astype(np.float32)
    enc = ref.value_encode(jnp.asarray(v), 2)
    w.add(
        "value_b2", {"bits": 2},
        {"v": v, "code": enc["code"], "z": enc["z"], "s": enc["s"],
         "v_hat": ref.value_decode(enc)},
    )

    # ---- full decode-attention head (quantized + residual + self) ----------
    group, r_bits, t_bits = 16, 4, 4
    t, d = 2 * group, 32
    k = outlier_keys(rng, t, d)
    vv = rng.standard_normal((t, d)).astype(np.float32)
    q = rng.standard_normal(d).astype(np.float32)
    rk = rng.standard_normal((5, d)).astype(np.float32)
    rv = rng.standard_normal((5, d)).astype(np.float32)
    enc = ref.polar_encode(jnp.asarray(k), r_bits, t_bits, group)
    out = ref.attn_decode_ref(
        jnp.asarray(q), enc, jnp.asarray(vv), group,
        residual_k=jnp.asarray(rk), residual_v=jnp.asarray(rv),
    )
    w.add(
        "attn_decode", {"r_bits": r_bits, "t_bits": t_bits, "group": group},
        {"q": q, "k": k, "v": vv, "resid_k": rk, "resid_v": rv, "out": out},
    )

    w.finish()


if __name__ == "__main__":
    main()
