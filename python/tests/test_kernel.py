# pytest: Pallas kernels vs pure-jnp oracle — the CORE correctness signal.
#
# hypothesis sweeps shapes / bit-widths / group sizes; every kernel must
# match ref.py to fp32 tolerance (identical op ordering -> tight atol).

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.polar_quant import polar_decode_pallas, polar_encode_pallas
from compile.kernels.polar_qk import polar_qk_pallas
from compile.kernels.kivi_qk import kivi_encode_pallas, kivi_qk_pallas
from compile.kernels.value_quant import value_decode_pallas, value_encode_pallas

jax.config.update("jax_enable_x64", False)


def outlier_keys(rng, n, t, d, severity=8.0):
    """Keys with channel-wise outliers on ONE dim of some RoPE pairs —
    the Figure-1(a) structure that motivates the paper."""
    k = rng.standard_normal((n, t, d)).astype(np.float32)
    n_out = max(1, d // 16)
    chans = rng.choice(d // 2, size=n_out, replace=False)
    for j in chans:
        k[:, :, 2 * j] += severity * np.sign(rng.standard_normal())
    # rotate pairs (post-RoPE): magnitudes preserved, outlier smeared
    pos = np.arange(t, dtype=np.int32)
    return np.asarray(ref.apply_rope(jnp.asarray(k), jnp.asarray(pos)))


# ---------------------------------------------------------------- polar


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 3),
    groups=st.integers(1, 3),
    group=st.sampled_from([16, 32, 64]),
    dh=st.sampled_from([8, 16, 32]),
    r_bits=st.sampled_from([2, 3, 4, 5]),
    t_bits=st.sampled_from([2, 3, 4, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_polar_encode_matches_ref(n, groups, group, dh, r_bits, t_bits, seed):
    rng = np.random.default_rng(seed)
    t, d = groups * group, 2 * dh
    k = outlier_keys(rng, n, t, d)
    got = polar_encode_pallas(jnp.asarray(k), r_bits, t_bits, group)
    names = ["rho_code", "theta_code", "rho_z", "rho_s", "theta_z", "theta_s"]
    for i in range(n):
        want = ref.polar_encode(jnp.asarray(k[i]), r_bits, t_bits, group)
        for name, g in zip(names, got):
            np.testing.assert_allclose(
                np.asarray(g[i]), np.asarray(want[name]), atol=1e-5, rtol=1e-5,
                err_msg=f"{name} mismatch (slice {i})",
            )


@settings(max_examples=8, deadline=None)
@given(
    group=st.sampled_from([16, 32]),
    r_bits=st.sampled_from([3, 4]),
    t_bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_polar_roundtrip_error_bounded(group, r_bits, t_bits, seed):
    """Dequantized keys land inside their quantization cell."""
    rng = np.random.default_rng(seed)
    k = outlier_keys(rng, 1, 2 * group, 32)[0]
    enc = ref.polar_encode(jnp.asarray(k), r_bits, t_bits, group)
    k_hat = np.asarray(ref.polar_decode(enc, group))
    rho, _ = ref.polar_transform(jnp.asarray(k))
    rho = np.asarray(rho)
    # error per pair bounded by half a rho cell plus the arc swept by half
    # a theta cell at the (dequantized) radius
    rs = np.repeat(np.asarray(enc["rho_s"]), group, axis=0)
    ts = np.repeat(np.asarray(enc["theta_s"]), group, axis=0)
    err = np.hypot(
        k[:, 0::2] - k_hat[:, 0::2], k[:, 1::2] - k_hat[:, 1::2]
    )
    bound = rs / 2 + (rho + rs / 2) * ts / 2 + 1e-4
    assert (err <= bound).all(), f"max excess {(err - bound).max()}"


def test_polar_decode_pallas_matches_ref():
    rng = np.random.default_rng(0)
    group, n, t, d = 32, 2, 64, 64
    k = outlier_keys(rng, n, t, d)
    rc, tc, rz, rs, tz, ts = polar_encode_pallas(jnp.asarray(k), 4, 4, group)
    got = polar_decode_pallas(rc, tc, rz, rs, tz, ts, group)
    for i in range(n):
        enc = ref.polar_encode(jnp.asarray(k[i]), 4, 4, group)
        want = ref.polar_decode(enc, group)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 2),
    hq=st.sampled_from([1, 2, 4]),
    groups=st.integers(1, 4),
    group=st.sampled_from([16, 32]),
    dh=st.sampled_from([16, 32]),
    r_bits=st.sampled_from([3, 4]),
    t_bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_polar_qk_lut_matches_dequant_matmul(n, hq, groups, group, dh, r_bits, t_bits, seed):
    """The LUT kernel must equal dequantize-then-matmul exactly (fp32)."""
    rng = np.random.default_rng(seed)
    t, d = groups * group, 2 * dh
    k = outlier_keys(rng, n, t, d)
    q = rng.standard_normal((n, hq, d)).astype(np.float32)
    rc, tc, rz, rs, tz, ts = polar_encode_pallas(jnp.asarray(k), r_bits, t_bits, group)
    got = polar_qk_pallas(jnp.asarray(q), tc, rc, rz, rs, tz, ts, group, t_bits)
    assert got.shape == (n, hq, t)
    for i in range(n):
        enc = ref.polar_encode(jnp.asarray(k[i]), r_bits, t_bits, group)
        for h in range(hq):
            want = ref.polar_qk_scores(jnp.asarray(q[i, h]), enc, group)
            np.testing.assert_allclose(
                np.asarray(got[i, h]), np.asarray(want), atol=2e-4, rtol=1e-4
            )


def test_polar_qk_ref_lut_equals_ref_dequant():
    """Sanity: the two reference formulations agree."""
    rng = np.random.default_rng(7)
    group, t_bits = 32, 4
    k = outlier_keys(rng, 1, 96, 64)[0]
    q = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    enc = ref.polar_encode(jnp.asarray(k), 4, t_bits, group)
    a = ref.polar_qk_scores(q, enc, group)
    b = ref.polar_qk_scores_lut(q, enc, group, t_bits)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=1e-4)


# ---------------------------------------------------------------- kivi


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 2),
    hq=st.sampled_from([1, 4]),
    groups=st.integers(1, 3),
    group=st.sampled_from([16, 32]),
    d=st.sampled_from([32, 64]),
    bits=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kivi_kernels_match_ref(n, hq, groups, group, d, bits, seed):
    rng = np.random.default_rng(seed)
    t = groups * group
    k = outlier_keys(rng, n, t, d)
    q = rng.standard_normal((n, hq, d)).astype(np.float32)
    code, z, s = kivi_encode_pallas(jnp.asarray(k), bits, group)
    got = kivi_qk_pallas(jnp.asarray(q), code, z, s, group)
    for i in range(n):
        enc = ref.kivi_encode(jnp.asarray(k[i]), bits, group)
        np.testing.assert_allclose(np.asarray(code[i]), np.asarray(enc["code"]), atol=0)
        for h in range(hq):
            want = ref.kivi_qk_scores(jnp.asarray(q[i, h]), enc, group)
            np.testing.assert_allclose(
                np.asarray(got[i, h]), np.asarray(want), atol=2e-4, rtol=1e-4
            )


# ---------------------------------------------------------------- values


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 2),
    tiles=st.integers(1, 3),
    d=st.sampled_from([32, 64]),
    bits=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_value_quant_matches_ref(n, tiles, d, bits, seed):
    rng = np.random.default_rng(seed)
    tile = 64
    t = tiles * tile
    v = rng.standard_normal((n, t, d)).astype(np.float32)
    code, z, s = value_encode_pallas(jnp.asarray(v), bits, tile)
    dec = value_decode_pallas(code, z, s, tile)
    for i in range(n):
        enc = ref.value_encode(jnp.asarray(v[i]), bits)
        np.testing.assert_allclose(np.asarray(code[i]), np.asarray(enc["code"]), atol=0)
        want = ref.value_decode(enc)
        np.testing.assert_allclose(np.asarray(dec[i]), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------- claims


def test_polar_beats_tokenwise_under_outliers():
    """The paper's Figure 2 claim: under channel outliers, PolarQuant's
    key reconstruction error is far below token-wise Int quantization at
    equal bit budget."""
    rng = np.random.default_rng(42)
    group = 32
    k = outlier_keys(rng, 1, 256, 64, severity=20.0)[0]
    kj = jnp.asarray(k)
    polar = np.asarray(ref.polar_decode(ref.polar_encode(kj, 4, 4, group), group))
    tok = np.asarray(ref.int_decode(ref.int_encode(kj, 4)))
    err_polar = float(np.mean((polar - k) ** 2))
    err_tok = float(np.mean((tok - k) ** 2))
    assert err_polar < 0.5 * err_tok, (err_polar, err_tok)
