# L2 model tests: the decode-step graph is validated against prefill
# (exact fp consistency through the residual path) and against a hand-built
# jnp reference for the quantized path.

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig(
    name="test", vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=16, ffn=48, group=8, r_bits=4, t_bits=4, resid=16,
)


@pytest.fixture(scope="module")
def weights():
    w = M.init_weights(CFG, seed=3)
    return M.flatten_weights(CFG, w)


def empty_cache(B, S):
    L, Kv, dh = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    dh2, G, R = dh // 2, S // CFG.group, CFG.resid
    z = jnp.zeros
    return {
        "theta_code": z((L, B, Kv, S, dh2), jnp.int32),
        "rho_code": z((L, B, Kv, S, dh2), jnp.int32),
        "rho_z": z((L, B, Kv, G, dh2)), "rho_s": jnp.full((L, B, Kv, G, dh2), 1e-8),
        "theta_z": z((L, B, Kv, G, dh2)), "theta_s": jnp.full((L, B, Kv, G, dh2), 1e-8),
        "v_cache": z((L, B, Kv, S, dh)),
        "resid_k": z((L, B, Kv, R, dh)), "resid_v": z((L, B, Kv, R, dh)),
    }


def run_decode(weights, tokens, positions, cache_len, resid_len, cache):
    return M.decode_step(
        CFG, tokens, positions, cache_len, resid_len,
        cache["theta_code"], cache["rho_code"],
        cache["rho_z"], cache["rho_s"], cache["theta_z"], cache["theta_s"],
        cache["v_cache"], cache["resid_k"], cache["resid_v"], *weights,
    )


def test_decode_matches_prefill_via_residual(weights):
    """Feed prefill's fp K/V through the decode residual path: decoding
    token T must equal prefill's logits over T+1 tokens (both exact fp)."""
    B, T = 2, 7
    rng = np.random.default_rng(0)
    toks = rng.integers(0, CFG.vocab, size=(B, T + 1)).astype(np.int32)
    plen_full = jnp.full((B,), T + 1, jnp.int32)
    logits_want, _, _ = M.prefill(CFG, jnp.asarray(toks), plen_full, *weights)

    plen = jnp.full((B,), T, jnp.int32)
    _, k_cache, v_cache = M.prefill(CFG, jnp.asarray(toks[:, :T]), plen, *weights)

    S = 2 * CFG.group
    cache = empty_cache(B, S)
    # all T tokens go to the residual buffer (fp) — nothing quantized
    cache["resid_k"] = cache["resid_k"].at[:, :, :, :T].set(k_cache)
    cache["resid_v"] = cache["resid_v"].at[:, :, :, :T].set(v_cache)
    logits_got, new_k, new_v = run_decode(
        weights,
        jnp.asarray(toks[:, T]),
        jnp.full((B,), T, jnp.int32),
        jnp.zeros((B,), jnp.int32),
        jnp.full((B,), T, jnp.int32),
        cache,
    )
    np.testing.assert_allclose(
        np.asarray(logits_got), np.asarray(logits_want), atol=2e-4, rtol=1e-4
    )
    assert new_k.shape == (CFG.n_layers, B, CFG.n_kv_heads, CFG.head_dim)
    assert new_v.shape == new_k.shape


def test_decode_quantized_region_matches_jnp_reference(weights):
    """Quantize the first 2 groups of prefill keys with ref.polar_encode and
    check decode_step equals a jnp attention over the dequantized keys."""
    B = 1
    g = CFG.group
    T = 2 * g + 3  # two full groups + residual tail of 3
    rng = np.random.default_rng(1)
    toks = rng.integers(0, CFG.vocab, size=(B, T + 1)).astype(np.int32)
    plen = jnp.full((B,), T, jnp.int32)
    _, k_cache, v_cache = M.prefill(CFG, jnp.asarray(toks[:, :T]), plen, *weights)

    S = 2 * g
    L, Kv, dh = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    cache = empty_cache(B, S)
    k_hat = np.zeros((L, B, Kv, S, dh), np.float32)
    for l in range(L):
        for b in range(B):
            for h in range(Kv):
                enc = ref.polar_encode(k_cache[l, b, h, :S], CFG.r_bits, CFG.t_bits, g)
                cache["theta_code"] = cache["theta_code"].at[l, b, h].set(enc["theta_code"])
                cache["rho_code"] = cache["rho_code"].at[l, b, h].set(enc["rho_code"])
                cache["rho_z"] = cache["rho_z"].at[l, b, h].set(enc["rho_z"])
                cache["rho_s"] = cache["rho_s"].at[l, b, h].set(enc["rho_s"])
                cache["theta_z"] = cache["theta_z"].at[l, b, h].set(enc["theta_z"])
                cache["theta_s"] = cache["theta_s"].at[l, b, h].set(enc["theta_s"])
                k_hat[l, b, h] = np.asarray(ref.polar_decode(enc, g))
    cache["v_cache"] = v_cache[:, :, :, :S]
    cache["resid_k"] = cache["resid_k"].at[:, :, :, : T - S].set(k_cache[:, :, :, S:])
    cache["resid_v"] = cache["resid_v"].at[:, :, :, : T - S].set(v_cache[:, :, :, S:])

    logits_got, _, _ = run_decode(
        weights,
        jnp.asarray(toks[:, T]),
        jnp.full((B,), T, jnp.int32),
        jnp.full((B,), S, jnp.int32),
        jnp.full((B,), T - S, jnp.int32),
        cache,
    )

    # reference: identical decode but with dequantized keys as fp residuals
    cache_fp = empty_cache(B, 2 * g + CFG.resid - (T - S) + g)  # unused quant region
    # Instead reconstruct attention directly: concatenate k_hat + resid as a
    # fully-fp prefill-style pass is not possible (k_hat != true k), so
    # verify at the logits level against a dequantized-key decode built from
    # the residual path of a *wider* cache.
    S2 = 0  # all fp
    R2 = T
    cfg2 = CFG
    wide = {
        "theta_code": jnp.zeros((L, B, Kv, g, dh // 2), jnp.int32),
        "rho_code": jnp.zeros((L, B, Kv, g, dh // 2), jnp.int32),
        "rho_z": jnp.zeros((L, B, Kv, 1, dh // 2)),
        "rho_s": jnp.full((L, B, Kv, 1, dh // 2), 1e-8),
        "theta_z": jnp.zeros((L, B, Kv, 1, dh // 2)),
        "theta_s": jnp.full((L, B, Kv, 1, dh // 2), 1e-8),
        "v_cache": jnp.zeros((L, B, Kv, g, dh)),
        "resid_k": jnp.concatenate(
            [jnp.asarray(k_hat), k_cache[:, :, :, S:],
             jnp.zeros((L, B, Kv, CFG.resid, dh))], axis=3
        )[:, :, :, : max(R2, CFG.resid)],
        "resid_v": jnp.concatenate(
            [v_cache, jnp.zeros((L, B, Kv, CFG.resid, dh))], axis=3
        )[:, :, :, : max(R2, CFG.resid)],
    }
    logits_want, _, _ = run_decode(
        weights,
        jnp.asarray(toks[:, T]),
        jnp.full((B,), T, jnp.int32),
        jnp.zeros((B,), jnp.int32),
        jnp.full((B,), T, jnp.int32),
        wide,
    )
    np.testing.assert_allclose(
        np.asarray(logits_got), np.asarray(logits_want), atol=3e-4, rtol=1e-3
    )


def test_prefill_padding_invariance(weights):
    """Right-padding must not change the last-valid-position logits."""
    B, T = 1, 6
    rng = np.random.default_rng(2)
    toks = rng.integers(0, CFG.vocab, size=(B, T)).astype(np.int32)
    plen = jnp.full((B,), T, jnp.int32)
    logits_a, _, _ = M.prefill(CFG, jnp.asarray(toks), plen, *weights)
    padded = np.concatenate([toks, np.zeros((B, 4), np.int32)], axis=1)
    logits_b, _, _ = M.prefill(CFG, jnp.asarray(padded), plen, *weights)
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), atol=1e-4, rtol=1e-4
    )


def test_prefill_batch_consistency(weights):
    """Each batch lane is independent."""
    rng = np.random.default_rng(4)
    toks = rng.integers(0, CFG.vocab, size=(2, 5)).astype(np.int32)
    plen = jnp.full((2,), 5, jnp.int32)
    lg, _, _ = M.prefill(CFG, jnp.asarray(toks), plen, *weights)
    for b in range(2):
        lg1, _, _ = M.prefill(
            CFG, jnp.asarray(toks[b : b + 1]), jnp.full((1,), 5, jnp.int32), *weights
        )
        np.testing.assert_allclose(np.asarray(lg[b]), np.asarray(lg1[0]), atol=2e-4, rtol=1e-3)
