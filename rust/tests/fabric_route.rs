//! Multi-node fabric end-to-end: cross-node prefix fetches through both
//! transports (shared directory + designated peer) return byte-identical
//! tokens, the `route` front tier honors drain for placement while
//! in-flight sessions finish, and a hedged request delivers exactly one
//! completion.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use polarquant::coordinator::{Engine, EngineOpts, FabricOpts};
use polarquant::fabric::{route, FrontOpts};
use polarquant::model::ModelConfig;
use polarquant::server::{serve, Client, GenParams};
use polarquant::util::json::Value;

/// Fleet-total counter from an `{"admin":"metrics"}` reply.
fn metric(v: &Value, key: &str) -> f64 {
    v.get(key).and_then(|x| x.as_f64()).unwrap_or(f64::NAN)
}

fn toy_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::tiny();
    cfg.n_layers = 2;
    cfg.vocab = 64;
    cfg.d_model = 32;
    cfg.n_heads = 4;
    cfg.n_kv_heads = 2;
    cfg.head_dim = 16;
    cfg.ffn = 48;
    cfg.group = 8;
    cfg.resid = 16;
    cfg
}

fn fabric_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("polarquant-fabric-it-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One "node": a single-worker server whose engine runs the prefix
/// cache and (optionally) binds the shared fabric.  Every node uses the
/// SAME weight seed — the fabric models one model replicated across
/// nodes, and the config fingerprint alone cannot tell two synthetic
/// seeds apart.
fn node_factory(seed: u64, fabric: Option<FabricOpts>) -> polarquant::server::EngineFactory {
    let cfg = toy_cfg();
    Arc::new(move |_w| {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 16; // multiple of group=8
        opts.prefill_quantize_eagerly = true;
        opts.prefix_cache = true;
        let mut engine = Engine::native_synthetic(cfg.clone(), seed, 4.0, opts);
        if let Some(f) = &fabric {
            engine.attach_fabric(f).expect("fabric attach");
        }
        engine
    })
}

/// Shared 32-token "system prompt" (4 pages at group 8) + a short tail.
fn warm_prompt() -> Vec<u32> {
    (0..32u32).map(|i| (i * 7 % 64)).chain([9, 10, 11]).collect()
}

#[test]
fn shared_dir_fabric_serves_cold_node_byte_identically() {
    let dir = fabric_dir("dir");
    let fab = FabricOpts { dir: Some(dir.clone()), peer: None };
    let prompt = warm_prompt();

    // node A: cold prefill, then a warm repeat — and publication
    let a = serve(node_factory(41, Some(fab.clone())), "127.0.0.1:0", 1).unwrap();
    let mut ca = Client::connect(&a.addr).unwrap();
    let cold = ca.generate(&prompt, 6, None).unwrap();
    let warm = ca.generate(&prompt, 6, None).unwrap();
    assert!(!cold.rejected && !warm.rejected);
    assert_eq!(cold.tokens, warm.tokens, "prefix caching never changes output");
    let ma = ca.metrics().unwrap();
    assert!(metric(&ma, "fabric_published") > 0.0, "node A must publish its prefix pages");
    assert_eq!(metric(&ma, "fabric_prefix_hits"), 0.0, "A computed locally, no fetch");
    a.stop();

    // node B: brand-new process, empty cache, same fabric directory —
    // its first request fetches A's pages instead of re-prefilling
    let b = serve(node_factory(41, Some(fab)), "127.0.0.1:0", 1).unwrap();
    let mut cb = Client::connect(&b.addr).unwrap();
    let fetched = cb.generate(&prompt, 6, None).unwrap();
    assert!(!fetched.rejected);
    assert_eq!(fetched.tokens, cold.tokens, "fetched prefix must be byte-identical");
    let mb = cb.metrics().unwrap();
    assert!(metric(&mb, "fabric_prefix_hits") >= 1.0, "{mb:?}");
    assert!(metric(&mb, "fabric_pages_fetched") >= 1.0, "{mb:?}");
    assert!(metric(&mb, "fabric_bytes_fetched") > 0.0, "{mb:?}");
    assert_eq!(metric(&mb, "fabric_rejected"), 0.0, "verified fetches only");
    assert!(
        metric(&mb, "prefix_tokens_reused") > 0.0,
        "the fetched chain must serve as a real prefix hit: {mb:?}"
    );
    b.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn peer_fabric_fetches_over_the_admin_channel() {
    let prompt = warm_prompt();

    // node A exports its resident pages over `{"peer":"fetch"}` (no
    // fabric attached — serving with the prefix cache is enough)
    let a = serve(node_factory(43, None), "127.0.0.1:0", 1).unwrap();
    let mut ca = Client::connect(&a.addr).unwrap();
    let control = ca.generate(&prompt, 6, None).unwrap();
    assert!(!control.rejected);

    // node B names A as its peer: cold miss -> fetch -> identical tokens
    let fab = FabricOpts { dir: None, peer: Some(a.addr.clone()) };
    let b = serve(node_factory(43, Some(fab)), "127.0.0.1:0", 1).unwrap();
    let mut cb = Client::connect(&b.addr).unwrap();
    let fetched = cb.generate(&prompt, 6, None).unwrap();
    assert!(!fetched.rejected);
    assert_eq!(fetched.tokens, control.tokens);
    let mb = cb.metrics().unwrap();
    assert!(metric(&mb, "fabric_prefix_hits") >= 1.0, "{mb:?}");
    assert_eq!(metric(&mb, "fabric_rejected"), 0.0);
    assert_eq!(metric(&mb, "fabric_published"), 0.0, "the peer transport is fetch-only");
    b.stop();
    a.stop();
}

/// Front-tier metrics: the per-backend objects under `"backends"`.
fn backend_stats(front: &mut Client) -> Vec<(String, bool, f64)> {
    let m = front.metrics().unwrap();
    m.get("backends")
        .and_then(|b| b.as_arr())
        .map(|arr| {
            arr.iter()
                .map(|n| {
                    (
                        n.str_or("addr", ""),
                        n.get("draining").and_then(|d| d.as_bool()).unwrap_or(false),
                        metric(n, "sessions"),
                    )
                })
                .collect()
        })
        .unwrap_or_default()
}

#[test]
fn drained_node_finishes_sessions_but_takes_no_new_placements() {
    let a = serve(node_factory(51, None), "127.0.0.1:0", 1).unwrap();
    let b = serve(node_factory(52, None), "127.0.0.1:0", 1).unwrap();
    let front = route(FrontOpts {
        addr: "127.0.0.1:0".into(),
        backends: vec![a.addr.clone(), b.addr.clone()],
        hedge_after: None,
        heartbeat: Duration::from_millis(50),
        vnodes: 16,
    })
    .unwrap();
    let mut client = Client::connect(&front.addr).unwrap();

    // place enough sessions that both nodes hold some
    let sids: Vec<u64> = (0..8).map(|_| client.open_session().unwrap()).collect();
    assert!(sids.iter().all(|&s| s >= 1 << 40), "front-owned session ids: {sids:?}");
    let before = backend_stats(&mut client);
    let (drain_addr, drained_sessions) = before
        .iter()
        .max_by(|x, y| x.2.total_cmp(&y.2))
        .map(|(addr, _, s)| (addr.clone(), *s))
        .unwrap();
    assert!(drained_sessions >= 1.0, "placement must spread: {before:?}");

    // drain the busier backend directly, then wait for the heartbeat to
    // carry the flag to the front
    Client::connect(&drain_addr).unwrap().drain().unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = backend_stats(&mut client);
        if stats.iter().any(|(addr, draining, _)| addr == &drain_addr && *draining) {
            break;
        }
        assert!(Instant::now() < deadline, "front never observed the drain: {stats:?}");
        std::thread::sleep(Duration::from_millis(25));
    }

    // every EXISTING session still completes turns, wherever it lives
    for (i, &sid) in sids.iter().enumerate() {
        let reply = client.turn(sid, &[3, 4, 5], &GenParams::greedy(4), |_| true).unwrap();
        assert!(!reply.rejected, "turn on session {i} rejected: {:?}", reply.reason);
        assert_eq!(reply.tokens.len(), 4, "session {i}");
    }

    // NEW sessions all land elsewhere: the drained node's count freezes
    for _ in 0..8 {
        client.open_session().unwrap();
    }
    let after = backend_stats(&mut client);
    let drained_after =
        after.iter().find(|(addr, _, _)| addr == &drain_addr).map(|t| t.2).unwrap();
    assert_eq!(
        drained_after, drained_sessions,
        "a draining node must take no new placements: {after:?}"
    );
    let total: f64 = after.iter().map(|t| t.2).sum();
    assert_eq!(total, 16.0, "all 16 sessions placed: {after:?}");

    front.stop();
    a.stop();
    b.stop();
}

/// A fake backend that answers heartbeat pings like a healthy `serve`
/// node but swallows every generate frame — the deterministic "stalled
/// node" a hedge is for.
fn spawn_stalling_backend() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(stream) = conn else { continue };
            std::thread::spawn(move || {
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut w = stream;
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => return,
                        Ok(_) => {}
                    }
                    if line.contains("\"admin\"") {
                        let _ = writeln!(
                            w,
                            "{{\"admin\":\"ping\",\"ok\":true,\"role\":\"serve\",\
                             \"workers\":1,\"draining\":false}}"
                        );
                    }
                    // anything else: stall forever (never reply, never close)
                }
            });
        }
    });
    addr
}

#[test]
fn hedged_request_delivers_exactly_one_completion() {
    let live = serve(node_factory(61, None), "127.0.0.1:0", 1).unwrap();
    let stalled = spawn_stalling_backend();
    let front = route(FrontOpts {
        addr: "127.0.0.1:0".into(),
        backends: vec![stalled, live.addr.clone()],
        hedge_after: Some(Duration::from_millis(5)),
        heartbeat: Duration::from_millis(100),
        vnodes: 16,
    })
    .unwrap();

    // the expected tokens, straight from the live node
    let mut direct = Client::connect(&live.addr).unwrap();
    let mut client = Client::connect(&front.addr).unwrap();

    // placement hashes the prompt prefix, so some first tokens land on
    // the stalled node and some on the live one; find a hedged one
    let mut hedged = false;
    for t in 0..64u32 {
        let prompt: Vec<u32> = [t].into_iter().chain(warm_prompt()).collect();
        let expected = direct.generate_stream(&prompt, &GenParams::greedy(5), None, |_| true);
        let expected = expected.unwrap();
        let fired_before = metric(&client.metrics().unwrap(), "hedges_fired");
        let reply = client.generate_stream(&prompt, &GenParams::greedy(5), None, |_| true);
        let reply = reply.unwrap();
        assert!(!reply.rejected, "attempt {t}: {:?}", reply.reason);
        assert_eq!(reply.tokens, expected.tokens, "attempt {t}");
        if metric(&client.metrics().unwrap(), "hedges_fired") > fired_before {
            hedged = true;
            break;
        }
    }
    assert!(hedged, "64 distinct prompt prefixes never placed on the stalled node");

    // exactly one completion: the connection is clean — the very next
    // exchange parses as its own reply, with no stray frames before it
    let reply = client
        .generate_stream(&warm_prompt(), &GenParams::greedy(3), None, |_| true)
        .unwrap();
    assert_eq!(reply.tokens.len(), 3);

    front.stop();
    live.stop();
}
