//! Property-based tests (hand-rolled generator loop — proptest is not in
//! the offline crate set).  Each property runs over many seeded random
//! cases; failures print the seed so they replay deterministically.

use polarquant::coordinator::router::Router;
use polarquant::coordinator::{Engine, EngineOpts, GenOptions, Request, SchedMode, TenancyOpts, TierOpts};
use polarquant::kvcache::eviction::snapkv_select;
use polarquant::kvcache::stream::GroupValues;
use polarquant::kvcache::tier::serde::{decode_page, encode_page};
use polarquant::kvcache::{CacheConfig, Page, SequenceCache};
use polarquant::model::ModelConfig;
use polarquant::quant::pack::PackedCodes;
use polarquant::quant::polar::{self, PolarSpec};
use polarquant::quant::value;
use polarquant::quant::{dequantize, qparams, quantize, QkLut, QuantSpec, SeqScoreJob};
use polarquant::tensor::ops::dot;
use polarquant::util::rng::Rng;

const CASES: u64 = 200;

#[test]
fn prop_pack_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let bits = rng.range(1, 9) as u32;
        let n = rng.range(1, 700);
        let codes: Vec<u8> = (0..n)
            .map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8)
            .collect();
        let p = PackedCodes::from_codes(&codes, bits);
        assert_eq!(p.unpack(), codes, "seed {seed} bits {bits}");
        // v2 lanes are byte-aligned: a nibble per code up to 4 bits, a
        // whole byte above
        let want_bytes = if bits <= 4 { n.div_ceil(2) } else { n };
        assert_eq!(p.nbytes(), want_bytes, "seed {seed}");
        // random access agrees with the bulk unpack
        for _ in 0..10 {
            let i = rng.below(n);
            assert_eq!(p.get(i), codes[i], "seed {seed} bits {bits} i {i}");
        }
        // the legacy v1 bitstream decodes the same codes from its tight
        // ceil(n*bits/8) bytes (tier records written pre-bump)
        let v1 = PackedCodes::from_codes_v1(&codes, bits);
        assert_eq!(v1.nbytes(), (n * bits as usize).div_ceil(8), "seed {seed} v1 tight");
        assert_eq!(v1.unpack(), codes, "seed {seed} bits {bits} v1");
    }
}

#[test]
fn prop_polar_bits_per_element_invariants() {
    // The paper's §B bit accounting: (r+t)/2 bits per original element
    // plus four fp16 params per (group, channel-pair) amortized over the
    // group — checked across every (r_bits, t_bits, group) combination.
    for r in 1..=8u32 {
        for t in 1..=8u32 {
            for group in [8usize, 16, 32, 64, 128, 256] {
                let spec = PolarSpec::new(r, t, group);
                let got = spec.bits_per_element();
                let want = (r + t) as f64 / 2.0 + 32.0 / group as f64;
                assert!((got - want).abs() < 1e-12, "r{r} t{t} g{group}: {got} vs {want}");
                // one extra bit on either channel costs exactly 1/2
                // bit/element (two elements share a sub-vector)
                if r < 8 {
                    let up = PolarSpec::new(r + 1, t, group).bits_per_element();
                    assert!((up - got - 0.5).abs() < 1e-12, "r{r} t{t} g{group}");
                }
                if t < 8 {
                    let up = PolarSpec::new(r, t + 1, group).bits_per_element();
                    assert!((up - got - 0.5).abs() < 1e-12, "r{r} t{t} g{group}");
                }
                // doubling the group strictly shrinks the param overhead
                let bigger = PolarSpec::new(r, t, group * 2).bits_per_element();
                assert!(bigger < got, "r{r} t{t} g{group}");
                // never worse than the fp16 baseline
                assert!(got < 16.0, "r{r} t{t} g{group}");
                // the QuantSpec facade agrees with the spec type
                let facade = QuantSpec::Polar { r_bits: r, t_bits: t, group };
                assert!(
                    (facade.bits_per_element(128) - got).abs() < 1e-12,
                    "facade disagrees at r{r} t{t} g{group}"
                );
            }
        }
    }
}

#[test]
fn prop_scores_batch_matches_per_sequence() {
    // The blocked multi-sequence entry point must be bit-identical to
    // scoring each sequence alone, across both the fused (r+t <= 8) and
    // general (r+t > 8) unpack paths, ragged lengths, and head counts.
    for seed in 0..40 {
        let mut rng = Rng::new(8000 + seed);
        let d = 2 * rng.range(2, 17);
        let group = [8usize, 16][rng.below(2)];
        let r_bits = rng.range(2, 7) as u32;
        let t_bits = rng.range(2, 7) as u32;
        let spec = PolarSpec::new(r_bits, t_bits, group);
        let hq = rng.range(1, 4);
        let n_seqs = rng.range(1, 5);
        let encs: Vec<polar::PolarEncoded> = (0..n_seqs)
            .map(|_| {
                let groups = rng.range(1, 4);
                polar::encode(&rng.normal_vec(groups * group * d), d, &spec)
            })
            .collect();
        let qs: Vec<Vec<Vec<f32>>> = (0..n_seqs)
            .map(|_| (0..hq).map(|_| rng.normal_vec(d)).collect())
            .collect();
        let qrefs: Vec<Vec<&[f32]>> = qs
            .iter()
            .map(|sq| sq.iter().map(|q| q.as_slice()).collect())
            .collect();
        let jobs: Vec<SeqScoreJob> = encs
            .iter()
            .zip(&qrefs)
            .map(|(e, q)| SeqScoreJob { qs: q, groups: &e.groups })
            .collect();

        let mut lut = QkLut::new(spec, d, hq);
        let mut batched: Vec<Vec<Vec<f32>>> = (0..n_seqs).map(|_| vec![Vec::new(); hq]).collect();
        lut.scores_batch(&jobs, &mut batched);
        for s in 0..n_seqs {
            let mut single = vec![Vec::new(); hq];
            lut.scores_multi(&qrefs[s], &encs[s], &mut single);
            assert_eq!(batched[s], single, "seed {seed} seq {s}");
            assert_eq!(batched[s][0].len(), encs[s].tokens(), "seed {seed} seq {s}");
        }
    }
}

#[test]
fn prop_page_serde_roundtrip_is_bit_exact() {
    // Random specs and group shapes: encode -> decode -> re-encode must
    // reproduce the exact bytes (codes, param bit patterns, values), and
    // any single-byte corruption must be rejected, never panic or
    // mis-decode.
    for seed in 0..80 {
        let mut rng = Rng::new(10_000 + seed);
        let r_bits = rng.range(1, 9) as u32;
        let t_bits = rng.range(1, 9) as u32;
        let group = [2usize, 4, 8, 16][rng.below(4)];
        let d = 2 * rng.range(1, 17);
        let streams = rng.range(1, 5);
        let value_bits = if rng.chance(0.5) { Some(rng.range(1, 9) as u32) } else { None };
        let spec = PolarSpec::new(r_bits, t_bits, group);
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..streams {
            keys.push(polar::encode_group(&rng.normal_vec(group * d), d, &spec));
            let v = rng.normal_vec(group * d);
            vals.push(match value_bits {
                None => GroupValues::Fp(v),
                Some(b) => GroupValues::Quant(value::encode(&v, d, b)),
            });
        }
        let page = Page::new(keys, vals, group);
        let enc = encode_page(&page);
        let dec = decode_page(&enc)
            .unwrap_or_else(|e| panic!("seed {seed} r{r_bits} t{t_bits} g{group} d{d}: {e:#}"));
        assert_eq!(encode_page(&dec), enc, "seed {seed}: roundtrip not bit-exact");
        assert_eq!(dec.tokens, page.tokens, "seed {seed}");
        assert_eq!(dec.nbytes(), page.nbytes(), "seed {seed}");
        // the fused plane is rebuilt exactly when it should exist
        assert_eq!(
            dec.keys[0].combined.is_some(),
            r_bits + t_bits <= 8,
            "seed {seed}: combined plane presence"
        );
        // corrupt one random byte: the checksum must catch it
        let mut bad = enc.clone();
        let i = rng.below(bad.len());
        bad[i] ^= (1 + rng.below(255)) as u8;
        assert!(decode_page(&bad).is_err(), "seed {seed}: flip at {i}/{} accepted", bad.len());
        // truncation at a random point is rejected too
        let cut = rng.below(enc.len());
        assert!(decode_page(&enc[..cut]).is_err(), "seed {seed}: truncation to {cut} accepted");
    }
}

#[test]
fn prop_scalar_quant_invariants() {
    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let bits = rng.range(1, 9) as u32;
        let lo = rng.uniform_in(-100.0, 100.0);
        let hi = lo + rng.uniform_in(0.0, 100.0);
        let (z, s) = qparams(lo, hi, bits);
        for _ in 0..20 {
            let x = rng.uniform_in(lo, hi);
            let c = quantize(x, z, s, bits);
            assert!((c as u32) < (1 << bits));
            let xd = dequantize(c, z, s);
            // in-range values reconstruct within half a cell
            assert!(
                (x - xd).abs() <= s / 2.0 + 1e-5 * (1.0 + x.abs()),
                "seed {seed}: x {x} xd {xd} s {s}"
            );
            // dequantized value stays within the original range (+half cell)
            assert!(xd >= lo - s && xd <= hi + s, "seed {seed}");
        }
    }
}

#[test]
fn prop_polar_lut_equals_dequant_dot() {
    for seed in 0..60 {
        let mut rng = Rng::new(2000 + seed);
        let d = 2 * rng.range(2, 33);
        let group = [8, 16, 32][rng.below(3)];
        let groups = rng.range(1, 4);
        let r_bits = rng.range(2, 6) as u32;
        let t_bits = rng.range(2, 6) as u32;
        let spec = PolarSpec::new(r_bits, t_bits, group);
        let k = rng.normal_vec(groups * group * d);
        let enc = polar::encode(&k, d, &spec);
        let k_hat = polar::decode(&enc, d);
        let q = rng.normal_vec(d);
        let mut lut = QkLut::new(spec, d, 1);
        let mut scores = Vec::new();
        lut.scores(&q, &enc, &mut scores);
        for n in 0..scores.len() {
            let want = dot(&q, &k_hat[n * d..(n + 1) * d]);
            assert!(
                (scores[n] - want).abs() < 5e-4 * (1.0 + want.abs()),
                "seed {seed} n {n}: {} vs {want}",
                scores[n]
            );
        }
    }
}

#[test]
fn prop_polar_error_shrinks_with_bits() {
    // more bits => no worse reconstruction (monotone in expectation; we
    // assert pairwise on the same data with a generous slack factor)
    for seed in 0..40 {
        let mut rng = Rng::new(3000 + seed);
        let d = 32;
        let group = 16;
        let k = rng.normal_vec(2 * group * d);
        let err = |r: u32, t: u32| {
            let spec = PolarSpec::new(r, t, group);
            let enc = polar::encode(&k, d, &spec);
            polarquant::tensor::ops::mse(&k, &polar::decode(&enc, d))
        };
        let e33 = err(3, 3);
        let e55 = err(5, 5);
        assert!(e55 <= e33 * 1.05, "seed {seed}: e55 {e55} e33 {e33}");
    }
}

#[test]
fn prop_cache_append_invariants() {
    for seed in 0..60 {
        let mut rng = Rng::new(4000 + seed);
        let group = [4usize, 8][rng.below(2)];
        let cfg = CacheConfig {
            n_layers: rng.range(1, 3),
            n_kv_heads: rng.range(1, 3),
            head_dim: 8,
            spec: PolarSpec::new(4, 4, group),
            value_bits: if rng.chance(0.5) { Some(4) } else { None },
        };
        let mut seq = SequenceCache::new(cfg.clone());
        let step = cfg.n_layers * cfg.n_kv_heads * cfg.head_dim;
        let total = rng.range(1, 40);
        for i in 0..total {
            let k = rng.normal_vec(step);
            let v = rng.normal_vec(step);
            seq.append_step(&k, &v);
            // invariants after every append
            assert_eq!(seq.len(), i + 1);
            assert_eq!(seq.quantized_len() + seq.resid_len(), seq.len());
            assert_eq!(seq.quantized_len() % group, 0);
            assert!(seq.resid_len() < group);
            // every page spans every stream; every stream view agrees on
            // the sequence length
            for p in &seq.pages {
                assert_eq!(p.keys.len(), cfg.streams(), "seed {seed}");
                assert_eq!(p.vals.len(), cfg.streams(), "seed {seed}");
                assert_eq!(p.tokens, group, "seed {seed}");
            }
            for l in 0..cfg.n_layers {
                for h in 0..cfg.n_kv_heads {
                    assert_eq!(seq.stream(l, h).len(), seq.len(), "seed {seed}");
                }
            }
        }
        assert_eq!(seq.next_pos, total);
    }
}

#[test]
fn prop_cow_fork_divergence() {
    // Fork a pooled sequence, decode DIFFERENT tokens into each side:
    // the parent's pages and residual must be untouched by the fork's
    // growth (and vice versa), shared pages stay physically single, and
    // releasing both sides drains every refcount to zero.
    use polarquant::kvcache::CacheManager;
    for seed in 0..40 {
        let mut rng = Rng::new(9000 + seed);
        let group = [4usize, 8][rng.below(2)];
        let cfg = CacheConfig {
            n_layers: rng.range(1, 3),
            n_kv_heads: rng.range(1, 3),
            head_dim: 8,
            spec: PolarSpec::new(4, 4, group),
            value_bits: if rng.chance(0.5) { Some(4) } else { None },
        };
        let mut m = CacheManager::new(cfg.clone(), usize::MAX);
        let step = cfg.n_layers * cfg.n_kv_heads * cfg.head_dim;
        let prompt_tokens = rng.range(group, 4 * group);
        {
            let parent = m.create(1);
            let mut parent = parent.lock().unwrap();
            for _ in 0..prompt_tokens {
                parent.append_step(&rng.normal_vec(step), &rng.normal_vec(step));
            }
        }
        let physical_before = m.report().physical_bytes;
        m.fork(1, 2).expect("fork");

        // snapshot the parent, then grow ONLY the fork
        let snap_keys: Vec<Vec<f32>> = {
            let p = m.get(1).unwrap();
            let p = p.lock().unwrap();
            (0..cfg.n_layers)
                .flat_map(|l| {
                    (0..cfg.n_kv_heads)
                        .map(|h| {
                            let mut v = p.stream(l, h).decode_keys();
                            v.extend_from_slice(p.stream(l, h).resid_k());
                            v
                        })
                        .collect::<Vec<_>>()
                })
                .collect()
        };
        let grow = rng.range(1, 2 * group);
        {
            let f = m.get(2).unwrap();
            let mut f = f.lock().unwrap();
            for _ in 0..grow {
                f.append_step(&rng.normal_vec(step), &rng.normal_vec(step));
            }
        }
        {
            let p = m.get(1).unwrap();
            let p = p.lock().unwrap();
            assert_eq!(p.len(), prompt_tokens, "seed {seed}: parent length moved");
            let mut si = 0;
            for l in 0..cfg.n_layers {
                for h in 0..cfg.n_kv_heads {
                    let mut v = p.stream(l, h).decode_keys();
                    v.extend_from_slice(p.stream(l, h).resid_k());
                    assert_eq!(v, snap_keys[si], "seed {seed}: parent stream mutated");
                    si += 1;
                }
            }
        }
        // shared pages counted once physically, twice logically
        let r = m.report();
        assert!(r.physical_bytes < r.bytes, "seed {seed}: fork must share");
        assert!(r.physical_bytes >= physical_before, "seed {seed}");
        // release everything: refcounts must drain to zero
        m.release(1);
        m.release(2);
        assert_eq!(m.pool().pages_in_use(), 0, "seed {seed}: leaked pages");
        assert_eq!(m.report().physical_bytes, 0, "seed {seed}: leaked bytes");
    }
}

#[test]
fn prop_snapkv_select_invariants() {
    for seed in 0..CASES {
        let mut rng = Rng::new(5000 + seed);
        let t = rng.range(1, 300);
        let budget = rng.range(1, 200);
        let window = rng.range(1, 64);
        let scores: Vec<f32> = (0..t).map(|_| rng.uniform() as f32).collect();
        let keep = snapkv_select(&scores, budget, window);
        // sorted, unique, bounded
        assert!(keep.windows(2).all(|w| w[0] < w[1]), "seed {seed}");
        assert!(keep.len() <= budget.max(t.min(budget)), "seed {seed}");
        assert!(keep.iter().all(|&i| i < t));
        if t <= budget {
            assert_eq!(keep.len(), t);
        } else {
            assert_eq!(keep.len(), budget);
            // the window tail is always kept
            let w = window.min(budget);
            for i in t - w..t {
                assert!(keep.contains(&i), "seed {seed}: window idx {i} dropped");
            }
        }
    }
}

#[test]
fn prop_router_conservation() {
    for seed in 0..100 {
        let mut rng = Rng::new(6000 + seed);
        let n = rng.range(1, 6);
        let mut r = Router::new(n);
        let mut outstanding = vec![0usize; n];
        for _ in 0..100 {
            if rng.chance(0.6) {
                let session = if rng.chance(0.5) { Some(rng.next_u64() % 10) } else { None };
                let w = r.route(session);
                assert!(w < n);
                outstanding[w] += 1;
            } else if let Some(w) = (0..n).find(|&w| outstanding[w] > 0) {
                r.complete(w);
                outstanding[w] -= 1;
            }
            for w in 0..n {
                assert_eq!(r.load(w), outstanding[w], "seed {seed}");
            }
        }
    }
}

fn prop_engine_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::tiny();
    cfg.n_layers = 2;
    cfg.vocab = 64;
    cfg.d_model = 32;
    cfg.n_heads = 4;
    cfg.n_kv_heads = 2;
    cfg.head_dim = 16;
    cfg.ffn = 48;
    cfg.group = 8;
    cfg.resid = 16;
    cfg
}

#[test]
fn prop_seeded_sampling_is_bit_identical_across_decode_widths() {
    // The streaming API's reproducibility contract: identical
    // GenOptions{seed} sampled rollouts are bit-identical no matter how
    // many decode workers the engine fans over (the per-token RNG is a
    // pure function of (request seed, token index), never of shard
    // assignment).  Exact-mode chunking keeps the logits identical too.
    for case in 0..10u64 {
        let mut rng = Rng::new(8000 + case);
        let n_reqs = rng.range(1, 4);
        let reqs: Vec<Request> = (0..n_reqs)
            .map(|i| {
                let plen = rng.range(3, 30);
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(64) as u32).collect();
                let gen = GenOptions {
                    max_new_tokens: rng.range(4, 12),
                    temperature: rng.uniform_in(0.3, 1.5),
                    top_k: if rng.chance(0.5) { rng.range(2, 32) } else { 0 },
                    top_p: if rng.chance(0.5) { rng.uniform_in(0.7, 1.0) } else { 1.0 },
                    seed: rng.next_u64(),
                    stop_tokens: Vec::new(),
                    logprobs: false,
                    snapkv: None,
                };
                Request::new(i as u64 + 1, prompt, gen)
            })
            .collect();
        let chunk = rng.range(1, 3) * 8;
        let run = |workers: usize| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = chunk; // exact mode: logits width-invariant
            opts.decode_workers = workers;
            let mut eng = Engine::native_synthetic(prop_engine_cfg(), 300 + case, 4.0, opts);
            for r in &reqs {
                eng.submit(r.clone()).unwrap();
            }
            let mut done = eng.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
        };
        let inline = run(1);
        assert_eq!(inline, run(3), "case {case}: width 3 diverged");
        assert_eq!(inline, run(8), "case {case}: width 8 diverged");
    }
}

#[test]
fn prop_speculative_is_bit_identical() {
    // The speculative-decoding contract: `--speculate K` NEVER changes a
    // greedy rollout — the draft plane only proposes, the exact plane
    // verifies, and any rejected tail unwinds.  Random prompt batches,
    // draft planes (including the explicit exact-width plane, where every
    // proposal verifies), window sizes, decode-worker widths, and
    // prefill-chunk sizes must all reproduce the k=0 baseline
    // bit-for-bit, and the pool must drain to zero after every run.
    for case in 0..12u64 {
        let mut rng = Rng::new(8300 + case);
        let n_reqs = rng.range(1, 4);
        let reqs: Vec<Request> = (0..n_reqs)
            .map(|i| {
                let plen = rng.range(3, 30);
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(64) as u32).collect();
                let mut r = Request::greedy(i as u64 + 1, prompt, rng.range(4, 14));
                // random stop tokens exercise the mid-window clamp; the
                // first request stays stop-free so at least one rollout
                // runs long enough for speculation to engage
                if i > 0 && rng.chance(0.3) {
                    r.gen.stop_tokens = vec![rng.below(64) as u32];
                }
                r
            })
            .collect();
        let chunk = [0usize, 8, 16][rng.below(3)];
        let run = |speculate: usize, draft: Option<(u32, u32)>, workers: usize| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = chunk;
            opts.decode_workers = workers;
            opts.speculate = speculate;
            opts.draft_bits = draft;
            let mut eng = Engine::native_synthetic(prop_engine_cfg(), 700 + case, 4.0, opts);
            for r in &reqs {
                eng.submit(r.clone()).unwrap();
            }
            let mut done = eng.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            let tokens: Vec<Vec<u32>> = done.into_iter().map(|c| c.tokens).collect();
            assert_eq!(eng.page_pool().pages_in_use(), 0, "case {case}: leaked pages");
            assert_eq!(eng.cache_report().physical_bytes, 0, "case {case}: leaked bytes");
            (tokens, eng.metrics.speculative_rounds)
        };
        let (baseline, rounds0) = run(0, None, 1);
        assert_eq!(rounds0, 0, "case {case}: k=0 must never speculate");
        let k = rng.range(2, 6);
        let draft = match rng.below(3) {
            0 => None, // halved default
            1 => Some((rng.range(1, 5) as u32, rng.range(1, 5) as u32)),
            _ => Some((4, 4)), // exact-width: every draft must verify
        };
        let workers = [1usize, 3][rng.below(2)];
        let (spec_tokens, rounds) = run(k, draft, workers);
        assert_eq!(
            spec_tokens, baseline,
            "case {case}: k={k} draft={draft:?} w={workers} chunk={chunk} changed a rollout"
        );
        assert!(rounds > 0, "case {case}: speculation never engaged");
    }
}

#[test]
fn prop_cancel_at_any_point_returns_pool_to_baseline() {
    // Cancel a request after a random number of engine steps — mid
    // queue, mid prefill, or mid decode — and the page pool plus the
    // byte counters must land exactly back at zero every time.
    for case in 0..20u64 {
        let mut rng = Rng::new(8600 + case);
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        let mut eng = Engine::native_synthetic(prop_engine_cfg(), 400 + case, 4.0, opts);
        let plen = rng.range(4, 40);
        let prompt: Vec<u32> = (0..plen).map(|_| rng.below(64) as u32).collect();
        eng.submit(Request::greedy(1, prompt, 16)).unwrap();
        for _ in 0..rng.range(0, 12) {
            if eng.idle() {
                break;
            }
            eng.step().unwrap();
        }
        if !eng.idle() {
            let c = eng.cancel(1).expect("request is live");
            assert!(!c.rejected, "case {case}");
        }
        assert!(eng.idle(), "case {case}");
        let r = eng.cache_report();
        assert_eq!(r.physical_bytes, 0, "case {case}: leaked bytes");
        assert_eq!(eng.page_pool().pages_in_use(), 0, "case {case}: leaked pages");
        assert_eq!(r.tokens, 0, "case {case}: leaked sequences");
    }
}

#[test]
fn prop_kernels_bit_identical() {
    // The ScoreKernel contract: every kernel (scalar, and SIMD whenever
    // this build/CPU can run it — that's what Auto resolves to) produces
    // BIT-identical scores, across random PolarSpecs (fused r+t<=8 and
    // general paths), group sizes, ragged tail groups, and head counts.
    use polarquant::quant::{select_kernel, KernelKind};
    let scalar = select_kernel(KernelKind::Scalar).unwrap();
    let other = select_kernel(KernelKind::Auto).unwrap();
    for seed in 0..CASES {
        let mut rng = Rng::new(9000 + seed);
        let d = [8usize, 16, 32][rng.below(3)];
        let r = rng.range(1, 9) as u32;
        let t = rng.range(1, 9) as u32;
        let group = [4usize, 8, 16, 32][rng.below(4)];
        let spec = PolarSpec::new(r, t, group);
        // 1..=3 full groups plus, half the time, a ragged tail group so
        // the SIMD kernel's scalar tail path is exercised
        let mut enc = polar::encode(&rng.normal_vec(rng.range(1, 4) * group * d), d, &spec);
        if rng.below(2) == 1 {
            let tail = rng.range(1, group);
            enc.groups.push(polar::encode_group(&rng.normal_vec(tail * d), d, &spec));
        }
        let heads = rng.range(1, 4);
        let qs: Vec<Vec<f32>> = (0..heads).map(|_| rng.normal_vec(d)).collect();
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();

        let mut lut_a = QkLut::with_kernel(spec, d, heads, scalar);
        let mut lut_b = QkLut::with_kernel(spec, d, heads, other);
        let mut out_a = vec![Vec::new(); heads];
        let mut out_b = vec![Vec::new(); heads];
        lut_a.scores_multi(&qrefs, &enc, &mut out_a);
        lut_b.scores_multi(&qrefs, &enc, &mut out_b);
        for h in 0..heads {
            assert_eq!(out_a[h].len(), enc.tokens(), "seed {seed}");
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(
                bits(&out_a[h]),
                bits(&out_b[h]),
                "seed {seed} d{d} r{r} t{t} g{group} head {h}: {} vs {} kernels differ",
                scalar.name(),
                other.name()
            );
        }
    }
}

#[test]
fn prop_wfq_never_starves_the_light_tenant() {
    // The fairness property behind `--sched wfq`: a light tenant with
    // weight >= 2 that submits AFTER a flood of heavy-tenant requests
    // must never be served last.  The per-step prefill budget is a
    // shared resource, so under FCFS the late arrival waits for every
    // flood prompt; under WFQ the deficit-stride reorder grants it the
    // weighted share and it overtakes the flood's tail.  Scheduling must
    // reorder ONLY — every request's greedy rollout stays bit-identical
    // across both modes (exact-mode chunking is batch-invariant).
    for case in 0..12u64 {
        let mut rng = Rng::new(9500 + case);
        let n_flood = rng.range(3, 7);
        let weight = rng.range(2, 6) as u32;
        let gen_tokens = rng.range(4, 10);
        // prompts long enough that prefill spans many steps (budget is
        // prefill_chunk=8 tokens per step across all running requests)
        let mk_prompt = |rng: &mut Rng| -> Vec<u32> {
            (0..rng.range(16, 33)).map(|_| rng.below(64) as u32).collect()
        };
        let mut reqs = Vec::new();
        for i in 0..n_flood {
            let mut r = Request::greedy(i as u64 + 1, mk_prompt(&mut rng), gen_tokens);
            r.tenant = "flood".to_string();
            reqs.push(r);
        }
        let calm_id = n_flood as u64 + 1;
        let mut calm = Request::greedy(calm_id, mk_prompt(&mut rng), gen_tokens);
        calm.tenant = "calm".to_string();
        reqs.push(calm);

        let run = |mode: SchedMode| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = 8;
            opts.sched = mode;
            let mut eng = Engine::native_synthetic(prop_engine_cfg(), 500 + case, 4.0, opts);
            if mode == SchedMode::Wfq {
                let mut t = TenancyOpts::default();
                t.weights.insert("calm".to_string(), weight);
                t.weights.insert("flood".to_string(), 1);
                eng.set_tenancy(&t);
            }
            for r in &reqs {
                eng.submit(r.clone()).unwrap();
            }
            // completion order = the order requests finished stepping
            eng.run_to_completion().unwrap()
        };
        let fcfs = run(SchedMode::Fcfs);
        let wfq = run(SchedMode::Wfq);

        // content is scheduling-invariant
        let by_id = |mut done: Vec<polarquant::coordinator::Completion>| {
            done.sort_by_key(|c| c.id);
            done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
        };
        assert_eq!(
            by_id(fcfs.clone()),
            by_id(wfq.clone()),
            "case {case}: wfq changed a rollout"
        );

        let pos = |done: &[polarquant::coordinator::Completion]| {
            done.iter().position(|c| c.id == calm_id).unwrap()
        };
        let (p_fcfs, p_wfq) = (pos(&fcfs), pos(&wfq));
        // FCFS sanity: the late arrival is served (near) last
        assert!(
            p_fcfs >= n_flood - 1,
            "case {case}: fcfs served the late request at {p_fcfs} of {n_flood}"
        );
        // the property: WFQ never starves the weighted tenant to the back
        assert!(
            p_wfq < n_flood,
            "case {case}: wfq starved calm (weight {weight}) to position {p_wfq}"
        );
        assert!(p_wfq < p_fcfs, "case {case}: wfq did not improve on fcfs ({p_wfq} vs {p_fcfs})");
        // same-tenant requests stay FCFS among themselves under WFQ
        let flood_order: Vec<u64> =
            wfq.iter().map(|c| c.id).filter(|&id| id != calm_id).collect();
        assert!(
            flood_order.windows(2).all(|w| w[0] < w[1]),
            "case {case}: wfq reordered within the flood tenant: {flood_order:?}"
        );
    }
}

#[test]
fn prop_ttl_reap_is_invisible_to_session_turns() {
    // The TTL-reaping contract: demoting an idle session chain to the
    // disk tier and promoting it on the next turn must be invisible —
    // every turn of a random multi-turn conversation decodes
    // bit-identically to a never-reaped baseline engine, no matter where
    // the reaps land.  (ttl=0 makes every inter-turn gap reap.)
    for case in 0..8u64 {
        let mut rng = Rng::new(9700 + case);
        let n_turns = rng.range(2, 6);
        let turns: Vec<(Vec<u32>, usize)> = (0..n_turns)
            .map(|_| {
                let toks: Vec<u32> =
                    (0..rng.range(1, 20)).map(|_| rng.below(64) as u32).collect();
                (toks, rng.range(3, 8))
            })
            .collect();
        let opts = || {
            let mut o = EngineOpts::default();
            o.prefill_chunk = 8;
            o.prefix_cache = true; // attach_tier requires it
            o
        };
        let run_turns = |eng: &mut Engine, reap: bool| -> Vec<Vec<u32>> {
            turns
                .iter()
                .enumerate()
                .map(|(i, (toks, gen))| {
                    let (tx, _rx) = std::sync::mpsc::channel();
                    eng.submit_turn(11, Request::greedy(i as u64 + 1, toks.clone(), *gen), tx)
                        .unwrap();
                    let out = eng.run_to_completion().unwrap()[0].tokens.clone();
                    if reap {
                        assert_eq!(eng.reap_idle_sessions(), 1, "case {case} turn {i}");
                    }
                    out
                })
                .collect()
        };

        let mut base_eng = Engine::native_synthetic(prop_engine_cfg(), 600 + case, 4.0, opts());
        let baseline = run_turns(&mut base_eng, false);

        let dir = std::env::temp_dir()
            .join(format!("polarquant-prop-ttl-{}-{case}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut eng = Engine::native_synthetic(prop_engine_cfg(), 600 + case, 4.0, opts());
        eng.attach_tier(&TierOpts { dir: dir.clone(), max_bytes: u64::MAX, snapshot: false })
            .unwrap();
        let mut tenancy = TenancyOpts::default();
        tenancy.session_ttl = Some(std::time::Duration::from_secs(0));
        eng.set_tenancy(&tenancy);
        let reaped = run_turns(&mut eng, true);

        assert_eq!(reaped, baseline, "case {case}: a reap changed a turn's rollout");
        assert_eq!(eng.metrics.sessions_reaped, n_turns as u64, "case {case}");
        // turn 1 creates the chain; every later turn promotes it back
        assert_eq!(eng.metrics.sessions_restored, n_turns as u64 - 1, "case {case}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn prop_export_dense_roundtrips_codes() {
    // exporting and re-reading the dense layout preserves every code
    for seed in 0..30 {
        let mut rng = Rng::new(7000 + seed);
        let group = 4;
        let cfg = CacheConfig {
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 8,
            spec: PolarSpec::new(3, 5, group),
            value_bits: None,
        };
        let mut seq = SequenceCache::new(cfg.clone());
        let step = cfg.n_layers * cfg.n_kv_heads * cfg.head_dim;
        let total = rng.range(group, 20);
        for _ in 0..total {
            seq.append_step(&rng.normal_vec(step), &rng.normal_vec(step));
        }
        let s_cap = 24;
        let dense = seq.export_dense(s_cap, group);
        let d2 = cfg.head_dim / 2;
        for l in 0..cfg.n_layers {
            for h in 0..cfg.n_kv_heads {
                let st = seq.stream(l, h);
                let base = (l * cfg.n_kv_heads + h) * s_cap * d2;
                for (gi, g) in st.key_groups().enumerate() {
                    // dense export is token-major; the plane channel-major
                    let tc = g.theta_codes.unpack();
                    for n in 0..g.tokens {
                        for j in 0..d2 {
                            assert_eq!(
                                dense.theta_code[base + (gi * group + n) * d2 + j],
                                tc[j * g.tokens + n] as i32,
                                "seed {seed}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_ring_assignments_stable_under_membership_change() {
    // The consistent-hash contract, over random fleets: assignments
    // depend only on the node-NAME set (construction order is
    // irrelevant), removing a node relocates exactly the removed node's
    // keys, and adding a node only ever steals keys FOR the new node —
    // surviving nodes never trade keys among themselves.
    use polarquant::fabric::HashRing;
    for seed in 0..60 {
        let mut rng = Rng::new(8000 + seed);
        let n = rng.range(2, 9);
        let vnodes = [16usize, 32, 64][rng.below(3)];
        let nodes: Vec<String> =
            (0..n).map(|i| format!("10.{seed}.0.{i}:7733")).collect();
        let ring = HashRing::new(&nodes, vnodes);
        let keys: Vec<u64> = (0..512).map(|_| rng.next_u64()).collect();
        let homes: Vec<usize> = keys.iter().map(|&k| ring.node_for(k).unwrap()).collect();

        // construction order never matters: a shuffled fleet maps every
        // key to the same node NAME
        let mut shuffled = nodes.clone();
        for i in (1..shuffled.len()).rev() {
            shuffled.swap(i, rng.below(i + 1));
        }
        let reordered = HashRing::new(&shuffled, vnodes);
        for (&k, &h) in keys.iter().zip(&homes) {
            let h2 = reordered.node_for(k).unwrap();
            assert_eq!(ring.node_name(h), reordered.node_name(h2), "seed {seed} key {k:#x}");
        }

        // remove a random node: only its own keys move
        let gone = rng.below(n);
        let mut fewer = nodes.clone();
        fewer.remove(gone);
        let reduced = HashRing::new(&fewer, vnodes);
        let mut moved = 0usize;
        for (&k, &h) in keys.iter().zip(&homes) {
            let after = reduced.node_for(k).unwrap();
            if h == gone {
                moved += 1;
                assert_ne!(reduced.node_name(after), ring.node_name(gone), "seed {seed}");
            } else {
                assert_eq!(
                    ring.node_name(h),
                    reduced.node_name(after),
                    "seed {seed}: a surviving assignment moved"
                );
            }
        }
        // ~1/N of the keyspace: the removed node's share, loosely bounded
        assert!(moved <= keys.len() * 4 / n, "seed {seed}: {moved} of {} moved", keys.len());

        // add a fresh node: keys stay home or join the newcomer
        let mut more = nodes.clone();
        more.push(format!("10.{seed}.0.{n}:7733"));
        let grown = HashRing::new(&more, vnodes);
        for (&k, &h) in keys.iter().zip(&homes) {
            let after = grown.node_for(k).unwrap();
            let name = grown.node_name(after);
            assert!(
                name == ring.node_name(h) || name == more[n],
                "seed {seed}: key {k:#x} traded between survivors"
            );
        }

        // pick() with everything healthy IS node_for
        for &k in keys.iter().take(32) {
            assert_eq!(ring.pick(k, |_| true), ring.node_for(k), "seed {seed}");
        }
    }
}

#[test]
fn prop_corrupted_fabric_record_is_a_clean_miss() {
    // Random chains published to a shared fabric directory, with one
    // record randomly flipped or truncated: a cold pool's lookup admits
    // a bit-exact prefix of the chain up to the damaged link, counts
    // exactly one rejection, and never admits a corrupted page.
    use std::sync::Arc;

    use polarquant::fabric::DirFabric;
    use polarquant::kvcache::tier::serde::encode_page;
    use polarquant::kvcache::PagePool;
    for seed in 0..40 {
        let mut rng = Rng::new(8500 + seed);
        let dir = std::env::temp_dir().join(format!(
            "polarquant-prop-fabric-{}-{seed}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let group = 4usize;
        let d = 8usize;
        let spec = PolarSpec::new(3, 3, group);
        let npages = rng.range(1, 5);
        let toks: Vec<u32> =
            (0..npages * group).map(|_| (rng.next_u64() % 97) as u32).collect();
        let tag = rng.next_u64();

        let a = PagePool::new(usize::MAX);
        a.set_fabric(Some(Arc::new(DirFabric::new(&dir, tag).unwrap())), tag);
        let pages: Vec<_> = (0..npages)
            .map(|_| {
                let keys = vec![polar::encode_group(&rng.normal_vec(group * d), d, &spec)];
                let vals = vec![GroupValues::Fp(rng.normal_vec(group * d))];
                a.adopt(Page::new(keys, vals, group))
            })
            .collect();
        a.register_prefix(&pages, &toks);
        assert_eq!(a.fabric_published(), npages as u64, "seed {seed}");
        let originals: Vec<Vec<u8>> = pages.iter().map(|p| encode_page(p)).collect();

        // damage exactly one record: flip a byte or truncate
        let mut records: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| p.extension().is_some_and(|e| e == "page"))
            .collect();
        records.sort();
        assert_eq!(records.len(), npages, "seed {seed}");
        let victim = &records[rng.below(npages)];
        let mut bytes = std::fs::read(victim).unwrap();
        if rng.chance(0.5) {
            let i = rng.below(bytes.len());
            bytes[i] ^= (1 + rng.below(255)) as u8;
        } else {
            bytes.truncate(rng.below(bytes.len()));
        }
        std::fs::write(victim, &bytes).unwrap();

        let b = PagePool::new(usize::MAX);
        b.set_fabric(Some(Arc::new(DirFabric::new(&dir, tag).unwrap())), tag);
        let hit = b.lookup_prefix(&toks, group, usize::MAX);
        assert!(hit.len() < npages, "seed {seed}: the damaged link must not admit");
        for (got, want) in hit.iter().zip(&originals) {
            assert_eq!(&encode_page(got), want, "seed {seed}: admitted page not bit-exact");
        }
        assert_eq!(b.fabric_rejected(), 1, "seed {seed}: the walk stops at the bad link");
        assert_eq!(b.fabric_pages_fetched(), hit.len() as u64, "seed {seed}");
        assert_eq!(b.pages_in_use(), hit.len(), "seed {seed}: nothing half-admitted");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
