//! Request-lifecycle tracing over the wire: a traced server records an
//! ordered span sequence per request, drains it through `{"admin":
//! "trace"}`, renders Prometheus text through `{"admin":"prometheus"}`,
//! and writes a Chrome trace_event file at graceful shutdown — while a
//! `--trace off` server generates the IDENTICAL tokens and zero events.

use std::sync::Arc;

use polarquant::coordinator::{Engine, EngineOpts};
use polarquant::model::ModelConfig;
use polarquant::server::{serve, serve_with_export, Client};
use polarquant::util::json::Value;

fn opts(trace: bool) -> EngineOpts {
    let mut o = EngineOpts::default();
    o.prefill_chunk = 4;
    o.trace = trace;
    o
}

fn factory(trace: bool) -> polarquant::server::EngineFactory {
    Arc::new(move |w| {
        Engine::native_synthetic(ModelConfig::tiny(), 300 + w as u64, 4.0, opts(trace))
    })
}

fn ev_name(v: &Value) -> String {
    v.str_or("event", "")
}

#[test]
fn traced_request_yields_ordered_lifecycle_over_tcp() {
    let handle = serve(factory(true), "127.0.0.1:0", 1).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let prompt: Vec<u32> = (0..10).map(|i| (i * 3 + 1) % 64).collect();
    let traced = client.generate(&prompt, 5, None).unwrap();
    assert_eq!(traced.tokens.len(), 5);

    let (events, term) = client.trace().unwrap();
    assert_eq!(term.str_or("admin", ""), "trace");
    assert_eq!(term.usize_or("events", 0), events.len());
    assert_eq!(term.usize_or("dropped", 9), 0, "65k ring never drops 10 events");

    // this request's events, already seq-ordered by the drain
    let mine: Vec<&Value> =
        events.iter().filter(|e| e.usize_or("id", 0) as u64 == traced.id).collect();
    let names: Vec<String> = mine.iter().map(|e| ev_name(e)).collect();
    assert_eq!(names.first().map(String::as_str), Some("admitted"), "{names:?}");
    assert_eq!(names.last().map(String::as_str), Some("done"), "{names:?}");
    // 10 prompt tokens / chunk 4 -> 3 chunks; 5 tokens, the first decoded
    // by the last chunk's step -> 4 decode steps
    assert_eq!(names.iter().filter(|n| *n == "prefill_chunk").count(), 3, "{names:?}");
    assert_eq!(names.iter().filter(|n| *n == "decode_step").count(), 4, "{names:?}");
    // phases don't interleave: every chunk precedes every decode step
    let last_chunk = names.iter().rposition(|n| n == "prefill_chunk").unwrap();
    let first_step = names.iter().position(|n| n == "decode_step").unwrap();
    assert!(last_chunk < first_step, "{names:?}");
    // seq strictly increases and the payloads carry their typed fields
    let seqs: Vec<u64> = mine.iter().map(|e| e.usize_or("seq", 0) as u64).collect();
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "{seqs:?}");
    let chunks: Vec<(usize, usize)> = mine
        .iter()
        .filter(|e| ev_name(e) == "prefill_chunk")
        .map(|e| (e.usize_or("start", 99), e.usize_or("tokens", 99)))
        .collect();
    assert_eq!(chunks, vec![(0, 4), (4, 4), (8, 2)]);
    let done = mine.last().unwrap();
    assert_eq!(done.str_or("finish_reason", ""), "length");
    assert_eq!(done.usize_or("tokens", 0), 5);

    // draining consumed the ring: a second drain is empty
    let (events, _) = client.trace().unwrap();
    assert!(events.is_empty(), "{events:?}");
    handle.stop();

    // the identical request against a --trace off server: identical
    // tokens (tracing never touches the computation), zero events
    let handle = serve(factory(false), "127.0.0.1:0", 1).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let plain = client.generate(&prompt, 5, None).unwrap();
    assert_eq!(plain.tokens, traced.tokens);
    let (events, term) = client.trace().unwrap();
    assert!(events.is_empty(), "disabled recorders must record nothing: {events:?}");
    assert_eq!(term.usize_or("events", 9), 0);
    handle.stop();
}

#[test]
fn prometheus_exposition_renders_over_tcp() {
    let handle = serve(factory(true), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let prompt: Vec<u32> = (0..8).map(|i| (i * 5 + 2) % 64).collect();
    client.generate(&prompt, 4, None).unwrap();

    let text = client.prometheus().unwrap();
    // both workers report every family; the one that served the request
    // has a nonzero finished counter
    assert!(text.contains("# TYPE polarquant_requests_finished_total counter"), "{text}");
    assert!(text.contains("polarquant_requests_finished_total{worker=\"0\"}"), "{text}");
    assert!(text.contains("polarquant_requests_finished_total{worker=\"1\"}"), "{text}");
    assert!(text.contains("polarquant_ttft_seconds_bucket{le=\"+Inf\",worker=\""), "{text}");
    assert!(text.contains("polarquant_build_info{kernel=\""), "{text}");
    // cumulative buckets are monotone non-decreasing per series
    for w in 0..2 {
        let needle = "polarquant_ttft_seconds_bucket{le=";
        let counts: Vec<f64> = text
            .lines()
            .filter(|l| l.starts_with(&needle) && l.contains(&format!("worker=\"{w}\"")))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(!counts.is_empty());
        assert!(counts.windows(2).all(|p| p[0] <= p[1]), "worker {w}: {counts:?}");
    }
    handle.stop();
}

#[test]
fn chrome_export_writes_trace_file_at_shutdown() {
    let path = std::env::temp_dir().join(format!("pq-trace-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let handle =
        serve_with_export(factory(true), "127.0.0.1:0", 1, Some(path.clone())).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    client.generate(&[1, 2, 3, 4, 5], 3, None).unwrap();
    handle.stop();

    let text = std::fs::read_to_string(&path).unwrap();
    let v = polarquant::util::json::parse(&text).unwrap();
    let events = v.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    // begin + end of the request's async span are both present
    let phases: Vec<String> =
        events.iter().map(|e| e.str_or("ph", "")).collect();
    assert!(phases.iter().any(|p| p == "b"), "{phases:?}");
    assert!(phases.iter().any(|p| p == "e"), "{phases:?}");
    let _ = std::fs::remove_file(&path);
}
