//! Tiered page store, end to end: greedy decode must be bit-identical
//! with tiering on vs off — including after a demote→promote cycle and
//! after a snapshot/restore across a server restart — and the tier
//! counters must actually move.

use std::path::PathBuf;
use std::sync::Arc;

use polarquant::coordinator::{Engine, EngineOpts, Request, TierOpts};
use polarquant::model::ModelConfig;
use polarquant::server::{serve, Client};

fn toy_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::tiny();
    cfg.n_layers = 2;
    cfg.vocab = 64;
    cfg.d_model = 32;
    cfg.n_heads = 4;
    cfg.n_kv_heads = 2;
    cfg.head_dim = 16;
    cfg.ffn = 48;
    cfg.group = 8;
    cfg.resid = 16;
    cfg
}

fn prefix_opts() -> EngineOpts {
    let mut opts = EngineOpts::default();
    opts.prefill_chunk = 8; // == group: aligned chunks
    opts.prefix_cache = true;
    opts
}

fn tier_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("polarquant-tier-it-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn tier_opts(dir: &PathBuf, snapshot: bool) -> TierOpts {
    TierOpts { dir: dir.clone(), max_bytes: u64::MAX, snapshot }
}

/// Shared 24-token system prefix (3 pages at group 8) + distinct tails.
fn prompts() -> Vec<Vec<u32>> {
    let system: Vec<u32> = (0..24).map(|i| (i * 5 % 64) as u32).collect();
    (0..4u32)
        .map(|t| system.iter().cloned().chain([t + 1, t + 2]).collect())
        .collect()
}

#[test]
fn greedy_decode_bit_identical_across_demote_promote_cycle() {
    // Reference: prefix caching on, NO tier — requests served one after
    // another so later prompts hit the prefix cache.
    let serve_all = |eng: &mut Engine| -> Vec<Vec<u32>> {
        let mut outs = Vec::new();
        for (i, p) in prompts().into_iter().enumerate() {
            eng.submit(Request::greedy(i as u64, p, 8)).unwrap();
            let done = eng.run_to_completion().unwrap();
            outs.push(done[0].tokens.clone());
        }
        outs
    };
    let mut cold = Engine::native_synthetic(toy_cfg(), 7, 4.0, prefix_opts());
    let want = serve_all(&mut cold);
    assert_eq!(cold.metrics.tier_hits, 0);

    // Tiered engine: serve the first prompt, force every cached page to
    // disk, then serve the rest — they must promote from disk and still
    // produce exactly the same rollouts.
    let dir = tier_dir("cycle");
    let mut eng = Engine::native_synthetic(toy_cfg(), 7, 4.0, prefix_opts());
    assert_eq!(eng.attach_tier(&tier_opts(&dir, false)).unwrap(), 0);
    let mut outs = Vec::new();
    for (i, p) in prompts().into_iter().enumerate() {
        eng.submit(Request::greedy(i as u64, p, 8)).unwrap();
        let done = eng.run_to_completion().unwrap();
        outs.push(done[0].tokens.clone());
        // after every request, push the whole prefix cache to disk so the
        // next sharer MUST promote
        let demoted = eng.page_pool().demote_all();
        if i == 0 {
            assert!(demoted > 0, "first prompt's pages must be demotable");
        }
    }
    assert_eq!(outs, want, "demote→promote must not change a single token");
    assert!(eng.metrics.tier_hits >= 2, "later sharers promote (hits {})", eng.metrics.tier_hits);
    assert!(eng.metrics.pages_promoted >= 3, "3-page prefix promoted");
    assert!(eng.metrics.pages_demoted > 0);
    assert!(eng.metrics.bytes_on_disk > 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn snapshot_then_restore_warm_starts_a_fresh_engine() {
    let dir = tier_dir("warm");
    let all = prompts();
    // engine 1: serve the prefix, snapshot, shut down
    {
        let mut eng = Engine::native_synthetic(toy_cfg(), 9, 4.0, prefix_opts());
        eng.attach_tier(&tier_opts(&dir, true)).unwrap();
        eng.submit(Request::greedy(1, all[0].clone(), 8)).unwrap();
        eng.run_to_completion().unwrap();
        let (entries, bytes) = eng.snapshot_tier().unwrap().expect("snapshot configured");
        assert!(entries >= 3, "3-page prefix persisted (got {entries})");
        assert!(bytes > 0);
    }
    // reference for the second prompt: a cold engine with no tier
    let want = {
        let mut eng = Engine::native_synthetic(toy_cfg(), 9, 4.0, prefix_opts());
        eng.submit(Request::greedy(2, all[1].clone(), 8)).unwrap();
        eng.run_to_completion().unwrap()[0].tokens.clone()
    };
    // engine 2: fresh process image, same dir — restores the index and
    // serves the sharing prompt off promoted pages
    let mut eng = Engine::native_synthetic(toy_cfg(), 9, 4.0, prefix_opts());
    let restored = eng.attach_tier(&tier_opts(&dir, true)).unwrap();
    assert!(restored >= 3, "snapshot entries restored (got {restored})");
    let before = eng.metrics.prefill_tokens;
    eng.submit(Request::greedy(2, all[1].clone(), 8)).unwrap();
    let done = eng.run_to_completion().unwrap();
    assert_eq!(done[0].tokens, want, "warm-started rollout must match cold");
    assert!(eng.metrics.tier_hits >= 1, "restored entries promote on first hit");
    assert!(eng.metrics.pages_promoted >= 3);
    assert!(
        eng.metrics.prefill_tokens - before < all[1].len() as u64,
        "promoted prefix skips prefill work"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn a_mismatched_model_config_refuses_the_snapshot() {
    let dir = tier_dir("tagged");
    {
        let mut eng = Engine::native_synthetic(toy_cfg(), 3, 4.0, prefix_opts());
        eng.attach_tier(&tier_opts(&dir, true)).unwrap();
        eng.submit(Request::greedy(1, prompts()[0].clone(), 4)).unwrap();
        eng.run_to_completion().unwrap();
        eng.snapshot_tier().unwrap().unwrap();
    }
    // a different geometry must start cold, not adopt foreign pages
    let mut other_cfg = toy_cfg();
    other_cfg.n_layers = 1;
    let mut eng = Engine::native_synthetic(other_cfg, 3, 4.0, prefix_opts());
    assert_eq!(eng.attach_tier(&tier_opts(&dir, false)).unwrap(), 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn server_restart_over_tcp_reports_tier_hits_on_the_second_run() {
    // The CI smoke in test form: serve → shared-prefix workload → admin
    // shutdown (writes the snapshot) → new server on the same dir →
    // same workload → admin metrics shows tier_hits > 0, and the tokens
    // match run 1 exactly.
    let dir = tier_dir("restart");
    let cfg = toy_cfg();
    let factory = |dir: PathBuf, cfg: ModelConfig| -> polarquant::server::EngineFactory {
        Arc::new(move |w| {
            let mut eng = Engine::native_synthetic(cfg.clone(), 11, 4.0, prefix_opts());
            eng.attach_tier(&TierOpts {
                dir: dir.join(format!("worker-{w}")),
                max_bytes: u64::MAX,
                snapshot: true,
            })
            .unwrap();
            eng
        })
    };
    let run = |factory: polarquant::server::EngineFactory| -> (Vec<Vec<u32>>, f64) {
        let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
        let addr = handle.addr.clone();
        let mut client = Client::connect(&addr).unwrap();
        let mut outs = Vec::new();
        for p in prompts() {
            let reply = client.generate(&p, 6, Some(1)).unwrap();
            assert!(!reply.rejected && !reply.truncated);
            outs.push(reply.tokens);
        }
        let m = client.metrics().unwrap();
        let hits = m.get("tier_hits").and_then(|v| v.as_f64()).unwrap();
        // graceful shutdown: workers drain, snapshot, exit
        client.shutdown().unwrap();
        handle.wait();
        (outs, hits)
    };
    let (first, hits1) = run(factory(dir.clone(), cfg.clone()));
    assert_eq!(hits1, 0.0, "run 1 starts cold");
    let (second, hits2) = run(factory(dir.clone(), cfg));
    assert!(hits2 > 0.0, "run 2 must warm-start from the snapshot (tier_hits {hits2})");
    assert_eq!(first, second, "restart must not change any rollout");
    std::fs::remove_dir_all(&dir).unwrap();
}
