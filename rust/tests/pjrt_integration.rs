//! End-to-end integration: the AOT HLO artifacts (L1 Pallas kernels inside
//! the L2 JAX graphs) executed through the PJRT runtime, cross-checked
//! against the Rust-native model on the SAME weights (`weights_tiny.bin`).
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).

use std::path::PathBuf;

use polarquant::kvcache::SequenceCache;
use polarquant::model::{Model, ModelConfig, Weights};
use polarquant::runtime::executor::{batch_dense, split_prefill_kv};
use polarquant::runtime::{DecodeInputs, PjrtRuntime};
use polarquant::tensor::ops::{argmax, cosine};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn load_native(dir: &PathBuf) -> (ModelConfig, Model) {
    let m = polarquant::runtime::Manifest::load(dir).unwrap();
    let cfg = m.config.clone();
    let w = Weights::load(&dir.join(&m.weights.file), &m.weights.tensors, &cfg).unwrap();
    (cfg.clone(), Model::new(cfg, w))
}

#[test]
fn prefill_graph_matches_native_model() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut rt = PjrtRuntime::load(&dir).unwrap();
    let (cfg, mut native) = load_native(&dir);

    let prompt: Vec<u32> = (0..10u32).map(|i| (i * 37 + 5) % cfg.vocab as u32).collect();
    let t_bucket = 64usize;
    let mut tokens = vec![0i32; t_bucket];
    for (i, &t) in prompt.iter().enumerate() {
        tokens[i] = t as i32;
    }
    let out = rt
        .prefill(&format!("prefill_{}_b1_t{}", cfg.name, t_bucket), &tokens, &[prompt.len() as i32])
        .unwrap();

    let (logits_native, k_native, v_native) = native.prefill_kv(&prompt);
    let cos = cosine(&out.logits, &logits_native);
    assert!(cos > 0.999, "prefill logits cosine {cos}");
    assert_eq!(argmax(&out.logits), argmax(&logits_native));

    // K/V match on the valid (non-padded) region
    let t = prompt.len();
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_kv_heads {
            let pj = split_prefill_kv(&out.k, cfg.n_layers, 1, cfg.n_kv_heads, t_bucket, cfg.head_dim, 0);
            for n in 0..t {
                for j in 0..cfg.head_dim {
                    let a = pj[((l * cfg.n_kv_heads + h) * t_bucket + n) * cfg.head_dim + j];
                    let b = k_native[((l * cfg.n_kv_heads + h) * t + n) * cfg.head_dim + j];
                    assert!(
                        (a - b).abs() < 2e-3 * (1.0 + b.abs()),
                        "k mismatch l{l} h{h} n{n} j{j}: {a} vs {b}"
                    );
                }
            }
            let _ = v_native.len();
        }
    }
}

#[test]
fn decode_graph_matches_native_model_with_quantized_cache() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut rt = PjrtRuntime::load(&dir).unwrap();
    let (cfg, mut native) = load_native(&dir);

    // prompt long enough to quantize one full group (group=64)
    let prompt: Vec<u32> = (0..100u32).map(|i| (i * 13 + 1) % cfg.vocab as u32).collect();
    let mut cache = SequenceCache::new(cfg.cache_config(None));
    native.prefill(&prompt, &mut cache);
    assert_eq!(cache.quantized_len(), 64);
    assert_eq!(cache.resid_len(), 36);

    // native decode (clone cache so both paths see identical state)
    let mut cache_native = cache.clone();
    let next_tok = 7u32;
    let logits_native = native.decode_step(next_tok, &mut cache_native).to_vec();

    // PJRT decode on the same cache state
    let s_cap = 256;
    let r_cap = cfg.resid;
    let dense = cache.export_dense(s_cap, r_cap);
    let mut ins: DecodeInputs = batch_dense(
        &[&dense],
        cfg.n_layers,
        cfg.n_kv_heads,
        s_cap,
        r_cap,
        cfg.head_dim,
        cfg.group,
        1,
    );
    ins.tokens[0] = next_tok as i32;
    ins.positions[0] = cache.next_pos as i32;
    let out = rt.decode(&format!("decode_{}_b1_s{}", cfg.name, s_cap), &ins).unwrap();

    let cos = cosine(&out.logits, &logits_native);
    assert!(cos > 0.999, "decode logits cosine {cos}");
    assert_eq!(argmax(&out.logits), argmax(&logits_native));

    // the new K/V returned by the graph must match the native appended step
    let dh = cfg.head_dim;
    let lkv = cfg.n_layers * cfg.n_kv_heads;
    assert_eq!(out.new_k.len(), lkv * dh);
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_kv_heads {
            let st = cache_native.stream(l, h);
            // the step token landed in the residual tail
            let r = st.resid_len() - 1;
            for j in 0..dh {
                let a = out.new_k[(l * cfg.n_kv_heads + h) * dh + j];
                let b = st.resid_k()[r * dh + j];
                assert!(
                    (a - b).abs() < 2e-3 * (1.0 + b.abs()),
                    "new_k mismatch l{l} h{h} j{j}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn encode_graph_matches_rust_encoder() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut rt = PjrtRuntime::load(&dir).unwrap();
    let cfg = rt.manifest.config.clone();
    let spec = cfg.polar_spec();

    // bulk-encode bucket: (N=2, T=64, dh)
    let n = 2usize;
    let t = 64usize;
    let dh = cfg.head_dim;
    let mut rng = polarquant::util::rng::Rng::new(77);
    let k = rng.normal_vec(n * t * dh);
    let outs = rt.encode(&format!("encode_{}_n{}_t{}", cfg.name, n, t), &k).unwrap();
    // outputs: rho_code, theta_code, rho_z, rho_s, theta_z, theta_s
    assert_eq!(outs.len(), 6);
    for ni in 0..n {
        let enc = polarquant::quant::polar::encode(&k[ni * t * dh..(ni + 1) * t * dh], dh, &spec);
        assert_eq!(enc.groups.len(), t / spec.group);
        for (gi, grp) in enc.groups.iter().enumerate() {
            let rc = grp.rho_codes.unpack();
            let tc = grp.theta_codes.unpack();
            let d2 = dh / 2;
            // graph outputs stay token-major (the external contract);
            // the Rust encoder's planes are channel-major (pack v2)
            for tok in 0..spec.group {
                for j in 0..d2 {
                    let flat = (ni * t + gi * spec.group + tok) * d2 + j;
                    assert_eq!(
                        outs[0][flat] as u8, rc[j * spec.group + tok],
                        "rho code mismatch n{ni} g{gi} tok{tok} j{j}"
                    );
                    assert_eq!(outs[1][flat] as u8, tc[j * spec.group + tok], "theta code mismatch");
                }
            }
            for j in 0..d2 {
                let flat = (ni * (t / spec.group) + gi) * d2 + j;
                assert!((outs[2][flat] - grp.rho_z[j]).abs() < 1e-5);
                assert!((outs[3][flat] - grp.rho_s[j]).abs() < 1e-5);
                assert!((outs[4][flat] - grp.theta_z[j]).abs() < 1e-5);
                assert!((outs[5][flat] - grp.theta_s[j]).abs() < 1e-5);
            }
        }
    }
}
