//! Engine + server integration: requests flow through router -> engine ->
//! cache -> backend and come back with sane metrics, on both backends.

use std::path::PathBuf;
use std::sync::Arc;

use polarquant::coordinator::engine::{Backend, SnapKvOpts};
use polarquant::coordinator::{Engine, EngineOpts, Request, SchedMode, TenancyOpts, TierOpts};
use polarquant::model::ModelConfig;
use polarquant::server::{serve, Client, GenParams};
use polarquant::util::json::Value;
use polarquant::workload::{PromptKind, RequestGen};

/// Fleet-total counter from an `{"admin":"metrics"}` reply.
fn metric(v: &Value, key: &str) -> f64 {
    v.get(key).and_then(|x| x.as_f64()).unwrap_or(f64::NAN)
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn toy_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::tiny();
    cfg.n_layers = 2;
    cfg.vocab = 64;
    cfg.d_model = 32;
    cfg.n_heads = 4;
    cfg.n_kv_heads = 2;
    cfg.head_dim = 16;
    cfg.ffn = 48;
    cfg.group = 8;
    cfg.resid = 16;
    cfg
}

#[test]
fn pjrt_engine_serves_batched_requests() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut eng = Engine::pjrt_from_artifacts(&dir, EngineOpts::default()).unwrap();
    let vocab = eng.cfg.vocab;
    let mut gen = RequestGen::new(vocab, 11);
    for _ in 0..5 {
        let req = gen.request(PromptKind::Mixed { lo: 4, hi: 40 }, 8);
        eng.submit(req).unwrap();
    }
    let done = eng.run_to_completion().unwrap();
    assert_eq!(done.len(), 5);
    for c in &done {
        assert_eq!(c.tokens.len(), 8, "req {}", c.id);
        assert!(!c.truncated);
    }
    // batching actually happened (mean decode batch > 1)
    assert!(eng.metrics.mean_batch() > 1.0, "mean batch {}", eng.metrics.mean_batch());
}

#[test]
fn pjrt_and_native_engines_agree_on_greedy_tokens() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = Engine::pjrt_from_artifacts(&dir, EngineOpts::default()).unwrap();
    let mut native = Engine::native_from_artifacts(&dir, EngineOpts::default()).unwrap();
    let prompt: Vec<u32> = (0..90u32).map(|i| (i * 7 + 3) % 512).collect();
    pjrt.submit(Request::greedy(1, prompt.clone(), 12)).unwrap();
    native.submit(Request::greedy(1, prompt, 12)).unwrap();
    let a = pjrt.run_to_completion().unwrap();
    let b = native.run_to_completion().unwrap();
    // same weights, same quantized cache semantics -> same greedy tokens
    // (fp32 vs XLA op-order differences can flip a near-tie late in the
    // rollout; demand agreement on a long prefix)
    let n = a[0].tokens.len().min(b[0].tokens.len()).min(8);
    assert_eq!(a[0].tokens[..n], b[0].tokens[..n]);
}

#[test]
fn decode_crosses_group_boundaries_and_buckets() {
    let Some(dir) = artifacts_dir() else { return };
    // long generation forces residual -> group finalization mid-flight
    let mut eng = Engine::pjrt_from_artifacts(&dir, EngineOpts::default()).unwrap();
    let prompt: Vec<u32> = (0..60u32).collect();
    eng.submit(Request::greedy(1, prompt, 80)).unwrap();
    let done = eng.run_to_completion().unwrap();
    assert_eq!(done[0].tokens.len(), 80);
    assert!(!done[0].truncated);
}

#[test]
fn server_end_to_end_native() {
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        Engine::native_synthetic(cfg.clone(), 100 + w as u64, 4.0, EngineOpts::default())
    });
    let handle = serve(factory, "127.0.0.1:0", 2).unwrap();
    let addr = handle.addr.clone();

    let mut threads = Vec::new();
    for t in 0..4 {
        let addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let prompt: Vec<u32> = (0..10).map(|i| (i * 3 + t) % 64).collect();
            let reply = client.generate(&prompt, 6, Some(t as u64)).unwrap();
            assert_eq!(reply.tokens.len(), 6);
            reply.worker
        }));
    }
    let workers: Vec<usize> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    // both workers participated (4 sessions, least-loaded spread)
    assert!(workers.iter().any(|&w| w == 0) && workers.iter().any(|&w| w == 1));
    handle.stop();
}

#[test]
fn server_session_affinity() {
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        Engine::native_synthetic(cfg.clone(), 200 + w as u64, 4.0, EngineOpts::default())
    });
    let handle = serve(factory, "127.0.0.1:0", 3).unwrap();
    eprintln!("[affinity] server up at {}", handle.addr);
    let mut client = Client::connect(&handle.addr).unwrap();
    let first = client.generate(&[1, 2, 3], 2, Some(99)).unwrap();
    eprintln!("[affinity] first reply from worker {}", first.worker);
    for i in 0..3 {
        let r = client.generate(&[4, 5, 6], 2, Some(99)).unwrap();
        eprintln!("[affinity] reply {i} from worker {}", r.worker);
        assert_eq!(r.worker, first.worker, "session must stick to one worker");
    }
    eprintln!("[affinity] stopping");
    handle.stop();
}

#[test]
fn every_client_gets_a_reply_when_the_queue_is_full() {
    // Regression: with max_queue = 0 every submit is rejected.  The old
    // worker_loop idle branch (recv_timeout) dropped the reply Sender on
    // rejection, so handle_conn's rx.recv() failed and the connection
    // died with no response — clients hung or errored.  Now every client
    // must receive an explicit rejected reply, on BOTH intake paths.
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let mut opts = EngineOpts::default();
        opts.admission.max_queue = 0;
        Engine::native_synthetic(cfg.clone(), 300 + w as u64, 4.0, opts)
    });
    let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
    // sequential requests land on the idle recv_timeout branch (the
    // engine drains instantly between them)
    let mut client = Client::connect(&handle.addr).unwrap();
    for i in 0..3 {
        let reply = client.generate(&[1, 2, 3], 4, None).unwrap();
        assert!(reply.rejected, "request {i} must be rejected, not hang");
        assert_eq!(reply.reason.as_deref(), Some("queue_full"));
        assert!(!reply.truncated, "rejection must not masquerade as truncation");
        assert!(reply.tokens.is_empty());
        assert_eq!(reply.prompt_len, 3, "rejected reply keeps the real prompt_len");
    }
    // a concurrent burst exercises the drain-loop path too
    let mut threads = Vec::new();
    for _ in 0..4 {
        let addr = handle.addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            c.generate(&[5, 6], 4, None).unwrap()
        }));
    }
    for t in threads {
        let reply = t.join().unwrap();
        assert!(reply.rejected && !reply.truncated);
    }
    handle.stop();
}

#[test]
fn empty_prompt_is_rejected_with_reason_over_the_wire() {
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        Engine::native_synthetic(cfg.clone(), 400 + w as u64, 4.0, EngineOpts::default())
    });
    let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    // a {} request parses to an empty prompt — previously this panicked
    // the engine thread mid-prefill and killed every later connection
    let reply = client.generate(&[], 4, None).unwrap();
    assert!(reply.rejected);
    assert_eq!(reply.reason.as_deref(), Some("empty_prompt"));
    // the worker survives: a valid request still completes
    let ok = client.generate(&[1, 2, 3], 4, None).unwrap();
    assert!(!ok.rejected);
    assert_eq!(ok.tokens.len(), 4);
    handle.stop();
}

#[test]
fn chunked_prefill_server_matches_unchunked() {
    // End-to-end through the TCP front-end: same session, same prompts,
    // chunked vs unchunked engines must return identical greedy tokens.
    let run = |chunk: usize| {
        let cfg = toy_cfg();
        let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = chunk;
            opts.decode_workers = 2;
            Engine::native_synthetic(cfg.clone(), 500 + w as u64, 4.0, opts)
        });
        let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
        let mut client = Client::connect(&handle.addr).unwrap();
        let mut out = Vec::new();
        for t in 0..3u32 {
            let prompt: Vec<u32> = (0..30).map(|i| (i * 3 + t) % 64).collect();
            let reply = client.generate(&prompt, 8, Some(t as u64)).unwrap();
            assert!(!reply.rejected && !reply.truncated);
            out.push(reply.tokens);
        }
        handle.stop();
        out
    };
    assert_eq!(run(0), run(7));
}

#[test]
fn prefix_cache_server_end_to_end_matches_cold_server() {
    // Through the TCP front-end: a server with prefix caching must return
    // exactly the tokens a cold server returns, while actually sharing
    // pages for repeated prefixes.
    let run = |prefix: bool| {
        let cfg = toy_cfg();
        let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = 16; // multiple of group=8
            opts.prefill_quantize_eagerly = true; // same math prefix on/off
            opts.prefix_cache = prefix;
            Engine::native_synthetic(cfg.clone(), 600 + w as u64, 4.0, opts)
        });
        let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
        let mut client = Client::connect(&handle.addr).unwrap();
        let system: Vec<u32> = (0..32).map(|i| (i * 7 % 64) as u32).collect();
        let mut out = Vec::new();
        for t in 0..3u32 {
            // shared 32-token "system prompt" + distinct user tail
            let prompt: Vec<u32> =
                system.iter().cloned().chain([t + 1, t + 2, t + 3]).collect();
            let reply = client.generate(&prompt, 6, Some(1)).unwrap();
            assert!(!reply.rejected && !reply.truncated);
            out.push(reply.tokens);
        }
        handle.stop();
        out
    };
    assert_eq!(run(false), run(true));
}

#[test]
fn preemption_under_prefix_caching_recovers_through_cached_pages() {
    // Eager/prefix mode + a tiny pool: preempted sequences re-attach to
    // their own still-cached prompt pages on recovery, so re-prefill is
    // nearly free — and everything still completes.
    let mut opts = EngineOpts::default();
    opts.prefill_chunk = 8;
    opts.prefix_cache = true;
    opts.cache_pages = 6;
    let mut eng = Engine::native_synthetic(toy_cfg(), 93, 4.0, opts);
    // warm the prefix index with the shared prompt
    let prompt: Vec<u32> = (0..16).map(|i| (i * 3 % 64) as u32).collect();
    eng.submit(Request::greedy(1, prompt.clone(), 4)).unwrap();
    eng.run_to_completion().unwrap();
    assert!(eng.cache_report().pages > 0 || eng.metrics.pages_in_use > 0);
    // two long decoders sharing that prompt, pool too small for both
    eng.submit(Request::greedy(2, prompt.clone(), 24)).unwrap();
    eng.step().unwrap();
    eng.submit(Request::greedy(3, prompt.clone(), 24)).unwrap();
    let mut done = eng.run_to_completion().unwrap();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 2);
    for c in &done {
        assert_eq!(c.tokens.len(), 24, "req {} must complete fully", c.id);
        assert!(!c.rejected, "pool pressure must preempt, not reject");
    }
    assert!(eng.metrics.prefix_hits >= 2, "both sharers attach to cached prompt pages");
}

#[test]
fn streaming_greedy_is_bit_identical_to_v1_one_shot_over_tcp() {
    // The tentpole acceptance check: the SAME prompt through the v1
    // one-shot path and the v2 streaming path (default GenOptions ==
    // greedy) must produce identical tokens, with the streamed tokens
    // arriving one event at a time and agreeing with the final reply.
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        opts.decode_workers = 2;
        Engine::native_synthetic(cfg.clone(), 800 + w as u64, 4.0, opts)
    });
    let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let prompt: Vec<u32> = (0..20).map(|i| (i * 3 % 64) as u32).collect();

    let one_shot = client.generate(&prompt, 10, None).unwrap();
    assert!(!one_shot.rejected);
    assert_eq!(one_shot.finish_reason, "length");

    let mut streamed = Vec::new();
    let reply = client
        .generate_stream(&prompt, &GenParams::greedy(10), None, |t| {
            assert_eq!(t.index, streamed.len(), "tokens stream in order");
            assert!(t.logprob.is_finite() && t.logprob <= 0.0);
            streamed.push(t.token);
            true
        })
        .unwrap();
    assert_eq!(streamed, one_shot.tokens, "streamed == one-shot greedy");
    assert_eq!(reply.tokens, one_shot.tokens, "done frame agrees with the stream");
    assert_eq!(reply.finish_reason, "length");
    handle.stop();
}

#[test]
fn mid_stream_cancel_frees_pages_over_tcp() {
    // Cancellation end-to-end: cancel after 3 streamed tokens of a
    // 2048-token budget (large enough that the engine cannot finish
    // before the cancel frame lands); the reply must say "cancelled"
    // with a partial generation, and the worker's page accounting must
    // return exactly to baseline (no other traffic, prefix off -> zero).
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        Engine::native_synthetic(cfg.clone(), 900 + w as u64, 4.0, opts)
    });
    let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let prompt: Vec<u32> = (0..24).map(|i| (i * 5 % 64) as u32).collect();
    let mut seen = 0usize;
    let reply = client
        .generate_stream(&prompt, &GenParams::greedy(2048), None, |_| {
            seen += 1;
            seen < 3
        })
        .unwrap();
    assert_eq!(reply.finish_reason, "cancelled");
    assert!(!reply.tokens.is_empty(), "partial generation comes back");
    assert!(
        reply.tokens.len() < 2048,
        "cancel must cut the stream short (got {})",
        reply.tokens.len()
    );
    let m = client.metrics().unwrap();
    assert_eq!(metric(&m, "requests_cancelled"), 1.0);
    assert_eq!(metric(&m, "pages_in_use"), 0.0, "cancel leaked pages");
    handle.stop();
}

#[test]
fn finish_reasons_thread_through_the_wire() {
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        Engine::native_synthetic(cfg.clone(), 1000 + w as u64, 4.0, opts)
    });
    let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let prompt = vec![7u32, 8, 9, 10];
    // length: runs out of budget
    let free = client.generate_stream(&prompt, &GenParams::greedy(6), None, |_| true).unwrap();
    assert_eq!(free.finish_reason, "length");
    assert_eq!(free.tokens.len(), 6);
    // stop: stop on the rollout's 2nd token (greedy == deterministic)
    let stop = free.tokens[1];
    if !free.tokens[..1].contains(&stop) {
        let mut p = GenParams::greedy(6);
        p.stop = vec![stop];
        let stopped = client.generate_stream(&prompt, &p, None, |_| true).unwrap();
        assert_eq!(stopped.finish_reason, "stop");
        assert_eq!(stopped.tokens, free.tokens[..2].to_vec(), "stop token included");
    }
    // rejected: empty prompt
    let rej = client.generate_stream(&[], &GenParams::greedy(4), None, |_| true).unwrap();
    assert!(rej.rejected);
    assert_eq!(rej.finish_reason, "rejected");
    assert_eq!(rej.reason.as_deref(), Some("empty_prompt"));
    // v1 replies carry the reason too (additive field)
    let v1 = client.generate(&prompt, 3, None).unwrap();
    assert_eq!(v1.finish_reason, "length");
    handle.stop();
}

#[test]
fn sampled_requests_are_reproducible_over_the_wire() {
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        opts.decode_workers = 2;
        Engine::native_synthetic(cfg.clone(), 1100 + w as u64, 4.0, opts)
    });
    let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let prompt: Vec<u32> = (0..12).map(|i| (i * 7 % 64) as u32).collect();
    let mut params = GenParams::greedy(8);
    params.temperature = 0.9;
    params.top_k = 16;
    params.top_p = 0.95;
    params.seed = 1234;
    let a = client.generate_stream(&prompt, &params, None, |_| true).unwrap();
    let b = client.generate_stream(&prompt, &params, None, |_| true).unwrap();
    assert_eq!(a.tokens, b.tokens, "same GenOptions{{seed}} -> bit-identical rollout");
    params.seed = 4321;
    let c = client.generate_stream(&prompt, &params, None, |_| true).unwrap();
    assert_eq!(c.tokens.len(), 8);
    handle.stop();
}

#[test]
fn three_turn_session_reuses_kv_and_close_frees_it() {
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        Engine::native_synthetic(cfg.clone(), 1200 + w as u64, 4.0, opts)
    });
    let handle = serve(factory, "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let sid = client.open_session().unwrap();
    assert!(sid > 0);
    let turns: Vec<Vec<u32>> =
        vec![(0..16).map(|i| (i * 3 % 64) as u32).collect(), vec![1, 2, 3], vec![60, 61]];
    let mut workers = Vec::new();
    for (i, t) in turns.iter().enumerate() {
        let reply = client.turn(sid, t, &GenParams::greedy(6), |_| true).unwrap();
        assert!(!reply.rejected, "turn {i} rejected: {:?}", reply.reason);
        assert_eq!(reply.tokens.len(), 6, "turn {i}");
        workers.push(reply.worker);
    }
    assert!(workers.windows(2).all(|w| w[0] == w[1]), "turns must stick to one worker");
    let m = client.metrics().unwrap();
    assert_eq!(metric(&m, "session_turns"), 3.0);
    assert!(
        metric(&m, "prefix_tokens_reused") > 0.0,
        "turn 2+ must reuse the conversation's KV chain"
    );
    assert!(metric(&m, "pages_in_use") > 0.0, "the session chain holds pages while open");
    client.close_session(sid).unwrap();
    // the close is async on the worker; poll briefly for the free
    let mut freed = false;
    for _ in 0..50 {
        let m = client.metrics().unwrap();
        if metric(&m, "pages_in_use") == 0.0 {
            freed = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    assert!(freed, "closing the session must return the pool to baseline");
    handle.stop();
}

#[test]
fn snapkv_native_engine_end_to_end() {
    let cfg = toy_cfg();
    let mut opts = EngineOpts::default();
    opts.snapkv = Some(SnapKvOpts { budget: 12, window: 4 });
    let mut eng = Engine::native_synthetic(cfg, 7, 6.0, opts);
    let mut gen = RequestGen::new(64, 3);
    let req = gen.request(PromptKind::Needle { len: 48, needle: 63 }, 6);
    eng.submit(req).unwrap();
    let done = eng.run_to_completion().unwrap();
    assert_eq!(done[0].tokens.len(), 6);
    // 48-token prompt compressed to the 12-token budget
    assert_eq!(eng.metrics.snapkv_tokens_dropped, 48 - 12);
    assert!(eng.metrics.summary().contains("snapkv dropped 36 tok"));
}

#[test]
fn snapkv_over_the_wire_reports_tokens_dropped() {
    // The serve-path wiring for --snapkv-budget/--snapkv-window: a
    // compressed prompt decodes normally and the admin metrics carry the
    // dropped-token count.
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let mut opts = EngineOpts::default();
        opts.snapkv = Some(SnapKvOpts { budget: 16, window: 4 });
        Engine::native_synthetic(cfg.clone(), 700 + w as u64, 4.0, opts)
    });
    let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let prompt: Vec<u32> = (0..40).map(|i| (i * 3 % 64) as u32).collect();
    let reply = client.generate(&prompt, 5, None).unwrap();
    assert!(!reply.rejected && !reply.truncated);
    assert_eq!(reply.tokens.len(), 5, "compressed prompt must still decode");
    let m = client.metrics().unwrap();
    let dropped = m.get("snapkv_tokens_dropped").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(dropped, (40 - 16) as f64, "40-token prompt at budget 16");
    handle.stop();
}

#[test]
fn kernel_choice_is_reported_and_bit_invisible() {
    use polarquant::quant::KernelKind;
    // same weights, same prompts, different --kernel: the rollouts must
    // be token-identical (kernels are bit-exact), and the admin metrics
    // must name the kernel each worker runs.
    let cfg = toy_cfg();
    let serve_with = |kernel: KernelKind| -> (Vec<Vec<u32>>, String) {
        let cfg = cfg.clone();
        let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
            let mut opts = EngineOpts::default();
            opts.kernel = kernel;
            opts.decode_workers = 2; // pool forks must inherit the kernel
            Engine::native_synthetic(cfg.clone(), 1100 + w as u64, 4.0, opts)
        });
        let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
        let mut client = Client::connect(&handle.addr).unwrap();
        let mut outs = Vec::new();
        for t in 0..3u32 {
            let prompt: Vec<u32> = (0..20).map(|i| (i * 7 + t as usize) as u32 % 64).collect();
            let reply = client.generate(&prompt, 8, None).unwrap();
            assert!(!reply.rejected);
            outs.push(reply.tokens);
        }
        let m = client.metrics().unwrap();
        let name = m
            .get("workers")
            .and_then(|w| w.as_arr())
            .and_then(|ws| ws.first())
            .and_then(|w| w.get("kernel"))
            .and_then(|k| k.as_str())
            .expect("metrics reply carries the worker's kernel name")
            .to_string();
        handle.stop();
        (outs, name)
    };
    let (scalar_outs, scalar_name) = serve_with(KernelKind::Scalar);
    assert_eq!(scalar_name, "scalar");
    let (auto_outs, auto_name) = serve_with(KernelKind::Auto);
    assert!(auto_name == "scalar" || auto_name == "simd", "{auto_name}");
    assert_eq!(
        scalar_outs, auto_outs,
        "kernel choice must never change a token (scalar vs {auto_name})"
    );
}

#[test]
fn engine_rejects_snapkv_on_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let mut opts = EngineOpts::default();
    opts.snapkv = Some(SnapKvOpts { budget: 8, window: 2 });
    assert!(Engine::pjrt_from_artifacts(&dir, opts).is_err());
}

#[test]
fn tenant_throttling_and_per_tenant_metrics_over_the_wire() {
    // A flooding tenant hits its admission bucket and gets typed
    // `tenant_throttled` rejections; a second tenant still admits; the
    // admin reply carries the fleet total AND the per-tenant breakdown.
    let cfg = toy_cfg();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        opts.sched = SchedMode::Wfq;
        let mut eng = Engine::native_synthetic(cfg.clone(), 1300 + w as u64, 4.0, opts);
        let mut ten = TenancyOpts::default();
        ten.rate = 1e-9; // effectively no refill within the test
        ten.burst = 2.0;
        ten.weights =
            [("flood".to_string(), 1u32), ("calm".to_string(), 4u32)].into_iter().collect();
        eng.set_tenancy(&ten);
        eng
    });
    let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
    let mut client = Client::connect(&handle.addr).unwrap();
    let prompt: Vec<u32> = (0..12).map(|i| (i * 5 % 64) as u32).collect();
    let mut flood = GenParams::greedy(4);
    flood.tenant = "flood".to_string();
    let mut rejected = 0;
    for _ in 0..4 {
        let r = client.generate_stream(&prompt, &flood, None, |_| true).unwrap();
        if r.rejected {
            assert_eq!(r.reason.as_deref(), Some("tenant_throttled"));
            rejected += 1;
        } else {
            assert_eq!(r.tokens.len(), 4);
        }
    }
    assert_eq!(rejected, 2, "burst 2 admits exactly two flood requests");
    // throttling one tenant must not touch another's admission
    let mut calm = GenParams::greedy(4);
    calm.tenant = "calm".to_string();
    let r = client.generate_stream(&prompt, &calm, None, |_| true).unwrap();
    assert!(!r.rejected, "calm tenant throttled: {:?}", r.reason);
    assert_eq!(r.tokens.len(), 4);
    let m = client.metrics().unwrap();
    assert_eq!(metric(&m, "tenant_throttled"), 2.0);
    assert_eq!(metric(&m, "requests_rejected"), 2.0);
    let w0 = m.get("workers").and_then(|w| w.as_arr()).and_then(|ws| ws.first()).unwrap();
    let flood_stats =
        w0.get("tenants").and_then(|t| t.get("flood")).expect("flood tenant listed");
    assert_eq!(metric(flood_stats, "admitted"), 2.0);
    assert_eq!(metric(flood_stats, "throttled"), 2.0);
    assert_eq!(metric(flood_stats, "finished"), 2.0);
    let calm_stats = w0.get("tenants").and_then(|t| t.get("calm")).expect("calm tenant listed");
    assert_eq!(metric(calm_stats, "admitted"), 1.0);
    assert_eq!(metric(calm_stats, "throttled"), 0.0);
    handle.stop();
}

#[test]
fn idle_session_ttl_reaps_and_warm_restarts_over_the_wire() {
    // --session-ttl through the TCP front-end: after turn 1 the idle
    // worker loop demotes the session chain to the disk tier; turn 2
    // restores it and must produce exactly the tokens a no-TTL server
    // produces (the reap is invisible except to the counters).
    let base_dir =
        std::env::temp_dir().join(format!("polarquant-wire-ttl-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base_dir);
    let run = |tier: Option<PathBuf>| -> (Vec<u32>, Vec<u32>, f64) {
        let cfg = toy_cfg();
        let reap = tier.is_some();
        let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = 8;
            opts.prefix_cache = true;
            let mut eng = Engine::native_synthetic(cfg.clone(), 1400 + w as u64, 4.0, opts);
            if let Some(d) = &tier {
                eng.attach_tier(&TierOpts {
                    dir: d.join(format!("w{w}")),
                    max_bytes: u64::MAX,
                    snapshot: false,
                })
                .unwrap();
                let mut ten = TenancyOpts::default();
                ten.session_ttl = Some(std::time::Duration::from_secs(0));
                eng.set_tenancy(&ten);
            }
            eng
        });
        let handle = serve(factory, "127.0.0.1:0", 1).unwrap();
        let mut client = Client::connect(&handle.addr).unwrap();
        let sid = client.open_session().unwrap();
        let t1: Vec<u32> = (0..16).map(|i| (i * 3 % 64) as u32).collect();
        let r1 = client.turn(sid, &t1, &GenParams::greedy(6), |_| true).unwrap();
        assert!(!r1.rejected, "turn 1 rejected: {:?}", r1.reason);
        if reap {
            // ttl 0: the idle sweep lands within a few 20ms worker spins
            let mut reaped = false;
            for _ in 0..200 {
                let m = client.metrics().unwrap();
                if metric(&m, "sessions_reaped") >= 1.0 {
                    reaped = true;
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            assert!(reaped, "idle session must reap to the tier");
        }
        let r2 = client.turn(sid, &[1, 2, 3], &GenParams::greedy(6), |_| true).unwrap();
        assert!(!r2.rejected, "turn 2 rejected: {:?}", r2.reason);
        let m = client.metrics().unwrap();
        let restored = metric(&m, "sessions_restored");
        handle.stop();
        (r1.tokens, r2.tokens, restored)
    };
    let (base1, base2, base_restored) = run(None);
    assert_eq!(base_restored, 0.0);
    let (warm1, warm2, restored) = run(Some(base_dir.clone()));
    assert_eq!(warm1, base1, "turn 1 is untouched by the TTL config");
    assert_eq!(warm2, base2, "the restored chain must continue bit-identically");
    assert_eq!(restored, 1.0, "turn 2 must come back through the tier");
    let _ = std::fs::remove_dir_all(&base_dir);
}

#[test]
fn backend_enum_is_constructible() {
    // docs claim both variants are public API
    let cfg = toy_cfg();
    let w = polarquant::model::Weights::synthetic(&cfg, 1, 2.0);
    let model = polarquant::model::Model::new(cfg.clone(), w);
    let _b = Backend::Native(Box::new(model));
}
