//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  Every graph input is listed positionally with
//! name/shape/dtype — marshalling is table-driven, never guessed.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::ModelConfig;
use crate::util::json::{self, Value};

#[derive(Clone, Debug, PartialEq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct GraphInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    /// (batch, seq) bucket
    pub batch: usize,
    pub seq: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct WeightsInfo {
    pub file: String,
    pub tensors: Value,
    pub total_bytes: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub weights: WeightsInfo,
    pub graphs: Vec<GraphInfo>,
}

fn parse_specs(v: &Value) -> Result<Vec<TensorSpec>> {
    let arr = v.as_arr().context("expected array of tensor specs")?;
    arr.iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e.str_or("name", ""),
                shape: e
                    .req("shape")
                    .map_err(anyhow::Error::msg)?
                    .usize_vec()
                    .context("bad shape")?,
                dtype: Dtype::parse(&e.str_or("dtype", "float32"))?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let v = json::parse(&text).map_err(anyhow::Error::msg)?;
        let config = ModelConfig::from_json(v.req("config").map_err(anyhow::Error::msg)?)?;
        let w = v.req("weights").map_err(anyhow::Error::msg)?;
        let weights = WeightsInfo {
            file: w.str_or("file", "weights.bin"),
            tensors: w.clone(),
            total_bytes: w.usize_or("total_bytes", 0),
        };
        let mut graphs = Vec::new();
        for g in v
            .req("graphs")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("graphs not an array")?
        {
            let bucket = g.req("bucket").map_err(anyhow::Error::msg)?;
            graphs.push(GraphInfo {
                name: g.str_or("name", ""),
                file: g.str_or("file", ""),
                kind: g.str_or("kind", ""),
                batch: bucket.usize_or("batch", 1),
                seq: bucket.usize_or("seq", 0),
                inputs: parse_specs(g.req("inputs").map_err(anyhow::Error::msg)?)?,
                outputs: parse_specs(g.req("outputs").map_err(anyhow::Error::msg)?)?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), config, weights, graphs })
    }

    pub fn graph(&self, name: &str) -> Option<&GraphInfo> {
        self.graphs.iter().find(|g| g.name == name)
    }

    /// Graphs of a kind, sorted by (batch, seq).
    pub fn graphs_of_kind(&self, kind: &str) -> Vec<&GraphInfo> {
        let mut v: Vec<&GraphInfo> = self.graphs.iter().filter(|g| g.kind == kind).collect();
        v.sort_by_key(|g| (g.batch, g.seq));
        v
    }

    /// Smallest bucket of `kind` that fits (batch, seq).
    pub fn pick_bucket(&self, kind: &str, batch: usize, seq: usize) -> Option<&GraphInfo> {
        self.graphs_of_kind(kind)
            .into_iter()
            .filter(|g| g.batch >= batch && g.seq >= seq)
            .min_by_key(|g| (g.batch, g.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.name, "tiny");
        assert!(!m.graphs_of_kind("decode").is_empty());
        assert!(!m.graphs_of_kind("prefill").is_empty());
        let g = m.graphs_of_kind("decode")[0];
        assert_eq!(g.inputs[0].name, "tokens");
        assert_eq!(g.inputs[0].dtype, Dtype::I32);
        // decode graph carries the weight inputs at the tail
        assert_eq!(g.inputs.last().unwrap().name, "lm_head");
    }

    #[test]
    fn bucket_selection() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let g = m.pick_bucket("decode", 1, 100).unwrap();
        assert!(g.batch >= 1 && g.seq >= 100);
        // asking beyond every bucket yields None
        assert!(m.pick_bucket("decode", 64, 1 << 20).is_none());
    }
}
