//! Host-side marshalling between the coordinator's per-sequence caches and
//! the fixed-shape AOT graph layouts.  Pure Rust — shared by the real PJRT
//! executor (`--features pjrt`) and the offline stub, and unit-testable
//! without any XLA runtime.

use crate::kvcache::seq::DenseCache;

/// Batched decode-step inputs, already in graph layout.
#[derive(Clone, Debug, Default)]
pub struct DecodeInputs {
    pub tokens: Vec<i32>,
    pub positions: Vec<i32>,
    pub cache_len: Vec<i32>,
    pub resid_len: Vec<i32>,
    pub theta_code: Vec<i32>,
    pub rho_code: Vec<i32>,
    pub rho_z: Vec<f32>,
    pub rho_s: Vec<f32>,
    pub theta_z: Vec<f32>,
    pub theta_s: Vec<f32>,
    pub v_cache: Vec<f32>,
    pub resid_k: Vec<f32>,
    pub resid_v: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct DecodeOutputs {
    /// (B, vocab)
    pub logits: Vec<f32>,
    /// (L, B, Kv, dh)
    pub new_k: Vec<f32>,
    pub new_v: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct PrefillOutputs {
    /// (B, vocab)
    pub logits: Vec<f32>,
    /// (L, B, Kv, T, dh)
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Batch per-sequence dense caches into graph layout (L, B, Kv, ...).
pub fn batch_dense(
    caches: &[&DenseCache],
    n_layers: usize,
    n_kv: usize,
    s_cap: usize,
    r_cap: usize,
    d: usize,
    group: usize,
    pad_to_batch: usize,
) -> DecodeInputs {
    let b_real = caches.len();
    let b = pad_to_batch.max(b_real);
    let d2 = d / 2;
    let gcap = s_cap / group;
    let mut ins = DecodeInputs {
        tokens: vec![0; b],
        positions: vec![0; b],
        cache_len: vec![0; b],
        resid_len: vec![0; b],
        theta_code: vec![0; n_layers * b * n_kv * s_cap * d2],
        rho_code: vec![0; n_layers * b * n_kv * s_cap * d2],
        rho_z: vec![0.0; n_layers * b * n_kv * gcap * d2],
        rho_s: vec![1e-8; n_layers * b * n_kv * gcap * d2],
        theta_z: vec![0.0; n_layers * b * n_kv * gcap * d2],
        theta_s: vec![1e-8; n_layers * b * n_kv * gcap * d2],
        v_cache: vec![0.0; n_layers * b * n_kv * s_cap * d],
        resid_k: vec![0.0; n_layers * b * n_kv * r_cap * d],
        resid_v: vec![0.0; n_layers * b * n_kv * r_cap * d],
    };
    for (bi, dc) in caches.iter().enumerate() {
        ins.cache_len[bi] = dc.cache_len as i32;
        ins.resid_len[bi] = dc.resid_len as i32;
        for l in 0..n_layers {
            for h in 0..n_kv {
                let src = l * n_kv + h; // per-seq (L, Kv, ...) index base
                let dst = (l * b + bi) * n_kv + h; // batched (L, B, Kv, ...)
                let (cs, cd) = (src * s_cap * d2, dst * s_cap * d2);
                ins.theta_code[cd..cd + s_cap * d2]
                    .copy_from_slice(&dc.theta_code[cs..cs + s_cap * d2]);
                ins.rho_code[cd..cd + s_cap * d2]
                    .copy_from_slice(&dc.rho_code[cs..cs + s_cap * d2]);
                let (ps, pd) = (src * gcap * d2, dst * gcap * d2);
                ins.rho_z[pd..pd + gcap * d2].copy_from_slice(&dc.rho_z[ps..ps + gcap * d2]);
                ins.rho_s[pd..pd + gcap * d2].copy_from_slice(&dc.rho_s[ps..ps + gcap * d2]);
                ins.theta_z[pd..pd + gcap * d2]
                    .copy_from_slice(&dc.theta_z[ps..ps + gcap * d2]);
                ins.theta_s[pd..pd + gcap * d2]
                    .copy_from_slice(&dc.theta_s[ps..ps + gcap * d2]);
                let (vs, vd) = (src * s_cap * d, dst * s_cap * d);
                ins.v_cache[vd..vd + s_cap * d].copy_from_slice(&dc.v[vs..vs + s_cap * d]);
                let (rs, rd) = (src * r_cap * d, dst * r_cap * d);
                ins.resid_k[rd..rd + r_cap * d].copy_from_slice(&dc.resid_k[rs..rs + r_cap * d]);
                ins.resid_v[rd..rd + r_cap * d].copy_from_slice(&dc.resid_v[rs..rs + r_cap * d]);
            }
        }
    }
    ins
}

/// Slice one sequence's (L, Kv, T, d) K or V block out of a batched
/// prefill output (L, B, Kv, T, d).
pub fn split_prefill_kv(
    batched: &[f32],
    n_layers: usize,
    batch: usize,
    n_kv: usize,
    t: usize,
    d: usize,
    b: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n_layers * n_kv * t * d];
    for l in 0..n_layers {
        for h in 0..n_kv {
            let src = (((l * batch + b) * n_kv) + h) * t * d;
            let dst = (l * n_kv + h) * t * d;
            out[dst..dst + t * d].copy_from_slice(&batched[src..src + t * d]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_prefill_layout() {
        // L=1, B=2, Kv=1, T=2, d=2 -> batched len 8
        let batched: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let b0 = split_prefill_kv(&batched, 1, 2, 1, 2, 2, 0);
        let b1 = split_prefill_kv(&batched, 1, 2, 1, 2, 2, 1);
        assert_eq!(b0, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(b1, vec![4.0, 5.0, 6.0, 7.0]);
    }
}
