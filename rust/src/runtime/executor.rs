//! PJRT executor: compile HLO-text artifacts, marshal literals, execute.
//!
//! v1 marshals host arrays as `xla::Literal`s per call (weights included);
//! the §Perf pass keeps weights resident as device buffers.  Executables
//! are compiled lazily on first use and cached.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{Dtype, GraphInfo, Manifest};
use crate::kvcache::seq::DenseCache;

pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    /// weight literals in manifest (= graph input) order
    weights: Vec<xla::Literal>,
    weight_names: Vec<String>,
}

/// Batched decode-step inputs, already in graph layout.
#[derive(Clone, Debug, Default)]
pub struct DecodeInputs {
    pub tokens: Vec<i32>,
    pub positions: Vec<i32>,
    pub cache_len: Vec<i32>,
    pub resid_len: Vec<i32>,
    pub theta_code: Vec<i32>,
    pub rho_code: Vec<i32>,
    pub rho_z: Vec<f32>,
    pub rho_s: Vec<f32>,
    pub theta_z: Vec<f32>,
    pub theta_s: Vec<f32>,
    pub v_cache: Vec<f32>,
    pub resid_k: Vec<f32>,
    pub resid_v: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct DecodeOutputs {
    /// (B, vocab)
    pub logits: Vec<f32>,
    /// (L, B, Kv, dh)
    pub new_k: Vec<f32>,
    pub new_v: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct PrefillOutputs {
    /// (B, vocab)
    pub logits: Vec<f32>,
    /// (L, B, Kv, T, dh)
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

impl PjrtRuntime {
    /// Load manifest + weights and create the CPU PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        // weight literals from the .bin, in tensor-table order
        let raw = std::fs::read(artifacts_dir.join(&manifest.weights.file))
            .with_context(|| format!("reading {}", manifest.weights.file))?;
        let table = manifest
            .weights
            .tensors
            .req("tensors")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("weights.tensors")?
            .to_vec();
        let mut weights = Vec::new();
        let mut weight_names = Vec::new();
        for entry in &table {
            let name = entry.str_or("name", "");
            let shape = entry
                .req("shape")
                .map_err(anyhow::Error::msg)?
                .usize_vec()
                .context("shape")?;
            let offset = entry.usize_or("offset_bytes", 0);
            let size = entry.usize_or("size_bytes", 0);
            let n = size / 4;
            let mut data = vec![0.0f32; n];
            for i in 0..n {
                let b = &raw[offset + 4 * i..offset + 4 * i + 4];
                data[i] = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            weights.push(literal_f32(&data, &shape)?);
            weight_names.push(name);
        }
        Ok(PjrtRuntime { client, manifest, execs: HashMap::new(), weights, weight_names })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the named graph.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.execs.contains_key(name) {
            let info = self
                .manifest
                .graph(name)
                .with_context(|| format!("unknown graph '{name}'"))?
                .clone();
            let path = self.manifest.dir.join(&info.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.execs.insert(name.to_string(), exe);
        }
        Ok(&self.execs[name])
    }

    /// Pre-compile every graph (used by the engine at startup so the first
    /// request doesn't pay compile latency).
    pub fn warmup(&mut self) -> Result<()> {
        let names: Vec<String> = self.manifest.graphs.iter().map(|g| g.name.clone()).collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    fn check_lens(info: &GraphInfo, lens: &[(usize, usize)]) -> Result<()> {
        // lens: (spec index, actual len) for the non-weight inputs
        for &(i, len) in lens {
            let spec = &info.inputs[i];
            if spec.numel() != len {
                bail!(
                    "graph {}: input '{}' expects {} elems ({:?}), got {len}",
                    info.name,
                    spec.name,
                    spec.numel(),
                    spec.shape
                );
            }
        }
        Ok(())
    }

    /// Execute a decode-step graph.
    pub fn decode(&mut self, graph: &str, ins: &DecodeInputs) -> Result<DecodeOutputs> {
        let info = self
            .manifest
            .graph(graph)
            .with_context(|| format!("unknown graph '{graph}'"))?
            .clone();
        if info.kind != "decode" {
            bail!("graph '{graph}' is not a decode graph");
        }
        Self::check_lens(
            &info,
            &[
                (0, ins.tokens.len()),
                (1, ins.positions.len()),
                (2, ins.cache_len.len()),
                (3, ins.resid_len.len()),
                (4, ins.theta_code.len()),
                (5, ins.rho_code.len()),
                (6, ins.rho_z.len()),
                (7, ins.rho_s.len()),
                (8, ins.theta_z.len()),
                (9, ins.theta_s.len()),
                (10, ins.v_cache.len()),
                (11, ins.resid_k.len()),
                (12, ins.resid_v.len()),
            ],
        )?;
        let lits: Vec<xla::Literal> = vec![
            literal_i32(&ins.tokens, &info.inputs[0].shape)?,
            literal_i32(&ins.positions, &info.inputs[1].shape)?,
            literal_i32(&ins.cache_len, &info.inputs[2].shape)?,
            literal_i32(&ins.resid_len, &info.inputs[3].shape)?,
            literal_i32(&ins.theta_code, &info.inputs[4].shape)?,
            literal_i32(&ins.rho_code, &info.inputs[5].shape)?,
            literal_f32(&ins.rho_z, &info.inputs[6].shape)?,
            literal_f32(&ins.rho_s, &info.inputs[7].shape)?,
            literal_f32(&ins.theta_z, &info.inputs[8].shape)?,
            literal_f32(&ins.theta_s, &info.inputs[9].shape)?,
            literal_f32(&ins.v_cache, &info.inputs[10].shape)?,
            literal_f32(&ins.resid_k, &info.inputs[11].shape)?,
            literal_f32(&ins.resid_v, &info.inputs[12].shape)?,
        ];
        self.executable(graph)?; // ensure compiled (needs &mut self)
        let exe = &self.execs[graph];
        let mut refs: Vec<&xla::Literal> = lits.iter().collect();
        refs.extend(self.weights.iter());
        let result = exe.execute::<&xla::Literal>(&refs)?[0][0].to_literal_sync()?;
        let (logits, new_k, new_v) = result.to_tuple3()?;
        Ok(DecodeOutputs {
            logits: logits.to_vec::<f32>()?,
            new_k: new_k.to_vec::<f32>()?,
            new_v: new_v.to_vec::<f32>()?,
        })
    }

    /// Execute a prefill graph. `tokens` is (B, T) right-padded.
    pub fn prefill(
        &mut self,
        graph: &str,
        tokens: &[i32],
        prompt_len: &[i32],
    ) -> Result<PrefillOutputs> {
        let info = self
            .manifest
            .graph(graph)
            .with_context(|| format!("unknown graph '{graph}'"))?
            .clone();
        if info.kind != "prefill" {
            bail!("graph '{graph}' is not a prefill graph");
        }
        Self::check_lens(&info, &[(0, tokens.len()), (1, prompt_len.len())])?;
        let lits = vec![
            literal_i32(tokens, &info.inputs[0].shape)?,
            literal_i32(prompt_len, &info.inputs[1].shape)?,
        ];
        self.executable(graph)?; // ensure compiled (needs &mut self)
        let exe = &self.execs[graph];
        let mut refs: Vec<&xla::Literal> = lits.iter().collect();
        refs.extend(self.weights.iter());
        let result = exe.execute::<&xla::Literal>(&refs)?[0][0].to_literal_sync()?;
        let (logits, k, v) = result.to_tuple3()?;
        Ok(PrefillOutputs {
            logits: logits.to_vec::<f32>()?,
            k: k.to_vec::<f32>()?,
            v: v.to_vec::<f32>()?,
        })
    }

    /// Execute the bulk polar-encode graph: k is (N, T, dh).
    pub fn encode(&mut self, graph: &str, k: &[f32]) -> Result<Vec<Vec<f32>>> {
        let info = self
            .manifest
            .graph(graph)
            .with_context(|| format!("unknown graph '{graph}'"))?
            .clone();
        if info.kind != "encode" {
            bail!("graph '{graph}' is not an encode graph");
        }
        Self::check_lens(&info, &[(0, k.len())])?;
        let lits = vec![literal_f32(k, &info.inputs[0].shape)?];
        let exe = self.executable(graph)?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        // rho_code/theta_code come back as i32; convert uniformly to f32
        // vectors for comparison convenience
        parts
            .into_iter()
            .zip(&info.outputs)
            .map(|(lit, spec)| {
                Ok(match spec.dtype {
                    Dtype::I32 => lit.to_vec::<i32>()?.into_iter().map(|x| x as f32).collect(),
                    Dtype::F32 => lit.to_vec::<f32>()?,
                })
            })
            .collect()
    }

    /// Names of the weight tensors, manifest order.
    pub fn weight_names(&self) -> &[String] {
        &self.weight_names
    }
}

/// Batch per-sequence dense caches into graph layout (L, B, Kv, ...).
pub fn batch_dense(
    caches: &[&DenseCache],
    n_layers: usize,
    n_kv: usize,
    s_cap: usize,
    r_cap: usize,
    d: usize,
    group: usize,
    pad_to_batch: usize,
) -> DecodeInputs {
    let b_real = caches.len();
    let b = pad_to_batch.max(b_real);
    let d2 = d / 2;
    let gcap = s_cap / group;
    let mut ins = DecodeInputs {
        tokens: vec![0; b],
        positions: vec![0; b],
        cache_len: vec![0; b],
        resid_len: vec![0; b],
        theta_code: vec![0; n_layers * b * n_kv * s_cap * d2],
        rho_code: vec![0; n_layers * b * n_kv * s_cap * d2],
        rho_z: vec![0.0; n_layers * b * n_kv * gcap * d2],
        rho_s: vec![1e-8; n_layers * b * n_kv * gcap * d2],
        theta_z: vec![0.0; n_layers * b * n_kv * gcap * d2],
        theta_s: vec![1e-8; n_layers * b * n_kv * gcap * d2],
        v_cache: vec![0.0; n_layers * b * n_kv * s_cap * d],
        resid_k: vec![0.0; n_layers * b * n_kv * r_cap * d],
        resid_v: vec![0.0; n_layers * b * n_kv * r_cap * d],
    };
    for (bi, dc) in caches.iter().enumerate() {
        ins.cache_len[bi] = dc.cache_len as i32;
        ins.resid_len[bi] = dc.resid_len as i32;
        for l in 0..n_layers {
            for h in 0..n_kv {
                let src = l * n_kv + h; // per-seq (L, Kv, ...) index base
                let dst = (l * b + bi) * n_kv + h; // batched (L, B, Kv, ...)
                let (cs, cd) = (src * s_cap * d2, dst * s_cap * d2);
                ins.theta_code[cd..cd + s_cap * d2]
                    .copy_from_slice(&dc.theta_code[cs..cs + s_cap * d2]);
                ins.rho_code[cd..cd + s_cap * d2]
                    .copy_from_slice(&dc.rho_code[cs..cs + s_cap * d2]);
                let (ps, pd) = (src * gcap * d2, dst * gcap * d2);
                ins.rho_z[pd..pd + gcap * d2].copy_from_slice(&dc.rho_z[ps..ps + gcap * d2]);
                ins.rho_s[pd..pd + gcap * d2].copy_from_slice(&dc.rho_s[ps..ps + gcap * d2]);
                ins.theta_z[pd..pd + gcap * d2]
                    .copy_from_slice(&dc.theta_z[ps..ps + gcap * d2]);
                ins.theta_s[pd..pd + gcap * d2]
                    .copy_from_slice(&dc.theta_s[ps..ps + gcap * d2]);
                let (vs, vd) = (src * s_cap * d, dst * s_cap * d);
                ins.v_cache[vd..vd + s_cap * d].copy_from_slice(&dc.v[vs..vs + s_cap * d]);
                let (rs, rd) = (src * r_cap * d, dst * r_cap * d);
                ins.resid_k[rd..rd + r_cap * d].copy_from_slice(&dc.resid_k[rs..rs + r_cap * d]);
                ins.resid_v[rd..rd + r_cap * d].copy_from_slice(&dc.resid_v[rs..rs + r_cap * d]);
            }
        }
    }
    ins
}

/// Slice one sequence's (L, Kv, T, d) K or V block out of a batched
/// prefill output (L, B, Kv, T, d).
pub fn split_prefill_kv(
    batched: &[f32],
    n_layers: usize,
    batch: usize,
    n_kv: usize,
    t: usize,
    d: usize,
    b: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n_layers * n_kv * t * d];
    for l in 0..n_layers {
        for h in 0..n_kv {
            let src = (((l * batch + b) * n_kv) + h) * t * d;
            let dst = (l * n_kv + h) * t * d;
            out[dst..dst + t * d].copy_from_slice(&batched[src..src + t * d]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_prefill_layout() {
        // L=1, B=2, Kv=1, T=2, d=2 -> batched len 8
        let batched: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let b0 = split_prefill_kv(&batched, 1, 2, 1, 2, 2, 0);
        let b1 = split_prefill_kv(&batched, 1, 2, 1, 2, 2, 1);
        assert_eq!(b0, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(b1, vec![4.0, 5.0, 6.0, 7.0]);
    }
}
