//! PJRT executor: compile HLO-text artifacts, marshal literals, execute.
//!
//! Compiled only with `--features pjrt` (needs the `xla` bindings crate,
//! which the offline image does not carry); default builds get
//! `executor_stub.rs` with the same public surface.
//!
//! v1 marshals host arrays as `xla::Literal`s per call (weights included);
//! the §Perf pass keeps weights resident as device buffers.  Executables
//! are compiled lazily on first use and cached.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{Dtype, GraphInfo, Manifest};
pub use super::marshal::{batch_dense, split_prefill_kv};
use super::marshal::{DecodeInputs, DecodeOutputs, PrefillOutputs};

pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    /// weight literals in manifest (= graph input) order
    weights: Vec<xla::Literal>,
    weight_names: Vec<String>,
}

fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

impl PjrtRuntime {
    /// Load manifest + weights and create the CPU PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        // weight literals from the .bin, in tensor-table order
        let raw = std::fs::read(artifacts_dir.join(&manifest.weights.file))
            .with_context(|| format!("reading {}", manifest.weights.file))?;
        let table = manifest
            .weights
            .tensors
            .req("tensors")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("weights.tensors")?
            .to_vec();
        let mut weights = Vec::new();
        let mut weight_names = Vec::new();
        for entry in &table {
            let name = entry.str_or("name", "");
            let shape = entry
                .req("shape")
                .map_err(anyhow::Error::msg)?
                .usize_vec()
                .context("shape")?;
            let offset = entry.usize_or("offset_bytes", 0);
            let size = entry.usize_or("size_bytes", 0);
            let n = size / 4;
            let mut data = vec![0.0f32; n];
            for i in 0..n {
                let b = &raw[offset + 4 * i..offset + 4 * i + 4];
                data[i] = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            weights.push(literal_f32(&data, &shape)?);
            weight_names.push(name);
        }
        Ok(PjrtRuntime { client, manifest, execs: HashMap::new(), weights, weight_names })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the named graph.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.execs.contains_key(name) {
            let info = self
                .manifest
                .graph(name)
                .with_context(|| format!("unknown graph '{name}'"))?
                .clone();
            let path = self.manifest.dir.join(&info.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.execs.insert(name.to_string(), exe);
        }
        Ok(&self.execs[name])
    }

    /// Pre-compile every graph (used by the engine at startup so the first
    /// request doesn't pay compile latency).
    pub fn warmup(&mut self) -> Result<()> {
        let names: Vec<String> = self.manifest.graphs.iter().map(|g| g.name.clone()).collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    fn check_lens(info: &GraphInfo, lens: &[(usize, usize)]) -> Result<()> {
        // lens: (spec index, actual len) for the non-weight inputs
        for &(i, len) in lens {
            let spec = &info.inputs[i];
            if spec.numel() != len {
                bail!(
                    "graph {}: input '{}' expects {} elems ({:?}), got {len}",
                    info.name,
                    spec.name,
                    spec.numel(),
                    spec.shape
                );
            }
        }
        Ok(())
    }

    /// Execute a decode-step graph.
    pub fn decode(&mut self, graph: &str, ins: &DecodeInputs) -> Result<DecodeOutputs> {
        let info = self
            .manifest
            .graph(graph)
            .with_context(|| format!("unknown graph '{graph}'"))?
            .clone();
        if info.kind != "decode" {
            bail!("graph '{graph}' is not a decode graph");
        }
        Self::check_lens(
            &info,
            &[
                (0, ins.tokens.len()),
                (1, ins.positions.len()),
                (2, ins.cache_len.len()),
                (3, ins.resid_len.len()),
                (4, ins.theta_code.len()),
                (5, ins.rho_code.len()),
                (6, ins.rho_z.len()),
                (7, ins.rho_s.len()),
                (8, ins.theta_z.len()),
                (9, ins.theta_s.len()),
                (10, ins.v_cache.len()),
                (11, ins.resid_k.len()),
                (12, ins.resid_v.len()),
            ],
        )?;
        let lits: Vec<xla::Literal> = vec![
            literal_i32(&ins.tokens, &info.inputs[0].shape)?,
            literal_i32(&ins.positions, &info.inputs[1].shape)?,
            literal_i32(&ins.cache_len, &info.inputs[2].shape)?,
            literal_i32(&ins.resid_len, &info.inputs[3].shape)?,
            literal_i32(&ins.theta_code, &info.inputs[4].shape)?,
            literal_i32(&ins.rho_code, &info.inputs[5].shape)?,
            literal_f32(&ins.rho_z, &info.inputs[6].shape)?,
            literal_f32(&ins.rho_s, &info.inputs[7].shape)?,
            literal_f32(&ins.theta_z, &info.inputs[8].shape)?,
            literal_f32(&ins.theta_s, &info.inputs[9].shape)?,
            literal_f32(&ins.v_cache, &info.inputs[10].shape)?,
            literal_f32(&ins.resid_k, &info.inputs[11].shape)?,
            literal_f32(&ins.resid_v, &info.inputs[12].shape)?,
        ];
        self.executable(graph)?; // ensure compiled (needs &mut self)
        let exe = &self.execs[graph];
        let mut refs: Vec<&xla::Literal> = lits.iter().collect();
        refs.extend(self.weights.iter());
        let result = exe.execute::<&xla::Literal>(&refs)?[0][0].to_literal_sync()?;
        let (logits, new_k, new_v) = result.to_tuple3()?;
        Ok(DecodeOutputs {
            logits: logits.to_vec::<f32>()?,
            new_k: new_k.to_vec::<f32>()?,
            new_v: new_v.to_vec::<f32>()?,
        })
    }

    /// Execute a prefill graph. `tokens` is (B, T) right-padded.
    pub fn prefill(
        &mut self,
        graph: &str,
        tokens: &[i32],
        prompt_len: &[i32],
    ) -> Result<PrefillOutputs> {
        let info = self
            .manifest
            .graph(graph)
            .with_context(|| format!("unknown graph '{graph}'"))?
            .clone();
        if info.kind != "prefill" {
            bail!("graph '{graph}' is not a prefill graph");
        }
        Self::check_lens(&info, &[(0, tokens.len()), (1, prompt_len.len())])?;
        let lits = vec![
            literal_i32(tokens, &info.inputs[0].shape)?,
            literal_i32(prompt_len, &info.inputs[1].shape)?,
        ];
        self.executable(graph)?; // ensure compiled (needs &mut self)
        let exe = &self.execs[graph];
        let mut refs: Vec<&xla::Literal> = lits.iter().collect();
        refs.extend(self.weights.iter());
        let result = exe.execute::<&xla::Literal>(&refs)?[0][0].to_literal_sync()?;
        let (logits, k, v) = result.to_tuple3()?;
        Ok(PrefillOutputs {
            logits: logits.to_vec::<f32>()?,
            k: k.to_vec::<f32>()?,
            v: v.to_vec::<f32>()?,
        })
    }

    /// Execute the bulk polar-encode graph: k is (N, T, dh).
    pub fn encode(&mut self, graph: &str, k: &[f32]) -> Result<Vec<Vec<f32>>> {
        let info = self
            .manifest
            .graph(graph)
            .with_context(|| format!("unknown graph '{graph}'"))?
            .clone();
        if info.kind != "encode" {
            bail!("graph '{graph}' is not an encode graph");
        }
        Self::check_lens(&info, &[(0, k.len())])?;
        let lits = vec![literal_f32(k, &info.inputs[0].shape)?];
        let exe = self.executable(graph)?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        // rho_code/theta_code come back as i32; convert uniformly to f32
        // vectors for comparison convenience
        parts
            .into_iter()
            .zip(&info.outputs)
            .map(|(lit, spec)| {
                Ok(match spec.dtype {
                    Dtype::I32 => lit.to_vec::<i32>()?.into_iter().map(|x| x as f32).collect(),
                    Dtype::F32 => lit.to_vec::<f32>()?,
                })
            })
            .collect()
    }

    /// Names of the weight tensors, manifest order.
    pub fn weight_names(&self) -> &[String] {
        &self.weight_names
    }
}
