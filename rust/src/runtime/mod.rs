//! PJRT runtime: loads the AOT artifacts (`artifacts/manifest.json` +
//! `*.hlo.txt`) and executes the decode / prefill / encode graphs on the
//! CPU PJRT client from the request path.
//!
//! Interchange is HLO **text** (xla_extension 0.5.1 rejects jax>=0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids — see
//! /opt/xla-example/README.md and DESIGN.md §7).  Executables are compiled
//! lazily per shape bucket and cached for the process lifetime.

pub mod executor;
pub mod manifest;

pub use executor::{DecodeInputs, DecodeOutputs, PjrtRuntime, PrefillOutputs};
pub use manifest::{GraphInfo, Manifest};
