//! PJRT runtime: loads the AOT artifacts (`artifacts/manifest.json` +
//! `*.hlo.txt`) and executes the decode / prefill / encode graphs on the
//! CPU PJRT client from the request path.
//!
//! Interchange is HLO **text** (xla_extension 0.5.1 rejects jax>=0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids — see
//! /opt/xla-example/README.md and DESIGN.md §7).  Executables are compiled
//! lazily per shape bucket and cached for the process lifetime.
//!
//! Host-side layout marshalling lives in [`marshal`] (pure Rust).  The
//! executor itself is feature-gated: `--features pjrt` compiles the real
//! XLA-backed [`executor`]; default (offline) builds compile the stub in
//! `executor_stub.rs`, whose `PjrtRuntime::load` fails with a clear
//! message — callers wanting to serve in an offline build must select the
//! native backend explicitly (e.g. `--backend native|synthetic`).

pub mod manifest;
pub mod marshal;

#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(not(feature = "pjrt"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use executor::PjrtRuntime;
pub use manifest::{GraphInfo, Manifest};
pub use marshal::{batch_dense, split_prefill_kv, DecodeInputs, DecodeOutputs, PrefillOutputs};
