//! Offline stand-in for the PJRT executor (default build).
//!
//! The real executor needs the `xla` bindings crate, which the offline
//! image does not carry.  This stub exposes the same public surface so the
//! engine's PJRT arm type-checks; `load` always fails with a clear
//! message, so no stub method past construction is ever reachable.  The
//! engine integration tests gate on `artifacts/manifest.json` existing and
//! skip cleanly where this stub is in play.

use std::path::Path;

use anyhow::{bail, Result};

use super::manifest::Manifest;
use super::marshal::{DecodeInputs, DecodeOutputs, PrefillOutputs};
pub use super::marshal::{batch_dense, split_prefill_kv};

const NO_PJRT: &str =
    "polarquant was built without the `pjrt` feature; the PJRT backend is \
     unavailable — use the native backend, or rebuild with `--features pjrt` \
     and a vendored `xla` crate";

pub struct PjrtRuntime {
    pub manifest: Manifest,
    weight_names: Vec<String>,
}

impl PjrtRuntime {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        // Parse the manifest first so a missing-artifacts error (the common
        // case) is reported as such, not as a feature problem.
        let _ = Manifest::load(artifacts_dir)?;
        bail!("{NO_PJRT}")
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn warmup(&mut self) -> Result<()> {
        bail!("{NO_PJRT}")
    }

    pub fn decode(&mut self, _graph: &str, _ins: &DecodeInputs) -> Result<DecodeOutputs> {
        bail!("{NO_PJRT}")
    }

    pub fn prefill(
        &mut self,
        _graph: &str,
        _tokens: &[i32],
        _prompt_len: &[i32],
    ) -> Result<PrefillOutputs> {
        bail!("{NO_PJRT}")
    }

    pub fn encode(&mut self, _graph: &str, _k: &[f32]) -> Result<Vec<Vec<f32>>> {
        bail!("{NO_PJRT}")
    }

    pub fn weight_names(&self) -> &[String] {
        &self.weight_names
    }
}
