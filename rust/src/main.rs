//! `polarquant` — leader entrypoint + CLI.
//!
//! ```text
//! polarquant info      --artifacts artifacts/
//! polarquant serve     --artifacts artifacts/ --addr 127.0.0.1:7733 --workers 2 --backend pjrt
//! polarquant serve     --backend synthetic --workers 2 --decode-workers 4 --prefill-chunk 64
//! polarquant generate  --artifacts artifacts/ --prompt 1,2,3 --max-tokens 16 --backend native
//! polarquant fidelity  --profile qwen-like --d 128 --tokens 512
//! ```
//!
//! `--decode-workers N` (native/synthetic backends) fans each engine's
//! decode iteration over a fixed N-thread pool (see `coordinator::pool`).
//! `--prefill-chunk N` (native/synthetic) enables chunked prefill with
//! continuous batching: prompts enter the cache N tokens per engine step,
//! so decode iterations of running sequences never stall behind a long
//! prompt for more than one chunk's compute (0 = off, the default).
//! `--cache-pages N` caps the page pool at N group-pages (0 = unbounded):
//! on exhaustion the engine reclaims refcount-zero cached prefix pages
//! LRU, then preempts the youngest decoder instead of stalling.
//! `--prefix-cache on` (requires `--prefill-chunk`) shares quantized
//! prefix pages across requests, refcounted — repeated system prompts
//! prefill once.
//!
//! Table/figure regeneration lives in the `bench_tables` binary and
//! `cargo bench` targets (see DESIGN.md §6).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use polarquant::coordinator::{Engine, EngineOpts, Request};
use polarquant::eval::{eval_codec, Table};
use polarquant::quant::QuantSpec;
use polarquant::runtime::Manifest;
use polarquant::server::serve;
use polarquant::workload::ActivationProfile;

/// Tiny hand-rolled flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                let val = argv.get(i + 1).cloned().unwrap_or_default();
                flags.insert(key.to_string(), val);
                i += 2;
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    let result = match cmd {
        "info" => cmd_info(&args),
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "fidelity" => cmd_fidelity(&args),
        _ => {
            eprintln!(
                "usage: polarquant <info|serve|generate|fidelity> [--flags]\n\
                 see crate docs / README for details"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

fn cmd_info(args: &Args) -> Result<()> {
    let m = Manifest::load(&artifacts(args))?;
    println!("model config : {:?}", m.config);
    println!("weights      : {} ({} bytes)", m.weights.file, m.weights.total_bytes);
    println!("graphs       :");
    for g in &m.graphs {
        println!(
            "  {:<28} kind={:<8} bucket=({}, {}) inputs={} outputs={}",
            g.name,
            g.kind,
            g.batch,
            g.seq,
            g.inputs.len(),
            g.outputs.len()
        );
    }
    Ok(())
}

fn build_engine(args: &Args, worker: usize) -> Result<Engine> {
    let dir = artifacts(args);
    let mut opts = EngineOpts::default();
    // native decode threads per engine (--decode-workers N; 1 = inline)
    opts.decode_workers = args.usize("decode-workers", 1);
    // chunked prefill tokens per engine step (0 = whole-prompt prefill)
    opts.prefill_chunk = args.usize("prefill-chunk", 0);
    // page-pool capacity in group-pages (0 = unbounded); exhaustion
    // preempts the youngest decoder instead of stalling
    opts.cache_pages = args.usize("cache-pages", 0);
    // prefix caching: share quantized prefix pages across requests
    opts.prefix_cache = match args.get("prefix-cache", "off").as_str() {
        "on" => true,
        "off" => false,
        other => bail!("--prefix-cache takes on|off, got '{other}'"),
    };
    let backend = args.get("backend", "pjrt");
    if opts.prefill_chunk > 0 && backend == "pjrt" {
        bail!("--prefill-chunk requires the native or synthetic backend");
    }
    if opts.prefix_cache && (opts.prefill_chunk == 0 || backend == "pjrt") {
        bail!("--prefix-cache on requires --prefill-chunk > 0 on the native/synthetic backend");
    }
    if opts.cache_pages > 0 && (opts.prefill_chunk == 0 || backend == "pjrt") {
        // the capacity check + preemption live in the chunked scheduler;
        // accepting the flag elsewhere would advertise a cap that never
        // engages (PagePool::adopt itself never fails)
        bail!("--cache-pages requires --prefill-chunk > 0 on the native/synthetic backend");
    }
    match backend.as_str() {
        "pjrt" => Engine::pjrt_from_artifacts(&dir, opts),
        "native" => Engine::native_from_artifacts(&dir, opts),
        "synthetic" => Ok(Engine::native_synthetic(
            polarquant::model::ModelConfig::tiny(),
            worker as u64,
            6.0,
            opts,
        )),
        other => bail!("unknown backend '{other}' (pjrt|native|synthetic)"),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7733");
    let workers = args.usize("workers", 1);
    let flags: HashMap<String, String> = args.flags.clone();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let args = Args { flags: flags.clone() };
        build_engine(&args, w).expect("engine construction failed")
    });
    let handle = serve(factory, &addr, workers)?;
    println!("serving on {} with {} workers (ctrl-c to stop)", handle.addr, workers);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let prompt: Vec<u32> = args
        .get("prompt", "1,2,3")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse().context("bad token id"))
        .collect::<Result<_>>()?;
    let max_tokens = args.usize("max-tokens", 16);
    let mut engine = build_engine(args, 0)?;
    engine.submit(Request::greedy(1, prompt, max_tokens)).ok();
    let done = engine.run_to_completion()?;
    let c = &done[0];
    println!("tokens: {:?}", c.tokens);
    println!(
        "ttft {:.2}ms total {:.2}ms ({} tokens)",
        c.ttft_s.unwrap_or(0.0) * 1e3,
        c.total_s.unwrap_or(0.0) * 1e3,
        c.tokens.len()
    );
    println!("{}", engine.metrics.summary());
    Ok(())
}

fn cmd_fidelity(args: &Args) -> Result<()> {
    let profile_name = args.get("profile", "llama31-like");
    let profile = ActivationProfile::by_name(&profile_name)
        .with_context(|| format!("unknown profile '{profile_name}'"))?;
    let d = args.usize("d", 128);
    let tokens = args.usize("tokens", 512);
    let group = args.usize("group", 128);
    let mut t = Table::new(
        &format!("Key-cache fidelity — {profile_name} (d={d}, T={tokens})"),
        &["method", "bits", "key MSE", "attn KL", "top8"],
    );
    let specs = [
        QuantSpec::Fp16,
        QuantSpec::Polar { r_bits: 4, t_bits: 4, group },
        QuantSpec::Polar { r_bits: 3, t_bits: 3, group },
        QuantSpec::Kivi { bits: 4, group },
        QuantSpec::Kivi { bits: 2, group: 32 },
        QuantSpec::Int { bits: 4 },
        QuantSpec::Zip { bits: 4 },
        QuantSpec::Qjl { bits_per_channel: 3 },
    ];
    for spec in specs {
        let f = eval_codec(&spec, profile, d, tokens, 16, 42);
        t.row(vec![
            spec.label(),
            format!("{:.2}", f.bits),
            polarquant::eval::tables::sci(f.key_mse),
            polarquant::eval::tables::sci(f.attn_kl),
            format!("{:.3}", f.top8_overlap),
        ]);
    }
    t.print();
    Ok(())
}
