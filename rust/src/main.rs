//! `polarquant` — leader entrypoint + CLI.
//!
//! ```text
//! polarquant info      --artifacts artifacts/
//! polarquant serve     --artifacts artifacts/ --addr 127.0.0.1:7733 --workers 2 --backend pjrt
//! polarquant serve     --backend synthetic --workers 2 --decode-workers 4 --prefill-chunk 64 \
//!                      --prefix-cache on --tier-dir /var/tmp/pq-tier --snapshot on
//! polarquant generate  --artifacts artifacts/ --prompt 1,2,3 --max-tokens 16 --backend native
//! polarquant generate  --backend synthetic --temperature 0.8 --top-k 40 --seed 7
//! polarquant fidelity  --profile qwen-like --d 128 --tokens 512
//! polarquant client    --addr 127.0.0.1:7733 --prompt 1,2,3 --max-tokens 8
//! polarquant client    --addr 127.0.0.1:7733 --prompt 1,2,3 --stream on --cancel-after 4
//! polarquant client    --addr 127.0.0.1:7733 --session-op open
//! polarquant client    --addr 127.0.0.1:7733 --session 4294967296 --turn 4,5,6 --stream on
//! polarquant client    --addr 127.0.0.1:7733 --session 4294967296 --session-op close
//! polarquant client    --addr 127.0.0.1:7733 --admin shutdown
//! polarquant serve     --backend synthetic --prefill-chunk 16 --trace on \
//!                      --trace-export chrome://trace.json
//! polarquant client    --addr 127.0.0.1:7733 --admin trace
//! polarquant client    --addr 127.0.0.1:7733 --admin prometheus
//! polarquant serve     --backend synthetic --prefill-chunk 16 --prefix-cache on \
//!                      --tier-dir /var/tmp/pq-a --fabric-dir /var/tmp/pq-fabric --addr 127.0.0.1:7801
//! polarquant route     --addr 127.0.0.1:7800 --backends 127.0.0.1:7801,127.0.0.1:7802
//! polarquant client    --addr 127.0.0.1:7801 --admin drain
//! ```
//!
//! `client --stream on` speaks wire protocol v2: one JSON line per
//! streamed token, then the final reply line with a `finish_reason`
//! (`stop` | `length` | `cancelled` | `rejected`).  Session turns send
//! only the NEW tokens; the server replays history and reuses the
//! session's KV chain, so turn 2 of a conversation prefills only its own
//! tokens.
//!
//! Every subcommand takes `--help`.  The parser is strict: unknown
//! flags, missing values, duplicate flags, and stray positional
//! arguments are errors, not silently swallowed.
//!
//! `--decode-workers N` (native/synthetic backends) fans each engine's
//! decode iteration over a fixed N-thread pool (see `coordinator::pool`).
//! `--prefill-chunk N` (native/synthetic) enables chunked prefill with
//! continuous batching (0 = off).  `--cache-pages N` caps the page pool
//! at N group-pages; `--prefix-cache on` shares quantized prefix pages
//! across requests.  `--tier-dir PATH` attaches the disk tier under the
//! page pool (requires `--prefix-cache on`): cold prefix pages spill to
//! append-only segments instead of being dropped, promote back on a hit,
//! and — with `--snapshot on` — the whole prefix index persists across
//! restarts (written on `{"admin":"shutdown"}`, restored at boot).
//! `--snapkv-budget N --snapkv-window W` (native/synthetic, whole-prompt
//! prefill only) compresses each prompt to its N most-attended tokens
//! before quantization (paper Table 8).
//!
//! Multi-tenant serving (`serve`): `--sched wfq --tenant-weight
//! paid=4,free=1` orders the queue by deficit-weighted round robin
//! across tenants instead of FCFS; `--tenant-rate R --tenant-burst B`
//! token-buckets admission per tenant (rejections carry reason
//! `tenant_throttled`); `--tenant-pages N` reserves a per-tenant floor
//! of resident prefix-cache pages; `--session-ttl SECS` (with
//! `--tier-dir`) demotes an idle session's KV chain to the disk tier
//! and restores it bit-identically on the conversation's next turn.
//! Requests name their tenant with the wire-v2 `tenant` field
//! (`client --tenant NAME`); absent means the shared `default` tenant.
//! `--tenant-tier-bytes N` (with `--tier-dir` and `--session-ttl`) caps
//! each tenant's reaped-session blob bytes on the disk tier.
//!
//! Multi-node serving: `route` runs the front tier — it speaks wire v2
//! to clients, places sessions on backend `serve` processes via a
//! consistent-hash ring (`--backends A,B,..`), probes node health
//! (`--heartbeat-ms`), honors `{"admin":"drain"}` (drained nodes take
//! no NEW placements; in-flight sessions finish), and optionally
//! hedges a stalled streaming request onto a second node
//! (`--hedge-after-ms`; the loser is cancelled, exactly one completion
//! reaches the client).  Backends share cached prefixes through
//! `--fabric-dir DIR` (a shared directory of checksummed records) or
//! `--fabric-peer HOST:PORT` (fetch from one designated peer over its
//! admin channel): a cold prefix miss fetches the quantized pages
//! instead of re-prefilling, and every fetched record is verified
//! (checksum, config fingerprint, chain hash) before admission.
//!
//! `--kernel auto|scalar|simd`
//! picks the QK score kernel (`quant::lut::ScoreKernel`); kernels are
//! bit-identical, so it is purely a performance knob — an explicit
//! `simd` is rejected up front when the build or CPU can't run it.
//!
//! `--speculate K` (native/synthetic, greedy requests only) turns on
//! self-drafting speculative decoding: each decode step proposes up to K
//! tokens by running attention on a coarse *draft* plane derived from
//! the stored PolarQuant codes by bit truncation (no second cache), then
//! verifies the whole window exactly in one batched LUT pass.  Output is
//! bit-identical to `--speculate 0`; only the step count changes.
//! `--draft-bits R,T` overrides the draft plane's radius/angle bits
//! (default: half the exact plane's, floor 1).
//!
//! Table/figure regeneration lives in the `bench_tables` binary and
//! `cargo bench` targets (see DESIGN.md §6).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use polarquant::coordinator::engine::SnapKvOpts;
use polarquant::coordinator::{
    Engine, EngineOpts, FabricOpts, GenOptions, Request, SchedMode, TenancyOpts, TierOpts,
};
use polarquant::fabric::FrontOpts;
use polarquant::eval::{eval_codec, Table};
use polarquant::quant::{select_kernel, DraftSpec, KernelKind, QuantSpec};
use polarquant::runtime::Manifest;
use polarquant::server::{serve_with_export, Client, GenParams};
use polarquant::util::json;
use polarquant::workload::ActivationProfile;

// ------------------------------------------------------------ CLI spec

struct FlagSpec {
    name: &'static str,
    value: &'static str,
    default: &'static str,
    help: &'static str,
}

struct CmdSpec {
    name: &'static str,
    about: &'static str,
    flags: &'static [FlagSpec],
}

const fn flag(
    name: &'static str,
    value: &'static str,
    default: &'static str,
    help: &'static str,
) -> FlagSpec {
    FlagSpec { name, value, default, help }
}

const INFO: CmdSpec = CmdSpec {
    name: "info",
    about: "print the artifact manifest (model config, weights, AOT graphs)",
    flags: &[flag("artifacts", "DIR", "artifacts", "artifact directory")],
};

const SERVE: CmdSpec = CmdSpec {
    name: "serve",
    about: "run the JSON-lines TCP server (one engine per worker)",
    flags: &[
        flag("artifacts", "DIR", "artifacts", "artifact directory (pjrt/native backends)"),
        flag("addr", "HOST:PORT", "127.0.0.1:7733", "listen address"),
        flag("workers", "N", "1", "engine worker threads"),
        flag("backend", "NAME", "pjrt", "pjrt | native | synthetic"),
        flag("kernel", "NAME", "auto", "QK score kernel: auto | scalar | simd"),
        flag("decode-workers", "N", "1", "decode threads per engine (1 = inline)"),
        flag("prefill-chunk", "N", "0", "chunked prefill tokens per step (0 = off)"),
        flag("cache-pages", "N", "0", "page-pool capacity in group-pages (0 = unbounded)"),
        flag("prefix-cache", "on|off", "off", "share quantized prefix pages across requests"),
        flag("snapkv-budget", "N", "0", "SnapKV prompt compression budget (0 = off)"),
        flag("snapkv-window", "W", "8", "SnapKV observation window (with --snapkv-budget)"),
        flag("tier-dir", "DIR", "", "disk tier directory (requires --prefix-cache on)"),
        flag("tier-bytes", "N", "1073741824", "stop demoting past this many segment bytes"),
        flag("snapshot", "on|off", "on", "persist the prefix index at graceful shutdown"),
        flag("sched", "NAME", "fcfs", "queued-request order: fcfs | wfq (weighted fair)"),
        flag("tenant-weight", "N=W,..", "", "WFQ weights, e.g. paid=4,free=1 (needs --sched wfq)"),
        flag("tenant-rate", "R", "0", "per-tenant admission bucket refill, requests/s (0 = off)"),
        flag("tenant-burst", "B", "0", "admission bucket burst (needs --tenant-rate; 0 = rate)"),
        flag("tenant-pages", "N", "0", "per-tenant resident prefix-page floor (needs --prefix-cache)"),
        flag("session-ttl", "SECS", "0", "reap idle session chains to the tier (0 = off; needs --tier-dir)"),
        flag("tenant-tier-bytes", "N", "0", "per-tenant session-blob cap on the tier (0 = off; needs --tier-dir)"),
        flag("fabric-dir", "DIR", "", "shared prefix-fabric directory (needs --prefix-cache on)"),
        flag("fabric-peer", "HOST:PORT", "", "fetch cold prefixes from this peer server (needs --prefix-cache on)"),
        flag("speculate", "K", "0", "draft K tokens/step on the coarse code plane (0 = off)"),
        flag("draft-bits", "R,T", "", "draft plane bits (default: half the exact bits, floor 1)"),
        flag("trace", "on|off", "off", "record request-lifecycle events (drain: --admin trace)"),
        flag("trace-export", "chrome://PATH", "",
             "also write a Chrome trace_event file at shutdown (needs --trace on)"),
    ],
};

const GENERATE: CmdSpec = CmdSpec {
    name: "generate",
    about: "one-shot generation through a local engine (greedy by default)",
    flags: &[
        flag("artifacts", "DIR", "artifacts", "artifact directory (pjrt/native backends)"),
        flag("backend", "NAME", "pjrt", "pjrt | native | synthetic"),
        flag("prompt", "T1,T2,..", "1,2,3", "comma-separated prompt token ids"),
        flag("max-tokens", "N", "16", "tokens to generate"),
        flag("temperature", "T", "0", "sampling temperature (0 = greedy)"),
        flag("top-k", "N", "0", "sample from the top-k tokens (0 = full vocab)"),
        flag("top-p", "P", "1.0", "nucleus sampling mass (1.0 = off)"),
        flag("seed", "N", "0", "per-request sampling seed (reproducible rollouts)"),
        flag("stop", "T1,T2,..", "", "stop generation at any of these token ids"),
        flag("kernel", "NAME", "auto", "QK score kernel: auto | scalar | simd"),
        flag("decode-workers", "N", "1", "decode threads (1 = inline)"),
        flag("prefill-chunk", "N", "0", "chunked prefill tokens per step (0 = off)"),
        flag("cache-pages", "N", "0", "page-pool capacity in group-pages (0 = unbounded)"),
        flag("prefix-cache", "on|off", "off", "share quantized prefix pages across requests"),
        flag("snapkv-budget", "N", "0", "SnapKV prompt compression budget (0 = off)"),
        flag("snapkv-window", "W", "8", "SnapKV observation window (with --snapkv-budget)"),
        flag("tier-dir", "DIR", "", "disk tier directory (requires --prefix-cache on)"),
        flag("tier-bytes", "N", "1073741824", "stop demoting past this many segment bytes"),
        flag("snapshot", "on|off", "on", "persist the prefix index at exit"),
        flag("speculate", "K", "0", "draft K tokens/step on the coarse code plane (0 = off)"),
        flag("draft-bits", "R,T", "", "draft plane bits (default: half the exact bits, floor 1)"),
    ],
};

const FIDELITY: CmdSpec = CmdSpec {
    name: "fidelity",
    about: "key-cache fidelity table across codecs on a synthetic profile",
    flags: &[
        flag("profile", "NAME", "llama31-like", "activation profile"),
        flag("d", "N", "128", "head dimension"),
        flag("tokens", "N", "512", "tokens per stream"),
        flag("group", "N", "128", "quantization group size"),
    ],
};

const ROUTE: CmdSpec = CmdSpec {
    name: "route",
    about: "run the multi-node front tier (consistent-hash placement over serve backends)",
    flags: &[
        flag("addr", "HOST:PORT", "127.0.0.1:7800", "listen address for clients"),
        flag("backends", "A,B,..", "", "backend serve addresses, comma-separated (required)"),
        flag("hedge-after-ms", "MS", "0", "re-dispatch a stalled streaming request after MS (0 = off)"),
        flag("heartbeat-ms", "MS", "1000", "node health probe interval"),
        flag("vnodes", "N", "64", "consistent-hash ring points per backend"),
    ],
};

const CLIENT: CmdSpec = CmdSpec {
    name: "client",
    about: "JSON-lines client: one-shot or streaming generation, sessions, admin",
    flags: &[
        flag("addr", "HOST:PORT", "127.0.0.1:7733", "server address"),
        flag("prompt", "T1,T2,..", "1,2,3", "comma-separated prompt token ids"),
        flag("max-tokens", "N", "16", "tokens to generate"),
        flag("temperature", "T", "0", "sampling temperature (0 = greedy)"),
        flag("top-k", "N", "0", "sample from the top-k tokens (0 = full vocab)"),
        flag("top-p", "P", "1.0", "nucleus sampling mass (1.0 = off)"),
        flag("seed", "N", "0", "per-request sampling seed (reproducible rollouts)"),
        flag("stop", "T1,T2,..", "", "stop generation at any of these token ids"),
        flag("stream", "on|off", "off", "stream tokens as they decode (wire v2)"),
        flag("cancel-after", "N", "0", "cancel mid-stream after N tokens (with --stream on)"),
        flag("session", "N", "", "session id (router affinity; turns reuse its KV chain)"),
        flag("turn", "T1,T2,..", "", "session-turn tokens, new tokens only (needs --session)"),
        flag("session-op", "open|close", "", "open a new session / close --session N"),
        flag("tenant", "NAME", "", "tenant identity for fair scheduling / quotas (wire v2)"),
        flag("admin", "CMD", "",
             "admin command instead of generating: metrics | prometheus | trace | ping | drain | shutdown"),
    ],
};

const CMDS: &[&CmdSpec] = &[&INFO, &SERVE, &ROUTE, &GENERATE, &FIDELITY, &CLIENT];

// ---------------------------------------------------------- arg parser

/// Strict `--key value` parser over one subcommand's flag spec.
#[derive(Debug)]
struct Args {
    flags: HashMap<String, String>,
}

#[derive(Debug)]
enum Parsed {
    Help,
    Flags(Args),
}

impl Args {
    /// Rejects unknown flags, flags without a value (including a
    /// trailing `--key`), duplicate flags, and stray positionals.
    /// `--help`/`-h` anywhere wins and short-circuits.
    fn parse(argv: &[String], spec: &CmdSpec) -> Result<Parsed, String> {
        if argv.iter().any(|a| a == "--help" || a == "-h") {
            return Ok(Parsed::Help);
        }
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            let Some(key) = tok.strip_prefix("--") else {
                return Err(format!("unexpected argument '{tok}' (flags are --key value)"));
            };
            let Some(fs) = spec.flags.iter().find(|f| f.name == key) else {
                return Err(format!("unknown flag --{key} for '{}'", spec.name));
            };
            let Some(val) = argv.get(i + 1) else {
                return Err(format!("--{key} expects a value ({})", fs.value));
            };
            if val.starts_with("--") {
                return Err(format!("--{key} expects a value ({}), got '{val}'", fs.value));
            }
            if flags.insert(key.to_string(), val.clone()).is_some() {
                return Err(format!("--{key} given twice"));
            }
            i += 2;
        }
        Ok(Parsed::Flags(Args { flags }))
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key}: expected an integer, got '{v}'")),
        }
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key}: expected an integer, got '{v}'")),
        }
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key}: expected a number, got '{v}'")),
        }
    }

    fn on_off(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key, if default { "on" } else { "off" }).as_str() {
            "on" => Ok(true),
            "off" => Ok(false),
            other => bail!("--{key} takes on|off, got '{other}'"),
        }
    }
}

fn usage(spec: &CmdSpec) -> String {
    let mut s = format!("polarquant {} — {}\n\nflags:\n", spec.name, spec.about);
    for f in spec.flags {
        let default = if f.default.is_empty() {
            String::new()
        } else {
            format!(" [default: {}]", f.default)
        };
        s.push_str(&format!("  --{:<16} {:<10} {}{}\n", f.name, f.value, f.help, default));
    }
    s
}

fn global_usage() -> String {
    let mut s = String::from("usage: polarquant <command> [--flags]\n\ncommands:\n");
    for c in CMDS {
        s.push_str(&format!("  {:<10} {}\n", c.name, c.about));
    }
    s.push_str("\nrun `polarquant <command> --help` for the command's flags\n");
    s
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    if matches!(cmd, "help" | "--help" | "-h") {
        print!("{}", global_usage());
        return;
    }
    let Some(spec) = CMDS.iter().find(|c| c.name == cmd) else {
        eprintln!("unknown command '{cmd}'\n\n{}", global_usage());
        std::process::exit(2);
    };
    let args = match Args::parse(&argv[1..], spec) {
        Ok(Parsed::Help) => {
            print!("{}", usage(spec));
            return;
        }
        Ok(Parsed::Flags(a)) => a,
        Err(msg) => {
            eprintln!("error: {msg}\n\n{}", usage(spec));
            std::process::exit(2);
        }
    };
    let result = match cmd {
        "info" => cmd_info(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "generate" => cmd_generate(&args),
        "fidelity" => cmd_fidelity(&args),
        "client" => cmd_client(&args),
        _ => unreachable!("spec table covers every command"),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

// ------------------------------------------------------------ commands

fn artifacts(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

fn cmd_info(args: &Args) -> Result<()> {
    let m = Manifest::load(&artifacts(args))?;
    println!("model config : {:?}", m.config);
    println!("weights      : {} ({} bytes)", m.weights.file, m.weights.total_bytes);
    println!("graphs       :");
    for g in &m.graphs {
        println!(
            "  {:<28} kind={:<8} bucket=({}, {}) inputs={} outputs={}",
            g.name,
            g.kind,
            g.batch,
            g.seq,
            g.inputs.len(),
            g.outputs.len()
        );
    }
    Ok(())
}

/// The validated engine configuration a worker builds from.  Splitting
/// validation from construction lets `serve` reject a bad flag
/// combination up front instead of panicking inside a worker thread.
struct EngineSpec {
    opts: EngineOpts,
    backend: String,
    /// (base dir, max bytes, snapshot) — each worker tiers into its own
    /// subdirectory of the base
    tier: Option<(PathBuf, u64, bool)>,
    /// multi-tenant policy knobs; the all-default value changes nothing
    tenancy: TenancyOpts,
    /// shared prefix-fabric transport (`--fabric-dir` / `--fabric-peer`);
    /// the all-`None` value attaches nothing
    fabric: FabricOpts,
    /// `--trace-export chrome://PATH` target (serve only): where the
    /// fleet's trace rings are rendered as a Chrome trace_event file at
    /// graceful shutdown
    trace_export: Option<PathBuf>,
}

fn engine_spec(args: &Args) -> Result<EngineSpec> {
    let mut opts = EngineOpts::default();
    // native decode threads per engine (--decode-workers N; 1 = inline)
    opts.decode_workers = args.usize("decode-workers", 1)?;
    // chunked prefill tokens per engine step (0 = whole-prompt prefill)
    opts.prefill_chunk = args.usize("prefill-chunk", 0)?;
    // page-pool capacity in group-pages (0 = unbounded); exhaustion
    // preempts the youngest decoder instead of stalling
    opts.cache_pages = args.usize("cache-pages", 0)?;
    // prefix caching: share quantized prefix pages across requests
    opts.prefix_cache = args.on_off("prefix-cache", false)?;
    // QK score kernel; availability of an explicit `simd` is checked HERE
    // so a bad flag is a clean CLI error, not a worker-thread panic
    opts.kernel = KernelKind::parse(&args.get("kernel", "auto"))
        .map_err(|e| anyhow::anyhow!("--kernel: {e}"))?;
    select_kernel(opts.kernel).map_err(|e| anyhow::anyhow!("--kernel: {e}"))?;
    let backend = args.get("backend", "pjrt");
    if !matches!(backend.as_str(), "pjrt" | "native" | "synthetic") {
        bail!("unknown backend '{backend}' (pjrt|native|synthetic)");
    }
    if opts.prefill_chunk > 0 && backend == "pjrt" {
        bail!("--prefill-chunk requires the native or synthetic backend");
    }
    if opts.prefix_cache && (opts.prefill_chunk == 0 || backend == "pjrt") {
        bail!("--prefix-cache on requires --prefill-chunk > 0 on the native/synthetic backend");
    }
    if opts.cache_pages > 0 && (opts.prefill_chunk == 0 || backend == "pjrt") {
        // the capacity check + preemption live in the chunked scheduler;
        // accepting the flag elsewhere would advertise a cap that never
        // engages (PagePool::adopt itself never fails)
        bail!("--cache-pages requires --prefill-chunk > 0 on the native/synthetic backend");
    }
    // speculative decoding: K drafted tokens per decode step, verified
    // exactly — the draft plane reuses the stored codes, so the flag
    // never changes output, only the number of decode iterations
    opts.speculate = args.usize("speculate", 0)?;
    if opts.speculate > 0 && backend == "pjrt" {
        bail!("--speculate requires the native or synthetic backend");
    }
    let draft_bits = args.get("draft-bits", "");
    if !draft_bits.is_empty() {
        if opts.speculate == 0 {
            bail!("--draft-bits shapes the speculative draft plane: needs --speculate > 0");
        }
        let d = DraftSpec::parse(&draft_bits).map_err(|e| anyhow::anyhow!("--draft-bits: {e}"))?;
        opts.draft_bits = Some((d.r_bits, d.t_bits));
    }
    let snapkv_budget = args.usize("snapkv-budget", 0)?;
    if snapkv_budget > 0 {
        if backend == "pjrt" {
            bail!("--snapkv-budget requires the native or synthetic backend");
        }
        if opts.prefill_chunk > 0 {
            bail!(
                "--snapkv-budget is incompatible with --prefill-chunk: SnapKV scores \
                 importance over the WHOLE prompt's attention, so prefill stays inline"
            );
        }
        let window = args.usize("snapkv-window", 8)?;
        if window == 0 || window > snapkv_budget {
            bail!("--snapkv-window must be in 1..=budget (got {window}, budget {snapkv_budget})");
        }
        opts.snapkv = Some(SnapKvOpts { budget: snapkv_budget, window });
    }
    let tier_dir = args.get("tier-dir", "");
    let tier = if tier_dir.is_empty() {
        None
    } else {
        if !opts.prefix_cache {
            bail!("--tier-dir requires --prefix-cache on (the tier stores prefix-index pages)");
        }
        Some((
            PathBuf::from(&tier_dir),
            args.u64("tier-bytes", 1 << 30)?,
            args.on_off("snapshot", true)?,
        ))
    };
    // queued-request ordering: fcfs (the default, bit-identical to
    // pre-tenancy builds) or deficit-weighted round robin across tenants
    opts.sched = match args.get("sched", "fcfs").as_str() {
        "fcfs" => SchedMode::Fcfs,
        "wfq" => SchedMode::Wfq,
        other => bail!("--sched takes fcfs|wfq, got '{other}'"),
    };
    let mut tenancy = TenancyOpts::default();
    let weights = args.get("tenant-weight", "");
    if !weights.is_empty() {
        if opts.sched != SchedMode::Wfq {
            bail!("--tenant-weight needs --sched wfq (weights are meaningless under fcfs)");
        }
        for part in weights.split(',').filter(|s| !s.is_empty()) {
            let Some((name, w)) = part.split_once('=') else {
                bail!("--tenant-weight entries are name=N, got '{part}'");
            };
            let (name, w) = (name.trim(), w.trim());
            let w: u32 = w
                .parse()
                .with_context(|| format!("--tenant-weight {name}: bad weight '{w}'"))?;
            if w == 0 {
                bail!("--tenant-weight {name}: weight must be >= 1");
            }
            if tenancy.weights.insert(name.to_string(), w).is_some() {
                bail!("--tenant-weight: tenant '{name}' listed twice");
            }
        }
    }
    tenancy.rate = args.f64("tenant-rate", 0.0)?;
    tenancy.burst = args.f64("tenant-burst", 0.0)?;
    if tenancy.rate < 0.0 || tenancy.burst < 0.0 {
        bail!("--tenant-rate / --tenant-burst must be non-negative");
    }
    if tenancy.burst > 0.0 && tenancy.rate == 0.0 {
        bail!("--tenant-burst needs --tenant-rate > 0 (burst caps a bucket that must refill)");
    }
    if tenancy.rate > 0.0 && tenancy.burst == 0.0 {
        // default burst: one second of refill, floored at a single request
        tenancy.burst = tenancy.rate.max(1.0);
    }
    tenancy.reserve_pages = args.usize("tenant-pages", 0)?;
    if tenancy.reserve_pages > 0 && !opts.prefix_cache {
        bail!("--tenant-pages reserves prefix-cache pages: needs --prefix-cache on");
    }
    let ttl = args.f64("session-ttl", 0.0)?;
    if ttl < 0.0 {
        bail!("--session-ttl must be non-negative seconds");
    }
    if ttl > 0.0 {
        if tier.is_none() {
            bail!("--session-ttl reaps idle session chains to the disk tier: needs --tier-dir");
        }
        tenancy.session_ttl = Some(std::time::Duration::from_secs_f64(ttl));
    }
    tenancy.tenant_tier_bytes = args.u64("tenant-tier-bytes", 0)?;
    if tenancy.tenant_tier_bytes > 0 && tier.is_none() {
        bail!("--tenant-tier-bytes caps reaped-session blobs on the disk tier: needs --tier-dir");
    }
    // shared prefix fabric: a directory of records or one designated peer
    let mut fabric = FabricOpts::default();
    let fabric_dir = args.get("fabric-dir", "");
    let fabric_peer = args.get("fabric-peer", "");
    if !fabric_dir.is_empty() && !fabric_peer.is_empty() {
        bail!("--fabric-dir and --fabric-peer are exclusive (one transport per node)");
    }
    if !fabric_dir.is_empty() || !fabric_peer.is_empty() {
        if !opts.prefix_cache {
            bail!("the prefix fabric shares cached prefix pages: needs --prefix-cache on");
        }
        if !fabric_dir.is_empty() {
            fabric.dir = Some(PathBuf::from(&fabric_dir));
        } else {
            fabric.peer = Some(fabric_peer);
        }
    }
    // request-lifecycle tracing (bounded ring per engine; a disabled
    // recorder is a single branch per event, so `off` costs nothing)
    opts.trace = args.on_off("trace", false)?;
    let export = args.get("trace-export", "");
    let trace_export = if export.is_empty() {
        None
    } else {
        if !opts.trace {
            bail!("--trace-export renders recorded events: needs --trace on");
        }
        let Some(path) = export.strip_prefix("chrome://") else {
            bail!("--trace-export takes chrome://PATH (only the Chrome trace_event sink exists)");
        };
        if path.is_empty() {
            bail!("--trace-export chrome://PATH needs a non-empty PATH");
        }
        Some(PathBuf::from(path))
    };
    Ok(EngineSpec { opts, backend, tier, tenancy, fabric, trace_export })
}

fn build_engine(args: &Args, worker: usize) -> Result<Engine> {
    let spec = engine_spec(args)?;
    let dir = artifacts(args);
    if let Some((r, t)) = spec.opts.draft_bits {
        // a draft plane can only DROP bits the exact plane stored, and
        // the exact plane lives in the model config — check here, where
        // the target config is known, so the engine never sees bad bits
        let exact = match spec.backend.as_str() {
            "native" => Manifest::load(&dir)?.config.polar_spec(),
            _ => polarquant::model::ModelConfig::tiny().polar_spec(),
        };
        DraftSpec::new(r, t)
            .shifts(&exact)
            .map_err(|e| anyhow::anyhow!("--draft-bits: {e}"))?;
    }
    let mut engine = match spec.backend.as_str() {
        "pjrt" => Engine::pjrt_from_artifacts(&dir, spec.opts)?,
        "native" => Engine::native_from_artifacts(&dir, spec.opts)?,
        _ => Engine::native_synthetic(
            polarquant::model::ModelConfig::tiny(),
            worker as u64,
            6.0,
            spec.opts,
        ),
    };
    if let Some((base, max_bytes, snapshot)) = spec.tier {
        // one pool per directory: each worker engine tiers into its own
        // subdir so segment files and snapshots never interleave
        let topts = TierOpts {
            dir: base.join(format!("worker-{worker}")),
            max_bytes,
            snapshot,
        };
        let restored = engine.attach_tier(&topts)?;
        eprintln!(
            "[engine {worker}] tier attached at {} ({restored} prefix entries restored, \
             {} bytes on disk)",
            topts.dir.display(),
            engine.page_pool().bytes_on_disk(),
        );
    }
    // after attach_tier so a --session-ttl engine reaps into a live tier
    engine.set_tenancy(&spec.tenancy);
    if spec.fabric.dir.is_some() || spec.fabric.peer.is_some() {
        // unlike the tier, the fabric is deliberately SHARED: every
        // worker (and every node) binds the same directory/peer so
        // prefixes cached anywhere serve cold misses everywhere
        let desc = engine.attach_fabric(&spec.fabric)?;
        eprintln!("[engine {worker}] prefix fabric attached: {desc}");
    }
    Ok(engine)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7733");
    let workers = args.usize("workers", 1)?;
    // validate the flag combination up front (cheap — no model is built),
    // and pre-flight the tier directory: an unwritable path must fail the
    // command here, not panic a worker thread after "serving on ..."
    let spec = engine_spec(args)?;
    if let Some((base, _, _)) = &spec.tier {
        std::fs::create_dir_all(base)
            .with_context(|| format!("--tier-dir {} is not writable", base.display()))?;
    }
    if let Some(dir) = &spec.fabric.dir {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("--fabric-dir {} is not writable", dir.display()))?;
    }
    let flags: HashMap<String, String> = args.flags.clone();
    let factory: polarquant::server::EngineFactory = Arc::new(move |w| {
        let args = Args { flags: flags.clone() };
        build_engine(&args, w).expect("engine construction failed")
    });
    let handle = serve_with_export(factory, &addr, workers, spec.trace_export.clone())?;
    println!(
        "serving on {} with {} workers (send {{\"admin\":\"shutdown\"}} to stop gracefully)",
        handle.addr, workers
    );
    // parks until a client requests shutdown; workers drain and snapshot
    // their tiers on the way out
    handle.wait();
    println!("server stopped");
    Ok(())
}

/// Parse + validate the front-tier flags.  Split from `cmd_route` so
/// tests can exercise the validation without binding a listener.
fn front_opts(args: &Args) -> Result<FrontOpts> {
    let backends: Vec<String> = args
        .get("backends", "")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().to_string())
        .collect();
    if backends.is_empty() {
        bail!("--backends needs at least one HOST:PORT (comma-separated)");
    }
    {
        let mut seen = std::collections::HashSet::new();
        for b in &backends {
            if !seen.insert(b.as_str()) {
                bail!("--backends: '{b}' listed twice (each node is one ring identity)");
            }
        }
    }
    let hedge = args.u64("hedge-after-ms", 0)?;
    if hedge > 0 && backends.len() < 2 {
        bail!("--hedge-after-ms re-dispatches to a SECOND node: needs >= 2 backends");
    }
    let heartbeat = args.u64("heartbeat-ms", 1000)?;
    if heartbeat == 0 {
        bail!("--heartbeat-ms must be > 0 (health probes keep the ring honest)");
    }
    Ok(FrontOpts {
        addr: args.get("addr", "127.0.0.1:7800"),
        backends,
        hedge_after: (hedge > 0).then(|| std::time::Duration::from_millis(hedge)),
        heartbeat: std::time::Duration::from_millis(heartbeat),
        vnodes: args.usize("vnodes", 64)?,
    })
}

fn cmd_route(args: &Args) -> Result<()> {
    let opts = front_opts(args)?;
    let n = opts.backends.len();
    let handle = polarquant::fabric::route(opts)?;
    println!(
        "front tier on {} over {n} backends (send {{\"admin\":\"shutdown\"}} to stop)",
        handle.addr
    );
    handle.wait();
    println!("front tier stopped");
    Ok(())
}

/// Comma-separated token-id list (`--prompt` / `--turn` / `--stop`).
fn parse_tokens(text: &str) -> Result<Vec<u32>> {
    text.split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse().context("bad token id"))
        .collect()
}

/// The sampling flags shared by `generate` and `client`.
fn gen_options(args: &Args) -> Result<GenOptions> {
    Ok(GenOptions {
        max_new_tokens: args.usize("max-tokens", 16)?,
        temperature: args.f64("temperature", 0.0)? as f32,
        top_k: args.usize("top-k", 0)?,
        top_p: args.f64("top-p", 1.0)? as f32,
        seed: args.u64("seed", 0)?,
        stop_tokens: parse_tokens(&args.get("stop", ""))?,
        logprobs: false, // the CLI surfaces tokens, not logprobs
        snapkv: None,
    })
}

fn cmd_generate(args: &Args) -> Result<()> {
    let prompt = parse_tokens(&args.get("prompt", "1,2,3"))?;
    let gen = gen_options(args)?;
    let mut engine = build_engine(args, 0)?;
    engine
        .submit(Request::new(1, prompt, gen))
        .map_err(|why| anyhow::anyhow!("request rejected: {}", why.as_str()))?;
    let done = engine.run_to_completion()?;
    let c = &done[0];
    println!("tokens: {:?}", c.tokens);
    println!(
        "ttft {:.2}ms total {:.2}ms ({} tokens, finish_reason {})",
        c.ttft_s.unwrap_or(0.0) * 1e3,
        c.total_s.unwrap_or(0.0) * 1e3,
        c.tokens.len(),
        c.finish_reason.as_str(),
    );
    println!("{}", engine.metrics.summary());
    if let Some((entries, bytes)) = engine.snapshot_tier()? {
        println!("tier snapshot written ({entries} prefix entries, {bytes} bytes on disk)");
    }
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7733");
    let mut client = Client::connect(&addr)?;
    match args.get("admin", "").as_str() {
        "" => {}
        "metrics" => {
            let v = client.metrics()?;
            println!("{}", json::write(&v));
            return Ok(());
        }
        "prometheus" => {
            // the exposition text, ready for a scrape or promtool check
            print!("{}", client.prometheus()?);
            return Ok(());
        }
        "trace" => {
            let (events, term) = client.trace()?;
            for ev in &events {
                println!("{}", json::write(ev));
            }
            println!("{}", json::write(&term));
            return Ok(());
        }
        "ping" => {
            let v = client.ping()?;
            println!("{}", json::write(&v));
            return Ok(());
        }
        "drain" => {
            let v = client.drain()?;
            println!("{}", json::write(&v));
            return Ok(());
        }
        "shutdown" => {
            client.shutdown()?;
            println!("shutdown requested");
            return Ok(());
        }
        other => {
            bail!(
                "unknown --admin command '{other}' \
                 (metrics | prometheus | trace | ping | drain | shutdown)"
            )
        }
    }
    let session = match args.get("session", "").as_str() {
        "" => None,
        s => Some(s.parse::<u64>().context("--session: expected an integer")?),
    };
    // session control frames
    match args.get("session-op", "").as_str() {
        "" => {}
        "open" => {
            let sid = client.open_session()?;
            println!("{{\"session\": {sid}}}");
            return Ok(());
        }
        "close" => {
            let sid = session.context("--session-op close needs --session N")?;
            client.close_session(sid)?;
            println!("{{\"session\": {sid}, \"closed\": true}}");
            return Ok(());
        }
        other => bail!("unknown --session-op '{other}' (open | close)"),
    }
    let gen = gen_options(args)?;
    let params = GenParams {
        max_tokens: gen.max_new_tokens,
        temperature: gen.temperature as f64,
        top_k: gen.top_k,
        top_p: gen.top_p as f64,
        seed: gen.seed,
        stop: gen.stop_tokens.clone(),
        tenant: args.get("tenant", ""),
    };
    let stream = args.on_off("stream", false)?;
    let cancel_after = args.usize("cancel-after", 0)?;
    if cancel_after > 0 && !stream {
        bail!("--cancel-after needs --stream on (cancel rides the event stream)");
    }
    let turn = args.get("turn", "");
    // the streamed-token callback: print each token as it lands and
    // cancel once `--cancel-after` tokens have arrived
    let mut seen = 0usize;
    let on_token = |t: &polarquant::server::TokenEvent| {
        if stream {
            println!(
                "{{\"token\": {}, \"index\": {}, \"logprob\": {:.4}}}",
                t.token, t.index, t.logprob
            );
        }
        seen += 1;
        cancel_after == 0 || seen < cancel_after
    };
    let r = if !turn.is_empty() {
        let sid = session.context("--turn needs --session N")?;
        client.turn(sid, &parse_tokens(&turn)?, &params, on_token)?
    } else {
        let prompt = parse_tokens(&args.get("prompt", "1,2,3"))?;
        let v2 = stream
            || params.temperature > 0.0
            || params.top_k > 0
            || params.top_p < 1.0
            || params.seed != 0
            || !params.stop.is_empty()
            || !params.tenant.is_empty();
        if v2 {
            client.generate_stream(&prompt, &params, session, on_token)?
        } else {
            client.generate(&prompt, params.max_tokens, session)?
        }
    };
    if r.rejected {
        bail!("request rejected: {}", r.reason.as_deref().unwrap_or("unknown"));
    }
    println!(
        "{{\"id\": {}, \"worker\": {}, \"tokens\": {:?}, \"ttft_ms\": {:.2}, \
         \"total_ms\": {:.2}, \"truncated\": {}, \"finish_reason\": \"{}\"}}",
        r.id, r.worker, r.tokens, r.ttft_ms, r.total_ms, r.truncated, r.finish_reason
    );
    Ok(())
}

fn cmd_fidelity(args: &Args) -> Result<()> {
    let profile_name = args.get("profile", "llama31-like");
    let profile = ActivationProfile::by_name(&profile_name)
        .with_context(|| format!("unknown profile '{profile_name}'"))?;
    let d = args.usize("d", 128)?;
    let tokens = args.usize("tokens", 512)?;
    let group = args.usize("group", 128)?;
    let mut t = Table::new(
        &format!("Key-cache fidelity — {profile_name} (d={d}, T={tokens})"),
        &["method", "bits", "key MSE", "attn KL", "top8"],
    );
    let specs = [
        QuantSpec::Fp16,
        QuantSpec::Polar { r_bits: 4, t_bits: 4, group },
        QuantSpec::Polar { r_bits: 3, t_bits: 3, group },
        QuantSpec::Kivi { bits: 4, group },
        QuantSpec::Kivi { bits: 2, group: 32 },
        QuantSpec::Int { bits: 4 },
        QuantSpec::Zip { bits: 4 },
        QuantSpec::Qjl { bits_per_channel: 3 },
    ];
    for spec in specs {
        let f = eval_codec(&spec, profile, d, tokens, 16, 42);
        t.row(vec![
            spec.label(),
            format!("{:.2}", f.bits),
            polarquant::eval::tables::sci(f.key_mse),
            polarquant::eval::tables::sci(f.attn_kl),
            format!("{:.3}", f.top8_overlap),
        ]);
    }
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    fn parse_ok(parts: &[&str], spec: &CmdSpec) -> Args {
        match Args::parse(&sv(parts), spec) {
            Ok(Parsed::Flags(a)) => a,
            Ok(Parsed::Help) => panic!("unexpected --help"),
            Err(e) => panic!("unexpected parse error: {e}"),
        }
    }

    #[test]
    fn parses_known_flags() {
        let a = parse_ok(&["--workers", "2", "--backend", "synthetic"], &SERVE);
        assert_eq!(a.usize("workers", 1).unwrap(), 2);
        assert_eq!(a.get("backend", "pjrt"), "synthetic");
        // defaults fill in for everything not given
        assert_eq!(a.usize("prefill-chunk", 0).unwrap(), 0);
        assert!(!a.on_off("prefix-cache", false).unwrap());
    }

    #[test]
    fn rejects_unknown_flags() {
        let err = Args::parse(&sv(&["--wrokers", "2"]), &SERVE).unwrap_err();
        assert!(err.contains("unknown flag --wrokers"), "{err}");
        // a flag valid for another subcommand is still unknown here
        let err = Args::parse(&sv(&["--profile", "x"]), &SERVE).unwrap_err();
        assert!(err.contains("unknown flag --profile"), "{err}");
    }

    #[test]
    fn rejects_trailing_flag_without_value() {
        let err = Args::parse(&sv(&["--workers"]), &SERVE).unwrap_err();
        assert!(err.contains("--workers expects a value"), "{err}");
        // ...and a flag whose "value" is the next flag
        let err = Args::parse(&sv(&["--prefix-cache", "--workers", "2"]), &SERVE).unwrap_err();
        assert!(err.contains("--prefix-cache expects a value"), "{err}");
    }

    #[test]
    fn rejects_positionals_and_duplicates() {
        let err = Args::parse(&sv(&["oops"]), &SERVE).unwrap_err();
        assert!(err.contains("unexpected argument 'oops'"), "{err}");
        let err = Args::parse(&sv(&["--workers", "1", "--workers", "2"]), &SERVE).unwrap_err();
        assert!(err.contains("given twice"), "{err}");
    }

    #[test]
    fn help_short_circuits_anywhere() {
        assert!(matches!(Args::parse(&sv(&["--help"]), &SERVE), Ok(Parsed::Help)));
        assert!(matches!(
            Args::parse(&sv(&["--workers", "2", "-h"]), &SERVE),
            Ok(Parsed::Help)
        ));
        // help text lists every flag with its default
        let u = usage(&SERVE);
        for f in SERVE.flags {
            assert!(u.contains(&format!("--{}", f.name)), "usage missing --{}: {u}", f.name);
        }
        assert!(global_usage().contains("client"));
    }

    #[test]
    fn typed_getters_reject_garbage() {
        let a = parse_ok(&["--workers", "two"], &SERVE);
        assert!(a.usize("workers", 1).is_err());
        let a = parse_ok(&["--prefix-cache", "maybe"], &SERVE);
        assert!(a.on_off("prefix-cache", false).is_err());
    }

    #[test]
    fn engine_spec_validates_flag_combinations() {
        let spec_of = |parts: &[&str]| engine_spec(&parse_ok(parts, &SERVE));
        // snapkv needs inline prefill
        let parts = ["--backend", "synthetic", "--snapkv-budget", "16", "--prefill-chunk", "8"];
        let err = spec_of(&parts).err().expect("snapkv + chunking must be rejected");
        assert!(format!("{err:#}").contains("incompatible"), "{err:#}");
        // window must fit the budget
        let parts = ["--backend", "synthetic", "--snapkv-budget", "4", "--snapkv-window", "9"];
        assert!(spec_of(&parts).is_err());
        // tier needs prefix caching
        let parts = ["--backend", "synthetic", "--tier-dir", "/tmp/x"];
        let err = spec_of(&parts).err().expect("tier without prefix cache must be rejected");
        assert!(format!("{err:#}").contains("--prefix-cache"), "{err:#}");
        // valid combinations pass without building a model
        let parts = ["--backend", "synthetic", "--snapkv-budget", "16"];
        assert!(spec_of(&parts).is_ok());
        let parts = [
            "--backend", "synthetic", "--prefill-chunk", "16", "--prefix-cache", "on",
            "--tier-dir", "/tmp/x",
        ];
        let spec = spec_of(&parts).unwrap();
        assert!(spec.tier.is_some());
        assert!(spec.opts.prefix_cache);
    }

    #[test]
    fn tenancy_flags_validate_and_parse() {
        let spec_of = |parts: &[&str]| engine_spec(&parse_ok(parts, &SERVE));
        // weights need wfq; burst needs a rate; ttl needs the tier;
        // page floors need the prefix cache
        assert!(spec_of(&["--backend", "synthetic", "--tenant-weight", "a=2"]).is_err());
        assert!(spec_of(&["--backend", "synthetic", "--tenant-burst", "4"]).is_err());
        assert!(spec_of(&["--backend", "synthetic", "--session-ttl", "5"]).is_err());
        assert!(spec_of(&["--backend", "synthetic", "--tenant-pages", "2"]).is_err());
        // malformed weight entries are rejected, not guessed at
        let base = ["--backend", "synthetic", "--sched", "wfq", "--tenant-weight"];
        for bad in ["a", "a=0", "a=x", "a=1,a=2"] {
            let parts: Vec<&str> = base.iter().copied().chain([bad]).collect();
            assert!(spec_of(&parts).is_err(), "--tenant-weight {bad} must be rejected");
        }
        assert!(spec_of(&["--backend", "synthetic", "--sched", "lifo"]).is_err());
        // a full valid combination lands in TenancyOpts
        let parts = [
            "--backend", "synthetic", "--prefill-chunk", "16", "--prefix-cache", "on",
            "--tier-dir", "/tmp/x", "--sched", "wfq", "--tenant-weight", "paid=4,free=1",
            "--tenant-rate", "10", "--tenant-pages", "2", "--session-ttl", "30",
        ];
        let spec = spec_of(&parts).unwrap();
        assert_eq!(spec.opts.sched, SchedMode::Wfq);
        assert_eq!(spec.tenancy.weights["paid"], 4);
        assert_eq!(spec.tenancy.weights["free"], 1);
        assert!((spec.tenancy.rate - 10.0).abs() < 1e-12);
        assert!(
            (spec.tenancy.burst - 10.0).abs() < 1e-12,
            "burst defaults to one second of refill"
        );
        assert_eq!(spec.tenancy.reserve_pages, 2);
        assert_eq!(spec.tenancy.session_ttl, Some(std::time::Duration::from_secs(30)));
        // no tenant flags: fcfs, no buckets, no ttl — the legacy shape
        let spec = spec_of(&["--backend", "synthetic"]).unwrap();
        assert_eq!(spec.opts.sched, SchedMode::Fcfs);
        assert!(spec.tenancy.weights.is_empty());
        assert_eq!(spec.tenancy.rate, 0.0);
        assert_eq!(spec.tenancy.session_ttl, None);
    }

    #[test]
    fn speculative_flags_validate_and_parse() {
        let spec_of = |parts: &[&str]| engine_spec(&parse_ok(parts, &SERVE));
        // off by default, and a bare --speculate parses on native/synthetic
        let spec = spec_of(&["--backend", "synthetic"]).unwrap();
        assert_eq!(spec.opts.speculate, 0);
        assert_eq!(spec.opts.draft_bits, None);
        let spec = spec_of(&["--backend", "synthetic", "--speculate", "3"]).unwrap();
        assert_eq!(spec.opts.speculate, 3);
        assert_eq!(spec.opts.draft_bits, None, "draft bits default to halved at engine build");
        // pjrt cannot speculate (no LUT decode path to verify through)
        assert!(spec_of(&["--speculate", "2"]).is_err());
        // draft bits require speculation and the R,T shape with 1..=8 bits
        assert!(spec_of(&["--backend", "synthetic", "--draft-bits", "2,2"]).is_err());
        for bad in ["2", "0,2", "2,9", "a,b"] {
            let parts = ["--backend", "synthetic", "--speculate", "2", "--draft-bits", bad];
            assert!(spec_of(&parts).is_err(), "--draft-bits {bad} must be rejected");
        }
        let parts = ["--backend", "synthetic", "--speculate", "2", "--draft-bits", "2,3"];
        assert_eq!(spec_of(&parts).unwrap().opts.draft_bits, Some((2, 3)));
        // generate shares both flags
        let a = parse_ok(&["--speculate", "4", "--draft-bits", "1,1"], &GENERATE);
        assert_eq!(a.usize("speculate", 0).unwrap(), 4);
        assert_eq!(a.get("draft-bits", ""), "1,1");
        // build_engine rejects a draft wider than the exact plane with a
        // clean CLI error (tiny()'s exact plane is r4/t4)
        let a = parse_ok(
            &["--backend", "synthetic", "--speculate", "2", "--draft-bits", "5,4"],
            &GENERATE,
        );
        let err = build_engine(&a, 0).err().expect("draft wider than exact must fail");
        assert!(format!("{err:#}").contains("exceed"), "{err:#}");
    }

    #[test]
    fn kernel_flag_is_validated_strictly() {
        let spec_of = |parts: &[&str]| engine_spec(&parse_ok(parts, &SERVE));
        // default and explicit valid names parse
        assert_eq!(spec_of(&["--backend", "synthetic"]).unwrap().opts.kernel, KernelKind::Auto);
        let parts = ["--backend", "synthetic", "--kernel", "scalar"];
        assert_eq!(spec_of(&parts).unwrap().opts.kernel, KernelKind::Scalar);
        // garbage is a clean CLI error naming the valid choices
        let parts = ["--backend", "synthetic", "--kernel", "gpu"];
        let err = spec_of(&parts).err().expect("bad kernel name must be rejected");
        assert!(format!("{err:#}").contains("auto|scalar|simd"), "{err:#}");
        // an explicit simd must be validated against this build/CPU up
        // front — accepted only when the vectorized path can really run
        let parts = ["--backend", "synthetic", "--kernel", "simd"];
        match spec_of(&parts) {
            Ok(spec) => {
                assert!(polarquant::quant::simd_available());
                assert_eq!(spec.opts.kernel, KernelKind::Simd);
            }
            Err(e) => {
                assert!(!polarquant::quant::simd_available());
                assert!(format!("{e:#}").contains("simd"), "{e:#}");
            }
        }
        // generate shares the flag
        let a = parse_ok(&["--kernel", "scalar"], &GENERATE);
        assert_eq!(a.get("kernel", "auto"), "scalar");
    }

    #[test]
    fn fabric_flags_validate_and_parse() {
        let spec_of = |parts: &[&str]| engine_spec(&parse_ok(parts, &SERVE));
        // off by default
        let spec = spec_of(&["--backend", "synthetic"]).unwrap();
        assert_eq!(spec.fabric.dir, None);
        assert_eq!(spec.fabric.peer, None);
        assert_eq!(spec.tenancy.tenant_tier_bytes, 0);
        // the fabric shares prefix pages: needs the prefix cache
        let parts = ["--backend", "synthetic", "--fabric-dir", "/tmp/fab"];
        let err = spec_of(&parts).err().expect("fabric without prefix cache must be rejected");
        assert!(format!("{err:#}").contains("--prefix-cache"), "{err:#}");
        // one transport per node
        let base = [
            "--backend", "synthetic", "--prefill-chunk", "16", "--prefix-cache", "on",
        ];
        let parts: Vec<&str> = base
            .iter()
            .copied()
            .chain(["--fabric-dir", "/tmp/fab", "--fabric-peer", "h:1"])
            .collect();
        let err = spec_of(&parts).err().expect("dir + peer must be rejected");
        assert!(format!("{err:#}").contains("exclusive"), "{err:#}");
        // each transport alone parses
        let parts: Vec<&str> =
            base.iter().copied().chain(["--fabric-dir", "/tmp/fab"]).collect();
        let spec = spec_of(&parts).unwrap();
        assert_eq!(spec.fabric.dir, Some(PathBuf::from("/tmp/fab")));
        assert_eq!(spec.fabric.peer, None);
        let parts: Vec<&str> =
            base.iter().copied().chain(["--fabric-peer", "127.0.0.1:7801"]).collect();
        let spec = spec_of(&parts).unwrap();
        assert_eq!(spec.fabric.peer.as_deref(), Some("127.0.0.1:7801"));
        // the per-tenant session-blob cap rides the disk tier
        let parts = ["--backend", "synthetic", "--tenant-tier-bytes", "4096"];
        let err = spec_of(&parts).err().expect("cap without tier must be rejected");
        assert!(format!("{err:#}").contains("--tier-dir"), "{err:#}");
        let parts = [
            "--backend", "synthetic", "--prefill-chunk", "16", "--prefix-cache", "on",
            "--tier-dir", "/tmp/x", "--tenant-tier-bytes", "4096",
        ];
        assert_eq!(spec_of(&parts).unwrap().tenancy.tenant_tier_bytes, 4096);
    }

    #[test]
    fn route_flags_validate_and_parse() {
        let opts_of = |parts: &[&str]| front_opts(&parse_ok(parts, &ROUTE));
        // backends are required, comma-separated, and unique
        let err = opts_of(&[]).err().expect("no backends must be rejected");
        assert!(format!("{err:#}").contains("--backends"), "{err:#}");
        let err = opts_of(&["--backends", "a:1,a:1"]).err().expect("dup backend");
        assert!(format!("{err:#}").contains("listed twice"), "{err:#}");
        // hedging needs somewhere to hedge TO
        let err = opts_of(&["--backends", "a:1", "--hedge-after-ms", "50"])
            .err()
            .expect("hedge on one node must be rejected");
        assert!(format!("{err:#}").contains(">= 2 backends"), "{err:#}");
        assert!(opts_of(&["--backends", "a:1", "--heartbeat-ms", "0"]).is_err());
        // a full valid line lands in FrontOpts
        let o = opts_of(&[
            "--addr", "127.0.0.1:7800", "--backends", "a:1, b:2", "--hedge-after-ms", "250",
            "--heartbeat-ms", "100", "--vnodes", "16",
        ])
        .unwrap();
        assert_eq!(o.addr, "127.0.0.1:7800");
        assert_eq!(o.backends, vec!["a:1".to_string(), "b:2".to_string()]);
        assert_eq!(o.hedge_after, Some(std::time::Duration::from_millis(250)));
        assert_eq!(o.heartbeat, std::time::Duration::from_millis(100));
        assert_eq!(o.vnodes, 16);
        // defaults: no hedging, 1s heartbeat, 64 vnodes
        let o = opts_of(&["--backends", "a:1"]).unwrap();
        assert_eq!(o.hedge_after, None);
        assert_eq!(o.heartbeat, std::time::Duration::from_millis(1000));
        assert_eq!(o.vnodes, 64);
        // the route spec rejects serve-only flags
        assert!(Args::parse(&sv(&["--workers", "2"]), &ROUTE).is_err());
    }

    #[test]
    fn trace_flags_validate_and_parse() {
        let spec_of = |parts: &[&str]| engine_spec(&parse_ok(parts, &SERVE));
        // off by default: the engines get disabled recorders and nothing
        // is exported
        let spec = spec_of(&["--backend", "synthetic"]).unwrap();
        assert!(!spec.opts.trace);
        assert_eq!(spec.trace_export, None);
        let spec = spec_of(&["--backend", "synthetic", "--trace", "on"]).unwrap();
        assert!(spec.opts.trace);
        assert_eq!(spec.trace_export, None);
        // an export target without tracing records nothing — reject it
        let parts = ["--backend", "synthetic", "--trace-export", "chrome://t.json"];
        let err = spec_of(&parts).err().expect("export without --trace on must be rejected");
        assert!(format!("{err:#}").contains("--trace on"), "{err:#}");
        // only the chrome:// sink exists, and it needs a real path
        for bad in ["t.json", "chrome://"] {
            let parts = ["--backend", "synthetic", "--trace", "on", "--trace-export", bad];
            assert!(spec_of(&parts).is_err(), "--trace-export {bad} must be rejected");
        }
        let parts =
            ["--backend", "synthetic", "--trace", "on", "--trace-export", "chrome://t.json"];
        let spec = spec_of(&parts).unwrap();
        assert_eq!(spec.trace_export, Some(PathBuf::from("t.json")));
        // the client spec knows the admin drain commands
        let a = parse_ok(&["--admin", "trace"], &CLIENT);
        assert_eq!(a.get("admin", ""), "trace");
        let a = parse_ok(&["--admin", "prometheus"], &CLIENT);
        assert_eq!(a.get("admin", ""), "prometheus");
    }
}
