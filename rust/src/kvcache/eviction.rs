//! SnapKV-style prompt compression (paper §5.2, Table 8).
//!
//! SnapKV selects, *before* the prompt's keys enter the cache, the tokens
//! that the last `window` prompt positions attend to most, keeps the
//! top-(budget − window) of them plus the window itself, and drops the
//! rest.  PolarQuant then quantizes only the survivors — the combination
//! the paper's Table 8 evaluates.

/// Importance = column-sum of attention weights from the observation
/// window (post-softmax), optionally max-pooled over a small neighborhood
/// (SnapKV's pooling trick to keep local context).
pub fn importance_from_attention(
    attn: &[f32],
    t: usize,
    window: usize,
    pool: usize,
) -> Vec<f32> {
    // attn: (window, t) rows = last `window` query positions
    assert_eq!(attn.len(), window * t);
    let mut score = vec![0.0f32; t];
    for w in 0..window {
        for j in 0..t {
            score[j] += attn[w * t + j];
        }
    }
    if pool > 1 {
        let mut pooled = vec![0.0f32; t];
        let half = pool / 2;
        for j in 0..t {
            let lo = j.saturating_sub(half);
            let hi = (j + half + 1).min(t);
            pooled[j] = score[lo..hi].iter().cloned().fold(0.0, f32::max);
        }
        score = pooled;
    }
    score
}

/// Select which prompt token indices to keep: the observation window
/// (last `window` tokens) plus the top-scoring earlier tokens up to
/// `budget` total.  Returns sorted indices.
pub fn snapkv_select(scores: &[f32], budget: usize, window: usize) -> Vec<usize> {
    let t = scores.len();
    if t <= budget {
        return (0..t).collect();
    }
    let window = window.min(budget).min(t);
    let keep_from_past = budget - window;
    let past = t - window;
    let mut idx: Vec<usize> = (0..past).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut keep: Vec<usize> = idx.into_iter().take(keep_from_past).collect();
    keep.extend(t - window..t);
    keep.sort_unstable();
    keep
}

/// Gather kept rows of a (t x d) buffer into a new contiguous buffer.
pub fn gather_rows(x: &[f32], d: usize, keep: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(keep.len() * d);
    for &i in keep {
        out.extend_from_slice(&x[i * d..(i + 1) * d]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_window_and_heavy_hitters() {
        let t = 10;
        let mut scores = vec![0.0f32; t];
        scores[2] = 5.0; // heavy hitter
        scores[4] = 3.0;
        let keep = snapkv_select(&scores, 4, 2);
        assert_eq!(keep, vec![2, 4, 8, 9]);
    }

    #[test]
    fn small_prompts_untouched() {
        let scores = vec![1.0; 5];
        assert_eq!(snapkv_select(&scores, 8, 4), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn budget_is_respected() {
        let scores: Vec<f32> = (0..100).map(|i| (i % 7) as f32).collect();
        let keep = snapkv_select(&scores, 32, 16);
        assert_eq!(keep.len(), 32);
        // window present
        for i in 84..100 {
            assert!(keep.contains(&i));
        }
    }

    #[test]
    fn importance_pools_neighbors() {
        let t = 6;
        let window = 1;
        let attn = vec![0.0, 0.0, 1.0, 0.0, 0.0, 0.0];
        let s = importance_from_attention(&attn, t, window, 3);
        assert_eq!(s[1], 1.0); // neighbor of the peak
        assert_eq!(s[2], 1.0);
        assert_eq!(s[3], 1.0);
        assert_eq!(s[5], 0.0);
    }

    #[test]
    fn gather_rows_layout() {
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect(); // 4 x 3
        let g = gather_rows(&x, 3, &[0, 2]);
        assert_eq!(g, vec![0.0, 1.0, 2.0, 6.0, 7.0, 8.0]);
    }
}
