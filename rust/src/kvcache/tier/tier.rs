//! Tier policy plumbing: the demotion queue + background writer, the
//! shared tier counters, and the persistent prefix-index snapshot codec.
//!
//! The policy itself (WHEN to demote, WHAT a lookup promotes) lives in
//! [`crate::kvcache::pool::PagePool`] because it is inseparable from the
//! prefix index's state machine; this module owns everything that runs
//! OFF the engine thread and everything that touches the snapshot file.
//!
//! Demotion protocol: the reclaim path never writes to disk.  It flips a
//! refcount-zero prefix entry to `Queued`, hands its `Arc<Page>` to a
//! bounded channel, and moves on — `demote_inflight` discounts queued
//! pages from the pool's capacity check so the reclaim takes effect
//! immediately (the RAM itself frees moments later, when the writer
//! finishes the record and drops the last `Arc`; transient overshoot is
//! bounded by the queue depth).  If the channel is full the page is
//! simply evicted instead — demotion is an optimization, never a stall.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;

use anyhow::{ensure, Context, Result};

use super::serde::{fnv1a, Cur};
use super::store::{SegmentStore, TierRef};
use crate::kvcache::pool::{Page, PrefixIndex, Slot};

/// Configuration for attaching a tier to a [`crate::kvcache::PagePool`].
#[derive(Clone, Debug)]
pub struct TierConfig {
    /// Directory for segment files + the snapshot index.  One pool per
    /// directory — engines in a multi-worker server each get a subdir.
    pub dir: PathBuf,
    /// Stop demoting (fall back to plain eviction) once the segments
    /// reach this size; promotion keeps working.
    pub max_bytes: u64,
    /// Fingerprint of the model/codec config the pages were cut under; a
    /// snapshot written under a different tag is ignored at restore.
    pub config_tag: u64,
    /// Demotion queue depth — bounds both the writer backlog and the
    /// transient capacity overshoot while writes land.
    pub queue_depth: usize,
}

impl TierConfig {
    pub fn new(dir: PathBuf, max_bytes: u64, config_tag: u64) -> Self {
        TierConfig { dir, max_bytes, config_tag, queue_depth: 64 }
    }
}

/// Monotone counters + gauges for the tier, readable without the index
/// lock (the engine mirrors them into its metrics every step).
#[derive(Debug, Default)]
pub struct TierCounters {
    /// prefix lookups that promoted at least one page from disk
    pub tier_hits: AtomicU64,
    /// pages written to segments by the background writer / demote_all
    pub pages_demoted: AtomicU64,
    /// pages read back and re-adopted on a prefix hit
    pub pages_promoted: AtomicU64,
    /// current segment bytes (gauge, mirrored from the store)
    pub bytes_on_disk: AtomicU64,
    /// pages queued to the writer whose RAM has not yet been released —
    /// discounted from the pool's capacity check
    pub demote_inflight: AtomicUsize,
    /// demotions skipped because the writer queue was full (the page was
    /// plainly evicted instead)
    pub demote_overflow: AtomicU64,
    /// segment bytes currently held by reaped session blobs (gauge: a
    /// slice of `bytes_on_disk`; spills add, fetches subtract) — they
    /// share the `--tier-bytes` budget with demoted prefix pages
    pub session_bytes: AtomicU64,
}

/// One queued demotion: the prefix-index key plus the page to persist.
pub(crate) struct DemoteJob {
    pub hash: u64,
    pub page: Arc<Page>,
}

/// The tier half that lives inside the prefix index (everything it
/// guards is index state or reached from index operations).
pub(crate) struct TierBackend {
    pub(crate) store: Arc<SegmentStore>,
    /// `None` once a snapshot has sealed the tier (no further demotion;
    /// promotion keeps working)
    pub(crate) tx: Option<SyncSender<DemoteJob>>,
    pub(crate) writer: Option<JoinHandle<()>>,
    pub(crate) max_bytes: u64,
    pub(crate) dir: PathBuf,
    pub(crate) config_tag: u64,
}

/// Background writer: drains the demotion queue, appends each page to
/// the segment store, then flips the index entry `Queued -> Tiered` so
/// its RAM can go.  Holds only a `Weak` to the index — dropping the last
/// pool handle closes the channel and the thread exits on its own.
pub(crate) fn spawn_writer(
    index: Weak<Mutex<PrefixIndex>>,
    store: Arc<SegmentStore>,
    stats: Arc<TierCounters>,
    trace: crate::trace::TraceSlot,
    rx: Receiver<DemoteJob>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("tier-writer".into())
        .spawn(move || {
            while let Ok(job) = rx.recv() {
                let res = store.put(&job.page);
                stats.bytes_on_disk.store(store.bytes_on_disk(), Ordering::Relaxed);
                let Some(ix) = index.upgrade() else { break };
                {
                    let mut idx = ix.lock().unwrap();
                    if let Some(e) = idx.entries.get_mut(&job.hash) {
                        // only flip if the entry still queues THIS page;
                        // if a lookup re-promoted it mid-write, record
                        // the landed copy so a later demotion is free.
                        // A displacement-replaced entry is left alone.
                        let queued_here =
                            matches!(&e.slot, Slot::Queued(p) if Arc::ptr_eq(p, &job.page));
                        let repromoted_here = matches!(
                            &e.slot,
                            Slot::Resident(p, None) if Arc::ptr_eq(p, &job.page)
                        );
                        if queued_here {
                            match res {
                                Ok(tref) => {
                                    e.slot = Slot::Tiered(tref);
                                    stats.pages_demoted.fetch_add(1, Ordering::Relaxed);
                                    // background work: not tied to a request
                                    if let Some(tr) = trace.get() {
                                        tr.record(
                                            0,
                                            crate::trace::TraceKind::PageDemote { pages: 1 },
                                        );
                                    }
                                }
                                Err(ref err) => {
                                    // disk refused: keep the page resident
                                    // and reclaimable the ordinary way
                                    eprintln!("[tier] demotion write failed: {err:#}");
                                    e.slot = Slot::Resident(job.page.clone(), None);
                                }
                            }
                        } else if repromoted_here {
                            if let Ok(tref) = res {
                                e.slot = Slot::Resident(job.page.clone(), Some(tref));
                            }
                        }
                    }
                }
                drop(job.page);
                stats.demote_inflight.fetch_sub(1, Ordering::Relaxed);
            }
        })
        .expect("spawning tier writer")
}

// ------------------------------------------------- snapshot index codec

const INDEX_MAGIC: u32 = 0x5051_4958; // "PQIX"
const INDEX_VERSION: u16 = 1;
const INDEX_FILE: &str = "prefix-index.bin";

/// One persisted prefix-index entry: enough to re-verify the chain
/// (`parent` + exact tokens) and to find the page on disk.
pub(crate) struct SnapshotEntry {
    pub parent: u64,
    pub toks: Vec<u32>,
    pub tref: TierRef,
}

/// Write the snapshot index atomically (tmp + rename).
pub(crate) fn write_snapshot(dir: &Path, config_tag: u64, entries: &[SnapshotEntry]) -> Result<()> {
    let mut buf = Vec::with_capacity(32 + entries.len() * 64);
    buf.extend_from_slice(&INDEX_MAGIC.to_le_bytes());
    buf.extend_from_slice(&INDEX_VERSION.to_le_bytes());
    buf.extend_from_slice(&[0u8; 2]); // reserved
    buf.extend_from_slice(&config_tag.to_le_bytes());
    buf.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for e in entries {
        buf.extend_from_slice(&e.parent.to_le_bytes());
        buf.extend_from_slice(&(e.toks.len() as u32).to_le_bytes());
        for t in &e.toks {
            buf.extend_from_slice(&t.to_le_bytes());
        }
        buf.extend_from_slice(&e.tref.seg.to_le_bytes());
        buf.extend_from_slice(&e.tref.off.to_le_bytes());
        buf.extend_from_slice(&e.tref.len.to_le_bytes());
    }
    let sum = fnv1a(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    let tmp = dir.join(format!("{INDEX_FILE}.tmp"));
    std::fs::write(&tmp, &buf).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, dir.join(INDEX_FILE)).context("renaming snapshot index")?;
    Ok(())
}

/// Read the snapshot index.  `Ok(None)` means no snapshot (cold start);
/// `Err` means a snapshot exists but is unreadable — the caller warns
/// and starts cold rather than trusting it.  A `config_tag` mismatch is
/// an error too: pages cut under a different model/codec must never be
/// shared into this pool.
pub(crate) fn read_snapshot(dir: &Path, config_tag: u64) -> Result<Option<Vec<SnapshotEntry>>> {
    let path = dir.join(INDEX_FILE);
    let buf = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    ensure!(buf.len() >= 4 + 2 + 2 + 8 + 4 + 8, "snapshot index too short");
    let (body, tail) = buf.split_at(buf.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().unwrap());
    ensure!(fnv1a(body) == want, "snapshot index checksum mismatch");
    let mut c = Cur::new(body);
    let magic = c.u32()?;
    ensure!(magic == INDEX_MAGIC, "snapshot index bad magic {magic:#x}");
    let version = c.u16()?;
    ensure!(version == INDEX_VERSION, "snapshot index version {version}");
    c.take(2)?; // reserved
    let tag = c.u64()?;
    ensure!(
        tag == config_tag,
        "snapshot index config tag {tag:#x} != this engine's {config_tag:#x} \
         (pages from a different model/codec config)"
    );
    let n = c.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let parent = c.u64()?;
        let ntoks = c.u32()? as usize;
        let toks = c.u32s(ntoks)?;
        let seg = c.u32()?;
        let off = c.u64()?;
        let len = c.u32()?;
        out.push(SnapshotEntry { parent, toks, tref: TierRef { seg, off, len } });
    }
    ensure!(c.done(), "snapshot index trailing bytes");
    Ok(Some(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("polarquant-tiersnap-test-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn snapshot_index_roundtrip() {
        let dir = tmp("roundtrip");
        let entries = vec![
            SnapshotEntry {
                parent: 0xdead_beef,
                toks: vec![1, 2, 3, 4],
                tref: TierRef { seg: 0, off: 0, len: 100 },
            },
            SnapshotEntry {
                parent: 42,
                toks: vec![9; 7],
                tref: TierRef { seg: 3, off: 4096, len: 17 },
            },
        ];
        write_snapshot(&dir, 7777, &entries).unwrap();
        let back = read_snapshot(&dir, 7777).unwrap().expect("snapshot present");
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].parent, 0xdead_beef);
        assert_eq!(back[0].toks, vec![1, 2, 3, 4]);
        assert_eq!(back[1].tref, TierRef { seg: 3, off: 4096, len: 17 });
        // missing file is a clean cold start
        let empty = tmp("empty");
        assert!(read_snapshot(&empty, 7777).unwrap().is_none());
        // a different config tag is rejected
        assert!(read_snapshot(&dir, 8888).is_err());
        // corruption is rejected
        let path = dir.join(INDEX_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, bytes).unwrap();
        assert!(read_snapshot(&dir, 7777).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&empty).unwrap();
    }
}
