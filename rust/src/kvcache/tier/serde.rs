//! Compact, versioned binary codec for [`Page`] — the unit the tiered
//! store writes to disk.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! u32 magic "PQPG"   u16 version   u16 flags (0)
//! u32 tokens         u32 n_streams
//! per stream:
//!   key group : u32 d2, 4 * d2 f32 params (rho_z, rho_s, theta_z, theta_s),
//!               packed rho codes, packed theta codes
//!   values    : u8 tag (0 = fp, 1 = quant)
//!               fp    -> u32 len, len f32
//!               quant -> u32 tokens, tokens f32 z, tokens f32 s, packed codes
//! u64 fnv1a-64 checksum over every preceding byte
//! ```
//!
//! A packed code stream is `u8 bits, u32 n, <packed bytes>` — the exact
//! at-rest bytes from [`crate::quant::pack::PackedCodes`], so
//! encode→decode is bit-for-bit: dequantization of a promoted page is the
//! same arithmetic on the same codes and the same param bit patterns.
//! The byte count is a function of the record version:
//!
//! * **v2 (current)** — pack layout v2 lane bytes
//!   ([`crate::quant::pack::lane_nbytes`]); key code planes are
//!   channel-major, matching the in-memory [`PolarGroup`] layout.
//! * **v1 (legacy, read-only)** — the tight `ceil(n*bits/8)` bitstream
//!   written before the pack-layout bump, with key codes token-major.
//!   On decode the codes are unpacked bit-exactly, key planes transposed
//!   to channel-major, and everything repacked into v2 lanes — so a
//!   promoted v1 page is indistinguishable from one encoded today, and
//!   its next demotion rewrites it as v2.
//!
//! The fused `combined` plane (see [`PolarGroup::combined`]) is NOT
//! stored: it is a pure function of the rho/theta planes and is rebuilt
//! at decode, byte-identical to what `encode_group` would have produced.
//!
//! Decoding is fully checked: the checksum is verified before parsing,
//! every length field is bounds-checked against the buffer, and trailing
//! garbage is rejected — a corrupt record yields `Err`, never a panic and
//! never a silently wrong page.

use anyhow::{bail, ensure, Result};

use crate::kvcache::pool::Page;
use crate::kvcache::stream::GroupValues;
use crate::quant::int_n::IntEncoded;
use crate::quant::pack::{lane_nbytes, PackedCodes};
use crate::quant::polar::PolarGroup;

pub const PAGE_MAGIC: u32 = 0x5051_5047; // "PQPG"
/// v2: pack-layout-v2 lane bytes, channel-major key planes.  v1 records
/// (tight bitstream, token-major keys) remain readable — see module doc.
pub const PAGE_VERSION: u16 = 2;
/// Oldest record version [`decode_page`] still reads.
pub const PAGE_VERSION_MIN: u16 = 1;

/// FNV-1a 64 — the same cheap deterministic hash family the prefix index
/// chains with; here it guards against torn/corrupt segment records.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ------------------------------------------------------------- writing

// the little-endian writers are shared with the session-chain codec in
// `super::session` (same record style: body + fnv1a trailer)

pub(crate) fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_packed(buf: &mut Vec<u8>, p: &PackedCodes) {
    buf.push(p.bits as u8);
    put_u32(buf, p.n as u32);
    buf.extend_from_slice(p.as_bytes());
}

/// Serialize one page into a self-contained checksummed record.
pub fn encode_page(page: &Page) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + page.nbytes());
    put_u32(&mut buf, PAGE_MAGIC);
    put_u16(&mut buf, PAGE_VERSION);
    put_u16(&mut buf, 0); // flags, reserved
    put_u32(&mut buf, page.tokens as u32);
    put_u32(&mut buf, page.keys.len() as u32);
    for (g, v) in page.keys.iter().zip(&page.vals) {
        put_u32(&mut buf, g.rho_z.len() as u32);
        put_f32s(&mut buf, &g.rho_z);
        put_f32s(&mut buf, &g.rho_s);
        put_f32s(&mut buf, &g.theta_z);
        put_f32s(&mut buf, &g.theta_s);
        put_packed(&mut buf, &g.rho_codes);
        put_packed(&mut buf, &g.theta_codes);
        match v {
            GroupValues::Fp(x) => {
                buf.push(0);
                put_u32(&mut buf, x.len() as u32);
                put_f32s(&mut buf, x);
            }
            GroupValues::Quant(e) => {
                buf.push(1);
                put_u32(&mut buf, e.z.len() as u32);
                put_f32s(&mut buf, &e.z);
                put_f32s(&mut buf, &e.s);
                put_packed(&mut buf, &e.codes);
            }
        }
    }
    let sum = fnv1a(&buf);
    put_u64(&mut buf, sum);
    buf
}

// ------------------------------------------------------------- reading

/// Bounds-checked cursor over an untrusted buffer — shared by the page
/// codec here and the snapshot-index codec in `super::tier`.
pub(crate) struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Self {
        Cur { b, p: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.b.len() - self.p,
            "tier record truncated: want {n} bytes at {}, have {}",
            self.p,
            self.b.len() - self.p
        );
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("length overflow"))?)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub(crate) fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("length overflow"))?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// One packed code stream at the record's version: v2 lane bytes or
    /// the legacy v1 tight bitstream.
    fn packed(&mut self, version: u16) -> Result<PackedCodes> {
        let bits = self.u8()? as u32;
        ensure!((1..=8).contains(&bits), "packed stream: bad bit width {bits}");
        let n = self.u32()? as usize;
        if version == 1 {
            let raw = self.take((n * bits as usize).div_ceil(8))?;
            PackedCodes::from_raw_v1(bits, n, raw.to_vec()).map_err(anyhow::Error::msg)
        } else {
            let raw = self.take(lane_nbytes(bits, n))?;
            PackedCodes::from_raw(bits, n, raw.to_vec()).map_err(anyhow::Error::msg)
        }
    }

    pub(crate) fn done(&self) -> bool {
        self.p == self.b.len()
    }
}

/// Rebuild the fused (rho << t_bits | theta) plane when it exists —
/// byte-identical to `polar::encode_group`'s construction.
fn rebuild_combined(rc: &PackedCodes, tc: &PackedCodes) -> Option<PackedCodes> {
    if rc.bits + tc.bits <= 8 {
        let r = rc.unpack();
        let t = tc.unpack();
        let mixed: Vec<u8> = r.iter().zip(&t).map(|(&r, &t)| (r << tc.bits) | t).collect();
        Some(PackedCodes::from_codes(&mixed, rc.bits + tc.bits))
    } else {
        None
    }
}

/// Unpack a legacy token-major key-code plane and repack it as a
/// channel-major v2 lane plane — code values are untouched, so the
/// migrated group is bit-identical to one encoded by the current writer
/// from the same data.
fn migrate_v1_key_plane(p: &PackedCodes, tokens: usize, d2: usize) -> PackedCodes {
    let old = p.unpack(); // token-major: old[n * d2 + j]
    let mut cm = vec![0u8; old.len()];
    for n in 0..tokens {
        for j in 0..d2 {
            cm[j * tokens + n] = old[n * d2 + j];
        }
    }
    PackedCodes::from_codes(&cm, p.bits)
}

/// Parse and verify one record.  Any corruption — bad magic, unknown
/// version, failed checksum, inconsistent lengths, trailing bytes —
/// returns `Err`.  Version-1 records are migrated to the in-memory v2
/// layout on the fly (see module doc).
pub fn decode_page(buf: &[u8]) -> Result<Page> {
    ensure!(buf.len() >= 4 + 2 + 2 + 4 + 4 + 8, "tier record too short ({} bytes)", buf.len());
    let (body, tail) = buf.split_at(buf.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().unwrap());
    ensure!(fnv1a(body) == want, "tier record checksum mismatch");

    let mut c = Cur::new(body);
    let magic = c.u32()?;
    ensure!(magic == PAGE_MAGIC, "tier record bad magic {magic:#x}");
    let version = c.u16()?;
    ensure!(
        (PAGE_VERSION_MIN..=PAGE_VERSION).contains(&version),
        "tier record version {version} (reader handles v{PAGE_VERSION_MIN}..=v{PAGE_VERSION})"
    );
    let _flags = c.u16()?;
    let tokens = c.u32()? as usize;
    let n_streams = c.u32()? as usize;
    ensure!(tokens > 0, "tier record: zero-token page");
    ensure!(n_streams > 0, "tier record: zero streams");

    let mut keys = Vec::with_capacity(n_streams.min(4096));
    let mut vals = Vec::with_capacity(n_streams.min(4096));
    for _ in 0..n_streams {
        let d2 = c.u32()? as usize;
        let rho_z = c.f32s(d2)?;
        let rho_s = c.f32s(d2)?;
        let theta_z = c.f32s(d2)?;
        let theta_s = c.f32s(d2)?;
        let mut rho_codes = c.packed(version)?;
        let mut theta_codes = c.packed(version)?;
        ensure!(
            rho_codes.n == tokens * d2 && theta_codes.n == tokens * d2,
            "tier record: code count disagrees with geometry"
        );
        if version == 1 {
            // pre-bump key planes are token-major bitstreams; everything
            // downstream (the SIMD kernel above all) assumes channel-major
            // v2 lanes
            rho_codes = migrate_v1_key_plane(&rho_codes, tokens, d2);
            theta_codes = migrate_v1_key_plane(&theta_codes, tokens, d2);
        }
        let combined = rebuild_combined(&rho_codes, &theta_codes);
        keys.push(PolarGroup {
            rho_codes,
            theta_codes,
            combined,
            rho_z,
            rho_s,
            theta_z,
            theta_s,
            tokens,
        });
        match c.u8()? {
            0 => {
                let len = c.u32()? as usize;
                ensure!(len % tokens == 0, "tier record: fp value len not token-aligned");
                vals.push(GroupValues::Fp(c.f32s(len)?));
            }
            1 => {
                let vt = c.u32()? as usize;
                ensure!(vt == tokens, "tier record: value token count disagrees");
                let z = c.f32s(vt)?;
                let s = c.f32s(vt)?;
                let mut codes = c.packed(version)?;
                if version == 1 {
                    // value codes keep their logical order; only the
                    // physical packing moves to v2 lanes
                    codes = PackedCodes::from_codes(&codes.unpack(), codes.bits);
                }
                let bits = codes.bits;
                ensure!(codes.n % vt == 0, "tier record: value code count not token-aligned");
                vals.push(GroupValues::Quant(IntEncoded { codes, z, s, bits }));
            }
            t => bail!("tier record: unknown value tag {t}"),
        }
    }
    ensure!(c.done(), "tier record: {} trailing bytes", body.len() - c.p);
    Ok(Page::new(keys, vals, tokens))
}

/// Replicates the PRE-BUMP (PAGE_VERSION 1) writer byte-for-byte: tight
/// little-endian bitstreams, key code planes token-major.  Test-only —
/// production code never writes v1 — but kept faithful so the migration
/// tests (here and in `super::store`) exercise real legacy segment
/// bytes.
#[cfg(test)]
pub(crate) fn encode_page_v1(page: &Page) -> Vec<u8> {
    let to_v1_token_major = |p: &PackedCodes, tokens: usize, d2: usize| {
        let cm = p.unpack(); // in-memory layout is channel-major
        let mut tm = vec![0u8; cm.len()];
        for n in 0..tokens {
            for j in 0..d2 {
                tm[n * d2 + j] = cm[j * tokens + n];
            }
        }
        PackedCodes::from_codes_v1(&tm, p.bits)
    };
    let mut buf = Vec::new();
    put_u32(&mut buf, PAGE_MAGIC);
    put_u16(&mut buf, 1);
    put_u16(&mut buf, 0);
    put_u32(&mut buf, page.tokens as u32);
    put_u32(&mut buf, page.keys.len() as u32);
    for (g, v) in page.keys.iter().zip(&page.vals) {
        let d2 = g.rho_z.len();
        put_u32(&mut buf, d2 as u32);
        put_f32s(&mut buf, &g.rho_z);
        put_f32s(&mut buf, &g.rho_s);
        put_f32s(&mut buf, &g.theta_z);
        put_f32s(&mut buf, &g.theta_s);
        put_packed(&mut buf, &to_v1_token_major(&g.rho_codes, g.tokens, d2));
        put_packed(&mut buf, &to_v1_token_major(&g.theta_codes, g.tokens, d2));
        match v {
            GroupValues::Fp(x) => {
                buf.push(0);
                put_u32(&mut buf, x.len() as u32);
                put_f32s(&mut buf, x);
            }
            GroupValues::Quant(e) => {
                buf.push(1);
                put_u32(&mut buf, e.z.len() as u32);
                put_f32s(&mut buf, &e.z);
                put_f32s(&mut buf, &e.s);
                put_packed(&mut buf, &PackedCodes::from_codes_v1(&e.codes.unpack(), e.bits));
            }
        }
    }
    let sum = fnv1a(&buf);
    put_u64(&mut buf, sum);
    buf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::polar::{self, PolarSpec};
    use crate::quant::value;
    use crate::util::rng::Rng;

    fn page(seed: u64, r: u32, t: u32, group: usize, d: usize, n: usize, vb: Option<u32>) -> Page {
        let spec = PolarSpec::new(r, t, group);
        let mut rng = Rng::new(seed);
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..n {
            let k = rng.normal_vec(group * d);
            keys.push(polar::encode_group(&k, d, &spec));
            let v = rng.normal_vec(group * d);
            vals.push(match vb {
                None => GroupValues::Fp(v),
                Some(b) => GroupValues::Quant(value::encode(&v, d, b)),
            });
        }
        Page::new(keys, vals, group)
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        for (seed, vbits) in [(1u64, None), (2, Some(4)), (3, Some(2))] {
            let p = page(seed, 4, 4, 8, 16, 3, vbits);
            let enc = encode_page(&p);
            let dec = decode_page(&enc).expect("decode");
            // re-encoding the decoded page reproduces the exact bytes —
            // codes, params, and values are bit-identical
            assert_eq!(encode_page(&dec), enc);
            assert_eq!(dec.tokens, p.tokens);
            assert_eq!(dec.nbytes(), p.nbytes());
            for (a, b) in p.keys.iter().zip(&dec.keys) {
                assert_eq!(a.rho_codes, b.rho_codes);
                assert_eq!(a.theta_codes, b.theta_codes);
                assert_eq!(a.combined, b.combined, "fused plane rebuilt identically");
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&a.rho_z), bits(&b.rho_z));
                assert_eq!(bits(&a.theta_s), bits(&b.theta_s));
            }
        }
    }

    #[test]
    fn wide_codes_skip_the_fused_plane() {
        // r+t > 8: combined is None on encode and stays None after decode
        let p = page(9, 5, 5, 4, 8, 2, None);
        assert!(p.keys[0].combined.is_none());
        let dec = decode_page(&encode_page(&p)).unwrap();
        assert!(dec.keys[0].combined.is_none());
    }

    #[test]
    fn corruption_is_rejected_not_panicking() {
        let p = page(4, 3, 3, 4, 8, 2, Some(4));
        let enc = encode_page(&p);
        // every single-byte flip breaks the checksum (or the checksum
        // itself) and must be rejected
        for i in [0usize, 5, enc.len() / 2, enc.len() - 9, enc.len() - 1] {
            let mut bad = enc.clone();
            bad[i] ^= 0x41;
            assert!(decode_page(&bad).is_err(), "flip at {i} accepted");
        }
        // truncation at any point is rejected
        for cut in [0usize, 7, enc.len() / 3, enc.len() - 1] {
            assert!(decode_page(&enc[..cut]).is_err(), "truncation to {cut} accepted");
        }
        // trailing garbage is rejected
        let mut long = enc.clone();
        long.extend_from_slice(&[0u8; 4]);
        assert!(decode_page(&long).is_err());
    }

    #[test]
    fn version_bump_is_rejected() {
        let p = page(5, 4, 4, 4, 8, 1, None);
        let mut enc = encode_page(&p);
        enc[4] = 99; // version field
        let body_len = enc.len() - 8;
        let sum = fnv1a(&enc[..body_len]);
        enc[body_len..].copy_from_slice(&sum.to_le_bytes());
        let err = decode_page(&enc).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        // ...and so is a version below the supported floor
        enc[4] = 0;
        let sum = fnv1a(&enc[..body_len]);
        enc[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(decode_page(&enc).is_err());
    }

    #[test]
    fn v1_records_migrate_bit_exactly() {
        // Pages demoted by pre-refactor builds must promote into EXACTLY
        // the page the current encoder would produce: same code values in
        // the new channel-major lanes, same fused plane, same params —
        // so scoring against a migrated page is bit-identical.
        for (seed, r, t, vbits) in [(31u64, 4u32, 4u32, Some(4)), (32, 5, 5, None), (33, 3, 2, Some(2))] {
            let p = page(seed, r, t, 8, 16, 3, vbits);
            let legacy = encode_page_v1(&p);
            assert_ne!(legacy, encode_page(&p), "v1 bytes differ from v2 on disk");
            let dec = decode_page(&legacy).expect("v1 record must decode");
            for (a, b) in p.keys.iter().zip(&dec.keys) {
                assert_eq!(a.rho_codes, b.rho_codes, "migrated rho plane");
                assert_eq!(a.theta_codes, b.theta_codes, "migrated theta plane");
                assert_eq!(a.combined, b.combined, "fused plane rebuilt identically");
            }
            // re-demoting the promoted page writes the CURRENT format,
            // byte-identical to encoding the original page
            assert_eq!(encode_page(&dec), encode_page(&p));
        }
    }
}
