//! Checksummed binary codec for a WHOLE session chain — the unit the
//! idle-session TTL reaper demotes to the disk tier.
//!
//! A reaped chain must come back bit-identical: its pages depend on the
//! exact chunk boundaries the session's turns happened to produce, so it
//! can never enter the shared prefix index (which assumes canonical
//! chunking).  Instead the entire chain — every quantized page plus the
//! fp residual tails and the position cursor — is serialized privately
//! as ONE opaque record:
//!
//! ```text
//! u32 magic "PQSS"   u16 version   u16 flags (0)
//! u64 config_tag     u64 next_pos
//! u32 n_streams      u32 n_pages
//! per page:   u32 rec_len, <rec_len bytes of a serde::encode_page record>
//! u32 resid_rows     u32 d
//! per stream: resid_rows * d f32 resid_k, then resid_rows * d f32 resid_v
//! u64 fnv1a-64 checksum over every preceding byte
//! ```
//!
//! Each embedded page record carries its own checksum; the outer fnv1a
//! guards the envelope (tag, cursor, tails).  `config_tag` is the same
//! engine-config fingerprint the snapshot index uses: a blob written
//! under a different model/quantization config decodes to `Err`, and the
//! caller degrades to a cold re-prefill — never a silently wrong cache.

use anyhow::{ensure, Result};

use super::serde::{self, Cur};
use crate::kvcache::pool::Page;
use crate::kvcache::seq::SequenceCache;

pub const SESSION_MAGIC: u32 = 0x5051_5353; // "PQSS"
pub const SESSION_VERSION: u16 = 1;

/// A decoded session chain, ready to rebuild a [`SequenceCache`] via
/// [`SequenceCache::adopt_pages`] + [`SequenceCache::restore_tail`].
pub struct SessionBlob {
    pub pages: Vec<Page>,
    /// per stream: (resid_k, resid_v) fp tails
    pub tails: Vec<(Vec<f32>, Vec<f32>)>,
    pub next_pos: usize,
}

/// Serialize one session chain into a self-contained checksummed record.
pub fn encode_session(seq: &SequenceCache, config_tag: u64) -> Vec<u8> {
    let d = seq.cfg.head_dim;
    let mut buf = Vec::with_capacity(256 + seq.nbytes());
    serde::put_u32(&mut buf, SESSION_MAGIC);
    serde::put_u16(&mut buf, SESSION_VERSION);
    serde::put_u16(&mut buf, 0); // flags, reserved
    serde::put_u64(&mut buf, config_tag);
    serde::put_u64(&mut buf, seq.next_pos as u64);
    serde::put_u32(&mut buf, seq.streams.len() as u32);
    serde::put_u32(&mut buf, seq.pages.len() as u32);
    for p in &seq.pages {
        let rec = serde::encode_page(p);
        serde::put_u32(&mut buf, rec.len() as u32);
        buf.extend_from_slice(&rec);
    }
    let resid_rows = seq.resid_len();
    serde::put_u32(&mut buf, resid_rows as u32);
    serde::put_u32(&mut buf, d as u32);
    for st in &seq.streams {
        debug_assert_eq!(st.resid_k.len(), resid_rows * d);
        serde::put_f32s(&mut buf, &st.resid_k);
        serde::put_f32s(&mut buf, &st.resid_v);
    }
    let sum = serde::fnv1a(&buf);
    serde::put_u64(&mut buf, sum);
    buf
}

/// Parse and verify one session record.  Any corruption — bad magic,
/// unknown version, foreign `config_tag`, failed checksum (outer or any
/// embedded page's), inconsistent geometry, trailing bytes — returns
/// `Err`; the caller treats the session as cold.
pub fn decode_session(buf: &[u8], expected_tag: u64) -> Result<SessionBlob> {
    ensure!(buf.len() >= 4 + 2 + 2 + 8 + 8 + 4 + 4 + 8, "session record too short ({} bytes)", buf.len());
    let (body, tail) = buf.split_at(buf.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().unwrap());
    ensure!(serde::fnv1a(body) == want, "session record checksum mismatch");

    let mut c = Cur::new(body);
    let magic = c.u32()?;
    ensure!(magic == SESSION_MAGIC, "session record bad magic {magic:#x}");
    let version = c.u16()?;
    ensure!(version == SESSION_VERSION, "session record version {version} (reader handles v{SESSION_VERSION})");
    let _flags = c.u16()?;
    let tag = c.u64()?;
    ensure!(
        tag == expected_tag,
        "session record config tag {tag:#x} != engine {expected_tag:#x}"
    );
    let next_pos = c.u64()? as usize;
    let n_streams = c.u32()? as usize;
    let n_pages = c.u32()? as usize;
    ensure!(n_streams > 0, "session record: zero streams");

    let mut pages = Vec::with_capacity(n_pages.min(4096));
    let mut paged_tokens = 0usize;
    for _ in 0..n_pages {
        let rec_len = c.u32()? as usize;
        let page = serde::decode_page(c.take(rec_len)?)?;
        ensure!(
            page.keys.len() == n_streams,
            "session record: page stream count {} != chain {}",
            page.keys.len(),
            n_streams
        );
        paged_tokens += page.tokens;
        pages.push(page);
    }

    let resid_rows = c.u32()? as usize;
    let d = c.u32()? as usize;
    ensure!(d > 0, "session record: zero head dim");
    let mut tails = Vec::with_capacity(n_streams);
    for _ in 0..n_streams {
        let k = c.f32s(resid_rows * d)?;
        let v = c.f32s(resid_rows * d)?;
        tails.push((k, v));
    }
    ensure!(c.done(), "session record: trailing bytes");
    ensure!(
        paged_tokens + resid_rows == next_pos,
        "session record: cursor {next_pos} disagrees with {paged_tokens} paged + {resid_rows} tail tokens"
    );
    Ok(SessionBlob { pages, tails, next_pos })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::seq::{CacheConfig, SequenceCache};
    use crate::quant::polar::PolarSpec;
    use crate::util::rng::Rng;

    fn chain(seed: u64, tokens: usize) -> SequenceCache {
        let cfg = CacheConfig {
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 8,
            spec: PolarSpec::new(4, 4, 4),
            value_bits: None,
        };
        let mut seq = SequenceCache::new(cfg.clone());
        let mut rng = Rng::new(seed);
        let w = cfg.streams() * cfg.head_dim;
        for _ in 0..tokens {
            let k = rng.normal_vec(w);
            let v = rng.normal_vec(w);
            seq.append_step(&k, &v);
        }
        seq
    }

    #[test]
    fn roundtrip_rebuilds_the_exact_chain() {
        // 11 tokens with group 4: 2 full groups paged (if page cuts ran)
        // or residing in tails — either way the restored chain must be
        // bit-identical stream by stream
        for tokens in [3usize, 11, 16] {
            let seq = chain(7, tokens);
            let blob = encode_session(&seq, 0xfeed);
            let dec = decode_session(&blob, 0xfeed).expect("decode");
            assert_eq!(dec.next_pos, seq.next_pos);
            assert_eq!(dec.pages.len(), seq.pages.len());
            for (a, b) in seq.pages.iter().zip(&dec.pages) {
                assert_eq!(serde::encode_page(a), serde::encode_page(b));
            }
            let mut back = SequenceCache::new(seq.cfg.clone());
            back.adopt_pages(dec.pages.into_iter().map(std::sync::Arc::new).collect());
            back.restore_tail(dec.tails, dec.next_pos);
            assert_eq!(back.len(), seq.len());
            assert_eq!(back.next_pos, seq.next_pos);
            for (a, b) in seq.streams.iter().zip(&back.streams) {
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&a.resid_k), bits(&b.resid_k));
                assert_eq!(bits(&a.resid_v), bits(&b.resid_v));
            }
            // and the restored chain re-encodes to the exact same blob
            assert_eq!(encode_session(&back, 0xfeed), blob);
        }
    }

    #[test]
    fn foreign_config_tag_is_rejected() {
        let seq = chain(9, 5);
        let blob = encode_session(&seq, 1);
        assert!(decode_session(&blob, 2).is_err(), "wrong tag must not decode");
        assert!(decode_session(&blob, 1).is_ok());
    }

    #[test]
    fn corruption_is_rejected_not_panicking() {
        let seq = chain(11, 13);
        let blob = encode_session(&seq, 42);
        for i in [0usize, 9, blob.len() / 2, blob.len() - 9, blob.len() - 1] {
            let mut bad = blob.clone();
            bad[i] ^= 0x41;
            assert!(decode_session(&bad, 42).is_err(), "flip at {i} accepted");
        }
        for cut in [0usize, 17, blob.len() / 3, blob.len() - 1] {
            assert!(decode_session(&blob[..cut], 42).is_err(), "truncation to {cut} accepted");
        }
        let mut long = blob.clone();
        long.extend_from_slice(&[0u8; 4]);
        assert!(decode_session(&long, 42).is_err());
    }

    #[test]
    fn empty_chain_roundtrips() {
        let seq = chain(1, 0);
        let blob = encode_session(&seq, 5);
        let dec = decode_session(&blob, 5).unwrap();
        assert_eq!(dec.next_pos, 0);
        assert!(dec.pages.is_empty());
        assert!(dec.tails.iter().all(|(k, v)| k.is_empty() && v.is_empty()));
    }
}
