//! Append-only segment file store for demoted pages.
//!
//! Pages are serialized ([`super::serde`]) and appended to numbered
//! segment files (`seg-000042.bin`) under the tier directory; a
//! [`TierRef`] names a record by (segment, offset, length).  Segments are
//! immutable once written: on restart the writer continues with a FRESH
//! segment id, so every `TierRef` persisted by an earlier run (the
//! snapshot's prefix index) stays valid forever — space from orphaned
//! records (entries displaced, re-registered, or re-snapshotted) is the
//! cost of never rewriting in place.
//!
//! Reads open the segment file per call: promotion runs at prefix-lookup
//! (admission) rate, not decode rate, and an fd cache would buy nothing
//! at that frequency.

use std::fs::{self, File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::serde;
use crate::kvcache::pool::Page;

/// Name of one on-disk record: which segment, where, how long.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TierRef {
    pub seg: u32,
    pub off: u64,
    pub len: u32,
}

struct SegWriter {
    seg: u32,
    off: u64,
    file: Option<File>,
}

pub struct SegmentStore {
    dir: PathBuf,
    /// start a new segment once the current one reaches this size
    roll_bytes: u64,
    w: Mutex<SegWriter>,
    bytes: AtomicU64,
}

fn seg_path(dir: &Path, seg: u32) -> PathBuf {
    dir.join(format!("seg-{seg:06}.bin"))
}

impl SegmentStore {
    /// Open (or create) the store at `dir`.  Existing segments are
    /// scanned for the byte total and the next free segment id; their
    /// contents are only ever read, never appended to.
    pub fn open(dir: &Path, roll_bytes: u64) -> Result<Self> {
        fs::create_dir_all(dir).with_context(|| format!("creating tier dir {}", dir.display()))?;
        let mut next_seg = 0u32;
        let mut total = 0u64;
        for entry in fs::read_dir(dir).context("scanning tier dir")? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(id) = name.strip_prefix("seg-").and_then(|s| s.strip_suffix(".bin")) else {
                continue;
            };
            let Ok(id) = id.parse::<u32>() else { continue };
            next_seg = next_seg.max(id + 1);
            total += entry.metadata()?.len();
        }
        Ok(SegmentStore {
            dir: dir.to_path_buf(),
            roll_bytes: roll_bytes.max(1),
            w: Mutex::new(SegWriter { seg: next_seg, off: 0, file: None }),
            bytes: AtomicU64::new(total),
        })
    }

    /// Total bytes across every segment (including records orphaned by
    /// displacement or re-registration).
    pub fn bytes_on_disk(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Append one already-serialized record; returns where it landed.
    fn append_record(&self, rec: &[u8]) -> Result<TierRef> {
        let mut w = self.w.lock().unwrap();
        if w.file.is_none() || (w.off > 0 && w.off + rec.len() as u64 > self.roll_bytes) {
            if w.file.is_some() {
                w.seg += 1;
            }
            let path = seg_path(&self.dir, w.seg);
            let file = OpenOptions::new()
                .create_new(true)
                .write(true)
                .open(&path)
                .with_context(|| format!("creating segment {}", path.display()))?;
            w.file = Some(file);
            w.off = 0;
        }
        w.file.as_mut().unwrap().write_all(rec).context("appending to segment")?;
        let tref = TierRef { seg: w.seg, off: w.off, len: rec.len() as u32 };
        w.off += rec.len() as u64;
        self.bytes.fetch_add(rec.len() as u64, Ordering::Relaxed);
        Ok(tref)
    }

    /// Serialize and append one page; returns where it landed.
    pub fn put(&self, page: &Page) -> Result<TierRef> {
        self.append_record(&serde::encode_page(page))
    }

    /// Append an opaque pre-serialized record (the session-blob path —
    /// [`super::session`] owns that format, including its checksum).
    pub fn put_bytes(&self, bytes: &[u8]) -> Result<TierRef> {
        self.append_record(bytes)
    }

    /// Read back one record's raw bytes without decoding.
    pub fn get_bytes(&self, r: TierRef) -> Result<Vec<u8>> {
        let path = seg_path(&self.dir, r.seg);
        let mut f =
            File::open(&path).with_context(|| format!("opening segment {}", path.display()))?;
        f.seek(SeekFrom::Start(r.off)).context("seeking record")?;
        let mut buf = vec![0u8; r.len as usize];
        f.read_exact(&mut buf).context("reading record")?;
        Ok(buf)
    }

    /// Read back and decode one record.  Corruption (checksum, lengths,
    /// short read) comes back as `Err` — the caller degrades to a cache
    /// miss.
    pub fn get(&self, r: TierRef) -> Result<Page> {
        serde::decode_page(&self.get_bytes(r)?)
    }

    /// Flush the active segment to stable storage (snapshot path).
    pub fn sync(&self) -> Result<()> {
        let w = self.w.lock().unwrap();
        if let Some(f) = &w.file {
            f.sync_all().context("syncing segment")?;
        }
        Ok(())
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::stream::GroupValues;
    use crate::quant::polar::{self, PolarSpec};
    use crate::util::rng::Rng;

    fn page(seed: u64) -> Page {
        let spec = PolarSpec::new(4, 4, 4);
        let d = 8;
        let mut rng = Rng::new(seed);
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..2 {
            keys.push(polar::encode_group(&rng.normal_vec(spec.group * d), d, &spec));
            vals.push(GroupValues::Fp(rng.normal_vec(spec.group * d)));
        }
        Page::new(keys, vals, spec.group)
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("polarquant-store-test-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn put_get_roundtrip_and_rolling() {
        let dir = tmp("roll");
        // tiny roll size: every page gets its own segment
        let store = SegmentStore::open(&dir, 1).unwrap();
        let refs: Vec<TierRef> = (0..3).map(|i| store.put(&page(i)).unwrap()).collect();
        assert!(refs[0].seg != refs[2].seg, "tiny roll size must cut segments");
        assert!(store.bytes_on_disk() > 0);
        for (i, r) in refs.iter().enumerate() {
            let got = store.get(*r).unwrap();
            assert_eq!(serde::encode_page(&got), serde::encode_page(&page(i as u64)));
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_preserves_old_records_and_advances_segments() {
        let dir = tmp("reopen");
        let r0 = {
            let store = SegmentStore::open(&dir, 1 << 20).unwrap();
            store.put(&page(7)).unwrap()
        };
        let store = SegmentStore::open(&dir, 1 << 20).unwrap();
        assert!(store.bytes_on_disk() > 0, "existing bytes counted on reopen");
        let r1 = store.put(&page(8)).unwrap();
        assert!(r1.seg > r0.seg, "reopen must never append into an old segment");
        // both generations readable; a ref to a missing segment errors
        assert_eq!(serde::encode_page(&store.get(r0).unwrap()), serde::encode_page(&page(7)));
        assert_eq!(serde::encode_page(&store.get(r1).unwrap()), serde::encode_page(&page(8)));
        assert!(store.get(TierRef { seg: 999, off: 0, len: 4 }).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn opaque_records_interleave_with_pages() {
        // session blobs (put_bytes) and pages (put) share segments; each
        // comes back verbatim through its own read path
        let dir = tmp("opaque");
        let store = SegmentStore::open(&dir, 1 << 20).unwrap();
        let blob: Vec<u8> = (0..513u32).map(|i| (i * 7) as u8).collect();
        let rb = store.put_bytes(&blob).unwrap();
        let rp = store.put(&page(11)).unwrap();
        let rb2 = store.put_bytes(&[0xAB; 3]).unwrap();
        assert_eq!(store.get_bytes(rb).unwrap(), blob);
        assert_eq!(serde::encode_page(&store.get(rp).unwrap()), serde::encode_page(&page(11)));
        assert_eq!(store.get_bytes(rb2).unwrap(), vec![0xAB; 3]);
        // short read on a truncated ref still errors
        let past = TierRef { seg: rb2.seg, off: rb2.off + 1, len: rb2.len };
        assert!(store.get_bytes(past).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupted_record_comes_back_as_err() {
        let dir = tmp("corrupt");
        let store = SegmentStore::open(&dir, 1 << 20).unwrap();
        let r = store.put(&page(3)).unwrap();
        store.sync().unwrap();
        // flip a byte in the middle of the record
        let path = seg_path(&dir, r.seg);
        let mut bytes = fs::read(&path).unwrap();
        let mid = r.off as usize + r.len as usize / 2;
        bytes[mid] ^= 0xff;
        fs::write(&path, bytes).unwrap();
        assert!(store.get(r).is_err(), "corrupt record must be rejected");
        // a ref past the end of the file errors too (short read)
        let bogus = TierRef { seg: r.seg, off: r.off + 1, len: r.len };
        assert!(store.get(bogus).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pre_bump_v1_segment_records_promote_bit_exactly() {
        // A tier directory left behind by a pre-pack-layout-bump build:
        // seg-000000.bin holds a PAGE_VERSION-1 record.  Opening the
        // store over it and promoting through a TierRef — exactly what a
        // restored snapshot index does — must yield the page the current
        // encoder would produce, and new writes must land in a fresh
        // segment, leaving the legacy file untouched.
        let dir = tmp("v1-migrate");
        fs::create_dir_all(&dir).unwrap();
        let p = page(21);
        let legacy = serde::encode_page_v1(&p);
        fs::write(seg_path(&dir, 0), &legacy).unwrap();

        let store = SegmentStore::open(&dir, 1 << 20).unwrap();
        let r = TierRef { seg: 0, off: 0, len: legacy.len() as u32 };
        let got = store.get(r).unwrap();
        assert_eq!(
            serde::encode_page(&got),
            serde::encode_page(&p),
            "promoted v1 page must be bit-identical to a freshly encoded one"
        );
        // re-demote: the rewrite is v2, in a new segment
        let r1 = store.put(&got).unwrap();
        assert!(r1.seg > 0, "reopen continues past the legacy segment");
        assert_eq!(fs::read(seg_path(&dir, 0)).unwrap(), legacy, "legacy segment immutable");
        assert_eq!(serde::encode_page(&store.get(r1).unwrap()), serde::encode_page(&p));
        fs::remove_dir_all(&dir).unwrap();
    }
}
