//! Tiered page store: spill-to-disk offload for the prefix cache.
//!
//! PolarQuant pages are tiny and immutable — one finalized, bit-packed
//! key group (plus values) across every stream — which makes a second
//! storage tier nearly free: pages serialize compactly, verify by
//! checksum, and promote back bit-exactly.  This subsystem turns the
//! PR-3 page pool into a two-level hierarchy:
//!
//! * [`serde`] — the versioned binary [`crate::kvcache::Page`] codec
//!   (packed code bitstreams + params + values, FNV-64 checksummed;
//!   decode is bit-exact, corruption is an `Err`, never a panic).
//! * [`store`] — the append-only segment file store:
//!   `put(page) -> TierRef`, `get(TierRef) -> Page`, segments immutable
//!   once written so persisted refs survive restarts.  Opaque records
//!   (`put_bytes`/`get_bytes`) share the same segments.
//! * [`session`] — the whole-chain codec behind idle-session TTL
//!   reaping: a session's pages + fp tails + cursor as one checksummed
//!   blob, restored bit-exactly on the tenant's next turn.
//! * [`tier`] — the policy plumbing: bounded demotion queue + background
//!   writer (reclaim never blocks on disk), shared counters, and the
//!   snapshot codec that persists the prefix index for warm starts.
//!
//! The policy itself is wired into [`crate::kvcache::PagePool`]: under
//! capacity pressure, refcount-zero cached pages are *demoted* (index
//! entry kept, pointing at a [`TierRef`]) instead of dropped, and a
//! prefix lookup that lands on a demoted entry *promotes* the page back
//! into RAM (`tier_hits`).  `PagePool::snapshot` / `attach_tier` persist
//! and restore the whole index across process restarts, so a server
//! warm-starts with its prefix cache populated.

pub mod serde;
pub mod session;
pub mod store;
#[allow(clippy::module_inception)]
pub mod tier;

pub use store::{SegmentStore, TierRef};
pub use tier::{TierConfig, TierCounters};

pub(crate) use tier::{
    read_snapshot, spawn_writer, write_snapshot, DemoteJob, SnapshotEntry, TierBackend,
};
