//! Multi-sequence cache allocation + the global memory budget that drives
//! admission control, plus the accounting behind Table 4's memory column.
//!
//! Sequences are held as [`SharedSeq`] handles (`Arc<Mutex<..>>`), so the
//! decode pool's worker threads can each walk their assigned sequences'
//! pages without going back through the manager.  The scheduler assigns
//! disjoint shards per step, so every per-sequence lock is uncontended in
//! the steady state — the mutex only arbitrates against management-plane
//! reads like [`CacheManager::report`].

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::seq::{CacheConfig, SequenceCache};

/// Shard-safe handle to one sequence's cache.  Clone is an `Arc` bump;
/// workers lock only the sequences in their own shard.
pub type SharedSeq = Arc<Mutex<SequenceCache>>;

/// Breakdown of cache memory at rest.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemoryReport {
    pub sequences: usize,
    pub tokens: usize,
    pub bytes: usize,
    pub budget_bytes: usize,
}

impl MemoryReport {
    pub fn utilization(&self) -> f64 {
        if self.budget_bytes == 0 {
            0.0
        } else {
            self.bytes as f64 / self.budget_bytes as f64
        }
    }
}

/// Owns every live sequence's cache; enforces a byte budget.
pub struct CacheManager {
    cfg: CacheConfig,
    budget_bytes: usize,
    seqs: HashMap<u64, SharedSeq>,
}

impl CacheManager {
    pub fn new(cfg: CacheConfig, budget_bytes: usize) -> Self {
        CacheManager { cfg, budget_bytes, seqs: HashMap::new() }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// Estimated bytes for a sequence of `tokens` (used for admission
    /// *before* the tokens exist): quantized groups + worst-case residual.
    pub fn estimate_bytes(&self, tokens: usize) -> usize {
        let d = self.cfg.head_dim;
        let streams = self.cfg.streams();
        let spec = self.cfg.spec;
        let groups = tokens / spec.group;
        let resid = tokens % spec.group;
        let key_bits_per_tok = (spec.r_bits + spec.t_bits) as usize * (d / 2);
        let key_group_bytes = (key_bits_per_tok * spec.group).div_ceil(8)
            + 4 * (d / 2) * std::mem::size_of::<f32>();
        let val_group_bytes = match self.cfg.value_bits {
            None => spec.group * d * 2,
            Some(b) => (spec.group * d * b as usize).div_ceil(8) + 2 * spec.group * 4,
        };
        let resid_bytes = resid * d * 2 * 2; // k+v fp16
        streams * (groups * (key_group_bytes + val_group_bytes) + resid_bytes)
    }

    /// True if a new sequence of `tokens` would fit the budget.
    pub fn admits(&self, tokens: usize) -> bool {
        self.report().bytes + self.estimate_bytes(tokens) <= self.budget_bytes
    }

    /// Create (or fetch) the sequence and return a shard-safe handle.
    pub fn create(&mut self, id: u64) -> SharedSeq {
        self.seqs
            .entry(id)
            .or_insert_with(|| Arc::new(Mutex::new(SequenceCache::new(self.cfg.clone()))))
            .clone()
    }

    /// Shard-safe handle for an existing sequence.
    pub fn get(&self, id: u64) -> Option<SharedSeq> {
        self.seqs.get(&id).cloned()
    }

    pub fn release(&mut self, id: u64) -> bool {
        self.seqs.remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn report(&self) -> MemoryReport {
        let mut bytes = 0;
        let mut tokens = 0;
        for s in self.seqs.values() {
            let s = s.lock().unwrap();
            bytes += s.nbytes();
            tokens += s.len();
        }
        MemoryReport {
            sequences: self.seqs.len(),
            tokens,
            bytes,
            budget_bytes: self.budget_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::polar::PolarSpec;
    use crate::util::rng::Rng;

    fn cfg() -> CacheConfig {
        CacheConfig {
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 16,
            spec: PolarSpec::new(4, 4, 8),
            value_bits: None,
        }
    }

    #[test]
    fn create_get_release() {
        let mut m = CacheManager::new(cfg(), usize::MAX);
        m.create(1);
        m.create(2);
        assert_eq!(m.len(), 2);
        assert!(m.get(1).is_some());
        assert!(m.release(1));
        assert!(!m.release(1));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn handles_share_one_cache() {
        let mut m = CacheManager::new(cfg(), usize::MAX);
        let a = m.create(9);
        let b = m.create(9);
        let mut rng = Rng::new(23);
        let step = 2 * 2 * 16;
        a.lock().unwrap().append_step(&rng.normal_vec(step), &rng.normal_vec(step));
        assert_eq!(b.lock().unwrap().len(), 1, "writes via one handle visible via the other");
        assert_eq!(m.report().tokens, 1);
    }

    #[test]
    fn estimate_tracks_actual_within_slack() {
        let c = cfg();
        let mut m = CacheManager::new(c.clone(), usize::MAX);
        let mut rng = Rng::new(20);
        let tokens = 24;
        let block = c.n_layers * c.n_kv_heads * tokens * c.head_dim;
        let (k, v) = (rng.normal_vec(block), rng.normal_vec(block));
        m.create(7).lock().unwrap().append_prefill(&k, &v, tokens);
        let actual = m.report().bytes;
        let est = m.estimate_bytes(tokens);
        let ratio = est as f64 / actual as f64;
        assert!((0.5..=2.0).contains(&ratio), "est {est} actual {actual}");
    }

    #[test]
    fn admission_respects_budget() {
        let c = cfg();
        let per_seq = {
            let m = CacheManager::new(c.clone(), usize::MAX);
            m.estimate_bytes(64)
        };
        let mut m = CacheManager::new(c.clone(), per_seq * 2 + per_seq / 2);
        assert!(m.admits(64));
        // fill up with two sequences' worth of real tokens
        let mut rng = Rng::new(21);
        for id in 0..2 {
            let block = c.n_layers * c.n_kv_heads * 64 * c.head_dim;
            let (k, v) = (rng.normal_vec(block), rng.normal_vec(block));
            m.create(id).lock().unwrap().append_prefill(&k, &v, 64);
        }
        assert!(!m.admits(64), "third sequence must be rejected");
        assert!(m.report().utilization() > 0.4);
    }

    #[test]
    fn quantized_cache_is_much_smaller_than_fp() {
        // Table 4's memory claim in miniature: Polar44 cache << fp16 cache.
        // (realistic geometry — at toy group sizes the fp16 param overhead
        // dominates and the comparison is meaningless)
        let mut c = cfg();
        c.head_dim = 64;
        c.spec = PolarSpec::new(4, 4, 32);
        let mut rng = Rng::new(22);
        let tokens = 128;
        let block = c.n_layers * c.n_kv_heads * tokens * c.head_dim;
        let (k, v) = (rng.normal_vec(block), rng.normal_vec(block));
        let mut m = CacheManager::new(c.clone(), usize::MAX);
        m.create(1).lock().unwrap().append_prefill(&k, &v, tokens);
        let quant_bytes = m.report().bytes;
        let fp_bytes = 2 * block * 2; // k+v in fp16
        // keys are ~3.8x smaller; values stay fp16 -> overall < 0.75x
        assert!(
            (quant_bytes as f64) < 0.75 * fp_bytes as f64,
            "quant {quant_bytes} fp {fp_bytes}"
        );
    }
}
