//! Multi-sequence cache allocation + the global memory budget that drives
//! admission control, plus the accounting behind Table 4's memory column.
//!
//! Sequences are held as [`SharedSeq`] handles (`Arc<Mutex<..>>`), so the
//! decode pool's worker threads can each walk their assigned sequences'
//! pages without going back through the manager.  The scheduler assigns
//! disjoint shards per step, so every per-sequence lock is uncontended in
//! the steady state — the mutex only arbitrates against management-plane
//! reads like [`CacheManager::report`].
//!
//! Every sequence allocates its pages from one shared [`PagePool`], so
//! admission is O(1): the pool's atomic counters are exact (pages
//! reconcile on drop, residual tails on every mutation), and shared
//! prefix pages are counted ONCE — `admits` never locks a sequence.
//! [`CacheManager::report`] keeps the old walk as the slow debug path and
//! reports both views: `bytes` (logical, per-sequence sum) and
//! `physical_bytes` (deduplicated, what the hardware holds).

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use super::pool::PagePool;
use super::seq::{CacheConfig, SequenceCache};

/// Shard-safe handle to one sequence's cache.  Clone is an `Arc` bump;
/// workers lock only the sequences in their own shard.
pub type SharedSeq = Arc<Mutex<SequenceCache>>;

/// Breakdown of cache memory at rest.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemoryReport {
    pub sequences: usize,
    pub tokens: usize,
    /// logical bytes: every sequence's pages summed, shared pages counted
    /// per sequence (what you'd pay without prefix sharing / COW forks)
    pub bytes: usize,
    /// physical bytes from the pool's exact counters: shared pages once
    pub physical_bytes: usize,
    /// physical pages resident in the pool
    pub pages: usize,
    /// segment bytes held by the disk tier (0 when no tier is attached)
    pub bytes_on_disk: u64,
    pub budget_bytes: usize,
}

impl MemoryReport {
    pub fn utilization(&self) -> f64 {
        if self.budget_bytes == 0 {
            0.0
        } else {
            self.physical_bytes as f64 / self.budget_bytes as f64
        }
    }

    /// Bytes saved by sharing (logical - physical).
    pub fn shared_savings(&self) -> usize {
        self.bytes.saturating_sub(self.physical_bytes)
    }
}

/// Owns every live sequence's cache; enforces a byte budget.
pub struct CacheManager {
    cfg: CacheConfig,
    budget_bytes: usize,
    seqs: HashMap<u64, SharedSeq>,
    pool: PagePool,
}

impl CacheManager {
    pub fn new(cfg: CacheConfig, budget_bytes: usize) -> Self {
        CacheManager {
            cfg,
            budget_bytes,
            seqs: HashMap::new(),
            pool: PagePool::new(usize::MAX),
        }
    }

    /// Bound the pool at `pages` physical pages (0 = unbounded).
    pub fn with_page_capacity(mut self, pages: usize) -> Self {
        if pages > 0 {
            self.pool = PagePool::new(pages);
        }
        self
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// The shared page pool (allocation, prefix index, exact counters).
    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// Estimated bytes for a sequence of `tokens` (used for admission
    /// *before* the tokens exist): quantized groups + worst-case residual.
    pub fn estimate_bytes(&self, tokens: usize) -> usize {
        let d = self.cfg.head_dim;
        let streams = self.cfg.streams();
        let spec = self.cfg.spec;
        let groups = tokens / spec.group;
        let resid = tokens % spec.group;
        let key_bits_per_tok = (spec.r_bits + spec.t_bits) as usize * (d / 2);
        let key_group_bytes = (key_bits_per_tok * spec.group).div_ceil(8)
            + 4 * (d / 2) * std::mem::size_of::<f32>();
        let val_group_bytes = match self.cfg.value_bits {
            None => spec.group * d * 2,
            Some(b) => (spec.group * d * b as usize).div_ceil(8) + 2 * spec.group * 4,
        };
        let resid_bytes = resid * d * 2 * 2; // k+v fp16
        streams * (groups * (key_group_bytes + val_group_bytes) + resid_bytes)
    }

    /// Exact physical bytes at rest, O(1): the pool's page counter
    /// (shared pages once) + every live residual tail.  No sequence lock
    /// is taken — this is what makes admission constant-time.
    pub fn physical_bytes(&self) -> usize {
        let c = self.pool.counters();
        c.page_bytes.load(Ordering::Relaxed) + c.resid_bytes.load(Ordering::Relaxed)
    }

    /// True if a new sequence of `tokens` would fit the budget.  O(1).
    pub fn admits(&self, tokens: usize) -> bool {
        self.physical_bytes() + self.estimate_bytes(tokens) <= self.budget_bytes
    }

    /// Create (or fetch) the sequence and return a shard-safe handle.
    pub fn create(&mut self, id: u64) -> SharedSeq {
        let cfg = self.cfg.clone();
        let pool = self.pool.clone();
        self.seqs
            .entry(id)
            .or_insert_with(|| Arc::new(Mutex::new(SequenceCache::new_pooled(cfg, pool))))
            .clone()
    }

    /// Replace the sequence's cache with a fresh empty one (preemption:
    /// the old pages drop as soon as the last outstanding handle does).
    pub fn reset(&mut self, id: u64) -> SharedSeq {
        let fresh: SharedSeq = Arc::new(Mutex::new(SequenceCache::new_pooled(
            self.cfg.clone(),
            self.pool.clone(),
        )));
        self.seqs.insert(id, fresh.clone());
        fresh
    }

    /// Copy-on-write fork of `src` registered as `dst` (n-way sampling):
    /// finalized pages are shared refcounted, residual tails deep-copied.
    pub fn fork(&mut self, src: u64, dst: u64) -> Option<SharedSeq> {
        let forked = self.seqs.get(&src)?.lock().unwrap().fork();
        let shared: SharedSeq = Arc::new(Mutex::new(forked));
        self.seqs.insert(dst, shared.clone());
        Some(shared)
    }

    /// Register an EXISTING shared cache under `id` (session-turn
    /// continuation: the conversation's live chain becomes this request's
    /// cache, so prefill resumes after the tokens it already holds).
    pub fn insert(&mut self, id: u64, handle: SharedSeq) {
        self.seqs.insert(id, handle);
    }

    /// Shard-safe handle for an existing sequence.
    pub fn get(&self, id: u64) -> Option<SharedSeq> {
        self.seqs.get(&id).cloned()
    }

    pub fn release(&mut self, id: u64) -> bool {
        self.seqs.remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Full memory breakdown.  This is the SLOW debug/observability path:
    /// it locks and walks every live sequence to compute the logical
    /// view; the physical fields come from the same O(1) counters
    /// admission uses.
    pub fn report(&self) -> MemoryReport {
        let mut bytes = 0;
        let mut tokens = 0;
        for s in self.seqs.values() {
            let s = s.lock().unwrap();
            bytes += s.nbytes();
            tokens += s.len();
        }
        MemoryReport {
            sequences: self.seqs.len(),
            tokens,
            bytes,
            physical_bytes: self.physical_bytes(),
            pages: self.pool.pages_in_use(),
            bytes_on_disk: self.pool.bytes_on_disk(),
            budget_bytes: self.budget_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::polar::PolarSpec;
    use crate::util::rng::Rng;

    fn cfg() -> CacheConfig {
        CacheConfig {
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 16,
            spec: PolarSpec::new(4, 4, 8),
            value_bits: None,
        }
    }

    #[test]
    fn create_get_release() {
        let mut m = CacheManager::new(cfg(), usize::MAX);
        m.create(1);
        m.create(2);
        assert_eq!(m.len(), 2);
        assert!(m.get(1).is_some());
        assert!(m.release(1));
        assert!(!m.release(1));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn handles_share_one_cache() {
        let mut m = CacheManager::new(cfg(), usize::MAX);
        let a = m.create(9);
        let b = m.create(9);
        let mut rng = Rng::new(23);
        let step = 2 * 2 * 16;
        a.lock().unwrap().append_step(&rng.normal_vec(step), &rng.normal_vec(step));
        assert_eq!(b.lock().unwrap().len(), 1, "writes via one handle visible via the other");
        assert_eq!(m.report().tokens, 1);
    }

    #[test]
    fn estimate_tracks_actual_within_slack() {
        let c = cfg();
        let mut m = CacheManager::new(c.clone(), usize::MAX);
        let mut rng = Rng::new(20);
        let tokens = 24;
        let block = c.n_layers * c.n_kv_heads * tokens * c.head_dim;
        let (k, v) = (rng.normal_vec(block), rng.normal_vec(block));
        m.create(7).lock().unwrap().append_prefill(&k, &v, tokens);
        let actual = m.report().bytes;
        let est = m.estimate_bytes(tokens);
        let ratio = est as f64 / actual as f64;
        assert!((0.5..=2.0).contains(&ratio), "est {est} actual {actual}");
    }

    #[test]
    fn o1_physical_accounting_matches_walk_without_sharing() {
        // the exact counters admission reads must agree with the slow
        // lock-walk whenever no pages are shared
        let c = cfg();
        let mut m = CacheManager::new(c.clone(), usize::MAX);
        let mut rng = Rng::new(25);
        for id in 0..3 {
            let tokens = 10 + 7 * id as usize; // mixed page/residual splits
            let block = c.n_layers * c.n_kv_heads * tokens * c.head_dim;
            let (k, v) = (rng.normal_vec(block), rng.normal_vec(block));
            m.create(id).lock().unwrap().append_prefill(&k, &v, tokens);
        }
        let r = m.report();
        assert_eq!(r.physical_bytes, r.bytes, "no sharing -> views agree");
        assert_eq!(r.physical_bytes, m.physical_bytes());
        // decode-step growth keeps them reconciled
        let step = c.n_layers * c.n_kv_heads * c.head_dim;
        m.get(0).unwrap().lock().unwrap().append_step(&rng.normal_vec(step), &rng.normal_vec(step));
        let r = m.report();
        assert_eq!(r.physical_bytes, r.bytes);
        // release drops both
        m.release(0);
        m.release(1);
        m.release(2);
        let r = m.report();
        assert_eq!(r.physical_bytes, 0);
        assert_eq!(r.tokens, 0);
        assert_eq!(r.pages, 0);
    }

    #[test]
    fn forked_pages_are_counted_once_physically() {
        let c = cfg();
        let mut m = CacheManager::new(c.clone(), usize::MAX);
        let mut rng = Rng::new(26);
        let tokens = 24; // 3 pages at group 8
        let block = c.n_layers * c.n_kv_heads * tokens * c.head_dim;
        let (k, v) = (rng.normal_vec(block), rng.normal_vec(block));
        m.create(1).lock().unwrap().append_prefill(&k, &v, tokens);
        let solo = m.report();
        m.fork(1, 2).expect("fork");
        m.fork(1, 3).expect("fork");
        let shared = m.report();
        assert_eq!(shared.sequences, 3);
        assert_eq!(shared.physical_bytes, solo.physical_bytes, "forks add no physical pages");
        assert_eq!(shared.bytes, 3 * solo.bytes, "logical view triples");
        assert!(shared.shared_savings() > 0);
        assert_eq!(shared.pages, 3);
        // releasing every sequence returns the pool to zero
        m.release(1);
        m.release(2);
        m.release(3);
        assert_eq!(m.report().physical_bytes, 0);
        assert_eq!(m.pool().pages_in_use(), 0, "refcounts drain to zero");
    }

    #[test]
    fn admission_respects_budget() {
        let c = cfg();
        let per_seq = {
            let m = CacheManager::new(c.clone(), usize::MAX);
            m.estimate_bytes(64)
        };
        let mut m = CacheManager::new(c.clone(), per_seq * 2 + per_seq / 2);
        assert!(m.admits(64));
        // fill up with two sequences' worth of real tokens
        let mut rng = Rng::new(21);
        for id in 0..2 {
            let block = c.n_layers * c.n_kv_heads * 64 * c.head_dim;
            let (k, v) = (rng.normal_vec(block), rng.normal_vec(block));
            m.create(id).lock().unwrap().append_prefill(&k, &v, 64);
        }
        assert!(!m.admits(64), "third sequence must be rejected");
        assert!(m.report().utilization() > 0.4);
    }

    #[test]
    fn quantized_cache_is_much_smaller_than_fp() {
        // Table 4's memory claim in miniature: Polar44 cache << fp16 cache.
        // (realistic geometry — at toy group sizes the fp16 param overhead
        // dominates and the comparison is meaningless)
        let mut c = cfg();
        c.head_dim = 64;
        c.spec = PolarSpec::new(4, 4, 32);
        let mut rng = Rng::new(22);
        let tokens = 128;
        let block = c.n_layers * c.n_kv_heads * tokens * c.head_dim;
        let (k, v) = (rng.normal_vec(block), rng.normal_vec(block));
        let mut m = CacheManager::new(c.clone(), usize::MAX);
        m.create(1).lock().unwrap().append_prefill(&k, &v, tokens);
        let quant_bytes = m.report().bytes;
        let fp_bytes = 2 * block * 2; // k+v in fp16
        // keys are ~3.8x smaller; values stay fp16 -> overall < 0.75x
        assert!(
            (quant_bytes as f64) < 0.75 * fp_bytes as f64,
            "quant {quant_bytes} fp {fp_bytes}"
        );
    }
}
