//! One KV stream: the cache of a single (layer, kv-head) pair.
//!
//! Keys: PolarQuant groups (bit-packed) + an fp residual ring that holds
//! the most recent `< group` tokens (the "residual length" every
//! quantization serving system keeps — paper §B notes all baselines need
//! one).  Values: fp32 rows aligned with the quantized keys, or token-wise
//! quantized per finalized group when `value_bits` is set (Table 7).

use crate::quant::polar::{self, PolarGroup, PolarSpec};
use crate::quant::value;

/// Value storage for finalized groups.
#[derive(Clone, Debug)]
pub enum GroupValues {
    Fp(Vec<f32>),
    Quant(value::ValueEncoded),
}

impl GroupValues {
    pub fn nbytes(&self, charge_fp16: bool) -> usize {
        match self {
            GroupValues::Fp(v) => v.len() * if charge_fp16 { 2 } else { 4 },
            GroupValues::Quant(e) => e.nbytes(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct StreamCache {
    pub d: usize,
    pub spec: PolarSpec,
    pub value_bits: Option<u32>,
    /// finalized (quantized) key groups
    pub key_groups: Vec<PolarGroup>,
    /// values per finalized group, aligned with `key_groups`
    pub value_groups: Vec<GroupValues>,
    /// fp tail: tokens not yet forming a full group (row-major tokens x d)
    pub resid_k: Vec<f32>,
    pub resid_v: Vec<f32>,
}

impl StreamCache {
    pub fn new(d: usize, spec: PolarSpec, value_bits: Option<u32>) -> Self {
        StreamCache {
            d,
            spec,
            value_bits,
            key_groups: Vec::new(),
            value_groups: Vec::new(),
            resid_k: Vec::with_capacity(spec.group * d),
            resid_v: Vec::with_capacity(spec.group * d),
        }
    }

    /// Tokens in finalized (quantized) groups.
    pub fn quantized_len(&self) -> usize {
        self.key_groups.iter().map(|g| g.tokens).sum()
    }

    /// Tokens in the fp residual tail.
    pub fn resid_len(&self) -> usize {
        self.resid_k.len() / self.d
    }

    pub fn len(&self) -> usize {
        self.quantized_len() + self.resid_len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one post-RoPE (k, v) token; finalize a group when the
    /// residual fills.  Returns true if a group was finalized.
    pub fn append(&mut self, k: &[f32], v: &[f32]) -> bool {
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        self.resid_k.extend_from_slice(k);
        self.resid_v.extend_from_slice(v);
        if self.resid_len() >= self.spec.group {
            self.flush_groups();
            true
        } else {
            false
        }
    }

    /// Bulk append (e.g. prompt prefill).  Finalizes as many full groups
    /// as possible.
    pub fn append_block(&mut self, k: &[f32], v: &[f32]) {
        let tokens = k.len() / self.d;
        debug_assert_eq!(k.len(), tokens * self.d);
        debug_assert_eq!(v.len(), k.len());
        for n in 0..tokens {
            self.append(&k[n * self.d..(n + 1) * self.d], &v[n * self.d..(n + 1) * self.d]);
        }
    }

    /// Bulk append WITHOUT finalizing groups: the residual tail grows past
    /// `group` tokens and stays fp until [`StreamCache::flush_groups`].
    /// Chunked prefill appends each chunk this way so later chunks attend
    /// over exact fp keys; finalization order at flush time matches what
    /// incremental [`StreamCache::append`] would have produced.
    pub fn append_block_deferred(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len() % self.d, 0);
        debug_assert_eq!(v.len(), k.len());
        self.resid_k.extend_from_slice(k);
        self.resid_v.extend_from_slice(v);
    }

    /// Finalize as many full groups as the residual holds, oldest first.
    /// All full groups are encoded in place and the flushed prefix is
    /// drained ONCE — a long deferred residual (chunked prefill's
    /// end-of-prompt flush) costs O(T·d), not O(T²·d/g) front-drains.
    pub fn flush_groups(&mut self) {
        let gd = self.spec.group * self.d;
        let full = self.resid_k.len() / gd;
        if full == 0 {
            return;
        }
        for gi in 0..full {
            let off = gi * gd;
            let g = polar::encode_group(&self.resid_k[off..off + gd], self.d, &self.spec);
            self.key_groups.push(g);
            self.value_groups.push(match self.value_bits {
                None => GroupValues::Fp(self.resid_v[off..off + gd].to_vec()),
                Some(bits) => {
                    GroupValues::Quant(value::encode(&self.resid_v[off..off + gd], self.d, bits))
                }
            });
        }
        // one front drain, and on BOTH buffers, so each keeps its
        // preallocated capacity (a previous mem::take of resid_v
        // discarded it, forcing a reallocation per finalized group on
        // the append hot path)
        self.resid_k.drain(..full * gd);
        self.resid_v.drain(..full * gd);
        // a deferred chunked prefill can have grown these to prompt size;
        // give that slack back to the allocator (nbytes() never charged
        // it) while keeping the steady-state one-group capacity
        self.resid_k.shrink_to(gd);
        self.resid_v.shrink_to(gd);
    }

    /// Physical bytes at rest (codes packed; fp tensors charged as fp16 to
    /// match the paper's accounting).
    pub fn nbytes(&self) -> usize {
        let keys: usize = self.key_groups.iter().map(|g| g.nbytes()).sum();
        let vals: usize = self.value_groups.iter().map(|v| v.nbytes(true)).sum();
        let resid = (self.resid_k.len() + self.resid_v.len()) * 2;
        keys + vals + resid
    }

    /// Dequantize all finalized keys (test/eval path).
    pub fn decode_keys(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.quantized_len() * self.d);
        for g in &self.key_groups {
            polar::decode_group_into(g, self.d, &mut out);
        }
        out
    }

    /// Dequantized values of group `gi` appended into `out`.
    pub fn decode_values_into(&self, gi: usize, out: &mut Vec<f32>) {
        match &self.value_groups[gi] {
            GroupValues::Fp(v) => out.extend_from_slice(v),
            GroupValues::Quant(e) => out.extend_from_slice(&value::decode(e, self.d)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spec() -> PolarSpec {
        PolarSpec::new(4, 4, 8)
    }

    #[test]
    fn append_finalizes_full_groups() {
        let mut rng = Rng::new(1);
        let d = 16;
        let mut sc = StreamCache::new(d, spec(), None);
        for i in 0..19 {
            let k = rng.normal_vec(d);
            let v = rng.normal_vec(d);
            let finalized = sc.append(&k, &v);
            assert_eq!(finalized, (i + 1) % 8 == 0);
        }
        assert_eq!(sc.quantized_len(), 16);
        assert_eq!(sc.resid_len(), 3);
        assert_eq!(sc.len(), 19);
        assert_eq!(sc.key_groups.len(), 2);
        assert_eq!(sc.value_groups.len(), 2);
    }

    #[test]
    fn block_append_equals_token_append() {
        let mut rng = Rng::new(2);
        let d = 8;
        let tokens = 21;
        let k = rng.normal_vec(tokens * d);
        let v = rng.normal_vec(tokens * d);
        let mut a = StreamCache::new(d, spec(), None);
        a.append_block(&k, &v);
        let mut b = StreamCache::new(d, spec(), None);
        for n in 0..tokens {
            b.append(&k[n * d..(n + 1) * d], &v[n * d..(n + 1) * d]);
        }
        assert_eq!(a.quantized_len(), b.quantized_len());
        assert_eq!(a.decode_keys(), b.decode_keys());
        assert_eq!(a.resid_k, b.resid_k);
    }

    #[test]
    fn finalize_preserves_capacity_of_both_residual_buffers() {
        let mut rng = Rng::new(11);
        let d = 16;
        let mut sc = StreamCache::new(d, spec(), None);
        // enough appends to finalize two groups
        for _ in 0..17 {
            let k = rng.normal_vec(d);
            let v = rng.normal_vec(d);
            sc.append(&k, &v);
        }
        assert_eq!(sc.key_groups.len(), 2);
        // both buffers must keep the preallocated group-sized capacity —
        // resid_v previously lost its buffer to mem::take every group
        assert!(sc.resid_k.capacity() >= sc.spec.group * d, "resid_k realloc");
        assert!(sc.resid_v.capacity() >= sc.spec.group * d, "resid_v realloc");
    }

    #[test]
    fn deferred_append_plus_flush_matches_eager() {
        let mut rng = Rng::new(12);
        let d = 8;
        let tokens = 21; // 2 full groups + 5 residual at group=8
        let k = rng.normal_vec(tokens * d);
        let v = rng.normal_vec(tokens * d);
        let mut eager = StreamCache::new(d, spec(), Some(4));
        eager.append_block(&k, &v);
        let mut deferred = StreamCache::new(d, spec(), Some(4));
        // split across uneven "chunks" like a chunked prefill would
        deferred.append_block_deferred(&k[..5 * d], &v[..5 * d]);
        assert_eq!(deferred.quantized_len(), 0, "no groups before flush");
        deferred.append_block_deferred(&k[5 * d..], &v[5 * d..]);
        assert_eq!(deferred.resid_len(), tokens);
        deferred.flush_groups();
        assert_eq!(deferred.quantized_len(), eager.quantized_len());
        assert_eq!(deferred.decode_keys(), eager.decode_keys());
        assert_eq!(deferred.resid_k, eager.resid_k);
        assert_eq!(deferred.resid_v, eager.resid_v);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        deferred.decode_values_into(0, &mut a);
        eager.decode_values_into(0, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn quantized_values_roundtrip() {
        let mut rng = Rng::new(3);
        let d = 8;
        let mut sc = StreamCache::new(d, spec(), Some(4));
        let k = rng.normal_vec(8 * d);
        let v = rng.normal_vec(8 * d);
        sc.append_block(&k, &v);
        let mut dec = Vec::new();
        sc.decode_values_into(0, &mut dec);
        assert_eq!(dec.len(), 8 * d);
        let err = crate::tensor::ops::mse(&v, &dec);
        assert!(err < 0.01, "4-bit value err {err}");
    }

    #[test]
    fn memory_shrinks_with_fewer_bits() {
        let mut rng = Rng::new(4);
        let d = 32;
        let k = rng.normal_vec(64 * d);
        let v = rng.normal_vec(64 * d);
        let mut big = StreamCache::new(d, PolarSpec::new(5, 5, 8), None);
        big.append_block(&k, &v);
        let mut small = StreamCache::new(d, PolarSpec::new(2, 2, 8), None);
        small.append_block(&k, &v);
        assert!(small.nbytes() < big.nbytes());
    }
}
