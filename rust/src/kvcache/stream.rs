//! One KV stream's fp residual tail: the cache of a single
//! (layer, kv-head) pair BEFORE quantization.
//!
//! Keys and values buffer here at full precision until a whole
//! `spec.group` of tokens is present (the "residual length" every
//! quantization serving system keeps — paper §B notes all baselines need
//! one).  Finalized groups do NOT live here: encoding cuts them into
//! cross-stream [`crate::kvcache::pool::Page`]s owned (and possibly
//! shared) at the sequence level — this type only encodes and drains its
//! slice of each page.

use crate::quant::polar::{self, PolarGroup, PolarSpec};
use crate::quant::value;

/// Value storage for one finalized group of one stream.
#[derive(Clone, Debug)]
pub enum GroupValues {
    Fp(Vec<f32>),
    Quant(value::ValueEncoded),
}

impl GroupValues {
    pub fn nbytes(&self, charge_fp16: bool) -> usize {
        match self {
            GroupValues::Fp(v) => v.len() * if charge_fp16 { 2 } else { 4 },
            GroupValues::Quant(e) => e.nbytes(),
        }
    }

    /// Dequantized rows appended into `out`.
    pub fn decode_into(&self, d: usize, out: &mut Vec<f32>) {
        match self {
            GroupValues::Fp(v) => out.extend_from_slice(v),
            GroupValues::Quant(e) => out.extend_from_slice(&value::decode(e, d)),
        }
    }
}

/// The fp tail of one stream: tokens not yet cut into a page
/// (row-major tokens x d).
#[derive(Clone, Debug)]
pub struct StreamCache {
    pub d: usize,
    pub spec: PolarSpec,
    pub value_bits: Option<u32>,
    pub resid_k: Vec<f32>,
    pub resid_v: Vec<f32>,
}

impl StreamCache {
    pub fn new(d: usize, spec: PolarSpec, value_bits: Option<u32>) -> Self {
        StreamCache {
            d,
            spec,
            value_bits,
            resid_k: Vec::with_capacity(spec.group * d),
            resid_v: Vec::with_capacity(spec.group * d),
        }
    }

    /// Tokens in the fp residual tail.
    pub fn resid_len(&self) -> usize {
        self.resid_k.len() / self.d
    }

    /// Append one post-RoPE (k, v) token to the tail.  Finalization is
    /// the sequence's job ([`crate::kvcache::SequenceCache`] cuts pages
    /// across ALL streams once the tails fill) — a lone stream never
    /// decides on its own.
    pub fn push_token(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        self.resid_k.extend_from_slice(k);
        self.resid_v.extend_from_slice(v);
    }

    /// Bulk append (prefill block or deferred chunk).
    pub fn push_block(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len() % self.d, 0);
        debug_assert_eq!(v.len(), k.len());
        self.resid_k.extend_from_slice(k);
        self.resid_v.extend_from_slice(v);
    }

    /// Encode every full group the tail holds, oldest first, and drain
    /// the encoded prefix ONCE — a long deferred residual (chunked
    /// prefill's end-of-prompt flush) costs O(T·d), not O(T²·d/g)
    /// front-drains.  Returns one (keys, values) pair per group; the
    /// caller assembles them into cross-stream pages.
    pub fn encode_full_groups(&mut self) -> Vec<(PolarGroup, GroupValues)> {
        let gd = self.spec.group * self.d;
        let full = self.resid_k.len() / gd;
        let mut out = Vec::with_capacity(full);
        for gi in 0..full {
            let off = gi * gd;
            let g = polar::encode_group(&self.resid_k[off..off + gd], self.d, &self.spec);
            let v = match self.value_bits {
                None => GroupValues::Fp(self.resid_v[off..off + gd].to_vec()),
                Some(bits) => {
                    GroupValues::Quant(value::encode(&self.resid_v[off..off + gd], self.d, bits))
                }
            };
            out.push((g, v));
        }
        if full > 0 {
            // one front drain, on BOTH buffers, so each keeps its
            // preallocated capacity; then give back any deferred-prefill
            // slack beyond the steady-state one-group capacity
            self.resid_k.drain(..full * gd);
            self.resid_v.drain(..full * gd);
            self.resid_k.shrink_to(gd);
            self.resid_v.shrink_to(gd);
        }
        out
    }

    /// Physical bytes of the tail at rest (fp tensors charged as fp16 to
    /// match the paper's accounting).
    pub fn nbytes(&self) -> usize {
        (self.resid_k.len() + self.resid_v.len()) * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spec() -> PolarSpec {
        PolarSpec::new(4, 4, 8)
    }

    #[test]
    fn tail_buffers_until_encoded() {
        let mut rng = Rng::new(1);
        let d = 16;
        let mut sc = StreamCache::new(d, spec(), None);
        for _ in 0..19 {
            let k = rng.normal_vec(d);
            let v = rng.normal_vec(d);
            sc.push_token(&k, &v);
        }
        assert_eq!(sc.resid_len(), 19);
        let groups = sc.encode_full_groups();
        assert_eq!(groups.len(), 2);
        assert_eq!(sc.resid_len(), 3, "partial group stays fp");
        for (g, v) in &groups {
            assert_eq!(g.tokens, 8);
            assert!(matches!(v, GroupValues::Fp(x) if x.len() == 8 * d));
        }
    }

    #[test]
    fn block_push_equals_token_push() {
        let mut rng = Rng::new(2);
        let d = 8;
        let tokens = 21;
        let k = rng.normal_vec(tokens * d);
        let v = rng.normal_vec(tokens * d);
        let mut a = StreamCache::new(d, spec(), None);
        a.push_block(&k, &v);
        let mut b = StreamCache::new(d, spec(), None);
        for n in 0..tokens {
            b.push_token(&k[n * d..(n + 1) * d], &v[n * d..(n + 1) * d]);
        }
        assert_eq!(a.resid_k, b.resid_k);
        assert_eq!(a.resid_v, b.resid_v);
        let ga: Vec<_> = a.encode_full_groups();
        let gb: Vec<_> = b.encode_full_groups();
        assert_eq!(ga.len(), gb.len());
        for ((x, _), (y, _)) in ga.iter().zip(&gb) {
            assert_eq!(x.theta_codes.unpack(), y.theta_codes.unpack());
            assert_eq!(x.rho_codes.unpack(), y.rho_codes.unpack());
        }
        assert_eq!(a.resid_k, b.resid_k);
    }

    #[test]
    fn encode_preserves_capacity_of_both_residual_buffers() {
        let mut rng = Rng::new(11);
        let d = 16;
        let mut sc = StreamCache::new(d, spec(), None);
        for _ in 0..17 {
            let k = rng.normal_vec(d);
            let v = rng.normal_vec(d);
            sc.push_token(&k, &v);
        }
        let _ = sc.encode_full_groups();
        // both buffers keep the preallocated group-sized capacity —
        // a historical mem::take of resid_v lost its buffer every group
        assert!(sc.resid_k.capacity() >= sc.spec.group * d, "resid_k realloc");
        assert!(sc.resid_v.capacity() >= sc.spec.group * d, "resid_v realloc");
    }

    #[test]
    fn quantized_values_roundtrip() {
        let mut rng = Rng::new(3);
        let d = 8;
        let mut sc = StreamCache::new(d, spec(), Some(4));
        let k = rng.normal_vec(8 * d);
        let v = rng.normal_vec(8 * d);
        sc.push_block(&k, &v);
        let groups = sc.encode_full_groups();
        assert_eq!(groups.len(), 1);
        let mut dec = Vec::new();
        groups[0].1.decode_into(d, &mut dec);
        assert_eq!(dec.len(), 8 * d);
        let err = crate::tensor::ops::mse(&v, &dec);
        assert!(err < 0.01, "4-bit value err {err}");
    }
}
