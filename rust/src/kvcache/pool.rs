//! Refcounted group-page pool: the shared physical store behind every
//! sequence cache.
//!
//! A [`Page`] is the allocation unit of the paged KV cache: ONE finalized
//! (quantized) key group plus its values for EVERY (layer, kv-head)
//! stream of a sequence — i.e. a horizontal slice of `spec.group` tokens
//! across the whole model.  Sequences hold `Arc<Page>` handles, so
//!
//! * **sharing is a refcount bump** — N sequences whose prompts share a
//!   prefix attach to the same physical pages (prefix caching), and
//!   [`crate::kvcache::SequenceCache::fork`] is copy-on-write by
//!   construction: finalized pages are shared, only the fp residual tail
//!   is deep-copied;
//! * **accounting is exact and O(1)** — pages carry a handle to the
//!   pool's atomic counters and reconcile on `Drop`, so
//!   `CacheManager::admits` never walks live sequences;
//! * **eviction is precise** — the prefix index holds its own `Arc`, so a
//!   cached page with `strong_count == 1` is provably referenced by no
//!   sequence and can be reclaimed LRU when the pool is exhausted.
//!
//! Sharing quantized pages across sequences is EXACT, not approximate: a
//! finalized `PolarGroup` is a deterministic function of the post-RoPE
//! keys at fixed absolute positions, which (under eager chunked prefill)
//! are themselves a deterministic function of the token prefix.  The
//! prefix index therefore keys pages by a verified hash-chain over the
//! token prefix — equal chain means equal pages, bit for bit.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, TrySendError};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use super::stream::GroupValues;
use super::tier::{
    read_snapshot, spawn_writer, write_snapshot, DemoteJob, SegmentStore, SnapshotEntry,
    TierBackend, TierConfig, TierCounters, TierRef,
};
use crate::fabric::{FabricCounters, PrefixFabric};
use crate::quant::polar::PolarGroup;
use crate::trace::{trace_slot, TraceKind, TraceRecorder, TraceSlot};

/// Roll segment files at this size (append-only; see `tier::store`).
const SEGMENT_ROLL_BYTES: u64 = 64 << 20;

/// Pool-wide accounting, shared by every page and sequence the pool has
/// adopted.  All counters are atomics so the decode workers' appends and
/// the engine thread's admission checks never contend on a lock.
#[derive(Debug, Default)]
pub struct PoolCounters {
    /// physical pages resident (each shared page counted ONCE)
    pub pages: AtomicUsize,
    /// physical bytes of those pages
    pub page_bytes: AtomicUsize,
    /// fp residual-tail bytes across live sequences (fp16-charged)
    pub resid_bytes: AtomicUsize,
    /// refcount-zero prefix pages reclaimed under pressure
    pub pages_evicted: AtomicU64,
}

/// One finalized group across all streams: `keys[s]` / `vals[s]` belong
/// to stream `s` (= layer * n_kv_heads + head).  Immutable once built —
/// that is what makes sharing across sequences sound.
#[derive(Debug)]
pub struct Page {
    pub keys: Vec<PolarGroup>,
    pub vals: Vec<GroupValues>,
    /// tokens this page covers (== spec.group; pages are only cut from
    /// full groups)
    pub tokens: usize,
    nbytes: usize,
    /// accounting handle; `None` for pages of an un-pooled sequence
    counters: Option<Arc<PoolCounters>>,
}

impl Page {
    pub fn new(keys: Vec<PolarGroup>, vals: Vec<GroupValues>, tokens: usize) -> Self {
        debug_assert_eq!(keys.len(), vals.len());
        let nbytes = keys.iter().map(|g| g.nbytes()).sum::<usize>()
            + vals.iter().map(|v| v.nbytes(true)).sum::<usize>();
        Page { keys, vals, tokens, nbytes, counters: None }
    }

    /// Physical bytes at rest (same accounting as the pre-paged cache:
    /// codes packed, params fp32, values fp16-charged).
    pub fn nbytes(&self) -> usize {
        self.nbytes
    }
}

impl Drop for Page {
    fn drop(&mut self) {
        if let Some(c) = &self.counters {
            c.pages.fetch_sub(1, Ordering::Relaxed);
            c.page_bytes.fetch_sub(self.nbytes, Ordering::Relaxed);
        }
    }
}

/// Where a cached prefix page currently lives.
///
/// * `Resident` — in RAM; the ordinary PR-3 state.  The `Option<TierRef>`
///   remembers a known-good on-disk copy when one exists (the page was
///   promoted, or its background write landed after a re-promotion):
///   pages are immutable, so that record stays valid forever and a later
///   demotion or snapshot is a FREE slot flip instead of a rewrite — a
///   hot prefix set does not grow the segments on every restart cycle.
/// * `Queued` — handed to the tier's background writer; still in RAM
///   (the queue holds an `Arc`) but already discounted from the
///   capacity check via `demote_inflight`.  A lookup hit cancels the
///   state back to `Resident` for free (the write still lands and is
///   recorded as the known copy when it does).
/// * `Tiered` — on disk only; a lookup hit reads, checks, and re-adopts
///   the page (promotion).  A corrupt record degrades to a miss.
pub(crate) enum Slot {
    Resident(Arc<Page>, Option<super::tier::TierRef>),
    Queued(Arc<Page>),
    Tiered(super::tier::TierRef),
}

/// One prefix-index entry: the page for the group whose token chain
/// hashes to the map key, plus enough material to VERIFY the chain (so a
/// hash collision can only cause a miss, never a wrong share).
pub(crate) struct PrefixEntry {
    /// chain hash of the parent group (`ROOT_HASH` for the first group)
    pub(crate) parent: u64,
    /// the exact tokens this group covers
    pub(crate) toks: Vec<u32>,
    pub(crate) slot: Slot,
    /// LRU clock value of the last hit/registration
    pub(crate) tick: u64,
    /// tenant whose request first registered this chain entry — the
    /// owner for the per-tenant resident-page reserve
    pub(crate) tenant: String,
}

/// Tenant name entries registered before multi-tenancy (or by paths with
/// no tenant in scope — snapshot restores, anonymous v1 requests) fall
/// back to.
pub(crate) const DEFAULT_TENANT: &str = "default";

const ROOT_HASH: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis

fn chain_hash(parent: u64, toks: &[u32]) -> u64 {
    // FNV-1a over the parent hash then the group's token ids: cheap,
    // deterministic, and collisions are harmless (entries are verified)
    let mut h = 0x1000_0000_01b3u64 ^ parent;
    for &t in toks {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

pub(crate) struct PrefixIndex {
    pub(crate) entries: HashMap<u64, PrefixEntry>,
    pub(crate) clock: u64,
    /// attached disk tier (None = PR-3 behavior: reclaim drops pages)
    pub(crate) tier: Option<TierBackend>,
    /// per-tenant resident-page floor: reclaim and displacement skip a
    /// tenant's entries once its resident count is at or below this, so
    /// one tenant's flood cannot strip another's last cached pages
    /// (0 = PR-3 behavior: every refcount-zero page is fair game)
    pub(crate) tenant_reserve: usize,
}

impl PrefixIndex {
    /// Resident (+ queued: still in RAM) indexed pages per tenant.
    fn resident_by_tenant(&self) -> HashMap<String, usize> {
        let mut counts: HashMap<String, usize> = HashMap::new();
        for e in self.entries.values() {
            if matches!(e.slot, Slot::Resident(..) | Slot::Queued(_)) {
                *counts.entry(e.tenant.clone()).or_insert(0) += 1;
            }
        }
        counts
    }
}

/// Hard ceiling on prefix-index entries when the pool itself is
/// unbounded.  Without it, a long-running server with `--prefix-cache on`
/// and no `--cache-pages` cap would pin every distinct prompt's pages
/// forever (nothing else evicts index entries) and leak without bound
/// under diverse traffic.  Bounded pools use their page capacity instead —
/// the index can never outgrow what is resident.
const UNBOUNDED_PREFIX_CAP: usize = 32_768;

/// The pool's bound fabric: the transport (when this node fetches) plus
/// the config fingerprint every record is stamped/verified with.  A
/// `None` transport with a real tag is the export-only mode — the node
/// serves peer fetches but never fetches itself.
struct FabricState {
    fabric: Option<Arc<dyn PrefixFabric>>,
    tag: u64,
}

/// Cloneable handle to the shared page pool: capacity bookkeeping plus
/// the prefix index.  Page *data* is never behind this lock — readers go
/// straight through their `Arc<Page>` handles; the mutex only guards the
/// index (touched at prefill/registration rate, not decode rate).
#[derive(Clone)]
pub struct PagePool {
    index: Arc<Mutex<PrefixIndex>>,
    counters: Arc<PoolCounters>,
    /// tier counters/gauges, readable without the index lock (zeros
    /// until/unless a tier is attached)
    tier_stats: Arc<TierCounters>,
    /// late-bound prefix fabric ([`PagePool::set_fabric`]); unfilled =
    /// single-node behavior.  Same late-binding rationale as `trace`.
    fabric: Arc<OnceLock<FabricState>>,
    /// fabric counters, readable without the index lock
    fabric_stats: Arc<FabricCounters>,
    /// reaped-session blob bytes on the tier, by tenant — the ledger
    /// behind the per-tenant `--tenant-tier-bytes` spill quota
    session_tenant_bytes: Arc<Mutex<HashMap<String, u64>>>,
    /// late-bound trace recorder ([`PagePool::set_trace`]); unfilled =
    /// no events.  A slot rather than a direct field because the pool
    /// (and possibly its tier writer) exist before `serve` decides
    /// whether tracing is on.
    trace: TraceSlot,
    /// physical page capacity; `usize::MAX` = unbounded
    capacity: usize,
}

impl std::fmt::Debug for PagePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagePool")
            .field("capacity", &self.capacity)
            .field("pages_in_use", &self.pages_in_use())
            .field("page_bytes", &self.page_bytes())
            .finish()
    }
}

impl PagePool {
    /// `capacity` bounds physical resident pages (`usize::MAX` for
    /// unbounded — the accounting still runs).
    pub fn new(capacity: usize) -> Self {
        PagePool {
            index: Arc::new(Mutex::new(PrefixIndex {
                entries: HashMap::new(),
                clock: 0,
                tier: None,
                tenant_reserve: 0,
            })),
            counters: Arc::new(PoolCounters::default()),
            tier_stats: Arc::new(TierCounters::default()),
            fabric: Arc::new(OnceLock::new()),
            fabric_stats: Arc::new(FabricCounters::default()),
            session_tenant_bytes: Arc::new(Mutex::new(HashMap::new())),
            trace: trace_slot(),
            capacity,
        }
    }

    pub fn counters(&self) -> &Arc<PoolCounters> {
        &self.counters
    }

    /// Bind the engine's trace recorder (once; later binds are ignored).
    /// Pool events — `page_promote` on tier hits, `page_demote` on
    /// reclaim — flow into it; the already-running tier writer sees the
    /// same slot.  Observation-only: never changes pool behavior.
    pub fn set_trace(&self, rec: Arc<TraceRecorder>) {
        let _ = self.trace.set(rec);
    }

    #[inline]
    fn trace_record(&self, request: u64, kind: TraceKind) {
        if let Some(tr) = self.trace.get() {
            tr.record(request, kind);
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn bounded(&self) -> bool {
        self.capacity != usize::MAX
    }

    pub fn pages_in_use(&self) -> usize {
        self.counters.pages.load(Ordering::Relaxed)
    }

    pub fn page_bytes(&self) -> usize {
        self.counters.page_bytes.load(Ordering::Relaxed)
    }

    pub fn pages_evicted(&self) -> u64 {
        self.counters.pages_evicted.load(Ordering::Relaxed)
    }

    /// Pages allocatable right now without reclaiming anything.  Pages
    /// queued to the tier writer count as free already: the reclaim that
    /// queued them has logically released their capacity, the RAM just
    /// lags by one bounded write (see `tier::TierConfig::queue_depth`).
    pub fn free_pages(&self) -> usize {
        let in_use = self.counters.pages.load(Ordering::Relaxed);
        let inflight = self.tier_stats.demote_inflight.load(Ordering::Relaxed);
        self.capacity.saturating_sub(in_use.saturating_sub(inflight))
    }

    /// Take ownership of a freshly finalized page: attach the accounting
    /// handle and hand back the shared form.  Never fails — capacity is
    /// enforced ahead of time by the scheduler via [`PagePool::try_free`]
    /// (a transient one-page overshoot beats a fallible deep-in-the-model
    /// allocation path).
    pub fn adopt(&self, mut page: Page) -> Arc<Page> {
        debug_assert!(page.counters.is_none());
        self.counters.pages.fetch_add(1, Ordering::Relaxed);
        self.counters.page_bytes.fetch_add(page.nbytes, Ordering::Relaxed);
        page.counters = Some(self.counters.clone());
        Arc::new(page)
    }

    /// Ensure `need` pages can be allocated, reclaiming LRU refcount-zero
    /// prefix pages if necessary.  With a tier attached the reclaim
    /// DEMOTES instead of dropping: the entry survives pointing at disk,
    /// and the page's RAM frees as soon as the background writer lands
    /// it.  Returns false if the shortfall remains (every resident page
    /// is still referenced by some sequence) — the engine then preempts
    /// a decoding sequence instead of stalling.
    pub fn try_free(&self, need: usize) -> bool {
        if need <= self.free_pages() {
            return true;
        }
        let mut guard = self.index.lock().unwrap();
        self.reclaim_locked(&mut guard, need)
    }

    /// The reclaim loop behind [`PagePool::try_free`], callable by paths
    /// that already hold the index lock (promotion).
    fn reclaim_locked(&self, idx: &mut PrefixIndex, need: usize) -> bool {
        while self.free_pages() < need {
            // LRU resident entry whose page no sequence holds (the index
            // owns the only Arc); Queued entries are already on their way
            // out, Tiered ones hold no RAM.  With a tenant reserve set,
            // entries of tenants at/below their resident floor are off
            // limits — the shortfall then falls through to preemption
            // rather than cross-tenant cache theft.
            let reserve = idx.tenant_reserve;
            let counts =
                if reserve > 0 { idx.resident_by_tenant() } else { HashMap::new() };
            let victim = idx
                .entries
                .iter()
                .filter(
                    |(_, e)| matches!(&e.slot, Slot::Resident(p, _) if Arc::strong_count(p) == 1),
                )
                .filter(|(_, e)| {
                    reserve == 0 || counts.get(&e.tenant).copied().unwrap_or(0) > reserve
                })
                .min_by_key(|(_, e)| e.tick)
                .map(|(&h, _)| h);
            match victim {
                Some(h) => self.demote_or_evict(idx, h),
                None => return false,
            }
        }
        true
    }

    /// Reclaim one refcount-zero resident entry.  A page with a known
    /// on-disk copy demotes for FREE (slot flip, RAM drops, no write —
    /// pages are immutable so the old record is still exact); otherwise
    /// queue it to the tier writer (entry kept, capacity freed
    /// immediately via the inflight discount) when the tier has demotion
    /// open, is under its byte budget, and has queue room; otherwise
    /// drop the entry outright.
    fn demote_or_evict(&self, idx: &mut PrefixIndex, h: u64) {
        if let Some(tier) = &idx.tier {
            let known = match &idx.entries[&h].slot {
                Slot::Resident(_, known) => *known,
                _ => unreachable!("demotion victims are resident"),
            };
            if let Some(r) = known {
                idx.entries.get_mut(&h).unwrap().slot = Slot::Tiered(r);
                self.tier_stats.pages_demoted.fetch_add(1, Ordering::Relaxed);
                self.trace_record(0, TraceKind::PageDemote { pages: 1 });
                return;
            }
            let under_budget =
                self.tier_stats.bytes_on_disk.load(Ordering::Relaxed) < tier.max_bytes;
            if let (Some(tx), true) = (tier.tx.as_ref(), under_budget) {
                let page = match &idx.entries[&h].slot {
                    Slot::Resident(p, _) => p.clone(),
                    _ => unreachable!("demotion victims are resident"),
                };
                match tx.try_send(DemoteJob { hash: h, page: page.clone() }) {
                    Ok(()) => {
                        self.tier_stats.demote_inflight.fetch_add(1, Ordering::Relaxed);
                        idx.entries.get_mut(&h).unwrap().slot = Slot::Queued(page);
                        return;
                    }
                    Err(TrySendError::Full(_)) => {
                        // never stall reclaim on the writer: fall through
                        // to plain eviction and note the overflow
                        self.tier_stats.demote_overflow.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(TrySendError::Disconnected(_)) => {}
                }
            }
        }
        idx.entries.remove(&h);
        self.counters.pages_evicted.fetch_add(1, Ordering::Relaxed);
    }

    /// Longest already-pooled prefix of `tokens`, as verified chain pages
    /// (each covering `group` tokens), capped at `max_tokens`.  Hits
    /// refresh the LRU clock.  A hit on a demoted entry PROMOTES the
    /// page: it is read back from the segment store, checksum-verified,
    /// re-adopted into the pool, and the entry goes resident again — a
    /// corrupt record degrades to a miss (the entry is dropped and the
    /// chain stops there), never a panic.
    ///
    /// Capacity: a bounded pool promotes like it allocates — each page
    /// first reclaims room ([`PagePool::try_free`] semantics); if nothing
    /// is reclaimable the chain stops there and the tail stays on disk
    /// (the caller prefills those tokens instead, under the ordinary
    /// preemption machinery).  Promotion never overshoots the cap.
    ///
    /// The disk read runs under the index lock — a deliberate tradeoff:
    /// lookups happen at admission rate (not decode rate) and the only
    /// other lock user is the tier writer, whose worst case is falling
    /// back to plain eviction when its queue fills, never blocking a
    /// decode step.
    pub fn lookup_prefix(&self, tokens: &[u32], group: usize, max_tokens: usize) -> Vec<Arc<Page>> {
        self.lookup_prefix_traced(tokens, group, max_tokens, 0)
    }

    /// [`PagePool::lookup_prefix`] keyed to a request id: a promotion on
    /// the walk records a `page_promote` trace span against `request`,
    /// so tier latency shows up on the request that paid for it.
    pub fn lookup_prefix_traced(
        &self,
        tokens: &[u32],
        group: usize,
        max_tokens: usize,
        request: u64,
    ) -> Vec<Arc<Page>> {
        let mut guard = self.index.lock().unwrap();
        let idx = &mut *guard;
        idx.clock += 1;
        let tick = idx.clock;
        let store = idx.tier.as_ref().map(|t| t.store.clone());
        let mut pages = Vec::new();
        let mut promoted = 0u64;
        let mut fetched = 0u64;
        let mut parent = ROOT_HASH;
        let mut pos = 0;
        enum Got {
            Page(Arc<Page>),
            Promote(super::tier::TierRef),
            Miss,
        }
        while pos + group <= tokens.len().min(max_tokens) {
            let toks = &tokens[pos..pos + group];
            let h = chain_hash(parent, toks);
            // verify BOTH the tokens and the chain parent: equal hash
            // alone is not proof of an equal prefix
            let got = match idx.entries.get_mut(&h) {
                Some(e) if e.parent == parent && e.toks == toks => match &e.slot {
                    Slot::Resident(p, _) => {
                        e.tick = tick;
                        Got::Page(p.clone())
                    }
                    Slot::Queued(p) => {
                        // cancel the demotion: the page is wanted again
                        // (the in-flight write still lands, and the
                        // writer records it as the known on-disk copy)
                        let p = p.clone();
                        e.slot = Slot::Resident(p.clone(), None);
                        e.tick = tick;
                        Got::Page(p)
                    }
                    Slot::Tiered(tref) => Got::Promote(*tref),
                },
                _ => Got::Miss,
            };
            match got {
                Got::Page(p) => pages.push(p),
                Got::Promote(r) => {
                    // make room first (chain pages already promoted are
                    // pinned by `pages`, so they are never victims); a dry
                    // bounded pool stops the chain instead of overshooting
                    if !self.reclaim_locked(idx, 1) {
                        break;
                    }
                    match store.as_ref().map(|s| s.get(r)) {
                        Some(Ok(page)) => {
                            let arc = self.adopt(page);
                            if let Some(e) = idx.entries.get_mut(&h) {
                                // keep the ref: the record stays exact, so
                                // re-demoting this page later is free
                                e.slot = Slot::Resident(arc.clone(), Some(r));
                                e.tick = tick;
                            }
                            promoted += 1;
                            pages.push(arc);
                        }
                        // corrupt/unreadable record, or the tier
                        // vanished: treat as a miss
                        _ => {
                            idx.entries.remove(&h);
                            break;
                        }
                    }
                }
                // a true local+tier miss: the shared fabric gets one shot
                // at the chain link before the walk gives up
                Got::Miss => match self.fabric_fetch_locked(idx, h, parent, toks, tick) {
                    Some(p) => {
                        fetched += 1;
                        pages.push(p);
                    }
                    None => break,
                },
            }
            parent = h;
            pos += group;
        }
        if promoted > 0 {
            self.tier_stats.tier_hits.fetch_add(1, Ordering::Relaxed);
            self.tier_stats.pages_promoted.fetch_add(promoted, Ordering::Relaxed);
            self.trace_record(request, TraceKind::PagePromote { pages: promoted as u32 });
        }
        if fetched > 0 {
            FabricCounters::bump(&self.fabric_stats.hits, 1);
            FabricCounters::bump(&self.fabric_stats.pages, fetched);
            self.trace_record(request, TraceKind::FabricFetch { pages: fetched as u32 });
        }
        pages
    }

    /// Try the attached fabric for one missing chain link.  The record
    /// goes through FULL verification before the pool trusts it: envelope
    /// checksum + config fingerprint ([`crate::fabric::decode_record`]),
    /// then the semantic identity of the link — parent hash, exact token
    /// run, token count vs the page.  Any failure is a clean miss (and a
    /// `fabric_rejected` tick), never a wrong cache entry.  An admitted
    /// page makes room like a tier promotion does: reclaim first, and a
    /// dry bounded pool stops the chain instead of overshooting.
    fn fabric_fetch_locked(
        &self,
        idx: &mut PrefixIndex,
        h: u64,
        parent: u64,
        toks: &[u32],
        tick: u64,
    ) -> Option<Arc<Page>> {
        let state = self.fabric.get()?;
        let fabric = state.fabric.as_ref()?;
        let bytes = fabric.fetch(h)?;
        FabricCounters::bump(&self.fabric_stats.bytes_fetched, bytes.len() as u64);
        let rec = match crate::fabric::decode_record(&bytes, state.tag) {
            Ok(r) => r,
            Err(e) => {
                FabricCounters::bump(&self.fabric_stats.rejected, 1);
                eprintln!("[fabric] rejected record for {h:#018x}: {e:#}");
                return None;
            }
        };
        if rec.parent != parent || rec.toks != toks || rec.page.tokens != toks.len() {
            FabricCounters::bump(&self.fabric_stats.rejected, 1);
            eprintln!("[fabric] record for {h:#018x} describes a different chain link");
            return None;
        }
        if !self.reclaim_locked(idx, 1) {
            return None;
        }
        let arc = self.adopt(rec.page);
        idx.entries.insert(
            h,
            PrefixEntry {
                parent,
                toks: toks.to_vec(),
                slot: Slot::Resident(arc.clone(), None),
                tick,
                tenant: DEFAULT_TENANT.to_string(),
            },
        );
        Some(arc)
    }

    /// Register a sequence's finalized pages under the token prefix that
    /// produced them.  Only pages covering tokens entirely inside
    /// `tokens` are registered (a page straddling the prompt/generation
    /// boundary is request-private).  Idempotent: existing entries are
    /// left untouched, so repeated registration as chunks land is cheap.
    pub fn register_prefix(&self, pages: &[Arc<Page>], tokens: &[u32]) {
        self.register_prefix_for(pages, tokens, DEFAULT_TENANT);
    }

    /// [`PagePool::register_prefix`] with an explicit owning tenant —
    /// the name the per-tenant reserve accounts these entries to.
    pub fn register_prefix_for(&self, pages: &[Arc<Page>], tokens: &[u32], tenant: &str) {
        let mut guard = self.index.lock().unwrap();
        let idx = &mut *guard;
        // with a tier attached, the index may legitimately outgrow the
        // page capacity: Tiered entries hold no RAM, so only the global
        // entry cap applies (pool capacity is bounded by disk, not memory)
        let cap = if idx.tier.is_some() {
            UNBOUNDED_PREFIX_CAP
        } else {
            self.capacity.min(UNBOUNDED_PREFIX_CAP)
        };
        idx.clock += 1;
        let tick = idx.clock;
        let mut parent = ROOT_HASH;
        let mut pos = 0;
        for page in pages {
            if pos + page.tokens > tokens.len() {
                break;
            }
            let toks = &tokens[pos..pos + page.tokens];
            let h = chain_hash(parent, toks);
            let exists = match idx.entries.get_mut(&h) {
                Some(e) => {
                    // re-registering a chain whose entry was demoted: the
                    // registering sequence holds the page resident, so
                    // upgrade in place, keeping the disk record as the
                    // known copy (same chain => bit-identical page) — but
                    // only after verifying the chain, never across a hash
                    // collision
                    if e.parent == parent && e.toks == toks {
                        if let Slot::Tiered(r) = e.slot {
                            e.slot = Slot::Resident(page.clone(), Some(r));
                            e.tick = tick;
                        }
                    }
                    true
                }
                None => false,
            };
            if !exists {
                // bound the index: past the cap, a new entry must displace
                // the LRU removable one, or it simply isn't cached.  The
                // tenant reserve shields OTHER tenants' resident floors
                // here too (Tiered entries hold no RAM and stay fair game)
                if idx.entries.len() >= cap {
                    let reserve = idx.tenant_reserve;
                    let counts =
                        if reserve > 0 { idx.resident_by_tenant() } else { HashMap::new() };
                    let lru = idx
                        .entries
                        .iter()
                        .filter(|(_, e)| match &e.slot {
                            Slot::Resident(p, _) => {
                                Arc::strong_count(p) == 1
                                    && (reserve == 0
                                        || e.tenant == tenant
                                        || counts.get(&e.tenant).copied().unwrap_or(0) > reserve)
                            }
                            Slot::Queued(_) => false, // writer owns it
                            Slot::Tiered(_) => true,  // forgetting a ref is free
                        })
                        .min_by_key(|(_, e)| e.tick)
                        .map(|(&k, _)| k);
                    match lru {
                        Some(k) => {
                            if matches!(idx.entries[&k].slot, Slot::Resident(..)) {
                                self.counters.pages_evicted.fetch_add(1, Ordering::Relaxed);
                            }
                            idx.entries.remove(&k);
                        }
                        None => break,
                    }
                }
                idx.entries.insert(
                    h,
                    PrefixEntry {
                        parent,
                        toks: toks.to_vec(),
                        slot: Slot::Resident(page.clone(), None),
                        tick,
                        tenant: tenant.to_string(),
                    },
                );
                // a NEW chain link is the publication point: offer it to
                // the shared fabric so a peer's cold cache can fetch it
                // instead of re-prefilling (directory transport only;
                // peer mode serves fetches from this same index instead)
                if let Some(state) = self.fabric.get() {
                    if let Some(fabric) = &state.fabric {
                        let rec = crate::fabric::encode_record(state.tag, parent, toks, page);
                        if fabric.publish(h, &rec) {
                            FabricCounters::bump(&self.fabric_stats.published, 1);
                        }
                    }
                }
            }
            parent = h;
            pos += page.tokens;
        }
    }

    // ----------------------------------------------------------- fabric

    /// Bind the prefix fabric (once; later binds are ignored, matching
    /// [`PagePool::set_trace`]).  `fabric = None` still records the
    /// config `tag`, enabling export-only mode: this node answers peer
    /// fetches ([`PagePool::fabric_export`]) without fetching itself.
    pub fn set_fabric(&self, fabric: Option<Arc<dyn PrefixFabric>>, tag: u64) {
        let _ = self.fabric.set(FabricState { fabric, tag });
    }

    /// Whether a fetch-capable fabric is bound.
    pub fn fabric_attached(&self) -> bool {
        matches!(self.fabric.get(), Some(s) if s.fabric.is_some())
    }

    /// The transfer record for chain hash `h`, for serving a PEER's
    /// fetch.  Only in-RAM entries export — promoting a tiered page on a
    /// peer's behalf would let remote traffic thrash the local tier.
    pub fn fabric_export(&self, h: u64) -> Option<Vec<u8>> {
        let state = self.fabric.get()?;
        let idx = self.index.lock().unwrap();
        let e = idx.entries.get(&h)?;
        match &e.slot {
            Slot::Resident(p, _) | Slot::Queued(p) => {
                Some(crate::fabric::encode_record(state.tag, e.parent, &e.toks, p))
            }
            Slot::Tiered(_) => None,
        }
    }

    /// Fabric counters (zeros until a fabric is bound and used).
    pub fn fabric_prefix_hits(&self) -> u64 {
        self.fabric_stats.get(&self.fabric_stats.hits)
    }

    pub fn fabric_pages_fetched(&self) -> u64 {
        self.fabric_stats.get(&self.fabric_stats.pages)
    }

    pub fn fabric_rejected(&self) -> u64 {
        self.fabric_stats.get(&self.fabric_stats.rejected)
    }

    pub fn fabric_published(&self) -> u64 {
        self.fabric_stats.get(&self.fabric_stats.published)
    }

    pub fn fabric_bytes_fetched(&self) -> u64 {
        self.fabric_stats.get(&self.fabric_stats.bytes_fetched)
    }

    /// Set the per-tenant resident-page floor (see
    /// [`PrefixIndex::tenant_reserve`]); 0 disables the protection.
    pub fn set_tenant_reserve(&self, pages: usize) {
        self.index.lock().unwrap().tenant_reserve = pages;
    }

    /// Resident (+ queued) prefix-cache pages per owning tenant
    /// (metrics/observability).
    pub fn tenant_pages(&self) -> HashMap<String, usize> {
        self.index.lock().unwrap().resident_by_tenant()
    }

    /// Prefix-index entries currently held (tests/observability).
    pub fn indexed_pages(&self) -> usize {
        self.index.lock().unwrap().entries.len()
    }

    /// Prefix-index entries currently living on disk only.
    pub fn tiered_pages(&self) -> usize {
        self.index
            .lock()
            .unwrap()
            .entries
            .values()
            .filter(|e| matches!(e.slot, Slot::Tiered(_)))
            .count()
    }

    /// Drop every cached prefix entry regardless of recency (tests).
    pub fn clear_prefix_index(&self) {
        let mut idx = self.index.lock().unwrap();
        let n = idx
            .entries
            .values()
            .filter(|e| matches!(&e.slot, Slot::Resident(p, _) if Arc::strong_count(p) == 1))
            .count() as u64;
        idx.entries.retain(|_, e| match &e.slot {
            Slot::Resident(p, _) => Arc::strong_count(p) > 1,
            Slot::Queued(_) => true, // writer still owns it; let it finish
            Slot::Tiered(_) => false,
        });
        self.counters.pages_evicted.fetch_add(n, Ordering::Relaxed);
    }

    // ------------------------------------------------------------- tier

    /// Attach a disk tier: reclaim demotes instead of dropping, lookups
    /// promote, and a snapshot written by an earlier process under the
    /// same `config_tag` warm-starts the prefix index (all entries come
    /// back `Tiered`; pages fault in lazily on their first hit).
    ///
    /// Returns the number of restored prefix entries.  A present-but-
    /// unreadable snapshot (corruption, version or config-tag mismatch)
    /// is reported and ignored — the pool starts cold, it never trusts a
    /// bad index.
    pub fn attach_tier(&self, cfg: TierConfig) -> Result<usize> {
        // cheap early rejection: don't scan directories or spawn a writer
        // just to find out a tier is already there (re-checked under the
        // lock below against races)
        if self.index.lock().unwrap().tier.is_some() {
            bail!("tier already attached to this pool");
        }
        let store = Arc::new(SegmentStore::open(&cfg.dir, SEGMENT_ROLL_BYTES)?);
        self.tier_stats.bytes_on_disk.store(store.bytes_on_disk(), Ordering::Relaxed);
        let restored = match read_snapshot(&cfg.dir, cfg.config_tag) {
            Ok(Some(entries)) => entries,
            Ok(None) => Vec::new(),
            Err(e) => {
                eprintln!("[tier] ignoring unusable snapshot in {}: {e:#}", cfg.dir.display());
                Vec::new()
            }
        };
        let (tx, rx) = sync_channel(cfg.queue_depth.max(1));
        let writer = spawn_writer(
            Arc::downgrade(&self.index),
            store.clone(),
            self.tier_stats.clone(),
            self.trace.clone(),
            rx,
        );
        let mut idx = self.index.lock().unwrap();
        if idx.tier.is_some() {
            bail!("tier already attached to this pool");
        }
        let n = restored.len();
        for e in restored {
            idx.clock += 1;
            let tick = idx.clock;
            let h = chain_hash(e.parent, &e.toks);
            idx.entries.insert(
                h,
                PrefixEntry {
                    parent: e.parent,
                    toks: e.toks,
                    slot: Slot::Tiered(e.tref),
                    tick,
                    tenant: DEFAULT_TENANT.to_string(),
                },
            );
        }
        idx.tier = Some(TierBackend {
            store,
            tx: Some(tx),
            writer: Some(writer),
            max_bytes: cfg.max_bytes,
            dir: cfg.dir,
            config_tag: cfg.config_tag,
        });
        Ok(n)
    }

    pub fn tier_attached(&self) -> bool {
        self.index.lock().unwrap().tier.is_some()
    }

    /// Tier counters (zeros when no tier is attached).
    pub fn tier_hits(&self) -> u64 {
        self.tier_stats.tier_hits.load(Ordering::Relaxed)
    }

    pub fn pages_demoted(&self) -> u64 {
        self.tier_stats.pages_demoted.load(Ordering::Relaxed)
    }

    pub fn pages_promoted(&self) -> u64 {
        self.tier_stats.pages_promoted.load(Ordering::Relaxed)
    }

    pub fn bytes_on_disk(&self) -> u64 {
        self.tier_stats.bytes_on_disk.load(Ordering::Relaxed)
    }

    /// Segment bytes currently held by reaped session blobs — the slice
    /// of [`PagePool::bytes_on_disk`] that belongs to sessions rather
    /// than demoted prefix pages.
    pub fn session_bytes(&self) -> u64 {
        self.tier_stats.session_bytes.load(Ordering::Relaxed)
    }

    /// Append one opaque session blob (`kvcache::tier::session`) to the
    /// tier's segment store — the idle-session TTL reaper's write path.
    /// Fails when no tier is attached, when the `--tier-bytes` budget
    /// is already exhausted (session blobs share it with demoted prefix
    /// pages), or — with `tenant_cap > 0` — when THIS tenant's reaped
    /// blobs would exceed `--tenant-tier-bytes`: over-cap spills refuse
    /// per-tenant, so one tenant's idle-session flood cannot eat the
    /// whole shared budget.  The engine keeps a refused session resident.
    pub fn session_spill(&self, bytes: &[u8], tenant: &str, tenant_cap: u64) -> Result<TierRef> {
        let (store, max_bytes) = {
            let idx = self.index.lock().unwrap();
            let Some(t) = &idx.tier else { bail!("no tier attached") };
            (t.store.clone(), t.max_bytes)
        };
        if self.tier_stats.bytes_on_disk.load(Ordering::Relaxed) >= max_bytes {
            bail!("tier byte budget exhausted ({max_bytes} B)");
        }
        if tenant_cap > 0 {
            // charge under the lock so concurrent reapers can't both
            // sneak under the cap
            let mut per = self.session_tenant_bytes.lock().unwrap();
            let used = per.entry(tenant.to_string()).or_insert(0);
            if used.saturating_add(bytes.len() as u64) > tenant_cap {
                bail!(
                    "tenant '{tenant}' session-blob quota exhausted \
                     ({used} + {} > {tenant_cap} B)",
                    bytes.len()
                );
            }
            *used += bytes.len() as u64;
        }
        let r = match store.put_bytes(bytes) {
            Ok(r) => r,
            Err(e) => {
                // roll the charge back: nothing landed on disk
                if tenant_cap > 0 {
                    if let Some(used) = self.session_tenant_bytes.lock().unwrap().get_mut(tenant)
                    {
                        *used = used.saturating_sub(bytes.len() as u64);
                    }
                }
                return Err(e);
            }
        };
        self.tier_stats.bytes_on_disk.store(store.bytes_on_disk(), Ordering::Relaxed);
        self.tier_stats.session_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(r)
    }

    /// Reaped-session blob bytes currently charged to `tenant`.
    pub fn tenant_session_bytes(&self, tenant: &str) -> u64 {
        self.session_tenant_bytes.lock().unwrap().get(tenant).copied().unwrap_or(0)
    }

    /// Read back a session blob written by [`PagePool::session_spill`].
    /// The caller verifies content (`tier::session::decode_session`).
    /// The blob's bytes leave the session gauge — and the owning
    /// tenant's quota ledger — a fetched session is live again and its
    /// tier copy is dead weight awaiting compaction.
    pub fn session_fetch(&self, r: TierRef, tenant: &str) -> Result<Vec<u8>> {
        let store = {
            let idx = self.index.lock().unwrap();
            let Some(t) = &idx.tier else { bail!("no tier attached") };
            t.store.clone()
        };
        let blob = store.get_bytes(r)?;
        let n = blob.len() as u64;
        // saturating: a restart re-opens the store with the gauge at 0,
        // so fetches of pre-restart blobs must not wrap
        let _ = self.tier_stats.session_bytes.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |cur| Some(cur.saturating_sub(n)),
        );
        if let Some(used) = self.session_tenant_bytes.lock().unwrap().get_mut(tenant) {
            *used = used.saturating_sub(n);
        }
        Ok(blob)
    }

    /// Synchronously demote every refcount-zero resident prefix entry
    /// (tests/benches force the demote→promote cycle deterministically;
    /// production demotion goes through the background writer instead).
    pub fn demote_all(&self) -> usize {
        let mut guard = self.index.lock().unwrap();
        let idx = &mut *guard;
        let Some(store) = idx.tier.as_ref().map(|t| t.store.clone()) else { return 0 };
        let mut n = 0;
        for e in idx.entries.values_mut() {
            // a known on-disk copy flips for free; only never-written
            // pages cost a record
            let flip = match &e.slot {
                Slot::Resident(p, known) if Arc::strong_count(p) == 1 => match known {
                    Some(r) => Some(*r),
                    None => match store.put(p) {
                        Ok(r) => Some(r),
                        Err(err) => {
                            eprintln!("[tier] demote_all write failed: {err:#}");
                            None
                        }
                    },
                },
                _ => None,
            };
            if let Some(r) = flip {
                e.slot = Slot::Tiered(r);
                self.tier_stats.pages_demoted.fetch_add(1, Ordering::Relaxed);
                n += 1;
            }
        }
        self.tier_stats.bytes_on_disk.store(store.bytes_on_disk(), Ordering::Relaxed);
        n
    }

    /// Persist the prefix index for a warm start: drain the background
    /// writer, write every still-resident entry's page to the segment
    /// store, and atomically replace the snapshot index file.  Demotion
    /// is sealed afterwards (this is a shutdown operation) but lookups —
    /// including promotions — keep working.
    ///
    /// Returns (entries persisted, bytes on disk).
    pub fn snapshot(&self) -> Result<(usize, u64)> {
        // 1. seal the demotion queue and drain the writer — after the
        //    join every Queued entry has become Tiered (or reverted to
        //    Resident on a write error).  The index lock is NOT held
        //    across the join: the writer needs it to flip entries.
        let (store, dir, tag, writer) = {
            let mut idx = self.index.lock().unwrap();
            let Some(t) = idx.tier.as_mut() else { bail!("no tier attached") };
            t.tx = None;
            (t.store.clone(), t.dir.clone(), t.config_tag, t.writer.take())
        };
        if let Some(w) = writer {
            let _ = w.join();
        }
        // 2. persist: entries with a known on-disk copy just re-record
        //    their refs (immutable pages — the old record is still
        //    exact); only never-written pages cost a new record
        let mut out: Vec<SnapshotEntry> = Vec::new();
        {
            let mut guard = self.index.lock().unwrap();
            let idx = &mut *guard;
            for e in idx.entries.values_mut() {
                let tref = match &mut e.slot {
                    Slot::Tiered(r) => *r,
                    Slot::Resident(_, Some(r)) => *r,
                    Slot::Resident(p, known) => {
                        let r = store.put(p).context("snapshot page write")?;
                        *known = Some(r);
                        r
                    }
                    Slot::Queued(p) => store.put(p).context("snapshot page write")?,
                };
                out.push(SnapshotEntry { parent: e.parent, toks: e.toks.clone(), tref });
            }
        }
        store.sync()?;
        write_snapshot(&dir, tag, &out)?;
        let bytes = store.bytes_on_disk();
        self.tier_stats.bytes_on_disk.store(bytes, Ordering::Relaxed);
        Ok((out.len(), bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::polar::{self, PolarSpec};
    use crate::util::rng::Rng;

    fn page(seed: u64) -> Page {
        let spec = PolarSpec::new(4, 4, 4);
        let d = 8;
        let mut rng = Rng::new(seed);
        let streams = 2;
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..streams {
            let k = rng.normal_vec(spec.group * d);
            keys.push(polar::encode_group(&k, d, &spec));
            vals.push(GroupValues::Fp(rng.normal_vec(spec.group * d)));
        }
        Page::new(keys, vals, spec.group)
    }

    #[test]
    fn adopt_and_drop_reconcile_counters() {
        let pool = PagePool::new(8);
        let p1 = pool.adopt(page(1));
        let p2 = pool.adopt(page(2));
        assert_eq!(pool.pages_in_use(), 2);
        assert!(pool.page_bytes() > 0);
        assert_eq!(pool.free_pages(), 6);
        let clone = p1.clone(); // refcount bump, no physical change
        assert_eq!(pool.pages_in_use(), 2);
        drop(p1);
        drop(clone);
        drop(p2);
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.page_bytes(), 0);
    }

    #[test]
    fn prefix_chain_roundtrip_and_partial_hit() {
        let pool = PagePool::new(usize::MAX);
        let g = 4;
        let toks: Vec<u32> = (0..12).collect();
        let pages: Vec<_> = (0..3).map(|i| pool.adopt(page(10 + i))).collect();
        pool.register_prefix(&pages, &toks);
        assert_eq!(pool.indexed_pages(), 3);
        // full match
        let hit = pool.lookup_prefix(&toks, g, usize::MAX);
        assert_eq!(hit.len(), 3);
        assert!(Arc::ptr_eq(&hit[0], &pages[0]));
        // longest-prefix: diverge in the second group
        let mut other = toks.clone();
        other[5] = 99;
        let hit = pool.lookup_prefix(&other, g, usize::MAX);
        assert_eq!(hit.len(), 1, "only the first group matches");
        // cap respected
        let hit = pool.lookup_prefix(&toks, g, 8);
        assert_eq!(hit.len(), 2);
        // shorter-than-group prompt: no hit
        assert!(pool.lookup_prefix(&toks[..3], g, usize::MAX).is_empty());
    }

    #[test]
    fn chain_keying_distinguishes_same_group_different_prefix() {
        // the SAME tokens at group 2 must not be shared across different
        // first groups — the chain hash keys on the whole prefix
        let pool = PagePool::new(usize::MAX);
        let g = 4;
        let a: Vec<u32> = vec![1, 2, 3, 4, 9, 9, 9, 9];
        let b: Vec<u32> = vec![5, 6, 7, 8, 9, 9, 9, 9];
        let pa: Vec<_> = (0..2).map(|i| pool.adopt(page(20 + i))).collect();
        pool.register_prefix(&pa, &a);
        let hit = pool.lookup_prefix(&b, g, usize::MAX);
        assert!(hit.is_empty(), "chain with different first group must miss");
    }

    #[test]
    fn try_free_reclaims_lru_unreferenced_only() {
        let pool = PagePool::new(3);
        let toks: Vec<u32> = (0..8).collect();
        let p0 = pool.adopt(page(30));
        let p1 = pool.adopt(page(31));
        pool.register_prefix(&[p0.clone(), p1.clone()], &toks);
        // a third page held by a "sequence"
        let held = pool.adopt(page(32));
        assert_eq!(pool.free_pages(), 0);
        // p0/p1 still referenced here -> nothing reclaimable
        assert!(!pool.try_free(1));
        // release the sequence refs; index entries become refcount-zero
        drop(p0);
        drop(p1);
        assert!(pool.try_free(1), "LRU prefix page must be reclaimed");
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.pages_evicted(), 1);
        // the reclaimed entry was the LRU one (registered first => oldest
        // tick); the survivor still verifies for the 2-group chain's head
        assert_eq!(pool.indexed_pages(), 1);
        drop(held);
        assert!(pool.try_free(3));
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn bounded_pool_caps_the_prefix_index_by_displacing_lru() {
        // capacity 2: registering a third (unreferenced) chain entry must
        // displace the LRU one instead of growing the index
        let pool = PagePool::new(2);
        let toks_a: Vec<u32> = (0..4).collect();
        let toks_b: Vec<u32> = (100..104).collect();
        let toks_c: Vec<u32> = (200..204).collect();
        let pa = pool.adopt(page(50));
        pool.register_prefix(std::slice::from_ref(&pa), &toks_a);
        drop(pa);
        let pb = pool.adopt(page(51));
        pool.register_prefix(std::slice::from_ref(&pb), &toks_b);
        drop(pb);
        assert_eq!(pool.indexed_pages(), 2);
        let pc = pool.adopt(page(52));
        pool.register_prefix(std::slice::from_ref(&pc), &toks_c);
        drop(pc);
        assert_eq!(pool.indexed_pages(), 2, "index stays at cap");
        assert_eq!(pool.pages_evicted(), 1);
        // the oldest chain (a) was displaced; b and c survive
        assert!(pool.lookup_prefix(&toks_a, 4, usize::MAX).is_empty());
        assert_eq!(pool.lookup_prefix(&toks_b, 4, usize::MAX).len(), 1);
        assert_eq!(pool.lookup_prefix(&toks_c, 4, usize::MAX).len(), 1);
    }

    #[test]
    fn tenant_reserve_shields_a_tenants_last_pages_from_reclaim() {
        let pool = PagePool::new(4);
        pool.set_tenant_reserve(1);
        let toks_a: Vec<u32> = (0..4).collect();
        let toks_b: Vec<u32> = (100..104).collect();
        let toks_c: Vec<u32> = (200..204).collect();
        let pa = pool.adopt(page(1));
        pool.register_prefix_for(std::slice::from_ref(&pa), &toks_a, "small");
        drop(pa);
        let pb = pool.adopt(page(2));
        pool.register_prefix_for(std::slice::from_ref(&pb), &toks_b, "flood");
        drop(pb);
        let pc = pool.adopt(page(3));
        pool.register_prefix_for(std::slice::from_ref(&pc), &toks_c, "flood");
        drop(pc);
        let _held = pool.adopt(page(4));
        assert_eq!(pool.free_pages(), 0);
        // flood is past its floor (2 resident) — its LRU entry is the
        // only eligible victim; small's lone page is protected
        assert!(pool.try_free(1));
        assert!(pool.lookup_prefix(&toks_b, 4, usize::MAX).is_empty(), "flood LRU evicted");
        assert_eq!(pool.lookup_prefix(&toks_a, 4, usize::MAX).len(), 1, "small survives");
        assert_eq!(pool.lookup_prefix(&toks_c, 4, usize::MAX).len(), 1);
        let counts = pool.tenant_pages();
        assert_eq!(counts.get("small"), Some(&1));
        assert_eq!(counts.get("flood"), Some(&1));
        // now every tenant sits at the floor: asking past the one free
        // page must refuse rather than strip a protected tenant (the
        // engine preempts instead)
        assert!(!pool.try_free(2));
        // without the reserve the same state reclaims fine
        pool.set_tenant_reserve(0);
        assert!(pool.try_free(2));
    }

    #[test]
    fn tenant_reserve_guards_displacement_but_not_own_entries() {
        // index at cap: a new registration may displace the registrant's
        // OWN floor entries, never another tenant's
        let pool = PagePool::new(2);
        pool.set_tenant_reserve(1);
        let toks_a: Vec<u32> = (0..4).collect();
        let toks_b: Vec<u32> = (100..104).collect();
        let toks_b2: Vec<u32> = (200..204).collect();
        let pa = pool.adopt(page(10));
        pool.register_prefix_for(std::slice::from_ref(&pa), &toks_a, "small");
        drop(pa);
        let pb = pool.adopt(page(11));
        pool.register_prefix_for(std::slice::from_ref(&pb), &toks_b, "flood");
        drop(pb);
        assert_eq!(pool.indexed_pages(), 2);
        let pb2 = pool.adopt(page(12));
        pool.register_prefix_for(std::slice::from_ref(&pb2), &toks_b2, "flood");
        drop(pb2);
        assert_eq!(pool.indexed_pages(), 2, "index stays at cap");
        assert_eq!(pool.lookup_prefix(&toks_a, 4, usize::MAX).len(), 1, "small protected");
        assert!(pool.lookup_prefix(&toks_b, 4, usize::MAX).is_empty(), "flood displaced itself");
        assert_eq!(pool.lookup_prefix(&toks_b2, 4, usize::MAX).len(), 1);
    }

    #[test]
    fn register_skips_pages_past_the_token_limit() {
        let pool = PagePool::new(usize::MAX);
        let pages: Vec<_> = (0..3).map(|i| pool.adopt(page(40 + i))).collect();
        // only 9 tokens: the third page (tokens 8..12) straddles the end
        let toks: Vec<u32> = (0..9).collect();
        pool.register_prefix(&pages, &toks);
        assert_eq!(pool.indexed_pages(), 2);
    }

    // --------------------------------------------------------- tiering

    fn tier_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("polarquant-pool-tier-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn wait_until(what: &str, f: impl Fn() -> bool) {
        for _ in 0..2000 {
            if f() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        panic!("timed out waiting for {what}");
    }

    #[test]
    fn demote_then_promote_is_bit_exact_and_counted() {
        let dir = tier_dir("roundtrip");
        let pool = PagePool::new(usize::MAX);
        pool.attach_tier(TierConfig::new(dir.clone(), u64::MAX, 1)).unwrap();
        let toks: Vec<u32> = (0..8).collect();
        let originals: Vec<Vec<u8>> = (0..2)
            .map(|i| crate::kvcache::tier::serde::encode_page(&page(60 + i)))
            .collect();
        let pages: Vec<_> = (0..2).map(|i| pool.adopt(page(60 + i))).collect();
        pool.register_prefix(&pages, &toks);
        drop(pages);
        assert_eq!(pool.demote_all(), 2);
        assert_eq!(pool.tiered_pages(), 2);
        assert_eq!(pool.pages_in_use(), 0, "demoted pages hold no RAM");
        assert!(pool.bytes_on_disk() > 0);
        // promotion: the lookup faults both pages back in, bit-exact
        let hit = pool.lookup_prefix(&toks, 4, usize::MAX);
        assert_eq!(hit.len(), 2);
        for (p, want) in hit.iter().zip(&originals) {
            assert_eq!(&crate::kvcache::tier::serde::encode_page(p), want);
        }
        assert_eq!(pool.tier_hits(), 1);
        assert_eq!(pool.pages_promoted(), 2);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.tiered_pages(), 0);
        // a second lookup is a plain resident hit — no new promotion
        let again = pool.lookup_prefix(&toks, 4, usize::MAX);
        assert_eq!(again.len(), 2);
        assert_eq!(pool.pages_promoted(), 2);
        drop(hit);
        drop(again);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupted_segment_record_is_a_miss_not_a_panic() {
        let dir = tier_dir("corrupt");
        let pool = PagePool::new(usize::MAX);
        pool.attach_tier(TierConfig::new(dir.clone(), u64::MAX, 1)).unwrap();
        let toks: Vec<u32> = (0..8).collect();
        let pages: Vec<_> = (0..2).map(|i| pool.adopt(page(70 + i))).collect();
        pool.register_prefix(&pages, &toks);
        drop(pages);
        assert_eq!(pool.demote_all(), 2);
        // scribble over every segment file: all records invalid
        for entry in std::fs::read_dir(&dir).unwrap() {
            let p = entry.unwrap().path();
            if p.extension().is_some_and(|e| e == "bin") {
                let len = std::fs::metadata(&p).unwrap().len() as usize;
                std::fs::write(&p, vec![0xAAu8; len]).unwrap();
            }
        }
        let hit = pool.lookup_prefix(&toks, 4, usize::MAX);
        assert!(hit.is_empty(), "corrupt records must miss, got {} pages", hit.len());
        assert!(pool.indexed_pages() < 2, "corrupt entry dropped from the index");
        assert_eq!(pool.pages_promoted(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bounded_pool_demotes_through_the_background_writer() {
        let dir = tier_dir("writer");
        let pool = PagePool::new(3);
        pool.attach_tier(TierConfig::new(dir.clone(), u64::MAX, 1)).unwrap();
        let toks: Vec<u32> = (0..8).collect();
        let p0 = pool.adopt(page(80));
        let p1 = pool.adopt(page(81));
        pool.register_prefix(&[p0.clone(), p1.clone()], &toks);
        drop(p0);
        drop(p1);
        let _held = pool.adopt(page(82));
        assert_eq!(pool.free_pages(), 0);
        // reclaim demotes the LRU entry instead of dropping it: capacity
        // frees immediately (inflight discount), the entry survives
        assert!(pool.try_free(1));
        assert_eq!(pool.indexed_pages(), 2, "demotion keeps the prefix entry");
        assert_eq!(pool.pages_evicted(), 0, "demotion is not eviction");
        wait_until("background demotion write", || pool.pages_demoted() == 1);
        wait_until("page RAM released", || pool.pages_in_use() == 2);
        assert_eq!(pool.tiered_pages(), 1);
        // the chain still resolves end-to-end: head promotes from disk,
        // tail was never demoted
        let hit = pool.lookup_prefix(&toks, 4, usize::MAX);
        assert_eq!(hit.len(), 2);
        assert_eq!(pool.pages_promoted(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bounded_pool_promotion_stops_at_capacity_instead_of_overshooting() {
        let dir = tier_dir("promote-cap");
        let pool = PagePool::new(2);
        pool.attach_tier(TierConfig::new(dir.clone(), u64::MAX, 1)).unwrap();
        let toks: Vec<u32> = (0..8).collect();
        let pages: Vec<_> = (0..2).map(|i| pool.adopt(page(95 + i))).collect();
        pool.register_prefix(&pages, &toks);
        drop(pages);
        assert_eq!(pool.demote_all(), 2);
        assert_eq!(pool.pages_in_use(), 0);
        // an unrelated resident page leaves room for exactly ONE promotion
        let _held = pool.adopt(page(97));
        let hit = pool.lookup_prefix(&toks, 4, usize::MAX);
        assert_eq!(hit.len(), 1, "chain must stop when the pool is full");
        assert_eq!(pool.pages_in_use(), 2, "promotion never overshoots the cap");
        assert_eq!(pool.pages_promoted(), 1);
        assert_eq!(pool.tiered_pages(), 1, "the tail stays on disk");
        // with room back, the full chain resolves
        drop(hit);
        drop(_held);
        let hit = pool.lookup_prefix(&toks, 4, usize::MAX);
        assert_eq!(hit.len(), 2);
        assert!(pool.pages_in_use() <= 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn session_blobs_roundtrip_through_the_tier() {
        let dir = tier_dir("session-blob");
        let pool = PagePool::new(usize::MAX);
        assert!(pool.session_spill(b"x", "default", 0).is_err(), "spill without a tier must fail");
        pool.attach_tier(TierConfig::new(dir.clone(), u64::MAX, 1)).unwrap();
        let blob: Vec<u8> = (0..777u32).map(|i| (i % 251) as u8).collect();
        let r = pool.session_spill(&blob, "default", 0).unwrap();
        assert!(pool.bytes_on_disk() >= blob.len() as u64);
        assert_eq!(pool.session_bytes(), blob.len() as u64);
        assert_eq!(pool.session_fetch(r, "default").unwrap(), blob);
        assert_eq!(pool.session_bytes(), 0, "a fetched session leaves the gauge");
        // blobs and demoted pages share segments without interference
        let toks: Vec<u32> = (0..4).collect();
        let p = pool.adopt(page(33));
        pool.register_prefix(std::slice::from_ref(&p), &toks);
        drop(p);
        assert_eq!(pool.demote_all(), 1);
        assert_eq!(pool.lookup_prefix(&toks, 4, usize::MAX).len(), 1);
        assert_eq!(pool.session_fetch(r, "default").unwrap(), blob);
        assert_eq!(pool.session_bytes(), 0, "gauge saturates instead of wrapping");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn session_spill_refuses_when_tier_budget_is_exhausted() {
        let dir = tier_dir("session-budget");
        let pool = PagePool::new(usize::MAX);
        // budget of 1 byte: the first spill squeaks under (checked before
        // the write, like demotion), the second finds the budget spent
        pool.attach_tier(TierConfig::new(dir.clone(), 1, 1)).unwrap();
        let blob = vec![7u8; 64];
        let r = pool.session_spill(&blob, "default", 0).unwrap();
        let err = pool.session_spill(&blob, "default", 0).unwrap_err();
        assert!(err.to_string().contains("budget"), "unexpected error: {err:#}");
        // the refusal leaves the stored blob and the gauge untouched
        assert_eq!(pool.session_bytes(), blob.len() as u64);
        assert_eq!(pool.session_fetch(r, "default").unwrap(), blob);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn session_spill_enforces_per_tenant_quota() {
        let dir = tier_dir("session-tenant-quota");
        let pool = PagePool::new(usize::MAX);
        pool.attach_tier(TierConfig::new(dir.clone(), u64::MAX, 1)).unwrap();
        let blob = vec![3u8; 100];
        let cap = 150u64; // room for one blob per tenant, not two
        let r = pool.session_spill(&blob, "acme", cap).unwrap();
        assert_eq!(pool.tenant_session_bytes("acme"), 100);
        let err = pool.session_spill(&blob, "acme", cap).unwrap_err();
        assert!(err.to_string().contains("tenant 'acme'"), "unexpected error: {err:#}");
        assert_eq!(pool.tenant_session_bytes("acme"), 100, "refusal leaves the ledger alone");
        // the refusal is per-tenant: another tenant still fits under the
        // shared disk budget
        pool.session_spill(&blob, "globex", cap).unwrap();
        assert_eq!(pool.tenant_session_bytes("globex"), 100);
        // fetching releases the quota and the tenant can spill again
        assert_eq!(pool.session_fetch(r, "acme").unwrap(), blob);
        assert_eq!(pool.tenant_session_bytes("acme"), 0);
        pool.session_spill(&blob, "acme", cap).unwrap();
        // cap 0 disables the per-tenant check entirely
        pool.session_spill(&blob, "acme", 0).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fabric_shared_dir_serves_cross_pool_fetches() {
        use crate::fabric::DirFabric;
        let dir = tier_dir("fabric-share");
        let tag = 0x00C0_FFEE;
        let toks: Vec<u32> = (0..8).collect();

        // node A: register a two-page chain; each new link publishes
        let a = PagePool::new(usize::MAX);
        a.set_fabric(Some(Arc::new(DirFabric::new(&dir, tag).unwrap())), tag);
        let pages = [a.adopt(page(60)), a.adopt(page(61))];
        a.register_prefix(&pages, &toks);
        assert_eq!(a.fabric_published(), 2, "both chain links publish");
        let originals: Vec<Vec<u8>> = pages
            .iter()
            .map(|p| crate::kvcache::tier::serde::encode_page(p))
            .collect();

        // node B: cold pool, same directory + fingerprint — the lookup
        // walks the whole chain out of the fabric
        let b = PagePool::new(usize::MAX);
        b.set_fabric(Some(Arc::new(DirFabric::new(&dir, tag).unwrap())), tag);
        let hit = b.lookup_prefix(&toks, 4, usize::MAX);
        assert_eq!(hit.len(), 2, "full chain fetched cross-node");
        for (got, want) in hit.iter().zip(&originals) {
            assert_eq!(&crate::kvcache::tier::serde::encode_page(got), want, "bit-exact page");
        }
        assert_eq!(b.fabric_prefix_hits(), 1);
        assert_eq!(b.fabric_pages_fetched(), 2);
        assert_eq!(b.fabric_rejected(), 0);
        assert!(b.fabric_bytes_fetched() > 0);
        // the fetched links are now local: a second lookup is fabric-free
        drop(hit);
        let again = b.lookup_prefix(&toks, 4, usize::MAX);
        assert_eq!(again.len(), 2);
        assert_eq!(b.fabric_pages_fetched(), 2, "second lookup hits locally");

        // a mismatched fingerprint never sees the records
        let c = PagePool::new(usize::MAX);
        c.set_fabric(Some(Arc::new(DirFabric::new(&dir, tag + 1).unwrap())), tag + 1);
        assert!(c.lookup_prefix(&toks, 4, usize::MAX).is_empty());
        assert_eq!(c.fabric_prefix_hits(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupted_fabric_record_is_a_clean_miss() {
        use crate::fabric::DirFabric;
        let dir = tier_dir("fabric-corrupt");
        let tag = 7u64;
        let toks: Vec<u32> = (0..4).collect();
        let a = PagePool::new(usize::MAX);
        a.set_fabric(Some(Arc::new(DirFabric::new(&dir, tag).unwrap())), tag);
        let p = a.adopt(page(70));
        a.register_prefix(std::slice::from_ref(&p), &toks);
        assert_eq!(a.fabric_published(), 1);

        // scribble over every published record
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            if path.extension().is_some_and(|e| e == "page") {
                let mut bytes = std::fs::read(&path).unwrap();
                for b in bytes.iter_mut() {
                    *b ^= 0xAA;
                }
                std::fs::write(&path, &bytes).unwrap();
            }
        }

        let b = PagePool::new(usize::MAX);
        b.set_fabric(Some(Arc::new(DirFabric::new(&dir, tag).unwrap())), tag);
        assert!(b.lookup_prefix(&toks, 4, usize::MAX).is_empty(), "corrupt record = miss");
        assert_eq!(b.fabric_rejected(), 1);
        assert_eq!(b.fabric_prefix_hits(), 0);
        assert_eq!(b.pages_in_use(), 0, "nothing half-admitted");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn snapshot_restores_the_prefix_index_into_a_fresh_pool() {
        let dir = tier_dir("snapshot");
        let toks: Vec<u32> = (0..12).collect();
        let originals: Vec<Vec<u8>> = (0..3)
            .map(|i| crate::kvcache::tier::serde::encode_page(&page(90 + i)))
            .collect();
        {
            let pool = PagePool::new(usize::MAX);
            pool.attach_tier(TierConfig::new(dir.clone(), u64::MAX, 42)).unwrap();
            let pages: Vec<_> = (0..3).map(|i| pool.adopt(page(90 + i))).collect();
            pool.register_prefix(&pages, &toks);
            drop(pages);
            let (entries, bytes) = pool.snapshot().unwrap();
            assert_eq!(entries, 3);
            assert!(bytes > 0);
        }
        // a different config tag must refuse the snapshot
        let other = PagePool::new(usize::MAX);
        assert_eq!(other.attach_tier(TierConfig::new(dir.clone(), u64::MAX, 7)).unwrap(), 0);
        // same tag: warm start with every entry tiered, pages fault in
        let pool = PagePool::new(usize::MAX);
        let restored = pool.attach_tier(TierConfig::new(dir.clone(), u64::MAX, 42)).unwrap();
        assert_eq!(restored, 3);
        assert_eq!(pool.tiered_pages(), 3);
        assert_eq!(pool.pages_in_use(), 0);
        let hit = pool.lookup_prefix(&toks, 4, usize::MAX);
        assert_eq!(hit.len(), 3);
        for (p, want) in hit.iter().zip(&originals) {
            assert_eq!(&crate::kvcache::tier::serde::encode_page(p), want);
        }
        assert_eq!(pool.tier_hits(), 1);
        assert_eq!(pool.pages_promoted(), 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
