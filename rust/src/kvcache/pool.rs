//! Refcounted group-page pool: the shared physical store behind every
//! sequence cache.
//!
//! A [`Page`] is the allocation unit of the paged KV cache: ONE finalized
//! (quantized) key group plus its values for EVERY (layer, kv-head)
//! stream of a sequence — i.e. a horizontal slice of `spec.group` tokens
//! across the whole model.  Sequences hold `Arc<Page>` handles, so
//!
//! * **sharing is a refcount bump** — N sequences whose prompts share a
//!   prefix attach to the same physical pages (prefix caching), and
//!   [`crate::kvcache::SequenceCache::fork`] is copy-on-write by
//!   construction: finalized pages are shared, only the fp residual tail
//!   is deep-copied;
//! * **accounting is exact and O(1)** — pages carry a handle to the
//!   pool's atomic counters and reconcile on `Drop`, so
//!   `CacheManager::admits` never walks live sequences;
//! * **eviction is precise** — the prefix index holds its own `Arc`, so a
//!   cached page with `strong_count == 1` is provably referenced by no
//!   sequence and can be reclaimed LRU when the pool is exhausted.
//!
//! Sharing quantized pages across sequences is EXACT, not approximate: a
//! finalized `PolarGroup` is a deterministic function of the post-RoPE
//! keys at fixed absolute positions, which (under eager chunked prefill)
//! are themselves a deterministic function of the token prefix.  The
//! prefix index therefore keys pages by a verified hash-chain over the
//! token prefix — equal chain means equal pages, bit for bit.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::stream::GroupValues;
use crate::quant::polar::PolarGroup;

/// Pool-wide accounting, shared by every page and sequence the pool has
/// adopted.  All counters are atomics so the decode workers' appends and
/// the engine thread's admission checks never contend on a lock.
#[derive(Debug, Default)]
pub struct PoolCounters {
    /// physical pages resident (each shared page counted ONCE)
    pub pages: AtomicUsize,
    /// physical bytes of those pages
    pub page_bytes: AtomicUsize,
    /// fp residual-tail bytes across live sequences (fp16-charged)
    pub resid_bytes: AtomicUsize,
    /// logical tokens across live sequences (shared pages counted per
    /// sequence — the "what you'd pay without sharing" token count)
    pub seq_tokens: AtomicUsize,
    /// refcount-zero prefix pages reclaimed under pressure
    pub pages_evicted: AtomicU64,
}

/// One finalized group across all streams: `keys[s]` / `vals[s]` belong
/// to stream `s` (= layer * n_kv_heads + head).  Immutable once built —
/// that is what makes sharing across sequences sound.
#[derive(Debug)]
pub struct Page {
    pub keys: Vec<PolarGroup>,
    pub vals: Vec<GroupValues>,
    /// tokens this page covers (== spec.group; pages are only cut from
    /// full groups)
    pub tokens: usize,
    nbytes: usize,
    /// accounting handle; `None` for pages of an un-pooled sequence
    counters: Option<Arc<PoolCounters>>,
}

impl Page {
    pub fn new(keys: Vec<PolarGroup>, vals: Vec<GroupValues>, tokens: usize) -> Self {
        debug_assert_eq!(keys.len(), vals.len());
        let nbytes = keys.iter().map(|g| g.nbytes()).sum::<usize>()
            + vals.iter().map(|v| v.nbytes(true)).sum::<usize>();
        Page { keys, vals, tokens, nbytes, counters: None }
    }

    /// Physical bytes at rest (same accounting as the pre-paged cache:
    /// codes packed, params fp32, values fp16-charged).
    pub fn nbytes(&self) -> usize {
        self.nbytes
    }
}

impl Drop for Page {
    fn drop(&mut self) {
        if let Some(c) = &self.counters {
            c.pages.fetch_sub(1, Ordering::Relaxed);
            c.page_bytes.fetch_sub(self.nbytes, Ordering::Relaxed);
        }
    }
}

/// One prefix-index entry: the page for the group whose token chain
/// hashes to the map key, plus enough material to VERIFY the chain (so a
/// hash collision can only cause a miss, never a wrong share).
struct PrefixEntry {
    /// chain hash of the parent group (`ROOT_HASH` for the first group)
    parent: u64,
    /// the exact tokens this group covers
    toks: Vec<u32>,
    page: Arc<Page>,
    /// LRU clock value of the last hit/registration
    tick: u64,
}

const ROOT_HASH: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis

fn chain_hash(parent: u64, toks: &[u32]) -> u64 {
    // FNV-1a over the parent hash then the group's token ids: cheap,
    // deterministic, and collisions are harmless (entries are verified)
    let mut h = 0x1000_0000_01b3u64 ^ parent;
    for &t in toks {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

struct PrefixIndex {
    entries: HashMap<u64, PrefixEntry>,
    clock: u64,
}

/// Hard ceiling on prefix-index entries when the pool itself is
/// unbounded.  Without it, a long-running server with `--prefix-cache on`
/// and no `--cache-pages` cap would pin every distinct prompt's pages
/// forever (nothing else evicts index entries) and leak without bound
/// under diverse traffic.  Bounded pools use their page capacity instead —
/// the index can never outgrow what is resident.
const UNBOUNDED_PREFIX_CAP: usize = 32_768;

/// Cloneable handle to the shared page pool: capacity bookkeeping plus
/// the prefix index.  Page *data* is never behind this lock — readers go
/// straight through their `Arc<Page>` handles; the mutex only guards the
/// index (touched at prefill/registration rate, not decode rate).
#[derive(Clone)]
pub struct PagePool {
    index: Arc<Mutex<PrefixIndex>>,
    counters: Arc<PoolCounters>,
    /// physical page capacity; `usize::MAX` = unbounded
    capacity: usize,
}

impl std::fmt::Debug for PagePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagePool")
            .field("capacity", &self.capacity)
            .field("pages_in_use", &self.pages_in_use())
            .field("page_bytes", &self.page_bytes())
            .finish()
    }
}

impl PagePool {
    /// `capacity` bounds physical resident pages (`usize::MAX` for
    /// unbounded — the accounting still runs).
    pub fn new(capacity: usize) -> Self {
        PagePool {
            index: Arc::new(Mutex::new(PrefixIndex { entries: HashMap::new(), clock: 0 })),
            counters: Arc::new(PoolCounters::default()),
            capacity,
        }
    }

    pub fn counters(&self) -> &Arc<PoolCounters> {
        &self.counters
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn bounded(&self) -> bool {
        self.capacity != usize::MAX
    }

    pub fn pages_in_use(&self) -> usize {
        self.counters.pages.load(Ordering::Relaxed)
    }

    pub fn page_bytes(&self) -> usize {
        self.counters.page_bytes.load(Ordering::Relaxed)
    }

    pub fn pages_evicted(&self) -> u64 {
        self.counters.pages_evicted.load(Ordering::Relaxed)
    }

    /// Pages allocatable right now without reclaiming anything.
    pub fn free_pages(&self) -> usize {
        self.capacity.saturating_sub(self.pages_in_use())
    }

    /// Take ownership of a freshly finalized page: attach the accounting
    /// handle and hand back the shared form.  Never fails — capacity is
    /// enforced ahead of time by the scheduler via [`PagePool::try_free`]
    /// (a transient one-page overshoot beats a fallible deep-in-the-model
    /// allocation path).
    pub fn adopt(&self, mut page: Page) -> Arc<Page> {
        debug_assert!(page.counters.is_none());
        self.counters.pages.fetch_add(1, Ordering::Relaxed);
        self.counters.page_bytes.fetch_add(page.nbytes, Ordering::Relaxed);
        page.counters = Some(self.counters.clone());
        Arc::new(page)
    }

    /// Ensure `need` pages can be allocated, reclaiming LRU refcount-zero
    /// prefix pages if necessary.  Returns false if the shortfall remains
    /// (every resident page is still referenced by some sequence) — the
    /// engine then preempts a decoding sequence instead of stalling.
    pub fn try_free(&self, need: usize) -> bool {
        if need <= self.free_pages() {
            return true;
        }
        let mut idx = self.index.lock().unwrap();
        while self.free_pages() < need {
            // LRU entry whose page no sequence holds (the index owns the
            // only Arc)
            let victim = idx
                .entries
                .iter()
                .filter(|(_, e)| Arc::strong_count(&e.page) == 1)
                .min_by_key(|(_, e)| e.tick)
                .map(|(&h, _)| h);
            match victim {
                Some(h) => {
                    idx.entries.remove(&h);
                    self.counters.pages_evicted.fetch_add(1, Ordering::Relaxed);
                }
                None => return false,
            }
        }
        true
    }

    /// Longest already-pooled prefix of `tokens`, as verified chain pages
    /// (each covering `group` tokens), capped at `max_tokens`.  Hits
    /// refresh the LRU clock.
    pub fn lookup_prefix(&self, tokens: &[u32], group: usize, max_tokens: usize) -> Vec<Arc<Page>> {
        let mut idx = self.index.lock().unwrap();
        idx.clock += 1;
        let tick = idx.clock;
        let mut pages = Vec::new();
        let mut parent = ROOT_HASH;
        let mut pos = 0;
        while pos + group <= tokens.len().min(max_tokens) {
            let toks = &tokens[pos..pos + group];
            let h = chain_hash(parent, toks);
            match idx.entries.get_mut(&h) {
                // verify BOTH the tokens and the chain parent: equal hash
                // alone is not proof of an equal prefix
                Some(e) if e.parent == parent && e.toks == toks => {
                    e.tick = tick;
                    pages.push(e.page.clone());
                }
                _ => break,
            }
            parent = h;
            pos += group;
        }
        pages
    }

    /// Register a sequence's finalized pages under the token prefix that
    /// produced them.  Only pages covering tokens entirely inside
    /// `tokens` are registered (a page straddling the prompt/generation
    /// boundary is request-private).  Idempotent: existing entries are
    /// left untouched, so repeated registration as chunks land is cheap.
    pub fn register_prefix(&self, pages: &[Arc<Page>], tokens: &[u32]) {
        let cap = self.capacity.min(UNBOUNDED_PREFIX_CAP);
        let mut idx = self.index.lock().unwrap();
        idx.clock += 1;
        let tick = idx.clock;
        let mut parent = ROOT_HASH;
        let mut pos = 0;
        for page in pages {
            if pos + page.tokens > tokens.len() {
                break;
            }
            let toks = &tokens[pos..pos + page.tokens];
            let h = chain_hash(parent, toks);
            if !idx.entries.contains_key(&h) {
                // bound the index: past the cap, a new entry must displace
                // the LRU refcount-zero one, or it simply isn't cached
                if idx.entries.len() >= cap {
                    let lru = idx
                        .entries
                        .iter()
                        .filter(|(_, e)| Arc::strong_count(&e.page) == 1)
                        .min_by_key(|(_, e)| e.tick)
                        .map(|(&k, _)| k);
                    match lru {
                        Some(k) => {
                            idx.entries.remove(&k);
                            self.counters.pages_evicted.fetch_add(1, Ordering::Relaxed);
                        }
                        None => break,
                    }
                }
                idx.entries.insert(
                    h,
                    PrefixEntry { parent, toks: toks.to_vec(), page: page.clone(), tick },
                );
            }
            parent = h;
            pos += page.tokens;
        }
    }

    /// Prefix-index entries currently held (tests/observability).
    pub fn indexed_pages(&self) -> usize {
        self.index.lock().unwrap().entries.len()
    }

    /// Drop every cached prefix entry regardless of recency (tests).
    pub fn clear_prefix_index(&self) {
        let mut idx = self.index.lock().unwrap();
        let n = idx
            .entries
            .iter()
            .filter(|(_, e)| Arc::strong_count(&e.page) == 1)
            .count() as u64;
        idx.entries.retain(|_, e| Arc::strong_count(&e.page) > 1);
        self.counters.pages_evicted.fetch_add(n, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::polar::{self, PolarSpec};
    use crate::util::rng::Rng;

    fn page(seed: u64) -> Page {
        let spec = PolarSpec::new(4, 4, 4);
        let d = 8;
        let mut rng = Rng::new(seed);
        let streams = 2;
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..streams {
            let k = rng.normal_vec(spec.group * d);
            keys.push(polar::encode_group(&k, d, &spec));
            vals.push(GroupValues::Fp(rng.normal_vec(spec.group * d)));
        }
        Page::new(keys, vals, spec.group)
    }

    #[test]
    fn adopt_and_drop_reconcile_counters() {
        let pool = PagePool::new(8);
        let p1 = pool.adopt(page(1));
        let p2 = pool.adopt(page(2));
        assert_eq!(pool.pages_in_use(), 2);
        assert!(pool.page_bytes() > 0);
        assert_eq!(pool.free_pages(), 6);
        let clone = p1.clone(); // refcount bump, no physical change
        assert_eq!(pool.pages_in_use(), 2);
        drop(p1);
        drop(clone);
        drop(p2);
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.page_bytes(), 0);
    }

    #[test]
    fn prefix_chain_roundtrip_and_partial_hit() {
        let pool = PagePool::new(usize::MAX);
        let g = 4;
        let toks: Vec<u32> = (0..12).collect();
        let pages: Vec<_> = (0..3).map(|i| pool.adopt(page(10 + i))).collect();
        pool.register_prefix(&pages, &toks);
        assert_eq!(pool.indexed_pages(), 3);
        // full match
        let hit = pool.lookup_prefix(&toks, g, usize::MAX);
        assert_eq!(hit.len(), 3);
        assert!(Arc::ptr_eq(&hit[0], &pages[0]));
        // longest-prefix: diverge in the second group
        let mut other = toks.clone();
        other[5] = 99;
        let hit = pool.lookup_prefix(&other, g, usize::MAX);
        assert_eq!(hit.len(), 1, "only the first group matches");
        // cap respected
        let hit = pool.lookup_prefix(&toks, g, 8);
        assert_eq!(hit.len(), 2);
        // shorter-than-group prompt: no hit
        assert!(pool.lookup_prefix(&toks[..3], g, usize::MAX).is_empty());
    }

    #[test]
    fn chain_keying_distinguishes_same_group_different_prefix() {
        // the SAME tokens at group 2 must not be shared across different
        // first groups — the chain hash keys on the whole prefix
        let pool = PagePool::new(usize::MAX);
        let g = 4;
        let a: Vec<u32> = vec![1, 2, 3, 4, 9, 9, 9, 9];
        let b: Vec<u32> = vec![5, 6, 7, 8, 9, 9, 9, 9];
        let pa: Vec<_> = (0..2).map(|i| pool.adopt(page(20 + i))).collect();
        pool.register_prefix(&pa, &a);
        let hit = pool.lookup_prefix(&b, g, usize::MAX);
        assert!(hit.is_empty(), "chain with different first group must miss");
    }

    #[test]
    fn try_free_reclaims_lru_unreferenced_only() {
        let pool = PagePool::new(3);
        let toks: Vec<u32> = (0..8).collect();
        let p0 = pool.adopt(page(30));
        let p1 = pool.adopt(page(31));
        pool.register_prefix(&[p0.clone(), p1.clone()], &toks);
        // a third page held by a "sequence"
        let held = pool.adopt(page(32));
        assert_eq!(pool.free_pages(), 0);
        // p0/p1 still referenced here -> nothing reclaimable
        assert!(!pool.try_free(1));
        // release the sequence refs; index entries become refcount-zero
        drop(p0);
        drop(p1);
        assert!(pool.try_free(1), "LRU prefix page must be reclaimed");
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.pages_evicted(), 1);
        // the reclaimed entry was the LRU one (registered first => oldest
        // tick); the survivor still verifies for the 2-group chain's head
        assert_eq!(pool.indexed_pages(), 1);
        drop(held);
        assert!(pool.try_free(3));
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn bounded_pool_caps_the_prefix_index_by_displacing_lru() {
        // capacity 2: registering a third (unreferenced) chain entry must
        // displace the LRU one instead of growing the index
        let pool = PagePool::new(2);
        let toks_a: Vec<u32> = (0..4).collect();
        let toks_b: Vec<u32> = (100..104).collect();
        let toks_c: Vec<u32> = (200..204).collect();
        let pa = pool.adopt(page(50));
        pool.register_prefix(std::slice::from_ref(&pa), &toks_a);
        drop(pa);
        let pb = pool.adopt(page(51));
        pool.register_prefix(std::slice::from_ref(&pb), &toks_b);
        drop(pb);
        assert_eq!(pool.indexed_pages(), 2);
        let pc = pool.adopt(page(52));
        pool.register_prefix(std::slice::from_ref(&pc), &toks_c);
        drop(pc);
        assert_eq!(pool.indexed_pages(), 2, "index stays at cap");
        assert_eq!(pool.pages_evicted(), 1);
        // the oldest chain (a) was displaced; b and c survive
        assert!(pool.lookup_prefix(&toks_a, 4, usize::MAX).is_empty());
        assert_eq!(pool.lookup_prefix(&toks_b, 4, usize::MAX).len(), 1);
        assert_eq!(pool.lookup_prefix(&toks_c, 4, usize::MAX).len(), 1);
    }

    #[test]
    fn register_skips_pages_past_the_token_limit() {
        let pool = PagePool::new(usize::MAX);
        let pages: Vec<_> = (0..3).map(|i| pool.adopt(page(40 + i))).collect();
        // only 9 tokens: the third page (tokens 8..12) straddles the end
        let toks: Vec<u32> = (0..9).collect();
        pool.register_prefix(&pages, &toks);
        assert_eq!(pool.indexed_pages(), 2);
    }
}
