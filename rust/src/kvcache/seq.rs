//! Per-sequence cache across all (layer, kv-head) streams, plus the dense
//! export that marshals it into the fixed-shape decode graphs.

use super::stream::StreamCache;
use crate::quant::polar::PolarSpec;

/// Cache geometry + codec config (derived from the artifact manifest).
#[derive(Clone, Debug)]
pub struct CacheConfig {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub spec: PolarSpec,
    /// None = fp values (the paper's default eval setting)
    pub value_bits: Option<u32>,
}

impl CacheConfig {
    pub fn streams(&self) -> usize {
        self.n_layers * self.n_kv_heads
    }
}

/// All streams of one sequence.  Every stream holds the same token count —
/// the state machine appends to all of them per decode step.
#[derive(Clone, Debug)]
pub struct SequenceCache {
    pub cfg: CacheConfig,
    pub streams: Vec<StreamCache>,
    /// absolute position of the next token (== tokens appended so far)
    pub next_pos: usize,
}

impl SequenceCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let streams = (0..cfg.streams())
            .map(|_| StreamCache::new(cfg.head_dim, cfg.spec, cfg.value_bits))
            .collect();
        SequenceCache { cfg, streams, next_pos: 0 }
    }

    #[inline]
    pub fn stream(&self, layer: usize, head: usize) -> &StreamCache {
        &self.streams[layer * self.cfg.n_kv_heads + head]
    }

    #[inline]
    pub fn stream_mut(&mut self, layer: usize, head: usize) -> &mut StreamCache {
        &mut self.streams[layer * self.cfg.n_kv_heads + head]
    }

    pub fn len(&self) -> usize {
        self.streams[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn quantized_len(&self) -> usize {
        self.streams[0].quantized_len()
    }

    pub fn resid_len(&self) -> usize {
        self.streams[0].resid_len()
    }

    /// Append one decode step's K/V: `k`/`v` are (L, Kv, d) row-major —
    /// exactly the `new_k`/`new_v` output layout of the decode graph.
    pub fn append_step(&mut self, k: &[f32], v: &[f32]) {
        let (l, h, d) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        assert_eq!(k.len(), l * h * d);
        assert_eq!(v.len(), k.len());
        for layer in 0..l {
            for head in 0..h {
                let off = (layer * h + head) * d;
                self.stream_mut(layer, head)
                    .append(&k[off..off + d], &v[off..off + d]);
            }
        }
        self.next_pos += 1;
    }

    /// Append a prefill block: `k`/`v` are (L, Kv, T, d) row-major —
    /// the prefill graph's cache output layout.
    pub fn append_prefill(&mut self, k: &[f32], v: &[f32], tokens: usize) {
        let (l, h, d) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        assert_eq!(k.len(), l * h * tokens * d);
        for layer in 0..l {
            for head in 0..h {
                let off = (layer * h + head) * tokens * d;
                self.stream_mut(layer, head)
                    .append_block(&k[off..off + tokens * d], &v[off..off + tokens * d]);
            }
        }
        self.next_pos += tokens;
    }

    /// Append a prefill chunk WITHOUT finalizing groups: layout as
    /// [`SequenceCache::append_prefill`], but every token lands in the fp
    /// residual tail.  Chunked prefill uses this so later chunks attend
    /// over exact fp keys; call [`SequenceCache::flush_groups`] once the
    /// whole prompt is in to quantize full groups in append order (the
    /// same groups eager appends would have produced).
    ///
    /// Residency note: until the flush, the whole prompt sits in the
    /// cache at fp width — the same transient peak the unchunked path
    /// reaches through its full-prompt `k_all`/`v_all` staging buffers,
    /// but now visible to [`SequenceCache::nbytes`], so concurrent
    /// admission checks see it (and get MORE conservative, not less).
    /// For prompts where that fp window matters, eager finalization
    /// (`EngineOpts::prefill_quantize_eagerly`) caps it at one chunk.
    pub fn append_prefill_deferred(&mut self, k: &[f32], v: &[f32], tokens: usize) {
        let (l, h, d) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        assert_eq!(k.len(), l * h * tokens * d);
        for layer in 0..l {
            for head in 0..h {
                let off = (layer * h + head) * tokens * d;
                self.stream_mut(layer, head)
                    .append_block_deferred(&k[off..off + tokens * d], &v[off..off + tokens * d]);
            }
        }
        self.next_pos += tokens;
    }

    /// Finalize every full group across all streams (end of a deferred
    /// chunked prefill).
    pub fn flush_groups(&mut self) {
        for st in &mut self.streams {
            st.flush_groups();
        }
    }

    /// Physical bytes at rest across streams.
    pub fn nbytes(&self) -> usize {
        self.streams.iter().map(|s| s.nbytes()).sum()
    }
}

/// Dense, padded export of a sequence cache for the fixed-shape decode
/// graph: codes unpacked to i32, params broadcast to the (G, d/2) grid,
/// values dequantized — the marshalling boundary between the coordinator
/// and the PJRT runtime.
#[derive(Clone, Debug, Default)]
pub struct DenseCache {
    /// (L, Kv, S, d/2) i32 each
    pub theta_code: Vec<i32>,
    pub rho_code: Vec<i32>,
    /// (L, Kv, S/g, d/2) f32 each
    pub rho_z: Vec<f32>,
    pub rho_s: Vec<f32>,
    pub theta_z: Vec<f32>,
    pub theta_s: Vec<f32>,
    /// (L, Kv, S, d)
    pub v: Vec<f32>,
    /// (L, Kv, R, d)
    pub resid_k: Vec<f32>,
    pub resid_v: Vec<f32>,
    pub cache_len: usize,
    pub resid_len: usize,
}

impl SequenceCache {
    /// Export into the decode bucket (capacity S quantized tokens,
    /// residual capacity R).  Panics if the sequence exceeds the bucket —
    /// bucket selection is the batcher's job.
    pub fn export_dense(&self, s_cap: usize, r_cap: usize) -> DenseCache {
        let (l, h, d) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        let d2 = d / 2;
        let g = self.cfg.spec.group;
        assert_eq!(s_cap % g, 0);
        let gcap = s_cap / g;
        let qlen = self.quantized_len();
        let rlen = self.resid_len();
        assert!(qlen <= s_cap, "sequence ({qlen}) exceeds bucket ({s_cap})");
        assert!(rlen <= r_cap);

        let mut out = DenseCache {
            theta_code: vec![0; l * h * s_cap * d2],
            rho_code: vec![0; l * h * s_cap * d2],
            rho_z: vec![0.0; l * h * gcap * d2],
            rho_s: vec![1e-8; l * h * gcap * d2],
            theta_z: vec![0.0; l * h * gcap * d2],
            theta_s: vec![1e-8; l * h * gcap * d2],
            v: vec![0.0; l * h * s_cap * d],
            resid_k: vec![0.0; l * h * r_cap * d],
            resid_v: vec![0.0; l * h * r_cap * d],
            cache_len: qlen,
            resid_len: rlen,
        };

        let mut vals_scratch = Vec::new();
        let mut codes_scratch = vec![0u8; g * d2];
        for layer in 0..l {
            for head in 0..h {
                let st = self.stream(layer, head);
                let base = layer * h + head;
                for (gi, grp) in st.key_groups.iter().enumerate() {
                    // codes
                    grp.theta_codes.unpack_into(&mut codes_scratch);
                    for n in 0..grp.tokens {
                        for j in 0..d2 {
                            out.theta_code[((base * s_cap) + gi * g + n) * d2 + j] =
                                codes_scratch[n * d2 + j] as i32;
                        }
                    }
                    grp.rho_codes.unpack_into(&mut codes_scratch);
                    for n in 0..grp.tokens {
                        for j in 0..d2 {
                            out.rho_code[((base * s_cap) + gi * g + n) * d2 + j] =
                                codes_scratch[n * d2 + j] as i32;
                        }
                    }
                    // params
                    let poff = (base * gcap + gi) * d2;
                    out.rho_z[poff..poff + d2].copy_from_slice(&grp.rho_z);
                    out.rho_s[poff..poff + d2].copy_from_slice(&grp.rho_s);
                    out.theta_z[poff..poff + d2].copy_from_slice(&grp.theta_z);
                    out.theta_s[poff..poff + d2].copy_from_slice(&grp.theta_s);
                    // values
                    vals_scratch.clear();
                    st.decode_values_into(gi, &mut vals_scratch);
                    let voff = (base * s_cap + gi * g) * d;
                    out.v[voff..voff + g * d].copy_from_slice(&vals_scratch);
                }
                // residual
                let roff = base * r_cap * d;
                out.resid_k[roff..roff + st.resid_k.len()].copy_from_slice(&st.resid_k);
                out.resid_v[roff..roff + st.resid_v.len()].copy_from_slice(&st.resid_v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> CacheConfig {
        CacheConfig {
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 8,
            spec: PolarSpec::new(4, 4, 4),
            value_bits: None,
        }
    }

    #[test]
    fn append_step_keeps_streams_aligned() {
        let mut rng = Rng::new(7);
        let c = cfg();
        let mut seq = SequenceCache::new(c.clone());
        let step = c.n_layers * c.n_kv_heads * c.head_dim;
        for _ in 0..10 {
            let k = rng.normal_vec(step);
            let v = rng.normal_vec(step);
            seq.append_step(&k, &v);
        }
        assert_eq!(seq.len(), 10);
        assert_eq!(seq.next_pos, 10);
        assert_eq!(seq.quantized_len(), 8);
        assert_eq!(seq.resid_len(), 2);
        for st in &seq.streams {
            assert_eq!(st.len(), 10);
        }
    }

    #[test]
    fn prefill_then_steps() {
        let mut rng = Rng::new(8);
        let c = cfg();
        let mut seq = SequenceCache::new(c.clone());
        let t = 6;
        let block = c.n_layers * c.n_kv_heads * t * c.head_dim;
        seq.append_prefill(&rng.normal_vec(block), &rng.normal_vec(block), t);
        assert_eq!(seq.len(), 6);
        assert_eq!(seq.quantized_len(), 4);
        let step = c.n_layers * c.n_kv_heads * c.head_dim;
        seq.append_step(&rng.normal_vec(step), &rng.normal_vec(step));
        seq.append_step(&rng.normal_vec(step), &rng.normal_vec(step));
        assert_eq!(seq.quantized_len(), 8);
        assert_eq!(seq.resid_len(), 0);
    }

    #[test]
    fn export_dense_layout() {
        let mut rng = Rng::new(9);
        let c = cfg();
        let mut seq = SequenceCache::new(c.clone());
        let t = 9; // 2 groups + 1 residual
        let block = c.n_layers * c.n_kv_heads * t * c.head_dim;
        let k = rng.normal_vec(block);
        let v = rng.normal_vec(block);
        seq.append_prefill(&k, &v, t);
        let s_cap = 12;
        let dense = seq.export_dense(s_cap, 4);
        assert_eq!(dense.cache_len, 8);
        assert_eq!(dense.resid_len, 1);
        let d = c.head_dim;
        // stream (0,0): values of first group must match the input block
        // (fp values path), i.e. v[0][0][0..4]
        for n in 0..4 {
            for j in 0..d {
                assert_eq!(dense.v[(0 * s_cap + n) * d + j], v[n * d + j]);
            }
        }
        // padding region is zero
        assert_eq!(dense.v[(0 * s_cap + 11) * d], 0.0);
        // residual k of stream (1,1) matches last token
        let base = (1 * c.n_kv_heads + 1) * 4 * d; // r_cap=4
        let koff = ((1 * c.n_kv_heads + 1) * t + 8) * d;
        for j in 0..d {
            assert_eq!(dense.resid_k[base + j], k[koff + j]);
        }
    }

    #[test]
    #[should_panic]
    fn export_overflow_panics() {
        let c = cfg();
        let mut seq = SequenceCache::new(c.clone());
        let mut rng = Rng::new(10);
        let block = c.n_layers * c.n_kv_heads * 16 * c.head_dim;
        seq.append_prefill(&rng.normal_vec(block), &rng.normal_vec(block), 16);
        seq.export_dense(8, 4); // 16 quantized > 8 cap
    }
}
