//! Per-sequence cache across all (layer, kv-head) streams: refcounted
//! page handles for the quantized region, per-stream fp residual tails,
//! plus the dense export that marshals it into the fixed-shape decode
//! graphs.
//!
//! The quantized region is a `Vec<Arc<Page>>` — each page one finalized
//! group across every stream, possibly shared with other sequences
//! (prefix caching) or with forks (copy-on-write).  Pages are immutable;
//! all mutation happens in the tails, so sharing never needs locks or
//! copies.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::pool::{Page, PagePool};
use super::stream::{GroupValues, StreamCache};
use crate::quant::polar::{PolarGroup, PolarSpec};

/// Cache geometry + codec config (derived from the artifact manifest).
#[derive(Clone, Debug)]
pub struct CacheConfig {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub spec: PolarSpec,
    /// None = fp values (the paper's default eval setting)
    pub value_bits: Option<u32>,
}

impl CacheConfig {
    pub fn streams(&self) -> usize {
        self.n_layers * self.n_kv_heads
    }
}

/// All streams of one sequence.  Every stream holds the same token count —
/// the state machine appends to all of them per decode step, and pages
/// are cut across all streams at once.
#[derive(Debug)]
pub struct SequenceCache {
    pub cfg: CacheConfig,
    /// finalized groups, oldest first; `pages[g].keys[s]` is group `g` of
    /// stream `s`
    pub pages: Vec<Arc<Page>>,
    /// per-stream fp residual tails
    pub streams: Vec<StreamCache>,
    /// absolute position of the next token (== tokens appended so far)
    pub next_pos: usize,
    /// tokens covered by `pages` (kept O(1) for the decode hot path)
    quantized_tokens: usize,
    /// accounting + allocation home; `None` for standalone caches
    pool: Option<PagePool>,
    /// this sequence's current contribution to the pool's residual-byte
    /// counter (reconciled on every mutation and on Drop)
    acc_resid_bytes: usize,
}

impl SequenceCache {
    pub fn new(cfg: CacheConfig) -> Self {
        Self::build(cfg, None)
    }

    /// A cache whose pages live in (and are accounted by) `pool`.
    pub fn new_pooled(cfg: CacheConfig, pool: PagePool) -> Self {
        Self::build(cfg, Some(pool))
    }

    fn build(cfg: CacheConfig, pool: Option<PagePool>) -> Self {
        let streams = (0..cfg.streams())
            .map(|_| StreamCache::new(cfg.head_dim, cfg.spec, cfg.value_bits))
            .collect();
        SequenceCache {
            cfg,
            pages: Vec::new(),
            streams,
            next_pos: 0,
            quantized_tokens: 0,
            pool,
            acc_resid_bytes: 0,
        }
    }

    /// Borrowed view of one (layer, kv-head) stream: its slice of every
    /// page plus its fp tail.
    #[inline]
    pub fn stream(&self, layer: usize, head: usize) -> StreamView<'_> {
        let idx = layer * self.cfg.n_kv_heads + head;
        StreamView { pages: &self.pages, idx, tail: &self.streams[idx] }
    }

    pub fn len(&self) -> usize {
        self.quantized_tokens + self.resid_len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tokens in finalized (paged) groups.
    pub fn quantized_len(&self) -> usize {
        self.quantized_tokens
    }

    /// Tokens in the fp residual tails (same across streams).
    pub fn resid_len(&self) -> usize {
        self.streams[0].resid_len()
    }

    /// Append one decode step's K/V: `k`/`v` are (L, Kv, d) row-major —
    /// exactly the `new_k`/`new_v` output layout of the decode graph.
    /// Cuts a page when the tails fill.
    pub fn append_step(&mut self, k: &[f32], v: &[f32]) {
        let (l, h, d) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        assert_eq!(k.len(), l * h * d);
        assert_eq!(v.len(), k.len());
        for (s, st) in self.streams.iter_mut().enumerate() {
            let off = s * d;
            st.push_token(&k[off..off + d], &v[off..off + d]);
        }
        self.next_pos += 1;
        if self.resid_len() >= self.cfg.spec.group {
            self.cut_pages();
        }
        self.sync_accounting();
    }

    /// Append a prefill block: `k`/`v` are (L, Kv, T, d) row-major — the
    /// prefill graph's cache output layout.  Finalizes as many full
    /// groups as possible.
    pub fn append_prefill(&mut self, k: &[f32], v: &[f32], tokens: usize) {
        self.push_prefill(k, v, tokens);
        self.cut_pages();
        self.sync_accounting();
    }

    /// Append a prefill chunk WITHOUT finalizing groups: layout as
    /// [`SequenceCache::append_prefill`], but every token stays in the fp
    /// residual tails.  Chunked prefill uses this so later chunks attend
    /// over exact fp keys; call [`SequenceCache::flush_groups`] once the
    /// whole prompt is in to cut pages in append order (the same pages
    /// eager appends would have produced).
    ///
    /// Residency note: until the flush, the whole prompt sits in the
    /// cache at fp width — the same transient peak the unchunked path
    /// reaches through its full-prompt `k_all`/`v_all` staging buffers,
    /// but visible to [`SequenceCache::nbytes`] AND to the pool's exact
    /// resid counters, so concurrent admission checks see it (and get
    /// MORE conservative, not less).
    pub fn append_prefill_deferred(&mut self, k: &[f32], v: &[f32], tokens: usize) {
        self.push_prefill(k, v, tokens);
        self.sync_accounting();
    }

    fn push_prefill(&mut self, k: &[f32], v: &[f32], tokens: usize) {
        let (l, h, d) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        assert_eq!(k.len(), l * h * tokens * d);
        assert_eq!(v.len(), k.len());
        for (s, st) in self.streams.iter_mut().enumerate() {
            let off = s * tokens * d;
            st.push_block(&k[off..off + tokens * d], &v[off..off + tokens * d]);
        }
        self.next_pos += tokens;
    }

    /// Finalize every full group across all streams (end of a deferred
    /// chunked prefill).
    pub fn flush_groups(&mut self) {
        self.cut_pages();
        self.sync_accounting();
    }

    /// Encode all full groups in every tail and assemble them into
    /// cross-stream pages (allocated from the pool when attached).
    fn cut_pages(&mut self) {
        let g = self.cfg.spec.group;
        let full = self.resid_len() / g;
        if full == 0 {
            return;
        }
        // encode per stream first (one front-drain per tail), then
        // transpose group-major into pages
        let mut per_stream: Vec<_> = self
            .streams
            .iter_mut()
            .map(|st| st.encode_full_groups().into_iter())
            .collect();
        for _ in 0..full {
            let mut keys = Vec::with_capacity(per_stream.len());
            let mut vals = Vec::with_capacity(per_stream.len());
            for it in per_stream.iter_mut() {
                let (k, v) = it.next().expect("streams finalize in lockstep");
                keys.push(k);
                vals.push(v);
            }
            let page = Page::new(keys, vals, g);
            let page = match &self.pool {
                Some(pool) => pool.adopt(page),
                None => Arc::new(page),
            };
            self.pages.push(page);
            self.quantized_tokens += g;
        }
    }

    /// Attach already-finalized pages (a prefix-cache hit) to this EMPTY
    /// cache: shares them refcounted and advances `next_pos` past the
    /// covered tokens, so prefill resumes right after the shared prefix.
    /// The pages may have just been PROMOTED from the disk tier
    /// (`kvcache::tier`) — promotion is bit-exact, so a tiered hit and a
    /// resident hit attach indistinguishable pages.
    pub fn adopt_pages(&mut self, pages: Vec<Arc<Page>>) {
        assert!(self.is_empty() && self.next_pos == 0, "prefix pages attach before prefill");
        for p in pages {
            self.quantized_tokens += p.tokens;
            self.next_pos += p.tokens;
            self.pages.push(p);
        }
        self.sync_accounting();
    }

    /// Restore the per-stream fp residual tails + position cursor of a
    /// session chain promoted from the disk tier.  Runs after
    /// [`SequenceCache::adopt_pages`] on a fresh cache — pages first,
    /// then tails — rebuilding exactly the state the chain was reaped
    /// with, so the next turn's prefill is bit-identical to resuming an
    /// unreaped chain.
    pub fn restore_tail(&mut self, tails: Vec<(Vec<f32>, Vec<f32>)>, next_pos: usize) {
        assert_eq!(tails.len(), self.streams.len(), "one tail per stream");
        assert_eq!(self.resid_len(), 0, "tails restore onto empty residuals");
        let d = self.cfg.head_dim;
        for (st, (k, v)) in self.streams.iter_mut().zip(tails) {
            assert_eq!(k.len(), v.len());
            assert_eq!(k.len() % d, 0, "tail rows must be d-aligned");
            st.resid_k = k;
            st.resid_v = v;
        }
        assert_eq!(
            self.quantized_tokens + self.resid_len(),
            next_pos,
            "cursor must cover exactly the restored pages + tails"
        );
        self.next_pos = next_pos;
        self.sync_accounting();
    }

    /// Copy-on-write fork for n-way sampling from one prompt: finalized
    /// pages are SHARED (refcount bump, no bytes copied); only the fp
    /// residual tails are deep-copied.  Either side cutting new pages
    /// later appends to its own `pages` vec — the other side never sees
    /// them, and the shared prefix is immutable by construction.
    pub fn fork(&self) -> SequenceCache {
        self.clone()
    }

    /// Physical bytes at rest across pages + tails.  NOTE: counts every
    /// page this sequence references, including pages shared with other
    /// sequences — the per-sequence "logical" size.  The pool's counters
    /// are the physical (deduplicated) truth.
    pub fn nbytes(&self) -> usize {
        self.pages.iter().map(|p| p.nbytes()).sum::<usize>()
            + self.streams.iter().map(|s| s.nbytes()).sum::<usize>()
    }

    /// Reconcile this sequence's contribution to the pool's exact O(1)
    /// residual-byte counter.  (Pages reconcile themselves on `Drop`;
    /// token totals come from the slow `report()` walk — a per-token
    /// atomic nobody reads is not worth the hot-path cacheline traffic.)
    fn sync_accounting(&mut self) {
        let Some(pool) = &self.pool else { return };
        let c = pool.counters();
        let rb: usize = self.streams.iter().map(|s| s.nbytes()).sum();
        if rb >= self.acc_resid_bytes {
            c.resid_bytes.fetch_add(rb - self.acc_resid_bytes, Ordering::Relaxed);
        } else {
            c.resid_bytes.fetch_sub(self.acc_resid_bytes - rb, Ordering::Relaxed);
        }
        self.acc_resid_bytes = rb;
    }
}

impl Clone for SequenceCache {
    fn clone(&self) -> Self {
        let mut c = SequenceCache {
            cfg: self.cfg.clone(),
            pages: self.pages.clone(), // Arc bumps — pages are shared
            streams: self.streams.clone(),
            next_pos: self.next_pos,
            quantized_tokens: self.quantized_tokens,
            pool: self.pool.clone(),
            acc_resid_bytes: 0,
        };
        // the clone contributes its own residual bytes
        c.sync_accounting();
        c
    }
}

impl Drop for SequenceCache {
    fn drop(&mut self) {
        if let Some(pool) = &self.pool {
            let c = pool.counters();
            c.resid_bytes.fetch_sub(self.acc_resid_bytes, Ordering::Relaxed);
        }
        // pages reconcile themselves on their own Drop (last Arc wins)
    }
}

/// Borrowed per-stream view: group `gi` of this stream is
/// `pages[gi].keys[idx]`, and the fp tail rides along.  `Copy` so the
/// forward pass can hold one per (layer, head) without borrow gymnastics.
#[derive(Clone, Copy)]
pub struct StreamView<'a> {
    pages: &'a [Arc<Page>],
    idx: usize,
    tail: &'a StreamCache,
}

impl<'a> StreamView<'a> {
    /// This stream's finalized key groups, oldest first — feeds straight
    /// into [`crate::quant::QkLut::scores_groups`].
    pub fn key_groups(self) -> impl ExactSizeIterator<Item = &'a PolarGroup> {
        self.pages.iter().map(move |p| &p.keys[self.idx])
    }

    /// (key group, value group) pairs, oldest first.
    pub fn groups(self) -> impl ExactSizeIterator<Item = (&'a PolarGroup, &'a GroupValues)> {
        self.pages.iter().map(move |p| (&p.keys[self.idx], &p.vals[self.idx]))
    }

    pub fn n_groups(self) -> usize {
        self.pages.len()
    }

    pub fn quantized_len(self) -> usize {
        self.pages.iter().map(|p| p.tokens).sum()
    }

    pub fn resid_len(self) -> usize {
        self.tail.resid_len()
    }

    pub fn len(self) -> usize {
        self.quantized_len() + self.resid_len()
    }

    pub fn is_empty(self) -> bool {
        self.len() == 0
    }

    /// fp residual keys, row-major (resid_len x d).
    pub fn resid_k(self) -> &'a [f32] {
        &self.tail.resid_k
    }

    /// fp residual values, row-major (resid_len x d).
    pub fn resid_v(self) -> &'a [f32] {
        &self.tail.resid_v
    }

    /// Dequantized values of group `gi` appended into `out`.
    pub fn decode_values_into(self, gi: usize, out: &mut Vec<f32>) {
        self.pages[gi].vals[self.idx].decode_into(self.tail.d, out);
    }

    /// Dequantize all finalized keys (test/eval path).
    pub fn decode_keys(self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.quantized_len() * self.tail.d);
        for g in self.key_groups() {
            crate::quant::polar::decode_group_into(g, self.tail.d, &mut out);
        }
        out
    }
}

/// Dense, padded export of a sequence cache for the fixed-shape decode
/// graph: codes unpacked to i32, params broadcast to the (G, d/2) grid,
/// values dequantized — the marshalling boundary between the coordinator
/// and the PJRT runtime.
#[derive(Clone, Debug, Default)]
pub struct DenseCache {
    /// (L, Kv, S, d/2) i32 each
    pub theta_code: Vec<i32>,
    pub rho_code: Vec<i32>,
    /// (L, Kv, S/g, d/2) f32 each
    pub rho_z: Vec<f32>,
    pub rho_s: Vec<f32>,
    pub theta_z: Vec<f32>,
    pub theta_s: Vec<f32>,
    /// (L, Kv, S, d)
    pub v: Vec<f32>,
    /// (L, Kv, R, d)
    pub resid_k: Vec<f32>,
    pub resid_v: Vec<f32>,
    pub cache_len: usize,
    pub resid_len: usize,
}

impl SequenceCache {
    /// Export into the decode bucket (capacity S quantized tokens,
    /// residual capacity R).  Panics if the sequence exceeds the bucket —
    /// bucket selection is the batcher's job.
    pub fn export_dense(&self, s_cap: usize, r_cap: usize) -> DenseCache {
        let (l, h, d) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        let d2 = d / 2;
        let g = self.cfg.spec.group;
        assert_eq!(s_cap % g, 0);
        let gcap = s_cap / g;
        let qlen = self.quantized_len();
        let rlen = self.resid_len();
        assert!(qlen <= s_cap, "sequence ({qlen}) exceeds bucket ({s_cap})");
        assert!(rlen <= r_cap);

        let mut out = DenseCache {
            theta_code: vec![0; l * h * s_cap * d2],
            rho_code: vec![0; l * h * s_cap * d2],
            rho_z: vec![0.0; l * h * gcap * d2],
            rho_s: vec![1e-8; l * h * gcap * d2],
            theta_z: vec![0.0; l * h * gcap * d2],
            theta_s: vec![1e-8; l * h * gcap * d2],
            v: vec![0.0; l * h * s_cap * d],
            resid_k: vec![0.0; l * h * r_cap * d],
            resid_v: vec![0.0; l * h * r_cap * d],
            cache_len: qlen,
            resid_len: rlen,
        };

        let mut vals_scratch = Vec::new();
        let mut codes_scratch = vec![0u8; g * d2];
        for layer in 0..l {
            for head in 0..h {
                let st = self.stream(layer, head);
                let base = layer * h + head;
                for (gi, (grp, _)) in st.groups().enumerate() {
                    // codes: in-memory planes are channel-major (pack v2);
                    // DenseCache keeps its external token-major contract
                    grp.theta_codes.unpack_into(&mut codes_scratch);
                    for n in 0..grp.tokens {
                        for j in 0..d2 {
                            out.theta_code[((base * s_cap) + gi * g + n) * d2 + j] =
                                codes_scratch[j * grp.tokens + n] as i32;
                        }
                    }
                    grp.rho_codes.unpack_into(&mut codes_scratch);
                    for n in 0..grp.tokens {
                        for j in 0..d2 {
                            out.rho_code[((base * s_cap) + gi * g + n) * d2 + j] =
                                codes_scratch[j * grp.tokens + n] as i32;
                        }
                    }
                    // params
                    let poff = (base * gcap + gi) * d2;
                    out.rho_z[poff..poff + d2].copy_from_slice(&grp.rho_z);
                    out.rho_s[poff..poff + d2].copy_from_slice(&grp.rho_s);
                    out.theta_z[poff..poff + d2].copy_from_slice(&grp.theta_z);
                    out.theta_s[poff..poff + d2].copy_from_slice(&grp.theta_s);
                    // values
                    vals_scratch.clear();
                    st.decode_values_into(gi, &mut vals_scratch);
                    let voff = (base * s_cap + gi * g) * d;
                    out.v[voff..voff + g * d].copy_from_slice(&vals_scratch);
                }
                // residual
                let roff = base * r_cap * d;
                out.resid_k[roff..roff + st.resid_k().len()].copy_from_slice(st.resid_k());
                out.resid_v[roff..roff + st.resid_v().len()].copy_from_slice(st.resid_v());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> CacheConfig {
        CacheConfig {
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 8,
            spec: PolarSpec::new(4, 4, 4),
            value_bits: None,
        }
    }

    #[test]
    fn append_step_keeps_streams_aligned() {
        let mut rng = Rng::new(7);
        let c = cfg();
        let mut seq = SequenceCache::new(c.clone());
        let step = c.n_layers * c.n_kv_heads * c.head_dim;
        for _ in 0..10 {
            let k = rng.normal_vec(step);
            let v = rng.normal_vec(step);
            seq.append_step(&k, &v);
        }
        assert_eq!(seq.len(), 10);
        assert_eq!(seq.next_pos, 10);
        assert_eq!(seq.quantized_len(), 8);
        assert_eq!(seq.resid_len(), 2);
        assert_eq!(seq.pages.len(), 2);
        for p in &seq.pages {
            assert_eq!(p.keys.len(), c.streams());
            assert_eq!(p.vals.len(), c.streams());
            assert_eq!(p.tokens, c.spec.group);
        }
        for l in 0..c.n_layers {
            for h in 0..c.n_kv_heads {
                assert_eq!(seq.stream(l, h).len(), 10);
            }
        }
    }

    #[test]
    fn prefill_then_steps() {
        let mut rng = Rng::new(8);
        let c = cfg();
        let mut seq = SequenceCache::new(c.clone());
        let t = 6;
        let block = c.n_layers * c.n_kv_heads * t * c.head_dim;
        seq.append_prefill(&rng.normal_vec(block), &rng.normal_vec(block), t);
        assert_eq!(seq.len(), 6);
        assert_eq!(seq.quantized_len(), 4);
        let step = c.n_layers * c.n_kv_heads * c.head_dim;
        seq.append_step(&rng.normal_vec(step), &rng.normal_vec(step));
        seq.append_step(&rng.normal_vec(step), &rng.normal_vec(step));
        assert_eq!(seq.quantized_len(), 8);
        assert_eq!(seq.resid_len(), 0);
    }

    #[test]
    fn deferred_prefill_plus_flush_matches_eager() {
        let mut rng = Rng::new(12);
        let c = cfg();
        let t = 11; // 2 full groups + 3 residual at group=4
        let block = c.n_layers * c.n_kv_heads * t * c.head_dim;
        let k = rng.normal_vec(block);
        let v = rng.normal_vec(block);
        let mut eager = SequenceCache::new(c.clone());
        eager.append_prefill(&k, &v, t);
        let mut deferred = SequenceCache::new(c.clone());
        deferred.append_prefill_deferred(&k, &v, t);
        assert_eq!(deferred.quantized_len(), 0, "no pages before flush");
        assert_eq!(deferred.resid_len(), t);
        deferred.flush_groups();
        assert_eq!(deferred.quantized_len(), eager.quantized_len());
        for l in 0..c.n_layers {
            for h in 0..c.n_kv_heads {
                let a = deferred.stream(l, h);
                let b = eager.stream(l, h);
                assert_eq!(a.decode_keys(), b.decode_keys());
                assert_eq!(a.resid_k(), b.resid_k());
                assert_eq!(a.resid_v(), b.resid_v());
            }
        }
    }

    #[test]
    fn fork_shares_pages_and_copies_tails() {
        let mut rng = Rng::new(13);
        let c = cfg();
        let mut seq = SequenceCache::new(c.clone());
        let t = 10; // 2 pages + 2 residual
        let block = c.n_layers * c.n_kv_heads * t * c.head_dim;
        seq.append_prefill(&rng.normal_vec(block), &rng.normal_vec(block), t);
        let baseline_keys = seq.stream(0, 0).decode_keys();
        let baseline_resid = seq.stream(0, 0).resid_k().to_vec();

        let mut fork = seq.fork();
        assert_eq!(fork.len(), seq.len());
        for (a, b) in seq.pages.iter().zip(&fork.pages) {
            assert!(Arc::ptr_eq(a, b), "fork must share pages, not copy");
            assert_eq!(Arc::strong_count(a), 2);
        }
        // diverge the fork: it cuts its OWN page, parent must not move
        let step = c.n_layers * c.n_kv_heads * c.head_dim;
        for _ in 0..2 {
            fork.append_step(&rng.normal_vec(step), &rng.normal_vec(step));
        }
        assert_eq!(fork.quantized_len(), 12);
        assert_eq!(seq.quantized_len(), 8, "parent untouched by fork growth");
        assert_eq!(seq.stream(0, 0).decode_keys(), baseline_keys);
        assert_eq!(seq.stream(0, 0).resid_k(), &baseline_resid[..]);
        // shared pages still shared; the fork's new page is private
        assert_eq!(Arc::strong_count(&seq.pages[0]), 2);
        assert_eq!(Arc::strong_count(&fork.pages[2]), 1);
        drop(fork);
        assert_eq!(Arc::strong_count(&seq.pages[0]), 1, "refcount drops on release");
    }

    #[test]
    fn export_dense_layout() {
        let mut rng = Rng::new(9);
        let c = cfg();
        let mut seq = SequenceCache::new(c.clone());
        let t = 9; // 2 groups + 1 residual
        let block = c.n_layers * c.n_kv_heads * t * c.head_dim;
        let k = rng.normal_vec(block);
        let v = rng.normal_vec(block);
        seq.append_prefill(&k, &v, t);
        let s_cap = 12;
        let dense = seq.export_dense(s_cap, 4);
        assert_eq!(dense.cache_len, 8);
        assert_eq!(dense.resid_len, 1);
        let d = c.head_dim;
        // stream (0,0): values of first group must match the input block
        // (fp values path), i.e. v[0][0][0..4]
        for n in 0..4 {
            for j in 0..d {
                assert_eq!(dense.v[(0 * s_cap + n) * d + j], v[n * d + j]);
            }
        }
        // padding region is zero
        assert_eq!(dense.v[(0 * s_cap + 11) * d], 0.0);
        // residual k of stream (1,1) matches last token
        let base = (1 * c.n_kv_heads + 1) * 4 * d; // r_cap=4
        let koff = ((1 * c.n_kv_heads + 1) * t + 8) * d;
        for j in 0..d {
            assert_eq!(dense.resid_k[base + j], k[koff + j]);
        }
    }

    #[test]
    fn memory_shrinks_with_fewer_bits() {
        let mut rng = Rng::new(4);
        let mut c = cfg();
        c.head_dim = 32;
        c.spec = PolarSpec::new(5, 5, 8);
        let block = c.n_layers * c.n_kv_heads * 64 * c.head_dim;
        let k = rng.normal_vec(block);
        let v = rng.normal_vec(block);
        let mut big = SequenceCache::new(c.clone());
        big.append_prefill(&k, &v, 64);
        c.spec = PolarSpec::new(2, 2, 8);
        let mut small = SequenceCache::new(c.clone());
        small.append_prefill(&k, &v, 64);
        assert!(small.nbytes() < big.nbytes());
    }

    #[test]
    #[should_panic]
    fn export_overflow_panics() {
        let c = cfg();
        let mut seq = SequenceCache::new(c.clone());
        let mut rng = Rng::new(10);
        let block = c.n_layers * c.n_kv_heads * 16 * c.head_dim;
        seq.append_prefill(&rng.normal_vec(block), &rng.normal_vec(block), 16);
        seq.export_dense(8, 4); // 16 quantized > 8 cap
    }
}
