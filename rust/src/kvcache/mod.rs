//! Quantized paged KV-cache: the object the paper studies, as a serving
//! substrate.
//!
//! * [`pool`] — the refcounted group-page pool: fixed-size pages (one
//!   finalized key group + its values per stream), exact O(1) atomic
//!   accounting, the verified prefix index, and LRU reclamation of
//!   refcount-zero cached pages.
//! * [`stream`] — one (layer, kv-head) stream's fp residual tail and the
//!   group encoder that cuts its slice of each page.
//! * [`seq`] — a sequence's cache: shared page handles across all
//!   layers/heads, the append/finalize state machine, COW forks, and the
//!   dense export for the PJRT graphs.
//! * [`eviction`] — SnapKV-style prompt compression (Table 8).
//! * [`manager`] — multi-sequence allocation over one shared pool, with
//!   constant-time admission against the global memory budget.
//! * [`tier`] — the disk tier under the pool: versioned page serde,
//!   append-only segment store, background demotion, on-demand
//!   promotion, and persistent prefix-cache snapshots for warm starts.

pub mod eviction;
pub mod manager;
pub mod pool;
pub mod seq;
pub mod stream;
pub mod tier;

pub use manager::{CacheManager, MemoryReport, SharedSeq};
pub use pool::{Page, PagePool};
pub use seq::{CacheConfig, SequenceCache, StreamView};
pub use stream::StreamCache;
pub use tier::{SegmentStore, TierConfig, TierRef};
