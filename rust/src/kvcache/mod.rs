//! Quantized paged KV-cache: the object the paper studies, as a serving
//! substrate.
//!
//! * [`stream`] — one (layer, kv-head) stream: PolarQuant-encoded key
//!   groups, (optionally quantized) values, and the fp residual tail that
//!   buffers tokens until a full group can be finalized.
//! * [`seq`] — a sequence's cache across all layers/heads, with the
//!   append/finalize state machine and dense export for the PJRT graphs.
//! * [`eviction`] — SnapKV-style prompt compression (Table 8).
//! * [`manager`] — multi-sequence allocation, global memory budget,
//!   accounting that backs the Table 4 memory column.

pub mod eviction;
pub mod manager;
pub mod seq;
pub mod stream;

pub use manager::{CacheManager, MemoryReport, SharedSeq};
pub use seq::{CacheConfig, SequenceCache};
pub use stream::StreamCache;
