//! Streaming statistics + percentile helpers used by metrics and benches.

/// Online mean/variance (Welford) with min/max.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a sample (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Simple fixed-bucket latency histogram (microseconds, exponential edges).
#[derive(Clone, Debug)]
pub struct LatencyHist {
    edges_us: Vec<f64>,
    counts: Vec<u64>,
    samples: Vec<f64>,
    sum_secs: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        // 1us .. ~100s, x2 per bucket
        let edges_us: Vec<f64> = (0..28).map(|i| (1u64 << i) as f64).collect();
        let counts = vec![0; edges_us.len() + 1];
        LatencyHist { edges_us, counts, samples: Vec::new(), sum_secs: 0.0 }
    }

    pub fn record_secs(&mut self, secs: f64) {
        let us = secs * 1e6;
        let idx = self.edges_us.partition_point(|&e| e <= us);
        self.counts[idx] += 1;
        self.sum_secs += secs;
        if self.samples.len() < 100_000 {
            self.samples.push(secs);
        }
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Total of every recorded value (exact — unlike the percentile
    /// sample set, the sum is never truncated).
    pub fn sum_secs(&self) -> f64 {
        self.sum_secs
    }

    /// Cumulative bucket counts in Prometheus shape: `(le_seconds,
    /// samples <= le)` per edge, monotone non-decreasing.  The overflow
    /// tail is the implicit `+Inf` bucket ([`LatencyHist::count`]).
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut cum = 0u64;
        self.edges_us
            .iter()
            .zip(&self.counts)
            .map(|(&edge_us, &c)| {
                cum += c;
                // counts[i] holds samples with us < edge[i] (partition
                // on e <= us), so the cumulative count through bucket i
                // is exactly "samples <= just under edge[i]" — expose
                // the edge itself as the le bound
                (edge_us * 1e-6, cum)
            })
            .collect()
    }

    pub fn p(&self, pct: f64) -> f64 {
        percentile(&self.samples, pct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.var() - var).abs() < 1e-9);
        assert_eq!(w.min, 1.0);
        assert_eq!(w.max, 10.0);
    }

    #[test]
    fn percentile_basics() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert!((percentile(&v, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn hist_counts() {
        let mut h = LatencyHist::new();
        for i in 0..100 {
            h.record_secs(i as f64 * 1e-4);
        }
        assert_eq!(h.count(), 100);
        assert!(h.p(50.0) > 0.0);
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_exhaustive() {
        let mut h = LatencyHist::new();
        let secs = [0.5e-6, 3e-6, 3e-6, 1e-3, 0.5, 400.0]; // incl. +Inf tail
        for s in secs {
            h.record_secs(s);
        }
        let b = h.cumulative_buckets();
        assert_eq!(b.len(), 28);
        assert!((b[0].0 - 1e-6).abs() < 1e-18, "first le is 1us in seconds");
        assert!(b.windows(2).all(|w| w[0].1 <= w[1].1), "cumulative counts are monotone");
        assert_eq!(b[0].1, 1, "one sample under 1us");
        assert_eq!(b.last().unwrap().1, 5, "400s overflows every edge into +Inf");
        assert_eq!(h.count(), 6);
        let sum: f64 = secs.iter().sum();
        assert!((h.sum_secs() - sum).abs() < 1e-12);
    }
}
