//! Minimal JSON codec (serde is unavailable offline).
//!
//! Implements the full JSON grammar (RFC 8259) minus `\u` surrogate-pair
//! edge cases we never emit, with a DOM [`Value`] type, accessor helpers
//! tuned for the artifact manifest / goldens index, and a compact writer
//! used by the server protocol and the table printers.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member access that errors with a path-ish message.
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key '{key}'")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// `[1, 2, 3]` -> Vec<usize>.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }
}

#[derive(Debug)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

// ---------------------------------------------------------------- parser

pub fn parse(text: &str) -> Result<Value, JsonError> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(JsonError(format!("trailing garbage at byte {}", p.i)));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError(format!(
                "expected '{}' at byte {} (got {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(JsonError(format!("unexpected {other:?} at byte {}", self.i))),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                other => return Err(JsonError(format!("expected , or }} got {other:?}"))),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                other => return Err(JsonError(format!("expected , or ] got {other:?}"))),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(JsonError("bad \\u escape".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| JsonError("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError("bad \\u escape".into()))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => {
                            return Err(JsonError(format!("bad escape {other:?}")));
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| JsonError("invalid utf8".into()))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| JsonError(format!("bad number '{text}'")))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------- writer

pub fn write(v: &Value) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Value::Str(k.clone()), out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(text: &str) -> Value {
    Value::Str(text.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = parse(t).unwrap();
            assert_eq!(parse(&write(&v)).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x\ny"
        );
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn parse_manifest_like() {
        let t = r#"{"graphs": [{"name": "decode", "inputs": [{"shape": [4, 256], "dtype": "int32"}]}]}"#;
        let v = parse(t).unwrap();
        let g = &v.get("graphs").unwrap().as_arr().unwrap()[0];
        let shape = g.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .usize_vec()
            .unwrap();
        assert_eq!(shape, vec![4, 256]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(parse(&write(&v)).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }
}
