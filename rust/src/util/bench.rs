//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`bench_fn`]: warmup, then timed batches until a wall budget or a target
//! iteration count is reached, reporting mean / p50 / p95 per iteration.
//! Deterministic enough for the paper-shape comparisons in EXPERIMENTS.md
//! (we compare ratios, not absolute numbers).

use std::time::{Duration, Instant};

use super::stats::percentile;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: Duration::from_millis(150),
            budget: Duration::from_millis(900),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

/// Time `f` and report per-iteration stats. `f` should return a value that
/// depends on its work so the optimizer cannot elide it; we black-box it.
pub fn bench_fn<T, F: FnMut() -> T>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    // warmup
    let start = Instant::now();
    while start.elapsed() < opts.warmup {
        black_box(f());
    }
    // measure
    let mut samples: Vec<f64> = Vec::new();
    let run_start = Instant::now();
    let mut iters = 0u64;
    while (run_start.elapsed() < opts.budget && iters < opts.max_iters)
        || iters < opts.min_iters
    {
        let t = Instant::now();
        black_box(f());
        samples.push(t.elapsed().as_secs_f64());
        iters += 1;
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: percentile(&samples, 50.0),
        p95_s: percentile(&samples, 95.0),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Optimizer barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 10_000,
        };
        let r = bench_fn("spin", opts, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s > 0.0);
        assert!(r.p95_s >= r.p50_s * 0.5);
    }
}
