//! Zero-dependency substrates: deterministic RNG, a JSON codec (the image
//! has no serde), streaming statistics, and a micro-benchmark harness
//! (criterion is likewise unavailable offline — `rust/benches/` use
//! [`bench`] instead).

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
