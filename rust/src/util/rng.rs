//! Deterministic PRNG (xoshiro256++) — no `rand` crate offline.
//!
//! Every stochastic component in the repo (workload generators, synthetic
//! activations, property tests) takes an explicit [`Rng`] so runs are
//! reproducible from a single seed recorded in EXPERIMENTS.md.

/// xoshiro256++ by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform().max(1e-12).ln() / lambda
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Random sign.
    pub fn sign(&mut self) -> f32 {
        if self.chance(0.5) {
            1.0
        } else {
            -1.0
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            let k = r.range(1, 10);
            let v = r.choose_distinct(20, k);
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), v.len());
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
