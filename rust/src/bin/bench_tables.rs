//! `bench_tables` — regenerate every quality/ablation table of the paper
//! on the synthetic substrate (DESIGN.md §6 maps ids to modules).
//!
//! ```text
//! bench_tables table1   # LongBench proxy: 3 model profiles x codecs x bits
//! bench_tables table2   # GSM8K CoT proxy: long-rollout agreement
//! bench_tables table3   # reasoning-model proxy: error accumulation
//! bench_tables table4-throughput [--backend native|pjrt]
//! bench_tables table5   # group-size ablation
//! bench_tables table6   # (r, t) bit-allocation ablation
//! bench_tables table7   # + value quantization
//! bench_tables table8   # + SnapKV prompt compression
//! bench_tables table9   # key vs value sensitivity
//! bench_tables all      # everything above (native throughput)
//! ```
//!
//! Absolute numbers differ from the paper (synthetic 0.85M-param models,
//! CPU); the *shape* — method ordering, collapse points, deltas — is the
//! reproduction target.  See EXPERIMENTS.md for recorded runs.

use std::time::Instant;

use polarquant::coordinator::engine::SnapKvOpts;
use polarquant::coordinator::{Engine, EngineOpts};
use polarquant::eval::proxy::{decode_agreement_kv, proxy_prompts};
use polarquant::eval::tables::{f2, sci, score_with_delta};
use polarquant::eval::{decode_agreement, eval_codec, Table};
use polarquant::model::ModelConfig;
use polarquant::quant::QuantSpec;
use polarquant::workload::{ActivationProfile, PromptKind, RequestGen, PROFILES};

/// Proxy model geometry: big enough that quantization effects mirror the
/// paper's (d=32 head, multiple groups per prompt), small enough for CPU.
fn proxy_cfg(group: usize) -> ModelConfig {
    let mut c = ModelConfig::tiny();
    c.n_layers = 2;
    c.vocab = 128;
    c.d_model = 64;
    c.n_heads = 4;
    c.n_kv_heads = 2;
    c.head_dim = 32;
    c.ffn = 96;
    c.group = group;
    c.resid = 2 * group;
    c
}

const GROUP: usize = 16;
const PROMPTS: usize = 4;
const PROMPT_LEN: usize = 48;
const STEPS: usize = 12;

fn codec_rows_4bit(group: usize) -> Vec<QuantSpec> {
    vec![
        QuantSpec::Int { bits: 4 },
        QuantSpec::Zip { bits: 4 },
        QuantSpec::Kivi { bits: 4, group },
        QuantSpec::Polar { r_bits: 4, t_bits: 4, group },
    ]
}

fn codec_rows_3bit(group: usize) -> Vec<QuantSpec> {
    vec![
        QuantSpec::Int { bits: 3 },
        QuantSpec::Zip { bits: 3 },
        QuantSpec::Kivi { bits: 2, group: 32 },
        QuantSpec::Polar { r_bits: 3, t_bits: 3, group },
    ]
}

fn table1() {
    let cfg = proxy_cfg(GROUP);
    let prompts = proxy_prompts(cfg.vocab, PROMPTS, PROMPT_LEN, 10);
    let mut t = Table::new(
        "Table 1 — LongBench proxy (greedy-decode agreement % vs fp; logit cos; attn KL)",
        &["profile", "method", "bits", "score", "logit cos", "attn KL"],
    );
    for (pi, profile) in PROFILES.iter().enumerate() {
        let seed = 100 + pi as u64;
        let base = decode_agreement(
            &cfg, seed, profile.weight_severity, &QuantSpec::Fp16, &prompts, STEPS,
        );
        let mut rows = vec![QuantSpec::Fp16];
        rows.extend(codec_rows_4bit(GROUP));
        rows.extend(codec_rows_3bit(GROUP));
        for spec in rows {
            let s = decode_agreement(&cfg, seed, profile.weight_severity, &spec, &prompts, STEPS);
            let fid = eval_codec(&spec, profile, cfg.head_dim, 256, 8, seed);
            t.row(vec![
                profile.name.to_string(),
                spec.label(),
                f2(spec.bits_per_element(cfg.head_dim)),
                score_with_delta(s.task_score(), base.task_score()),
                format!("{:.4}", s.logit_cos),
                sci(fid.attn_kl),
            ]);
        }
    }
    t.print();
    println!(
        "(QJL is score-only — no key reconstruction — so it appears in the\n\
         fidelity table: `polarquant fidelity --profile <name>`)\n"
    );
}

fn long_rollout(title: &str, steps: usize, severity: f32, seed: u64) {
    let cfg = proxy_cfg(GROUP);
    let prompts = proxy_prompts(cfg.vocab, 3, 24, seed);
    let mut t = Table::new(title, &["method", "bits", "score", "logit cos"]);
    let base = decode_agreement(&cfg, seed, severity, &QuantSpec::Fp16, &prompts, steps);
    let rows = vec![
        QuantSpec::Fp16,
        QuantSpec::Int { bits: 4 },
        QuantSpec::Zip { bits: 4 },
        QuantSpec::Kivi { bits: 4, group: GROUP },
        QuantSpec::Polar { r_bits: 4, t_bits: 4, group: GROUP },
    ];
    for spec in rows {
        let s = decode_agreement(&cfg, seed, severity, &spec, &prompts, steps);
        t.row(vec![
            spec.label(),
            f2(spec.bits_per_element(cfg.head_dim)),
            score_with_delta(s.task_score(), base.task_score()),
            format!("{:.4}", s.logit_cos),
        ]);
    }
    t.print();
}

fn table2() {
    // GSM8K 5-shot CoT: medium-length generation, llama-like outliers
    long_rollout(
        "Table 2 — GSM8K CoT proxy (32-step rollouts, llama-like profile)",
        32,
        6.0,
        20,
    );
}

fn table3() {
    // reasoning models: LONG rollouts amplify error accumulation; the
    // hard (qwen-distill-like) profile
    long_rollout(
        "Table 3 — reasoning-model proxy (64-step rollouts, qwen-like profile)",
        64,
        14.0,
        30,
    );
}

fn native_engine(group: usize, rbits: u32, tbits: u32, opts: EngineOpts) -> Engine {
    let mut cfg = proxy_cfg(group.min(64));
    cfg.group = group;
    cfg.resid = if group >= 1 << 20 { 1 << 20 } else { 2 * group };
    cfg.r_bits = rbits;
    cfg.t_bits = tbits;
    Engine::native_synthetic(cfg, 7, 6.0, opts)
}

fn table4_throughput(backend: &str) {
    // throughput/memory at fixed prompt, sweeping generation length —
    // Fp16 (never-quantized cache) vs PolarQuant variants (+ value quant)
    let mut t = Table::new(
        &format!("Table 4 (bottom) — e2e throughput / cache memory ({backend} backend)"),
        &["config", "gen len", "tok/s", "peak cache KB/seq", "mean batch"],
    );
    for gen_len in [32usize, 96] {
        for (label, group, rbits, tbits, vbits) in [
            ("Fp16", 1usize << 20, 4u32, 4u32, None),
            ("PolarQuant44", 64, 4, 4, None),
            ("PolarQuant33", 64, 3, 3, None),
            ("PolarQuant44+V2", 64, 4, 4, Some(2u32)),
        ] {
            let dir = std::path::PathBuf::from("artifacts");
            let mut opts = EngineOpts::default();
            opts.value_bits = vbits;
            let mut eng = if backend == "pjrt" && group < (1 << 20) {
                match Engine::pjrt_from_artifacts(&dir, opts) {
                    Ok(e) => e,
                    Err(_) => {
                        eprintln!("(no artifacts; falling back to native)");
                        native_engine(group, rbits, tbits, opts)
                    }
                }
            } else {
                native_engine(group, rbits, tbits, opts)
            };
            let vocab = eng.cfg.vocab;
            let mut gen = RequestGen::new(vocab, 42);
            let n_req = 8;
            for _ in 0..n_req {
                let req = gen.request(PromptKind::Random { len: 64 }, gen_len);
                eng.submit(req).unwrap();
            }
            let start = Instant::now();
            let mut peak_bytes = 0usize;
            // step manually so we can sample peak cache memory
            while !eng.idle() {
                eng.step().unwrap();
                peak_bytes = peak_bytes.max(eng.cache_report().bytes);
            }
            let secs = start.elapsed().as_secs_f64();
            let toks = eng.metrics.decode_tokens as f64;
            t.row(vec![
                label.to_string(),
                gen_len.to_string(),
                format!("{:.1}", toks / secs),
                format!("{:.1}", peak_bytes as f64 / n_req as f64 / 1024.0),
                format!("{:.2}", eng.metrics.mean_batch()),
            ]);
        }
    }
    t.print();
    println!("(kernel-level latency: `cargo bench --bench fig3_qk_latency`)\n");
}

fn table5() {
    let mut t = Table::new(
        "Table 5 — group-size ablation (llama31-like profile)",
        &["method", "group", "bits", "score", "attn KL"],
    );
    let profile = ActivationProfile::by_name("llama31-like").unwrap();
    for group in [8usize, 16, 32, 64] {
        let cfg = proxy_cfg(group);
        let prompts = proxy_prompts(cfg.vocab, PROMPTS, 4 * group, 50);
        let base = decode_agreement(&cfg, 51, 6.0, &QuantSpec::Fp16, &prompts, STEPS);
        for spec in [
            QuantSpec::Kivi { bits: 4, group },
            QuantSpec::Polar { r_bits: 4, t_bits: 4, group },
        ] {
            let s = decode_agreement(&cfg, 51, 6.0, &spec, &prompts, STEPS);
            let fid = eval_codec(&spec, profile, cfg.head_dim, 256, 8, 52);
            t.row(vec![
                spec.label(),
                group.to_string(),
                f2(spec.bits_per_element(cfg.head_dim)),
                score_with_delta(s.task_score(), base.task_score()),
                sci(fid.attn_kl),
            ]);
        }
    }
    t.print();
}

fn table6() {
    let mut t = Table::new(
        "Table 6 — (r, t) bit-allocation ablation",
        &["alloc", "bits", "score", "logit cos", "attn KL"],
    );
    let cfg = proxy_cfg(GROUP);
    let profile = ActivationProfile::by_name("llama31-like").unwrap();
    let prompts = proxy_prompts(cfg.vocab, PROMPTS, PROMPT_LEN, 60);
    let base = decode_agreement(&cfg, 61, 6.0, &QuantSpec::Fp16, &prompts, STEPS);
    for (r, tt) in [(5u32, 3u32), (4, 4), (3, 5), (4, 2), (3, 3), (2, 4)] {
        let spec = QuantSpec::Polar { r_bits: r, t_bits: tt, group: GROUP };
        let s = decode_agreement(&cfg, 61, 6.0, &spec, &prompts, STEPS);
        let fid = eval_codec(&spec, profile, cfg.head_dim, 256, 8, 62);
        t.row(vec![
            format!("(r{r}, t{tt})"),
            f2(spec.bits_per_element(cfg.head_dim)),
            score_with_delta(s.task_score(), base.task_score()),
            format!("{:.4}", s.logit_cos),
            sci(fid.attn_kl),
        ]);
    }
    t.print();
    println!("(expected shape: t<3 collapses — angle bits matter more; paper Obs. 1/2)\n");
}

fn table7() {
    let mut t = Table::new(
        "Table 7 — PolarQuant44 + value quantization",
        &["value bits", "score", "logit cos"],
    );
    let cfg = proxy_cfg(GROUP);
    let prompts = proxy_prompts(cfg.vocab, PROMPTS, PROMPT_LEN, 70);
    let key = QuantSpec::Polar { r_bits: 4, t_bits: 4, group: GROUP };
    let base = decode_agreement_kv(&cfg, 71, 6.0, &key, None, &prompts, STEPS);
    for (label, vbits) in [("16 (fp)", None), ("4", Some(4u32)), ("2", Some(2))] {
        let s = decode_agreement_kv(&cfg, 71, 6.0, &key, vbits, &prompts, STEPS);
        t.row(vec![
            label.to_string(),
            score_with_delta(s.task_score(), base.task_score()),
            format!("{:.4}", s.logit_cos),
        ]);
    }
    t.print();
}

fn table8() {
    // SnapKV + PolarQuant: generation agreement vs the full-cache engine
    // on needle-retrieval prompts
    let mut t = Table::new(
        "Table 8 — SnapKV prompt compression (+PolarQuant), needle workload",
        &["config", "kept/prompt", "token agreement %"],
    );
    let cfg = proxy_cfg(8);
    let prompt_len = 96;
    let gen_len = 12;
    let n_req = 6;

    let run = |snapkv: Option<SnapKvOpts>| -> Vec<Vec<u32>> {
        let mut opts = EngineOpts::default();
        opts.snapkv = snapkv;
        let mut eng = Engine::native_synthetic(cfg.clone(), 80, 6.0, opts);
        let mut gen = RequestGen::new(cfg.vocab, 81);
        for _ in 0..n_req {
            let req = gen.request(
                PromptKind::Needle { len: prompt_len, needle: 111 },
                gen_len,
            );
            eng.submit(req).unwrap();
        }
        let mut done = eng.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| c.tokens).collect()
    };

    let full = run(None);
    for budget in [64usize, 32, 16] {
        let snap = run(Some(SnapKvOpts { budget, window: 8 }));
        let mut agree = 0;
        let mut total = 0;
        for (a, b) in full.iter().zip(&snap) {
            for (x, y) in a.iter().zip(b) {
                agree += (x == y) as usize;
                total += 1;
            }
        }
        t.row(vec![
            format!("SnapKV:{budget} + Polar44"),
            format!("{budget}/{prompt_len}"),
            format!("{:.1}", 100.0 * agree as f64 / total as f64),
        ]);
    }
    t.print();
    println!("(expected shape: agreement degrades gracefully as budget shrinks — Table 8)\n");
}

fn table9() {
    let mut t = Table::new(
        "Table 9 — key vs value quantization sensitivity",
        &["config", "score", "logit cos"],
    );
    let cfg = proxy_cfg(GROUP);
    let prompts = proxy_prompts(cfg.vocab, PROMPTS, PROMPT_LEN, 90);
    let base = decode_agreement_kv(&cfg, 91, 6.0, &QuantSpec::Fp16, None, &prompts, STEPS);
    let rows: Vec<(&str, QuantSpec, Option<u32>)> = vec![
        ("(K16, V16)", QuantSpec::Fp16, None),
        ("(K16, V4)", QuantSpec::Fp16, Some(4)),
        ("(K16, V2)", QuantSpec::Fp16, Some(2)),
        ("(K2,  V16)", QuantSpec::Kivi { bits: 2, group: GROUP }, None),
    ];
    for (label, key, vbits) in rows {
        let s = decode_agreement_kv(&cfg, 91, 6.0, &key, vbits, &prompts, STEPS);
        t.row(vec![
            label.to_string(),
            score_with_delta(s.task_score(), base.task_score()),
            format!("{:.4}", s.logit_cos),
        ]);
    }
    t.print();
    println!("(expected shape: V2 barely moves the score; K2 drops it — Appendix D)\n");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let which = args.first().map(String::as_str).unwrap_or("all");
    let backend = args
        .iter()
        .position(|a| a == "--backend")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "native".to_string());
    let t0 = Instant::now();
    match which {
        "table1" => table1(),
        "table2" => table2(),
        "table3" => table3(),
        "table4-throughput" => table4_throughput(&backend),
        "table5" => table5(),
        "table6" => table6(),
        "table7" => table7(),
        "table8" => table8(),
        "table9" => table9(),
        "all" => {
            table1();
            table2();
            table3();
            table4_throughput(&backend);
            table5();
            table6();
            table7();
            table8();
            table9();
        }
        other => {
            eprintln!("unknown table '{other}'");
            std::process::exit(2);
        }
    }
    eprintln!("[bench_tables {which}: {:.1}s]", t0.elapsed().as_secs_f64());
}
