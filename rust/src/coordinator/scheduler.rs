//! Prefill/decode interleaving policy + the pluggable admission ordering.
//!
//! vLLM-style "decode-priority with prefill admission": each engine step
//! first admits up to `prefill_per_step` queued requests (prefill is the
//! long pole; bounding it caps decode stall), then runs one decode
//! iteration over every running sequence.  The policy is a pure function
//! of queue state so it is unit-testable without an engine.
//!
//! WHICH queued request is admitted (and whose prefill chunks are granted
//! first) is a separate, pluggable concern: [`SchedMode::Fcfs`] keeps the
//! historical arrival order bit-identical, and [`SchedMode::Wfq`] orders
//! by per-tenant virtual finish time ([`WfqState`], stride scheduling) so
//! one tenant's flood cannot starve another — every backlogged tenant's
//! pass value is finite while the flooder's grows with every token of
//! service it receives, so the well-behaved tenant reaches the front of
//! the order within a bounded number of steps.

use std::collections::HashMap;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerPolicy {
    /// max prefills admitted per engine step
    pub prefill_per_step: usize,
    /// max sequences decoding concurrently
    pub max_running: usize,
}

impl Default for SchedulerPolicy {
    fn default() -> Self {
        SchedulerPolicy { prefill_per_step: 2, max_running: 32 }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepPlan {
    /// how many queued requests to prefill this step
    pub admit: usize,
    /// whether to run a decode iteration
    pub decode: bool,
}

impl SchedulerPolicy {
    pub fn plan(&self, queued: usize, running: usize) -> StepPlan {
        let slots = self.max_running.saturating_sub(running);
        let admit = queued.min(self.prefill_per_step).min(slots);
        StepPlan { admit, decode: running > 0 || admit > 0 }
    }

    /// Plan for the chunked-prefill engine, where admitted requests stay
    /// in `Prefilling` across several steps.  `prefill_per_step` bounds
    /// the number of CONCURRENTLY prefilling sequences rather than
    /// admissions per step: admitting more while others are mid-prefill
    /// only multiplies half-filled caches without finishing anyone's
    /// prompt sooner (the chunk quota is FCFS).
    pub fn plan_chunked(&self, queued: usize, prefilling: usize, decoding: usize) -> StepPlan {
        let running = prefilling + decoding;
        let slots = self.max_running.saturating_sub(running);
        let admit = queued
            .min(self.prefill_per_step.saturating_sub(prefilling))
            .min(slots);
        // decode MAY run: something is already decoding, or this step's
        // prefill work (running or newly admitted) can finish a prompt
        // and decode it in the same iteration — the engine refines this
        // against actual request states after the chunk phase
        StepPlan { admit, decode: decoding > 0 || prefilling > 0 || admit > 0 }
    }
}

/// Which ordering the engine applies over queued requests and prefill
/// chunk grants.  `Fcfs` (the default) is the historical behavior and is
/// bit-identical to pre-WFQ builds; `Wfq` orders by tenant pass value.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedMode {
    #[default]
    Fcfs,
    Wfq,
}

impl SchedMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "fcfs" => Ok(SchedMode::Fcfs),
            "wfq" => Ok(SchedMode::Wfq),
            other => Err(format!("unknown scheduler '{other}' (fcfs|wfq)")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SchedMode::Fcfs => "fcfs",
            SchedMode::Wfq => "wfq",
        }
    }
}

/// Pass-value resolution: tokens are charged as `tokens * SCALE / weight`
/// so integer division loses at most 1/SCALE of a token per charge.
pub const WFQ_SCALE: u64 = 1 << 16;

/// Stride-scheduling state for weighted-fair queueing over tenants.
///
/// Each tenant carries a monotone *pass* value; serving `t` tokens to a
/// tenant of weight `w` advances its pass by `t * SCALE / w`, so at equal
/// backlog a weight-2 tenant receives twice the tokens of a weight-1
/// tenant.  Ordering queued work by `(pass, arrival)` is all the engine
/// does — the state itself never blocks anyone, which is what makes the
/// policy starvation-free: a backlogged tenant's pass is frozen while
/// everyone ahead of it keeps advancing.
///
/// A tenant that was idle has its pass clamped up to the scheduler's
/// virtual time (the pass of the last tenant served) on re-arrival, so
/// idling never banks credit for a later burst.
#[derive(Debug, Default)]
pub struct WfqState {
    weights: HashMap<String, u32>,
    pass: HashMap<String, u64>,
    /// virtual time: the pass value of the most recently served tenant
    vt: u64,
}

impl WfqState {
    pub fn new(weights: HashMap<String, u32>) -> Self {
        WfqState { weights, pass: HashMap::new(), vt: 0 }
    }

    /// A tenant's weight (default 1; a configured 0 is treated as 1).
    pub fn weight(&self, tenant: &str) -> u32 {
        self.weights.get(tenant).copied().unwrap_or(1).max(1)
    }

    /// The tenant's current pass value, clamped up to virtual time
    /// (re-arriving idle tenants start "now", not in the past).
    pub fn pass_of(&mut self, tenant: &str) -> u64 {
        let vt = self.vt;
        let p = self.pass.entry(tenant.to_string()).or_insert(vt);
        if *p < vt {
            *p = vt;
        }
        *p
    }

    /// Charge `tokens` of service to a tenant and advance virtual time
    /// to its (pre-charge) pass — it was just served, so "now" is at
    /// least its place in line.
    pub fn charge(&mut self, tenant: &str, tokens: usize) {
        let w = self.weight(tenant) as u64;
        let p = self.pass_of(tenant);
        self.vt = self.vt.max(p);
        let stride = (tokens as u64).saturating_mul(WFQ_SCALE) / w;
        self.pass.insert(tenant.to_string(), p.saturating_add(stride));
    }

    /// Stable-reorder `items` by their tenant's pass value.  Stability
    /// keeps same-tenant (and same-pass) items in FCFS order, so the
    /// ordering degrades to exactly FCFS when every item shares one
    /// tenant.
    pub fn reorder<T>(&mut self, items: &mut [T], tenant_of: impl Fn(&T) -> &str) {
        if items.len() < 2 {
            return;
        }
        let keys: Vec<u64> = items.iter().map(|it| self.pass_of(tenant_of(it))).collect();
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by_key(|&i| (keys[i], i));
        // apply the permutation by cycle-walking (no T: Clone required)
        for i in 0..order.len() {
            while order[i] != i {
                let j = order[i];
                items.swap(i, j);
                order.swap(i, j);
            }
        }
    }
}

/// Preemptive-eviction victim selection: when the page pool is exhausted
/// mid-decode, the YOUNGEST decoding sequence is preempted — it has the
/// least sunk compute to recompute and the oldest sequences keep their
/// latency SLO.  `decoding` carries any monotone arrival key (the engine
/// passes arrival `Instant`s); ties break toward the larger id, i.e.
/// the later admission.  Returns `None` when nothing is decoding.
pub fn pick_preemption_victim<K: Ord + Copy>(decoding: &[(u64, K)]) -> Option<u64> {
    decoding.iter().max_by_key(|&&(id, k)| (k, id)).map(|&(id, _)| id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_is_youngest_with_id_tiebreak() {
        assert_eq!(pick_preemption_victim::<u32>(&[]), None);
        assert_eq!(pick_preemption_victim(&[(7, 10u32)]), Some(7));
        assert_eq!(pick_preemption_victim(&[(1, 5u32), (2, 9), (3, 7)]), Some(2));
        // equal arrival keys: the higher id (later admission) goes
        assert_eq!(pick_preemption_victim(&[(4, 1u32), (9, 1)]), Some(9));
    }

    #[test]
    fn admits_up_to_limit() {
        let p = SchedulerPolicy { prefill_per_step: 2, max_running: 4 };
        assert_eq!(p.plan(5, 0), StepPlan { admit: 2, decode: true });
        assert_eq!(p.plan(1, 0), StepPlan { admit: 1, decode: true });
    }

    #[test]
    fn respects_running_cap() {
        let p = SchedulerPolicy { prefill_per_step: 4, max_running: 4 };
        assert_eq!(p.plan(5, 3).admit, 1);
        assert_eq!(p.plan(5, 4).admit, 0);
    }

    #[test]
    fn idle_engine_does_nothing() {
        let p = SchedulerPolicy::default();
        assert_eq!(p.plan(0, 0), StepPlan { admit: 0, decode: false });
    }

    #[test]
    fn chunked_plan_bounds_concurrent_prefills() {
        let p = SchedulerPolicy { prefill_per_step: 2, max_running: 8 };
        // nothing prefilling: admit up to the bound (the admitted prompt
        // may finish prefill and decode this very step)
        assert_eq!(p.plan_chunked(5, 0, 0), StepPlan { admit: 2, decode: true });
        // one mid-prefill: only one more slot
        assert_eq!(p.plan_chunked(5, 1, 3), StepPlan { admit: 1, decode: true });
        // saturated prefill lane: no admissions, decode continues
        assert_eq!(p.plan_chunked(5, 2, 3), StepPlan { admit: 0, decode: true });
        // fully idle: nothing to do
        assert_eq!(p.plan_chunked(0, 0, 0), StepPlan { admit: 0, decode: false });
        // running cap still applies
        let tight = SchedulerPolicy { prefill_per_step: 4, max_running: 4 };
        assert_eq!(tight.plan_chunked(9, 1, 3).admit, 0);
    }

    #[test]
    fn sched_mode_parses_strictly() {
        assert_eq!(SchedMode::parse("fcfs").unwrap(), SchedMode::Fcfs);
        assert_eq!(SchedMode::parse("wfq").unwrap(), SchedMode::Wfq);
        assert!(SchedMode::parse("priority").is_err());
        assert_eq!(SchedMode::default(), SchedMode::Fcfs);
        assert_eq!(SchedMode::Wfq.as_str(), "wfq");
    }

    #[test]
    fn wfq_single_tenant_is_fcfs() {
        let mut w = WfqState::new(HashMap::new());
        let mut items = vec![(1, "default"), (2, "default"), (3, "default")];
        w.reorder(&mut items, |it| it.1);
        assert_eq!(items.iter().map(|i| i.0).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn wfq_charged_tenant_yields_the_front() {
        let mut w = WfqState::new(HashMap::new());
        // "noisy" has already consumed service; "quiet" has not
        w.charge("noisy", 100);
        let mut items = vec![(1, "noisy"), (2, "noisy"), (3, "quiet")];
        w.reorder(&mut items, |it| it.1);
        assert_eq!(items[0], (3, "quiet"));
        // same-tenant relative order is preserved
        assert_eq!(items[1], (1, "noisy"));
        assert_eq!(items[2], (2, "noisy"));
    }

    #[test]
    fn wfq_weights_scale_service_share() {
        let mut w = WfqState::new(HashMap::from([("heavy".to_string(), 2u32)]));
        // after equal token charges, the weight-2 tenant has the smaller
        // pass -> it sorts first and receives ~2x the service over time
        w.charge("heavy", 64);
        w.charge("light", 64);
        let mut items = vec![(1, "light"), (2, "heavy")];
        w.reorder(&mut items, |it| it.1);
        assert_eq!(items[0], (2, "heavy"));
    }

    #[test]
    fn wfq_idle_tenant_banks_no_credit() {
        let mut w = WfqState::new(HashMap::new());
        // a busy tenant advances virtual time far ahead
        for _ in 0..50 {
            w.charge("busy", 64);
        }
        let busy_pass = w.pass_of("busy");
        // a tenant arriving NOW starts at virtual time, not at 0 — its
        // first scheduling advantage is one charge, not fifty
        let fresh = w.pass_of("fresh");
        assert!(busy_pass >= fresh);
        assert!(fresh + 64 * WFQ_SCALE >= busy_pass, "fresh {fresh} busy {busy_pass}");
    }
}
