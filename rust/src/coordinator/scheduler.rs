//! Prefill/decode interleaving policy.
//!
//! vLLM-style "decode-priority with prefill admission": each engine step
//! first admits up to `prefill_per_step` queued requests (prefill is the
//! long pole; bounding it caps decode stall), then runs one decode
//! iteration over every running sequence.  The policy is a pure function
//! of queue state so it is unit-testable without an engine.

#[derive(Clone, Copy, Debug)]
pub struct SchedulerPolicy {
    /// max prefills admitted per engine step
    pub prefill_per_step: usize,
    /// max sequences decoding concurrently
    pub max_running: usize,
}

impl Default for SchedulerPolicy {
    fn default() -> Self {
        SchedulerPolicy { prefill_per_step: 2, max_running: 32 }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepPlan {
    /// how many queued requests to prefill this step
    pub admit: usize,
    /// whether to run a decode iteration
    pub decode: bool,
}

impl SchedulerPolicy {
    pub fn plan(&self, queued: usize, running: usize) -> StepPlan {
        let slots = self.max_running.saturating_sub(running);
        let admit = queued.min(self.prefill_per_step).min(slots);
        StepPlan { admit, decode: running > 0 || admit > 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_limit() {
        let p = SchedulerPolicy { prefill_per_step: 2, max_running: 4 };
        assert_eq!(p.plan(5, 0), StepPlan { admit: 2, decode: true });
        assert_eq!(p.plan(1, 0), StepPlan { admit: 1, decode: true });
    }

    #[test]
    fn respects_running_cap() {
        let p = SchedulerPolicy { prefill_per_step: 4, max_running: 4 };
        assert_eq!(p.plan(5, 3).admit, 1);
        assert_eq!(p.plan(5, 4).admit, 0);
    }

    #[test]
    fn idle_engine_does_nothing() {
        let p = SchedulerPolicy::default();
        assert_eq!(p.plan(0, 0), StepPlan { admit: 0, decode: false });
    }
}
