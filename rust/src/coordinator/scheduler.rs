//! Prefill/decode interleaving policy.
//!
//! vLLM-style "decode-priority with prefill admission": each engine step
//! first admits up to `prefill_per_step` queued requests (prefill is the
//! long pole; bounding it caps decode stall), then runs one decode
//! iteration over every running sequence.  The policy is a pure function
//! of queue state so it is unit-testable without an engine.

#[derive(Clone, Copy, Debug)]
pub struct SchedulerPolicy {
    /// max prefills admitted per engine step
    pub prefill_per_step: usize,
    /// max sequences decoding concurrently
    pub max_running: usize,
}

impl Default for SchedulerPolicy {
    fn default() -> Self {
        SchedulerPolicy { prefill_per_step: 2, max_running: 32 }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepPlan {
    /// how many queued requests to prefill this step
    pub admit: usize,
    /// whether to run a decode iteration
    pub decode: bool,
}

impl SchedulerPolicy {
    pub fn plan(&self, queued: usize, running: usize) -> StepPlan {
        let slots = self.max_running.saturating_sub(running);
        let admit = queued.min(self.prefill_per_step).min(slots);
        StepPlan { admit, decode: running > 0 || admit > 0 }
    }

    /// Plan for the chunked-prefill engine, where admitted requests stay
    /// in `Prefilling` across several steps.  `prefill_per_step` bounds
    /// the number of CONCURRENTLY prefilling sequences rather than
    /// admissions per step: admitting more while others are mid-prefill
    /// only multiplies half-filled caches without finishing anyone's
    /// prompt sooner (the chunk quota is FCFS).
    pub fn plan_chunked(&self, queued: usize, prefilling: usize, decoding: usize) -> StepPlan {
        let running = prefilling + decoding;
        let slots = self.max_running.saturating_sub(running);
        let admit = queued
            .min(self.prefill_per_step.saturating_sub(prefilling))
            .min(slots);
        // decode MAY run: something is already decoding, or this step's
        // prefill work (running or newly admitted) can finish a prompt
        // and decode it in the same iteration — the engine refines this
        // against actual request states after the chunk phase
        StepPlan { admit, decode: decoding > 0 || prefilling > 0 || admit > 0 }
    }
}

/// Preemptive-eviction victim selection: when the page pool is exhausted
/// mid-decode, the YOUNGEST decoding sequence is preempted — it has the
/// least sunk compute to recompute and the oldest sequences keep their
/// latency SLO.  `decoding` carries any monotone arrival key (the engine
/// passes arrival `Instant`s); ties break toward the larger id, i.e.
/// the later admission.  Returns `None` when nothing is decoding.
pub fn pick_preemption_victim<K: Ord + Copy>(decoding: &[(u64, K)]) -> Option<u64> {
    decoding.iter().max_by_key(|&&(id, k)| (k, id)).map(|&(id, _)| id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_is_youngest_with_id_tiebreak() {
        assert_eq!(pick_preemption_victim::<u32>(&[]), None);
        assert_eq!(pick_preemption_victim(&[(7, 10u32)]), Some(7));
        assert_eq!(pick_preemption_victim(&[(1, 5u32), (2, 9), (3, 7)]), Some(2));
        // equal arrival keys: the higher id (later admission) goes
        assert_eq!(pick_preemption_victim(&[(4, 1u32), (9, 1)]), Some(9));
    }

    #[test]
    fn admits_up_to_limit() {
        let p = SchedulerPolicy { prefill_per_step: 2, max_running: 4 };
        assert_eq!(p.plan(5, 0), StepPlan { admit: 2, decode: true });
        assert_eq!(p.plan(1, 0), StepPlan { admit: 1, decode: true });
    }

    #[test]
    fn respects_running_cap() {
        let p = SchedulerPolicy { prefill_per_step: 4, max_running: 4 };
        assert_eq!(p.plan(5, 3).admit, 1);
        assert_eq!(p.plan(5, 4).admit, 0);
    }

    #[test]
    fn idle_engine_does_nothing() {
        let p = SchedulerPolicy::default();
        assert_eq!(p.plan(0, 0), StepPlan { admit: 0, decode: false });
    }

    #[test]
    fn chunked_plan_bounds_concurrent_prefills() {
        let p = SchedulerPolicy { prefill_per_step: 2, max_running: 8 };
        // nothing prefilling: admit up to the bound (the admitted prompt
        // may finish prefill and decode this very step)
        assert_eq!(p.plan_chunked(5, 0, 0), StepPlan { admit: 2, decode: true });
        // one mid-prefill: only one more slot
        assert_eq!(p.plan_chunked(5, 1, 3), StepPlan { admit: 1, decode: true });
        // saturated prefill lane: no admissions, decode continues
        assert_eq!(p.plan_chunked(5, 2, 3), StepPlan { admit: 0, decode: true });
        // fully idle: nothing to do
        assert_eq!(p.plan_chunked(0, 0, 0), StepPlan { admit: 0, decode: false });
        // running cap still applies
        let tight = SchedulerPolicy { prefill_per_step: 4, max_running: 4 };
        assert_eq!(tight.plan_chunked(9, 1, 3).admit, 0);
    }
}
