//! Admission control: bound the waiting queue and respect the cache
//! manager's memory budget so the engine degrades by *rejecting* rather
//! than thrashing.

use crate::kvcache::CacheManager;

/// NOTE: the concurrency cap lives on
/// [`crate::coordinator::scheduler::SchedulerPolicy::max_running`] — the
/// scheduler owns it.  A `max_running` here too (as an early revision
/// had) is config drift waiting to happen.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// max requests waiting for prefill
    pub max_queue: usize,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy { max_queue: 256 }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitDecision {
    Admit,
    QueueFull,
    MemoryPressure,
    /// a prompt with no tokens can never produce logits to sample from
    EmptyPrompt,
    /// the session already has a turn in flight — turns are serialized
    /// because they mutate one shared KV chain
    SessionBusy,
    /// the request asked for options this engine cannot honor (e.g. a
    /// per-request SnapKV override on a chunked or PJRT engine)
    UnsupportedOptions,
}

impl AdmitDecision {
    /// Stable wire-format label for the rejection protocol (the server's
    /// `reason` field).
    pub fn reason(&self) -> &'static str {
        match self {
            AdmitDecision::Admit => "admit",
            AdmitDecision::QueueFull => "queue_full",
            AdmitDecision::MemoryPressure => "memory_pressure",
            AdmitDecision::EmptyPrompt => "empty_prompt",
            AdmitDecision::SessionBusy => "session_busy",
            AdmitDecision::UnsupportedOptions => "unsupported_options",
        }
    }
}

impl AdmissionPolicy {
    /// Decide whether a new request (prompt + expected generation) fits.
    pub fn admit(
        &self,
        queued: usize,
        cache: &CacheManager,
        prompt_tokens: usize,
        expected_tokens: usize,
    ) -> AdmitDecision {
        if prompt_tokens == 0 {
            return AdmitDecision::EmptyPrompt;
        }
        if queued >= self.max_queue {
            return AdmitDecision::QueueFull;
        }
        if !cache.admits(expected_tokens) {
            return AdmitDecision::MemoryPressure;
        }
        AdmitDecision::Admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheConfig;
    use crate::quant::polar::PolarSpec;

    fn cache(budget: usize) -> CacheManager {
        CacheManager::new(
            CacheConfig {
                n_layers: 2,
                n_kv_heads: 2,
                head_dim: 16,
                spec: PolarSpec::new(4, 4, 8),
                value_bits: None,
            },
            budget,
        )
    }

    #[test]
    fn queue_limit() {
        let p = AdmissionPolicy { max_queue: 2 };
        let c = cache(usize::MAX);
        assert_eq!(p.admit(1, &c, 4, 10), AdmitDecision::Admit);
        assert_eq!(p.admit(2, &c, 4, 10), AdmitDecision::QueueFull);
    }

    #[test]
    fn memory_limit() {
        let p = AdmissionPolicy::default();
        let c = cache(16); // tiny budget
        assert_eq!(p.admit(0, &c, 4, 4096), AdmitDecision::MemoryPressure);
    }

    #[test]
    fn empty_prompt_is_rejected_with_a_reason() {
        let p = AdmissionPolicy::default();
        let c = cache(usize::MAX);
        assert_eq!(p.admit(0, &c, 0, 16), AdmitDecision::EmptyPrompt);
        assert_eq!(AdmitDecision::EmptyPrompt.reason(), "empty_prompt");
        assert_eq!(AdmitDecision::QueueFull.reason(), "queue_full");
        assert_eq!(AdmitDecision::MemoryPressure.reason(), "memory_pressure");
    }
}
