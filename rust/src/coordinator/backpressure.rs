//! Admission control: bound the waiting queue, respect the cache
//! manager's memory budget, and rate-limit individual tenants so the
//! engine degrades by *rejecting* rather than thrashing.
//!
//! Every way a request can be refused — here, in the engine's session
//! logic, or by the per-tenant token buckets — is one variant of
//! [`RejectReason`], and its [`RejectReason::as_str`] label is the single
//! spelling used by the engine, the completion JSON, and the metrics.

use std::collections::HashMap;

use crate::kvcache::CacheManager;

/// NOTE: the concurrency cap lives on
/// [`crate::coordinator::scheduler::SchedulerPolicy::max_running`] — the
/// scheduler owns it.  A `max_running` here too (as an early revision
/// had) is config drift waiting to happen.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// max requests waiting for prefill
    pub max_queue: usize,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy { max_queue: 256 }
    }
}

/// Why a request was refused.  One enum, one wire label per variant —
/// the engine's `submit*` errors, `Completion::reason`, the v2
/// `rejected` event, and the per-tenant throttle all speak this type, so
/// a new rejection cause can never become an ad-hoc fourth string.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RejectReason {
    QueueFull,
    MemoryPressure,
    /// a prompt with no tokens can never produce logits to sample from
    EmptyPrompt,
    /// the session already has a turn in flight — turns are serialized
    /// because they mutate one shared KV chain
    SessionBusy,
    /// the request asked for options this engine cannot honor (e.g. a
    /// per-request SnapKV override on a chunked or PJRT engine)
    UnsupportedOptions,
    /// the tenant's token bucket is empty (`--tenant-rate`); retry later
    TenantThrottled,
}

impl RejectReason {
    /// Stable wire-format label for the rejection protocol (the server's
    /// `reason` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue_full",
            RejectReason::MemoryPressure => "memory_pressure",
            RejectReason::EmptyPrompt => "empty_prompt",
            RejectReason::SessionBusy => "session_busy",
            RejectReason::UnsupportedOptions => "unsupported_options",
            RejectReason::TenantThrottled => "tenant_throttled",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitDecision {
    Admit,
    Reject(RejectReason),
}

impl AdmitDecision {
    /// Stable wire-format label (kept for logging; rejection paths carry
    /// the typed [`RejectReason`] itself).
    pub fn reason(&self) -> &'static str {
        match self {
            AdmitDecision::Admit => "admit",
            AdmitDecision::Reject(r) => r.as_str(),
        }
    }
}

impl AdmissionPolicy {
    /// Decide whether a new request (prompt + expected generation) fits.
    pub fn admit(
        &self,
        queued: usize,
        cache: &CacheManager,
        prompt_tokens: usize,
        expected_tokens: usize,
    ) -> AdmitDecision {
        if prompt_tokens == 0 {
            return AdmitDecision::Reject(RejectReason::EmptyPrompt);
        }
        if queued >= self.max_queue {
            return AdmitDecision::Reject(RejectReason::QueueFull);
        }
        if !cache.admits(expected_tokens) {
            return AdmitDecision::Reject(RejectReason::MemoryPressure);
        }
        AdmitDecision::Admit
    }
}

/// Per-tenant token-bucket admission (`--tenant-rate R --tenant-burst B`):
/// each tenant's bucket refills at `rate` requests/s up to `burst`, and a
/// submission costs one token.  Buckets are lazily created full, so a
/// tenant's first `burst` requests always pass.  Time is caller-supplied
/// (seconds from any fixed origin) so the refill arithmetic is exactly
/// testable without sleeping.
#[derive(Debug)]
pub struct TenantBuckets {
    rate: f64,
    burst: f64,
    /// tenant -> (tokens available, last refill time in seconds)
    buckets: HashMap<String, (f64, f64)>,
}

impl TenantBuckets {
    pub fn new(rate: f64, burst: f64) -> Self {
        TenantBuckets { rate: rate.max(0.0), burst: burst.max(1.0), buckets: HashMap::new() }
    }

    /// Spend one token from `tenant`'s bucket at time `now_s`.  Returns
    /// false when the bucket is empty — the caller rejects the request
    /// with [`RejectReason::TenantThrottled`].
    pub fn try_admit(&mut self, tenant: &str, now_s: f64) -> bool {
        let burst = self.burst;
        let rate = self.rate;
        let b = match self.buckets.get_mut(tenant) {
            Some(b) => b,
            None => {
                self.buckets.insert(tenant.to_string(), (burst, now_s));
                self.buckets.get_mut(tenant).unwrap()
            }
        };
        let dt = (now_s - b.1).max(0.0);
        b.0 = (b.0 + dt * rate).min(burst);
        b.1 = now_s;
        if b.0 >= 1.0 {
            b.0 -= 1.0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheConfig;
    use crate::quant::polar::PolarSpec;

    fn cache(budget: usize) -> CacheManager {
        CacheManager::new(
            CacheConfig {
                n_layers: 2,
                n_kv_heads: 2,
                head_dim: 16,
                spec: PolarSpec::new(4, 4, 8),
                value_bits: None,
            },
            budget,
        )
    }

    #[test]
    fn queue_limit() {
        let p = AdmissionPolicy { max_queue: 2 };
        let c = cache(usize::MAX);
        assert_eq!(p.admit(1, &c, 4, 10), AdmitDecision::Admit);
        assert_eq!(p.admit(2, &c, 4, 10), AdmitDecision::Reject(RejectReason::QueueFull));
    }

    #[test]
    fn memory_limit() {
        let p = AdmissionPolicy::default();
        let c = cache(16); // tiny budget
        assert_eq!(p.admit(0, &c, 4, 4096), AdmitDecision::Reject(RejectReason::MemoryPressure));
    }

    #[test]
    fn reject_reason_wire_labels_are_stable() {
        let p = AdmissionPolicy::default();
        let c = cache(usize::MAX);
        assert_eq!(p.admit(0, &c, 0, 16), AdmitDecision::Reject(RejectReason::EmptyPrompt));
        assert_eq!(RejectReason::EmptyPrompt.as_str(), "empty_prompt");
        assert_eq!(RejectReason::QueueFull.as_str(), "queue_full");
        assert_eq!(RejectReason::MemoryPressure.as_str(), "memory_pressure");
        assert_eq!(RejectReason::SessionBusy.as_str(), "session_busy");
        assert_eq!(RejectReason::UnsupportedOptions.as_str(), "unsupported_options");
        assert_eq!(RejectReason::TenantThrottled.as_str(), "tenant_throttled");
        assert_eq!(AdmitDecision::Reject(RejectReason::QueueFull).reason(), "queue_full");
        assert_eq!(AdmitDecision::Admit.reason(), "admit");
    }

    #[test]
    fn token_bucket_throttles_and_refills() {
        let mut b = TenantBuckets::new(1.0, 2.0); // 1 req/s, burst 2
        // the first `burst` requests pass, the next is throttled
        assert!(b.try_admit("a", 0.0));
        assert!(b.try_admit("a", 0.0));
        assert!(!b.try_admit("a", 0.0));
        // refill is proportional to elapsed time...
        assert!(b.try_admit("a", 1.0));
        assert!(!b.try_admit("a", 1.0));
        // ...and caps at burst no matter how long the tenant was idle
        assert!(b.try_admit("a", 1000.0));
        assert!(b.try_admit("a", 1000.0));
        assert!(!b.try_admit("a", 1000.0));
        // buckets are per tenant — one tenant's flood never drains another's
        assert!(b.try_admit("b", 1000.0));
    }

    #[test]
    fn token_bucket_ignores_clock_skew() {
        let mut b = TenantBuckets::new(10.0, 1.0);
        assert!(b.try_admit("a", 5.0));
        // a non-monotone clock must not refill (negative dt clamps to 0)
        assert!(!b.try_admit("a", 4.0));
    }
}
