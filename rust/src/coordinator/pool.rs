//! Fixed decode worker pool: one engine step's running sequences fanned
//! out over `n` long-lived threads.
//!
//! Design goals (ISSUE 1 tentpole):
//!
//! * **Fixed pool, no per-step allocation.**  Threads are spawned once at
//!   engine construction.  The per-worker task and result `Vec`s round-trip
//!   through the worker on every step, so their capacity is reused; the
//!   only per-task cost is an `Arc` refcount bump on the cache handle.
//! * **Thread-local scratch.**  Each worker owns a [`Model::fork`] — the
//!   weights are shared behind one `Arc`, the `QkLut`, score and
//!   activation buffers are private — so the LUT hot loop never shares a
//!   cache line between workers.  The fork also carries the engine's
//!   resolved [`crate::quant::ScoreKernel`] (`--kernel`), so every
//!   worker scores through the same scalar/SIMD backend as the inline
//!   path — kernels are bit-identical, so worker count remains
//!   invisible in the output.
//! * **Shard-safe cache access.**  Tasks carry [`SharedSeq`] handles.  The
//!   scheduler assigns disjoint shards ([`super::batcher::plan_decode_shards`]),
//!   so each per-sequence mutex is uncontended in the steady state.
//!
//! Determinism: every task carries its request's PER-TOKEN derived RNG
//! ([`crate::model::sampling::token_rng`] of the request seed and token
//! index), so sampled rollouts — greedy and stochastic alike — are
//! bit-identical to the inline path regardless of worker count or shard
//! assignment.  Workers hold no RNG state of their own.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::kvcache::SharedSeq;
use crate::model::sampling::Sampler;
use crate::model::Model;
use crate::util::rng::Rng;

/// One sequence's slice of a decode step.
pub struct DecodeTask {
    pub id: u64,
    pub cache: SharedSeq,
    pub last_token: u32,
    pub sampler: Sampler,
    /// per-token RNG for THIS sample, derived by the engine from the
    /// request's seed and token index — worker-assignment-independent
    pub rng: Rng,
    /// compute the token's full-softmax logprob (the request has a
    /// streaming subscriber); off = two fewer O(vocab) passes per step
    pub want_logprob: bool,
    /// Preemption-recovery replay: the fed token is already known (it was
    /// generated before the sequence lost its pages), so the step only
    /// rebuilds cache state — the logits are discarded, nothing is
    /// sampled, and no RNG is consumed.
    pub replay: bool,
}

/// One sampled token, keyed back to its request.
#[derive(Clone, Copy, Debug)]
pub struct StepResult {
    pub id: u64,
    pub token: u32,
    /// full-softmax logprob of `token` (streaming `Event::Token` payload)
    pub logprob: f32,
    /// true for replay steps: `token` is meaningless and must not be
    /// appended to the request's generation
    pub replay: bool,
}

enum Msg {
    Step { tasks: Vec<DecodeTask>, results: Vec<StepResult> },
    Shutdown,
}

struct Worker {
    tx: Sender<Msg>,
    rx: Receiver<(Vec<StepResult>, Vec<DecodeTask>)>,
    join: Option<JoinHandle<()>>,
    /// tasks staged for the next step (recycled capacity)
    pending: Vec<DecodeTask>,
    /// empty result buffer awaiting the next step (recycled capacity)
    spare_results: Vec<StepResult>,
    inflight: bool,
}

pub struct DecodePool {
    workers: Vec<Worker>,
}

impl DecodePool {
    /// Spawn `n` workers, each owning a fork of `model` (shared weights,
    /// private scratch).
    pub fn new(model: &Model, n: usize) -> Self {
        assert!(n > 0);
        let workers = (0..n)
            .map(|_| {
                let (tx, job_rx) = channel::<Msg>();
                let (result_tx, rx) = channel();
                let mut m = model.fork();
                let join = std::thread::spawn(move || loop {
                    match job_rx.recv() {
                        Ok(Msg::Step { mut tasks, mut results }) => {
                            results.clear();
                            for t in tasks.drain(..) {
                                // uncontended: this worker is the only one
                                // assigned this sequence for the step
                                let mut cache = t.cache.lock().unwrap();
                                let logits = m.decode_step(t.last_token, &mut cache);
                                let (token, logprob) = if t.replay {
                                    (0, 0.0) // state-rebuild; logits discarded
                                } else {
                                    let mut rng = t.rng;
                                    if t.want_logprob {
                                        t.sampler.sample_with_logprob(logits, &mut rng)
                                    } else {
                                        (t.sampler.sample(logits, &mut rng), 0.0)
                                    }
                                };
                                results.push(StepResult {
                                    id: t.id,
                                    token,
                                    logprob,
                                    replay: t.replay,
                                });
                            }
                            if result_tx.send((results, tasks)).is_err() {
                                return;
                            }
                        }
                        Ok(Msg::Shutdown) | Err(_) => return,
                    }
                });
                Worker {
                    tx,
                    rx,
                    join: Some(join),
                    pending: Vec::new(),
                    spare_results: Vec::new(),
                    inflight: false,
                }
            })
            .collect();
        DecodePool { workers }
    }

    pub fn width(&self) -> usize {
        self.workers.len()
    }

    /// Stage a task on worker `shard` for the next [`DecodePool::flush`].
    pub fn submit(&mut self, shard: usize, task: DecodeTask) {
        self.workers[shard % self.workers.len()].pending.push(task);
    }

    /// Run one step: fan staged shards out, then gather every sampled
    /// token into `out`.  Buffers are recycled; steady state allocates
    /// nothing.
    pub fn flush(&mut self, out: &mut Vec<StepResult>) {
        for w in &mut self.workers {
            if w.pending.is_empty() {
                continue;
            }
            let tasks = std::mem::take(&mut w.pending);
            let results = std::mem::take(&mut w.spare_results);
            w.tx.send(Msg::Step { tasks, results }).expect("decode worker died");
            w.inflight = true;
        }
        for w in &mut self.workers {
            if !w.inflight {
                continue;
            }
            let (mut results, tasks) = w.rx.recv().expect("decode worker died");
            out.extend(results.iter().copied());
            results.clear();
            w.spare_results = results;
            w.pending = tasks;
            w.inflight = false;
        }
    }
}

impl Drop for DecodePool {
    fn drop(&mut self) {
        for w in &mut self.workers {
            let _ = w.tx.send(Msg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SequenceCache;
    use crate::model::{ModelConfig, Weights};
    use std::sync::{Arc, Mutex};

    fn tiny_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::tiny();
        cfg.n_layers = 2;
        cfg.vocab = 64;
        cfg.d_model = 32;
        cfg.n_heads = 4;
        cfg.n_kv_heads = 2;
        cfg.head_dim = 16;
        cfg.ffn = 48;
        cfg.group = 8;
        cfg.resid = 16;
        cfg
    }

    #[test]
    fn pool_decodes_matching_inline_model() {
        let cfg = tiny_cfg();
        let w = Weights::synthetic(&cfg, 11, 4.0);
        let mut model = Model::new(cfg.clone(), w);

        // three prefilled sequences with different prompts
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![9, 8, 7, 6, 5], vec![4; 10]];
        let mut caches: Vec<SharedSeq> = Vec::new();
        let mut inline_tokens = Vec::new();
        for p in &prompts {
            let mut c = SequenceCache::new(cfg.cache_config(None));
            model.prefill(p, &mut c);
            // inline reference: one greedy step on a cloned cache
            let mut c_ref = c.clone();
            let logits = model.decode_step(3, &mut c_ref).to_vec();
            inline_tokens.push(crate::tensor::ops::argmax(&logits) as u32);
            caches.push(Arc::new(Mutex::new(c)));
        }

        let mut pool = DecodePool::new(&model, 2);
        for (i, c) in caches.iter().enumerate() {
            pool.submit(
                i,
                DecodeTask {
                    id: i as u64,
                    cache: c.clone(),
                    last_token: 3,
                    sampler: Sampler::Greedy,
                    rng: Rng::new(0),
                    want_logprob: false,
                    replay: false,
                },
            );
        }
        let mut out = Vec::new();
        pool.flush(&mut out);
        assert_eq!(out.len(), 3);
        out.sort_by_key(|r| r.id);
        for (r, want) in out.iter().zip(&inline_tokens) {
            assert_eq!(r.token, *want, "seq {}", r.id);
        }
        // the step advanced every cache
        for (c, p) in caches.iter().zip(&prompts) {
            assert_eq!(c.lock().unwrap().len(), p.len() + 1);
        }
    }

    #[test]
    fn flush_reuses_buffers_across_steps() {
        let cfg = tiny_cfg();
        let mut model = Model::new(cfg.clone(), Weights::synthetic(&cfg, 12, 4.0));
        let cache: SharedSeq = Arc::new(Mutex::new(SequenceCache::new(cfg.cache_config(None))));
        model.prefill(&[1, 2, 3], &mut cache.lock().unwrap());
        let mut pool = DecodePool::new(&model, 1);
        let mut out = Vec::new();
        for step in 0..4 {
            pool.submit(
                0,
                DecodeTask {
                    id: 1,
                    cache: cache.clone(),
                    last_token: 2,
                    sampler: Sampler::Greedy,
                    rng: Rng::new(0),
                    want_logprob: false,
                    replay: false,
                },
            );
            out.clear();
            pool.flush(&mut out);
            assert_eq!(out.len(), 1, "step {step}");
        }
        assert_eq!(cache.lock().unwrap().len(), 3 + 4);
    }

    #[test]
    fn forked_workers_inherit_the_engine_kernel() {
        use crate::quant::{select_kernel, KernelKind};
        let cfg = tiny_cfg();
        let mut model = Model::new(cfg.clone(), Weights::synthetic(&cfg, 13, 4.0));
        model.set_kernel(select_kernel(KernelKind::Scalar).unwrap());
        assert_eq!(model.kernel_name(), "scalar");
        assert_eq!(model.fork().kernel_name(), "scalar", "fork preserves the kernel");
        // the auto default also survives forking
        let auto = Model::new(cfg.clone(), Weights::synthetic(&cfg, 13, 4.0));
        assert_eq!(auto.fork().kernel_name(), auto.kernel_name());
    }
}
