//! Fixed decode worker pool: one engine step's running sequences fanned
//! out over `n` long-lived threads.
//!
//! Design goals (ISSUE 1 tentpole):
//!
//! * **Fixed pool, no per-step allocation.**  Threads are spawned once at
//!   engine construction.  The per-worker task and result `Vec`s round-trip
//!   through the worker on every step, so their capacity is reused; the
//!   only per-task cost is an `Arc` refcount bump on the cache handle.
//! * **Thread-local scratch.**  Each worker owns a [`Model::fork`] — the
//!   weights are shared behind one `Arc`, the `QkLut`, score and
//!   activation buffers are private — so the LUT hot loop never shares a
//!   cache line between workers.  The fork also carries the engine's
//!   resolved [`crate::quant::ScoreKernel`] (`--kernel`), so every
//!   worker scores through the same scalar/SIMD backend as the inline
//!   path — kernels are bit-identical, so worker count remains
//!   invisible in the output.
//! * **Shard-safe cache access.**  Tasks carry [`SharedSeq`] handles.  The
//!   scheduler assigns disjoint shards ([`super::batcher::plan_decode_shards`]),
//!   so each per-sequence mutex is uncontended in the steady state.
//!
//! Determinism: every task carries its request's PER-TOKEN derived RNG
//! ([`crate::model::sampling::token_rng`] of the request seed and token
//! index), so sampled rollouts — greedy and stochastic alike — are
//! bit-identical to the inline path regardless of worker count or shard
//! assignment.  Workers hold no RNG state of their own.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::kvcache::SharedSeq;
use crate::model::sampling::Sampler;
use crate::model::Model;
use crate::trace::TraceKind;
use crate::util::rng::Rng;

/// One sequence's slice of a decode step.
pub struct DecodeTask {
    pub id: u64,
    pub cache: SharedSeq,
    pub last_token: u32,
    pub sampler: Sampler,
    /// per-token RNG for THIS sample, derived by the engine from the
    /// request's seed and token index — worker-assignment-independent
    pub rng: Rng,
    /// compute the token's full-softmax logprob (the request has a
    /// streaming subscriber); off = two fewer O(vocab) passes per step
    pub want_logprob: bool,
    /// Preemption-recovery replay: the fed token is already known (it was
    /// generated before the sequence lost its pages), so the step only
    /// rebuilds cache state — the logits are discarded, nothing is
    /// sampled, and no RNG is consumed.
    pub replay: bool,
    /// Speculative draft length k (0 = plain decode).  The engine sets
    /// this only when the request is eligible: greedy sampler, no replay,
    /// draft plane configured on the model.
    pub speculate: usize,
    /// Remaining generation budget for this request — the speculative
    /// window never emits past it (keeps stop/budget clamping identical
    /// to sequential decode).
    pub max_emit: usize,
    /// Request stop tokens, for the in-window clamp.
    pub stops: Vec<u32>,
}

/// One decode burst, keyed back to its request: a single sampled token on
/// the plain path, up to `speculate + 1` on an accepted speculative window.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub id: u64,
    /// `(token, logprob)` in emission order; logprob is the full-softmax
    /// value when the task asked for it (streaming `Event::Token`
    /// payload), else 0.0.  Always non-empty for non-replay steps.
    pub tokens: Vec<(u32, f32)>,
    /// true for replay steps: `tokens` is meaningless and must not be
    /// appended to the request's generation
    pub replay: bool,
    /// draft tokens proposed this step (0 on the plain path)
    pub drafted: u32,
    /// draft tokens verification accepted (before stop/budget clamping)
    pub accepted: u32,
}

enum Msg {
    Step { tasks: Vec<DecodeTask>, results: Vec<StepResult> },
    Shutdown,
}

/// Record one `decode_step` span (the pooled decode path).  `t0` is
/// `Some` only when the worker's recorder is enabled, so a worker with
/// tracing off never reads the clock.
fn record_step(m: &Model, id: u64, pos: usize, t0: Option<std::time::Instant>) {
    if let (Some(t0), Some(tr)) = (t0, m.trace()) {
        tr.record(
            id,
            TraceKind::DecodeStep { pos: pos as u32, us: t0.elapsed().as_micros() as u32 },
        );
    }
}

struct Worker {
    tx: Sender<Msg>,
    rx: Receiver<(Vec<StepResult>, Vec<DecodeTask>)>,
    join: Option<JoinHandle<()>>,
    /// tasks staged for the next step (recycled capacity)
    pending: Vec<DecodeTask>,
    /// empty result buffer awaiting the next step (recycled capacity)
    spare_results: Vec<StepResult>,
    inflight: bool,
}

pub struct DecodePool {
    workers: Vec<Worker>,
}

impl DecodePool {
    /// Spawn `n` workers, each owning a fork of `model` (shared weights,
    /// private scratch).
    pub fn new(model: &Model, n: usize) -> Self {
        assert!(n > 0);
        let workers = (0..n)
            .map(|_| {
                let (tx, job_rx) = channel::<Msg>();
                let (result_tx, rx) = channel();
                let mut m = model.fork();
                // the fork carries the engine's recorder; a disabled (or
                // absent) recorder keeps this loop allocation- and
                // clock-free exactly as before
                let traced = m.trace().is_some_and(|tr| tr.enabled());
                let join = std::thread::spawn(move || loop {
                    match job_rx.recv() {
                        Ok(Msg::Step { mut tasks, mut results }) => {
                            results.clear();
                            for t in tasks.drain(..) {
                                // uncontended: this worker is the only one
                                // assigned this sequence for the step
                                let mut cache = t.cache.lock().unwrap();
                                m.set_trace_request(t.id);
                                let t0 = traced.then(std::time::Instant::now);
                                if t.speculate > 0
                                    && !t.replay
                                    && t.sampler == Sampler::Greedy
                                    && m.draft_spec().is_some()
                                {
                                    let out = m.speculative_decode(
                                        t.last_token,
                                        &mut cache,
                                        t.speculate,
                                        t.max_emit,
                                        &t.stops,
                                        t.want_logprob,
                                    );
                                    record_step(&m, t.id, cache.len(), t0);
                                    results.push(StepResult {
                                        id: t.id,
                                        tokens: out.tokens,
                                        replay: false,
                                        drafted: out.drafted,
                                        accepted: out.accepted,
                                    });
                                    continue;
                                }
                                let logits = m.decode_step(t.last_token, &mut cache);
                                let (token, logprob) = if t.replay {
                                    (0, 0.0) // state-rebuild; logits discarded
                                } else {
                                    let mut rng = t.rng;
                                    if t.want_logprob {
                                        t.sampler.sample_with_logprob(logits, &mut rng)
                                    } else {
                                        (t.sampler.sample(logits, &mut rng), 0.0)
                                    }
                                };
                                if !t.replay {
                                    // replay rebuilds state for a page-less
                                    // sequence; it is not a lifecycle step
                                    record_step(&m, t.id, cache.len(), t0);
                                }
                                results.push(StepResult {
                                    id: t.id,
                                    tokens: vec![(token, logprob)],
                                    replay: t.replay,
                                    drafted: 0,
                                    accepted: 0,
                                });
                            }
                            if result_tx.send((results, tasks)).is_err() {
                                return;
                            }
                        }
                        Ok(Msg::Shutdown) | Err(_) => return,
                    }
                });
                Worker {
                    tx,
                    rx,
                    join: Some(join),
                    pending: Vec::new(),
                    spare_results: Vec::new(),
                    inflight: false,
                }
            })
            .collect();
        DecodePool { workers }
    }

    pub fn width(&self) -> usize {
        self.workers.len()
    }

    /// Stage a task on worker `shard` for the next [`DecodePool::flush`].
    pub fn submit(&mut self, shard: usize, task: DecodeTask) {
        self.workers[shard % self.workers.len()].pending.push(task);
    }

    /// Run one step: fan staged shards out, then gather every sampled
    /// token into `out`.  Buffers are recycled; steady state allocates
    /// nothing.
    pub fn flush(&mut self, out: &mut Vec<StepResult>) {
        for w in &mut self.workers {
            if w.pending.is_empty() {
                continue;
            }
            let tasks = std::mem::take(&mut w.pending);
            let results = std::mem::take(&mut w.spare_results);
            w.tx.send(Msg::Step { tasks, results }).expect("decode worker died");
            w.inflight = true;
        }
        for w in &mut self.workers {
            if !w.inflight {
                continue;
            }
            let (mut results, tasks) = w.rx.recv().expect("decode worker died");
            out.extend(results.drain(..));
            w.spare_results = results;
            w.pending = tasks;
            w.inflight = false;
        }
    }
}

impl Drop for DecodePool {
    fn drop(&mut self) {
        for w in &mut self.workers {
            let _ = w.tx.send(Msg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SequenceCache;
    use crate::model::{ModelConfig, Weights};
    use std::sync::{Arc, Mutex};

    fn tiny_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::tiny();
        cfg.n_layers = 2;
        cfg.vocab = 64;
        cfg.d_model = 32;
        cfg.n_heads = 4;
        cfg.n_kv_heads = 2;
        cfg.head_dim = 16;
        cfg.ffn = 48;
        cfg.group = 8;
        cfg.resid = 16;
        cfg
    }

    #[test]
    fn pool_decodes_matching_inline_model() {
        let cfg = tiny_cfg();
        let w = Weights::synthetic(&cfg, 11, 4.0);
        let mut model = Model::new(cfg.clone(), w);

        // three prefilled sequences with different prompts
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![9, 8, 7, 6, 5], vec![4; 10]];
        let mut caches: Vec<SharedSeq> = Vec::new();
        let mut inline_tokens = Vec::new();
        for p in &prompts {
            let mut c = SequenceCache::new(cfg.cache_config(None));
            model.prefill(p, &mut c);
            // inline reference: one greedy step on a cloned cache
            let mut c_ref = c.clone();
            let logits = model.decode_step(3, &mut c_ref).to_vec();
            inline_tokens.push(crate::tensor::ops::argmax(&logits) as u32);
            caches.push(Arc::new(Mutex::new(c)));
        }

        let mut pool = DecodePool::new(&model, 2);
        for (i, c) in caches.iter().enumerate() {
            pool.submit(
                i,
                DecodeTask {
                    id: i as u64,
                    cache: c.clone(),
                    last_token: 3,
                    sampler: Sampler::Greedy,
                    rng: Rng::new(0),
                    want_logprob: false,
                    replay: false,
                    speculate: 0,
                    max_emit: 1,
                    stops: Vec::new(),
                },
            );
        }
        let mut out = Vec::new();
        pool.flush(&mut out);
        assert_eq!(out.len(), 3);
        out.sort_by_key(|r| r.id);
        for (r, want) in out.iter().zip(&inline_tokens) {
            assert_eq!(r.tokens, vec![(*want, 0.0)], "seq {}", r.id);
        }
        // the step advanced every cache
        for (c, p) in caches.iter().zip(&prompts) {
            assert_eq!(c.lock().unwrap().len(), p.len() + 1);
        }
    }

    #[test]
    fn flush_reuses_buffers_across_steps() {
        let cfg = tiny_cfg();
        let mut model = Model::new(cfg.clone(), Weights::synthetic(&cfg, 12, 4.0));
        let cache: SharedSeq = Arc::new(Mutex::new(SequenceCache::new(cfg.cache_config(None))));
        model.prefill(&[1, 2, 3], &mut cache.lock().unwrap());
        let mut pool = DecodePool::new(&model, 1);
        let mut out = Vec::new();
        for step in 0..4 {
            pool.submit(
                0,
                DecodeTask {
                    id: 1,
                    cache: cache.clone(),
                    last_token: 2,
                    sampler: Sampler::Greedy,
                    rng: Rng::new(0),
                    want_logprob: false,
                    replay: false,
                    speculate: 0,
                    max_emit: 1,
                    stops: Vec::new(),
                },
            );
            out.clear();
            pool.flush(&mut out);
            assert_eq!(out.len(), 1, "step {step}");
        }
        assert_eq!(cache.lock().unwrap().len(), 3 + 4);
    }

    #[test]
    fn speculative_task_bursts_match_inline_sequential_decode() {
        let cfg = tiny_cfg();
        let w = Weights::synthetic(&cfg, 14, 4.0);
        let mut model = Model::new(cfg.clone(), w);
        // exact-width draft: acceptance is deterministic, so the burst
        // shape is predictable; workers inherit the draft via fork()
        model.set_draft(crate::quant::DraftSpec::new(4, 4)).unwrap();
        let prompt: Vec<u32> = (0..20).map(|i| (i % cfg.vocab) as u32).collect();

        let mut c_ref = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&prompt, &mut c_ref);
        let mut want = Vec::new();
        let mut last = 3u32;
        for _ in 0..4 {
            let l = model.decode_step(last, &mut c_ref).to_vec();
            last = crate::tensor::ops::argmax(&l) as u32;
            want.push(last);
        }

        let mut c = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&prompt, &mut c);
        let cache: SharedSeq = Arc::new(Mutex::new(c));
        let mut pool = DecodePool::new(&model, 2);
        pool.submit(
            0,
            DecodeTask {
                id: 7,
                cache: cache.clone(),
                last_token: 3,
                sampler: Sampler::Greedy,
                rng: Rng::new(0),
                want_logprob: false,
                replay: false,
                speculate: 3,
                max_emit: 16,
                stops: Vec::new(),
            },
        );
        let mut out = Vec::new();
        pool.flush(&mut out);
        assert_eq!(out.len(), 1);
        let r = &out[0];
        assert_eq!(r.drafted, 3, "resid 4 in group 8 fits the full window");
        assert_eq!(r.accepted, 3, "exact-width draft always verifies");
        let got: Vec<u32> = r.tokens.iter().map(|(t, _)| *t).collect();
        assert_eq!(got, want, "burst must equal inline sequential decode");
        assert_eq!(cache.lock().unwrap().len(), 20 + 4);
    }

    #[test]
    fn pooled_workers_record_decode_and_speculative_spans() {
        use crate::trace::{TraceKind, TraceRecorder};
        let cfg = tiny_cfg();
        let mut model = Model::new(cfg.clone(), Weights::synthetic(&cfg, 15, 4.0));
        model.set_draft(crate::quant::DraftSpec::new(4, 4)).unwrap();
        let rec = Arc::new(TraceRecorder::new(true, 256));
        model.set_trace(rec.clone());
        let mut c = SequenceCache::new(cfg.cache_config(None));
        model.prefill(&[1, 2, 3, 4], &mut c);
        let cache: SharedSeq = Arc::new(Mutex::new(c));
        let mut pool = DecodePool::new(&model, 1);
        for (speculate, id) in [(0usize, 21u64), (3, 22)] {
            pool.submit(
                0,
                DecodeTask {
                    id,
                    cache: cache.clone(),
                    last_token: 3,
                    sampler: Sampler::Greedy,
                    rng: Rng::new(0),
                    want_logprob: false,
                    replay: false,
                    speculate,
                    max_emit: 8,
                    stops: Vec::new(),
                },
            );
            let mut out = Vec::new();
            pool.flush(&mut out);
            assert_eq!(out.len(), 1);
        }
        let events = rec.drain();
        let steps: Vec<u64> = events
            .iter()
            .filter(|e| matches!(e.kind, TraceKind::DecodeStep { .. }))
            .map(|e| e.request)
            .collect();
        assert_eq!(steps, vec![21, 22], "one decode_step span per task, keyed by request");
        assert!(
            events
                .iter()
                .any(|e| e.request == 22 && matches!(e.kind, TraceKind::SpeculativeRound { .. })),
            "the speculative task records its round: {events:?}"
        );
    }

    #[test]
    fn forked_workers_inherit_the_engine_kernel() {
        use crate::quant::{select_kernel, KernelKind};
        let cfg = tiny_cfg();
        let mut model = Model::new(cfg.clone(), Weights::synthetic(&cfg, 13, 4.0));
        model.set_kernel(select_kernel(KernelKind::Scalar).unwrap());
        assert_eq!(model.kernel_name(), "scalar");
        assert_eq!(model.fork().kernel_name(), "scalar", "fork preserves the kernel");
        // the auto default also survives forking
        let auto = Model::new(cfg.clone(), Weights::synthetic(&cfg, 13, 4.0));
        assert_eq!(auto.fork().kernel_name(), auto.kernel_name());
    }
}
