//! Request router: session-affinity + least-loaded assignment across
//! engine workers (the vllm-router pattern at miniature scale).

use std::collections::HashMap;

#[derive(Debug)]
pub struct Router {
    workers: usize,
    /// session -> worker (sticky so a conversation reuses its KV cache)
    sessions: HashMap<u64, usize>,
    /// outstanding requests per worker
    loads: Vec<usize>,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Router { workers, sessions: HashMap::new(), loads: vec![0; workers] }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Pick a worker: sticky per session, least-loaded otherwise.
    pub fn route(&mut self, session: Option<u64>) -> usize {
        let w = match session.and_then(|s| self.sessions.get(&s).copied()) {
            Some(w) => w,
            None => {
                let w = self
                    .loads
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &l)| l)
                    .map(|(i, _)| i)
                    .unwrap();
                if let Some(s) = session {
                    self.sessions.insert(s, w);
                }
                w
            }
        };
        self.loads[w] += 1;
        w
    }

    /// Mark a request finished on `worker`.
    pub fn complete(&mut self, worker: usize) {
        self.loads[worker] = self.loads[worker].saturating_sub(1);
    }

    /// Drop a session's affinity (conversation ended).
    pub fn end_session(&mut self, session: u64) {
        self.sessions.remove(&session);
    }

    pub fn load(&self, worker: usize) -> usize {
        self.loads[worker]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sessions_stick() {
        let mut r = Router::new(3);
        let w = r.route(Some(42));
        for _ in 0..5 {
            assert_eq!(r.route(Some(42)), w);
        }
    }

    #[test]
    fn anonymous_requests_balance() {
        let mut r = Router::new(2);
        let a = r.route(None);
        let b = r.route(None);
        assert_ne!(a, b, "second request must go to the idle worker");
    }

    #[test]
    fn completion_frees_load() {
        let mut r = Router::new(2);
        let a = r.route(None);
        let _b = r.route(None);
        r.complete(a);
        // worker a is now least-loaded again
        assert_eq!(r.route(None), a);
    }

    #[test]
    fn ended_session_can_move() {
        let mut r = Router::new(2);
        let w = r.route(Some(7));
        r.complete(w);
        r.end_session(7);
        // load the old worker so the session lands elsewhere
        r.loads[w] = 10;
        assert_ne!(r.route(Some(7)), w);
    }
}
