//! Request router: session-affinity + least-loaded assignment across
//! engine workers (the vllm-router pattern at miniature scale).

use std::collections::HashMap;

#[derive(Debug)]
pub struct Router {
    workers: usize,
    /// session -> worker (sticky so a conversation reuses its KV cache)
    sessions: HashMap<u64, usize>,
    /// outstanding requests per worker
    loads: Vec<usize>,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Router { workers, sessions: HashMap::new(), loads: vec![0; workers] }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Pick a worker: sticky per session, least-loaded otherwise.
    pub fn route(&mut self, session: Option<u64>) -> usize {
        let w = match session.and_then(|s| self.sessions.get(&s).copied()) {
            Some(w) => w,
            None => {
                let w = self
                    .loads
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &l)| l)
                    .map(|(i, _)| i)
                    .unwrap();
                if let Some(s) = session {
                    self.sessions.insert(s, w);
                }
                w
            }
        };
        self.loads[w] += 1;
        w
    }

    /// Place a request on a worker chosen by an EXTERNAL policy (the
    /// multi-node front tier's consistent-hash ring), keeping this
    /// router's load/affinity books straight: the sticky mapping is
    /// recorded for `session` and the load increments like `route()`.
    /// Pairs with exactly one `complete()`, same as `route()`.
    pub fn route_to(&mut self, session: Option<u64>, worker: usize) -> usize {
        assert!(worker < self.workers);
        if let Some(s) = session {
            self.sessions.insert(s, worker);
        }
        self.loads[worker] += 1;
        worker
    }

    /// Mark a request finished on `worker`.  Every `route()` must be
    /// paired with EXACTLY ONE `complete()` — the serve path calls it
    /// from the single place each request terminates (the event
    /// forwarder's terminal frame, or the one-shot reply write), so a
    /// rejected, cancelled, or client-abandoned request still decrements
    /// once and only once.
    pub fn complete(&mut self, worker: usize) {
        self.loads[worker] = self.loads[worker].saturating_sub(1);
    }

    /// The worker a session is stuck to, if any (the serve path uses
    /// this to address session close frames without re-routing).
    pub fn session_worker(&self, session: u64) -> Option<usize> {
        self.sessions.get(&session).copied()
    }

    /// Drop a session's affinity (conversation ended).
    pub fn end_session(&mut self, session: u64) {
        self.sessions.remove(&session);
    }

    pub fn load(&self, worker: usize) -> usize {
        self.loads[worker]
    }

    /// Outstanding requests across all workers (tests/observability).
    pub fn total_load(&self) -> usize {
        self.loads.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sessions_stick() {
        let mut r = Router::new(3);
        let w = r.route(Some(42));
        for _ in 0..5 {
            assert_eq!(r.route(Some(42)), w);
        }
    }

    #[test]
    fn anonymous_requests_balance() {
        let mut r = Router::new(2);
        let a = r.route(None);
        let b = r.route(None);
        assert_ne!(a, b, "second request must go to the idle worker");
    }

    #[test]
    fn completion_frees_load() {
        let mut r = Router::new(2);
        let a = r.route(None);
        let _b = r.route(None);
        r.complete(a);
        // worker a is now least-loaded again
        assert_eq!(r.route(None), a);
    }

    #[test]
    fn load_accounting_is_exactly_once_per_request() {
        // N routes + N completes must return every worker to zero load —
        // no double decrement (saturating_sub would hide one) and no
        // leaked increment, across sticky and anonymous requests alike.
        let mut r = Router::new(3);
        let mut placed = Vec::new();
        for i in 0..12u64 {
            let session = (i % 3 == 0).then_some(i / 3);
            placed.push(r.route(session));
        }
        assert_eq!(r.total_load(), 12, "every route increments exactly once");
        for &w in &placed {
            r.complete(w);
        }
        assert_eq!(r.total_load(), 0, "every complete decrements exactly once");
        for w in 0..3 {
            assert_eq!(r.load(w), 0, "worker {w}");
        }
        // a stray double-complete must not underflow or skew future routing
        r.complete(0);
        assert_eq!(r.load(0), 0);
    }

    #[test]
    fn sticky_sessions_count_load_on_their_worker() {
        let mut r = Router::new(2);
        let w = r.route(Some(42));
        assert_eq!(r.session_worker(42), Some(w));
        // 3 more turns on the same session: all on w, load 4
        for _ in 0..3 {
            assert_eq!(r.route(Some(42)), w);
        }
        assert_eq!(r.load(w), 4);
        // anonymous traffic avoids the loaded worker
        assert_eq!(r.route(None), 1 - w);
        for _ in 0..4 {
            r.complete(w);
        }
        assert_eq!(r.load(w), 0);
        // stickiness survives completion until end_session
        assert_eq!(r.session_worker(42), Some(w));
        r.end_session(42);
        assert_eq!(r.session_worker(42), None);
    }

    #[test]
    fn route_to_records_affinity_and_load() {
        let mut r = Router::new(3);
        // an external policy pins session 9 to worker 2
        assert_eq!(r.route_to(Some(9), 2), 2);
        assert_eq!(r.session_worker(9), Some(2));
        assert_eq!(r.load(2), 1);
        // subsequent plain routes honor the recorded affinity
        assert_eq!(r.route(Some(9)), 2);
        r.complete(2);
        r.complete(2);
        assert_eq!(r.total_load(), 0);
        // anonymous external placement just counts load
        assert_eq!(r.route_to(None, 0), 0);
        assert_eq!(r.load(0), 1);
    }

    #[test]
    fn ended_session_can_move() {
        let mut r = Router::new(2);
        let w = r.route(Some(7));
        r.complete(w);
        r.end_session(7);
        // load the old worker so the session lands elsewhere
        r.loads[w] = 10;
        assert_ne!(r.route(Some(7)), w);
    }
}
