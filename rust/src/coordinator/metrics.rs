//! Serving metrics: the numbers behind Table 4 (throughput, latency,
//! memory) and the engine's own health counters — plus the per-tenant
//! breakdown multi-tenant deployments read from the admin protocol.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::stats::LatencyHist;

/// One tenant's slice of the serving counters.  Created lazily on the
/// tenant's first request; the map is ordered so `summary()` and the
/// admin reply list tenants deterministically.
#[derive(Debug, Default)]
pub struct TenantStats {
    pub admitted: u64,
    /// rejected with `tenant_throttled` (a subset of the engine-wide
    /// `requests_rejected`)
    pub throttled: u64,
    pub finished: u64,
    pub decode_tokens: u64,
    /// inter-token latency, per tenant — the number the WFQ acceptance
    /// bench compares against a solo baseline
    pub itl: LatencyHist,
}

#[derive(Debug)]
pub struct Metrics {
    pub started: Instant,
    pub requests_submitted: u64,
    pub requests_finished: u64,
    pub requests_rejected: u64,
    /// requests cancelled while queued or running (`Engine::cancel`)
    pub requests_cancelled: u64,
    /// session turns admitted (`Engine::submit_turn`)
    pub session_turns: u64,
    /// prompt tokens skipped because a session turn resumed the
    /// conversation's live KV chain (also counted in
    /// `prefix_tokens_reused`, which CI asserts on)
    pub session_tokens_reused: u64,
    pub prefill_tokens: u64,
    /// prefill chunks executed (chunked-prefill engines only)
    pub prefill_chunks: u64,
    /// wall-clock model time of one prefill chunk (whole-prompt prefill
    /// records its single chunk here too) — the number capacity planning
    /// reads to bound decode-stall from `--prefill-chunk` sizing
    pub prefill_chunk_us: LatencyHist,
    pub decode_tokens: u64,
    /// decode iterations: exactly one per engine step that decoded at
    /// least one token, on BOTH backends (the PJRT path used to count one
    /// per bucket batch, which skewed `mean_batch` across backends)
    pub decode_steps: u64,
    pub decode_batch_sum: u64,
    pub ttft: LatencyHist,
    /// inter-token latency: gap between consecutive token EMISSIONS of
    /// one request (measurable because the streaming engine emits tokens
    /// as they decode, not only at completion)
    pub itl: LatencyHist,
    pub per_token: LatencyHist,
    pub e2e: LatencyHist,
    pub queue_delay: LatencyHist,
    /// time decoding sequences spent stalled behind prefill-chunk work,
    /// recorded once per engine step that ran chunks while ≥1 sequence
    /// was decoding — the head-of-line blocking chunked prefill bounds
    pub decode_stall: LatencyHist,
    /// requests whose prompt attached to ≥1 already-pooled prefix page
    pub prefix_hits: u64,
    /// prompt tokens skipped at prefill because their pages were shared
    pub prefix_tokens_reused: u64,
    /// decoding sequences preempted (pages released, requeued to prefill)
    /// because the page pool was exhausted
    pub preemptions: u64,
    /// physical pages resident in the pool (gauge, synced per step)
    pub pages_in_use: u64,
    /// refcount-zero cached prefix pages reclaimed under pressure
    /// (gauge, synced per step from the pool's counter)
    pub pages_evicted: u64,
    /// prefix lookups that promoted ≥1 page from the disk tier
    /// (gauge, synced per step from the tier counters)
    pub tier_hits: u64,
    /// cached pages spilled to the disk tier instead of dropped
    pub pages_demoted: u64,
    /// pages read back from the tier and re-adopted on a prefix hit
    pub pages_promoted: u64,
    /// segment bytes held by the disk tier
    pub bytes_on_disk: u64,
    /// prompt tokens dropped by SnapKV compression before quantization
    pub snapkv_tokens_dropped: u64,
    /// requests rejected because a tenant's token bucket ran dry
    pub tenant_throttled: u64,
    /// idle session chains demoted to the disk tier (`--session-ttl`)
    pub sessions_reaped: u64,
    /// reaped session chains promoted back on the next turn
    pub sessions_restored: u64,
    /// segment bytes held by reaped session blobs — a slice of
    /// `bytes_on_disk`, charged against `--tier-bytes`
    /// (gauge, synced per step from the tier counters)
    pub tier_session_bytes: u64,
    /// decode iterations that ran a speculative window (`--speculate`;
    /// fallback single-token iterations don't count)
    pub speculative_rounds: u64,
    /// draft tokens proposed across all speculative windows
    pub speculative_drafted: u64,
    /// draft tokens the exact verification pass accepted
    pub speculative_accepted: u64,
    /// per-tenant breakdown (empty until a request names a tenant)
    pub tenants: BTreeMap<String, TenantStats>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            started: Instant::now(),
            requests_submitted: 0,
            requests_finished: 0,
            requests_rejected: 0,
            requests_cancelled: 0,
            session_turns: 0,
            session_tokens_reused: 0,
            prefill_tokens: 0,
            prefill_chunks: 0,
            prefill_chunk_us: LatencyHist::new(),
            decode_tokens: 0,
            decode_steps: 0,
            decode_batch_sum: 0,
            ttft: LatencyHist::new(),
            itl: LatencyHist::new(),
            per_token: LatencyHist::new(),
            e2e: LatencyHist::new(),
            queue_delay: LatencyHist::new(),
            decode_stall: LatencyHist::new(),
            prefix_hits: 0,
            prefix_tokens_reused: 0,
            preemptions: 0,
            pages_in_use: 0,
            pages_evicted: 0,
            tier_hits: 0,
            pages_demoted: 0,
            pages_promoted: 0,
            bytes_on_disk: 0,
            snapkv_tokens_dropped: 0,
            tenant_throttled: 0,
            sessions_reaped: 0,
            sessions_restored: 0,
            tier_session_bytes: 0,
            speculative_rounds: 0,
            speculative_drafted: 0,
            speculative_accepted: 0,
            tenants: BTreeMap::new(),
        }
    }

    /// The tenant's stats bucket, created on first touch.
    pub fn tenant(&mut self, name: &str) -> &mut TenantStats {
        if !self.tenants.contains_key(name) {
            self.tenants.insert(name.to_string(), TenantStats::default());
        }
        self.tenants.get_mut(name).expect("inserted above")
    }

    /// Generated tokens per second since start.
    pub fn decode_throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / secs
        }
    }

    /// Mean decode batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_batch_sum as f64 / self.decode_steps as f64
        }
    }

    /// Fraction of proposed draft tokens the exact verification accepted.
    pub fn speculative_acceptance(&self) -> f64 {
        if self.speculative_drafted == 0 {
            0.0
        } else {
            self.speculative_accepted as f64 / self.speculative_drafted as f64
        }
    }

    /// Mean accepted-run length per speculative round (tokens one
    /// verified window contributed beyond the plain decode step).
    pub fn speculative_run_length(&self) -> f64 {
        if self.speculative_rounds == 0 {
            0.0
        } else {
            self.speculative_accepted as f64 / self.speculative_rounds as f64
        }
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "reqs {}/{} (rej {}), prefill {} tok, decode {} tok @ {:.1} tok/s, \
             mean batch {:.2}, ttft p50/p95/p99 {:.1}/{:.1}/{:.1}ms, \
             itl p50/p95/p99 {:.2}/{:.2}/{:.2}ms, tok p50 {:.2}ms",
            self.requests_finished,
            self.requests_submitted,
            self.requests_rejected,
            self.prefill_tokens,
            self.decode_tokens,
            self.decode_throughput(),
            self.mean_batch(),
            self.ttft.p(50.0) * 1e3,
            self.ttft.p(95.0) * 1e3,
            self.ttft.p(99.0) * 1e3,
            self.itl.p(50.0) * 1e3,
            self.itl.p(95.0) * 1e3,
            self.itl.p(99.0) * 1e3,
            self.per_token.p(50.0) * 1e3,
        );
        if self.requests_cancelled > 0 {
            s.push_str(&format!(", cancelled {}", self.requests_cancelled));
        }
        if self.session_turns > 0 {
            s.push_str(&format!(
                ", session turns {} ({} tok resumed)",
                self.session_turns, self.session_tokens_reused,
            ));
        }
        if self.prefill_chunks > 0 {
            s.push_str(&format!(
                ", {} chunks (p50 {:.2}ms), decode stall p95 {:.2}ms",
                self.prefill_chunks,
                self.prefill_chunk_us.p(50.0) * 1e3,
                self.decode_stall.p(95.0) * 1e3,
            ));
        }
        if self.pages_in_use > 0 || self.pages_evicted > 0 || self.preemptions > 0 {
            s.push_str(&format!(
                ", pages {} (evicted {}), preempt {}",
                self.pages_in_use, self.pages_evicted, self.preemptions,
            ));
        }
        if self.prefix_hits > 0 {
            s.push_str(&format!(
                ", prefix hits {} ({} tok reused)",
                self.prefix_hits, self.prefix_tokens_reused,
            ));
        }
        if self.tier_hits > 0
            || self.pages_demoted > 0
            || self.pages_promoted > 0
            || self.bytes_on_disk > 0
        {
            s.push_str(&format!(
                ", tier hits {} (demoted {}, promoted {}, {} B on disk)",
                self.tier_hits, self.pages_demoted, self.pages_promoted, self.bytes_on_disk,
            ));
        }
        if self.snapkv_tokens_dropped > 0 {
            s.push_str(&format!(", snapkv dropped {} tok", self.snapkv_tokens_dropped));
        }
        if self.sessions_reaped > 0 || self.sessions_restored > 0 {
            s.push_str(&format!(
                ", sessions reaped {} (restored {})",
                self.sessions_reaped, self.sessions_restored,
            ));
            if self.tier_session_bytes > 0 {
                s.push_str(&format!(", {} session B on disk", self.tier_session_bytes));
            }
        }
        if self.speculative_rounds > 0 {
            s.push_str(&format!(
                ", speculative {} rounds ({}/{} accepted, run len {:.2})",
                self.speculative_rounds,
                self.speculative_accepted,
                self.speculative_drafted,
                self.speculative_run_length(),
            ));
        }
        // the per-tenant breakdown only appears once a SECOND tenant (or
        // a throttle) shows up: a single default tenant would repeat the
        // engine-wide numbers
        if self.tenants.len() > 1 || self.tenant_throttled > 0 {
            for (name, t) in &self.tenants {
                s.push_str(&format!(
                    "\n  tenant {name}: adm {} fin {} thr {} tok {} itl p50/p99 \
                     {:.2}/{:.2}ms",
                    t.admitted,
                    t.finished,
                    t.throttled,
                    t.decode_tokens,
                    t.itl.p(50.0) * 1e3,
                    t.itl.p(99.0) * 1e3,
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_mean() {
        let mut m = Metrics::new();
        m.decode_steps = 4;
        m.decode_batch_sum = 10;
        assert!((m.mean_batch() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn summary_is_printable() {
        let m = Metrics::new();
        assert!(m.summary().contains("reqs"));
        assert!(!m.summary().contains("prefix hits"), "quiet when unused");
    }

    #[test]
    fn summary_surfaces_paged_cache_counters() {
        let mut m = Metrics::new();
        m.pages_in_use = 12;
        m.pages_evicted = 3;
        m.preemptions = 1;
        m.prefix_hits = 5;
        m.prefix_tokens_reused = 640;
        let s = m.summary();
        assert!(s.contains("pages 12 (evicted 3)"), "{s}");
        assert!(s.contains("preempt 1"), "{s}");
        assert!(s.contains("prefix hits 5 (640 tok reused)"), "{s}");
        assert!(!s.contains("tier hits"), "tier line quiet when unused: {s}");
    }

    #[test]
    fn summary_surfaces_streaming_counters() {
        let mut m = Metrics::new();
        assert!(!m.summary().contains("cancelled"), "quiet when unused");
        assert!(!m.summary().contains("session turns"), "quiet when unused");
        m.requests_cancelled = 2;
        m.session_turns = 3;
        m.session_tokens_reused = 40;
        m.itl.record_secs(0.001);
        let s = m.summary();
        assert!(s.contains("cancelled 2"), "{s}");
        assert!(s.contains("session turns 3 (40 tok resumed)"), "{s}");
        assert!(s.contains("itl p50/p95/p99"), "{s}");
    }

    #[test]
    fn summary_surfaces_tier_and_snapkv_counters() {
        let mut m = Metrics::new();
        m.tier_hits = 4;
        m.pages_demoted = 9;
        m.pages_promoted = 6;
        m.bytes_on_disk = 12345;
        m.snapkv_tokens_dropped = 77;
        let s = m.summary();
        assert!(s.contains("tier hits 4 (demoted 9, promoted 6, 12345 B on disk)"), "{s}");
        assert!(s.contains("snapkv dropped 77 tok"), "{s}");
    }

    #[test]
    fn summary_surfaces_tenant_counters() {
        let mut m = Metrics::new();
        assert!(!m.summary().contains("tenant "), "quiet when unused");
        m.tenant("default").admitted = 3;
        assert!(
            !m.summary().contains("tenant "),
            "a lone tenant repeats the engine-wide numbers: stay quiet"
        );
        m.tenant("flood").admitted = 7;
        m.tenant("flood").throttled = 5;
        m.tenant_throttled = 5;
        m.sessions_reaped = 2;
        m.sessions_restored = 1;
        let s = m.summary();
        assert!(s.contains("tenant default: adm 3"), "{s}");
        assert!(s.contains("tenant flood: adm 7 fin 0 thr 5"), "{s}");
        assert!(s.contains("sessions reaped 2 (restored 1)"), "{s}");
    }

    #[test]
    fn summary_surfaces_speculative_counters() {
        let mut m = Metrics::new();
        assert!(!m.summary().contains("speculative"), "quiet when unused");
        m.speculative_rounds = 4;
        m.speculative_drafted = 12;
        m.speculative_accepted = 9;
        let s = m.summary();
        assert!(s.contains("speculative 4 rounds (9/12 accepted, run len 2.25)"), "{s}");
        assert!((m.speculative_acceptance() - 0.75).abs() < 1e-9);
        assert!((m.speculative_run_length() - 2.25).abs() < 1e-9);
    }

    #[test]
    fn summary_surfaces_session_tier_bytes() {
        let mut m = Metrics::new();
        m.tier_session_bytes = 512;
        assert!(
            !m.summary().contains("session B"),
            "session bytes only appear once a session actually reaped"
        );
        m.sessions_reaped = 1;
        assert!(m.summary().contains("512 session B on disk"), "{}", m.summary());
    }

    #[test]
    fn tenant_accessor_is_lazy_and_ordered() {
        let mut m = Metrics::new();
        m.tenant("b").finished = 1;
        m.tenant("a").finished = 2;
        m.tenant("b").finished += 1;
        let names: Vec<&str> = m.tenants.keys().map(|s| s.as_str()).collect();
        assert_eq!(names, vec!["a", "b"], "deterministic listing order");
        assert_eq!(m.tenants["b"].finished, 2);
    }
}
