//! L3 coordinator: the serving system around the quantized cache.
//!
//! * [`request`] — request/response types + lifecycle state machine:
//!   per-request [`GenOptions`], the streaming [`Event`] frames, and the
//!   typed [`FinishReason`] every completion carries
//! * [`backpressure`] — admission control against queue depth and the
//!   cache manager's memory budget, with typed rejection reasons
//! * [`batcher`] — dynamic batching into the AOT shape buckets + the
//!   chunked-prefill token-quota planner
//! * [`scheduler`] — prefill/decode interleaving policy (whole-prompt or
//!   chunked continuous batching)
//! * [`engine`] — ties backend (native or PJRT) + cache + scheduler into
//!   the decode loop
//! * [`pool`] — fixed decode worker pool: thread-parallel native decode
//!   over balanced cache-length shards, thread-local LUT scratch
//! * [`router`] — session-affinity routing across engine workers
//! * [`metrics`] — counters + latency histograms behind every table-4 row

pub mod backpressure;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod router;
pub mod scheduler;

pub use backpressure::{RejectReason, TenantBuckets};
pub use engine::{Backend, Engine, EngineOpts, FabricOpts, TenancyOpts, TierOpts};
pub use pool::{DecodePool, DecodeTask, StepResult};
pub use request::{
    Completion, Event, FinishReason, GenOptions, Request, RequestId, RequestState, SnapKvOpts,
};
pub use scheduler::{SchedMode, WfqState};
