//! The serving engine: scheduler + cache + backend in one decode loop.
//!
//! `step()` is one scheduler iteration: admit up to `prefill_per_step`
//! queued requests (prefill + cache fill + first token), then run one
//! decode iteration across every running sequence — natively through the
//! fixed [`DecodePool`] (thread-parallel over balanced cache-length
//! shards) or inline when `decode_workers <= 1`, or batched into AOT
//! shape buckets on the PJRT backend.

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backpressure::{AdmissionPolicy, AdmitDecision};
use super::batcher::{plan_decode_batches, plan_decode_shards};
use super::metrics::Metrics;
use super::pool::{DecodePool, DecodeTask, StepResult};
use super::request::{Request, RequestId, RequestState, Tracked};
use super::scheduler::SchedulerPolicy;
use crate::kvcache::eviction::{gather_rows, snapkv_select};
use crate::kvcache::CacheManager;
use crate::model::{Model, ModelConfig, Weights};
use crate::runtime::marshal::{batch_dense, split_prefill_kv};
use crate::runtime::PjrtRuntime;
use crate::util::rng::Rng;

/// Compute backend: Rust-native model or PJRT-executed AOT graphs.
pub enum Backend {
    Native(Box<Model>),
    Pjrt(Box<PjrtRuntime>),
}

#[derive(Clone, Copy, Debug)]
pub struct SnapKvOpts {
    pub budget: usize,
    pub window: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct EngineOpts {
    pub policy: SchedulerPolicy,
    pub admission: AdmissionPolicy,
    /// quantize values token-wise at this width (None = fp values)
    pub value_bits: Option<u32>,
    /// SnapKV prompt compression (native backend only)
    pub snapkv: Option<SnapKvOpts>,
    pub cache_budget_bytes: usize,
    pub seed: u64,
    /// Decode threads for the native backend: > 1 fans each decode
    /// iteration over a fixed worker pool (0 and 1 both mean inline).
    pub decode_workers: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            policy: SchedulerPolicy::default(),
            admission: AdmissionPolicy::default(),
            value_bits: None,
            snapkv: None,
            cache_budget_bytes: usize::MAX,
            seed: 0,
            decode_workers: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub ttft_s: Option<f64>,
    pub total_s: Option<f64>,
    /// true if the sequence outgrew every AOT bucket and was truncated
    pub truncated: bool,
}

pub struct Engine {
    backend: Backend,
    pub cfg: ModelConfig,
    cache: CacheManager,
    queue: VecDeque<Tracked>,
    running: HashMap<RequestId, Tracked>,
    /// id -> cache id (same value; kept for clarity)
    pub metrics: Metrics,
    opts: EngineOpts,
    rng: Rng,
    /// fixed thread pool for native decode (None = inline decode)
    pool: Option<DecodePool>,
    /// recycled gather buffer for pool results
    step_results: Vec<StepResult>,
}

impl Engine {
    pub fn new(backend: Backend, cfg: ModelConfig, opts: EngineOpts) -> Self {
        let cache = CacheManager::new(cfg.cache_config(opts.value_bits), opts.cache_budget_bytes);
        // the pool shares the native model's weights; PJRT decode batches
        // inside the graph instead, so it never uses one
        let pool = match &backend {
            Backend::Native(model) if opts.decode_workers > 1 => {
                Some(DecodePool::new(model, opts.decode_workers, opts.seed))
            }
            _ => None,
        };
        Engine {
            backend,
            cfg,
            cache,
            queue: VecDeque::new(),
            running: HashMap::new(),
            metrics: Metrics::new(),
            opts,
            rng: Rng::new(opts.seed),
            pool,
            step_results: Vec::new(),
        }
    }

    /// Decode parallelism of the native backend (1 = inline).
    pub fn decode_pool_width(&self) -> usize {
        self.pool.as_ref().map(|p| p.width()).unwrap_or(1)
    }

    /// Native engine from synthetic weights (tests/benches).
    pub fn native_synthetic(cfg: ModelConfig, seed: u64, severity: f32, opts: EngineOpts) -> Self {
        let w = Weights::synthetic(&cfg, seed, severity);
        let model = Model::new(cfg.clone(), w);
        Engine::new(Backend::Native(Box::new(model)), cfg, opts)
    }

    /// PJRT engine from the artifact directory.
    pub fn pjrt_from_artifacts(dir: &Path, opts: EngineOpts) -> Result<Self> {
        let rt = PjrtRuntime::load(dir)?;
        let cfg = rt.manifest.config.clone();
        if opts.snapkv.is_some() {
            bail!("SnapKV prompt compression requires the native backend");
        }
        Ok(Engine::new(Backend::Pjrt(Box::new(rt)), cfg, opts))
    }

    /// Native engine using the artifact weights (bit-identical to PJRT).
    pub fn native_from_artifacts(dir: &Path, opts: EngineOpts) -> Result<Self> {
        let m = crate::runtime::Manifest::load(dir)?;
        let cfg = m.config.clone();
        let w = Weights::load(&dir.join(&m.weights.file), &m.weights.tensors, &cfg)?;
        let model = Model::new(cfg.clone(), w);
        Ok(Engine::new(Backend::Native(Box::new(model)), cfg, opts))
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    pub fn cache_report(&self) -> crate::kvcache::MemoryReport {
        self.cache.report()
    }

    /// Submit a request; rejects under backpressure.
    pub fn submit(&mut self, req: Request) -> std::result::Result<(), AdmitDecision> {
        let expected = req.prompt.len() + req.max_new_tokens;
        match self.opts.admission.admit(self.queue.len(), &self.cache, expected) {
            AdmitDecision::Admit => {
                self.metrics.requests_submitted += 1;
                self.queue.push_back(Tracked::new(req));
                Ok(())
            }
            other => {
                self.metrics.requests_rejected += 1;
                Err(other)
            }
        }
    }

    /// One scheduler iteration; returns completions.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        let plan = self.opts.policy.plan(self.queue.len(), self.running.len());
        for _ in 0..plan.admit {
            let Some(mut tr) = self.queue.pop_front() else { break };
            self.metrics
                .queue_delay
                .record_secs(tr.arrived.elapsed().as_secs_f64());
            self.prefill_one(&mut tr)?;
            self.running.insert(tr.req.id, tr);
        }
        let mut done = Vec::new();
        if plan.decode && !self.running.is_empty() {
            self.decode_iteration(&mut done)?;
        }
        Ok(done)
    }

    /// Run until every queued/running request finishes.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    // ---------------------------------------------------------- prefill

    fn prefill_one(&mut self, tr: &mut Tracked) -> Result<()> {
        tr.state = RequestState::Prefilling;
        let id = tr.req.id;
        let prompt = tr.req.prompt.clone();
        self.metrics.prefill_tokens += prompt.len() as u64;

        let logits = match &mut self.backend {
            Backend::Native(model) => {
                if let Some(sk) = self.opts.snapkv {
                    let (logits, k, v, imp) =
                        model.prefill_kv_importance(&prompt, sk.window);
                    let keep = snapkv_select(&imp, sk.budget, sk.window);
                    let shared = self.cache.create(id);
                    let mut cache = shared.lock().unwrap();
                    let (l, kv, dh, t) =
                        (cache.cfg.n_layers, cache.cfg.n_kv_heads, cache.cfg.head_dim, prompt.len());
                    // gather kept rows per (layer, head) stream
                    let mut k_kept = Vec::with_capacity(l * kv * keep.len() * dh);
                    let mut v_kept = Vec::with_capacity(l * kv * keep.len() * dh);
                    for li in 0..l {
                        for h in 0..kv {
                            let off = (li * kv + h) * t * dh;
                            k_kept.extend(gather_rows(&k[off..off + t * dh], dh, &keep));
                            v_kept.extend(gather_rows(&v[off..off + t * dh], dh, &keep));
                        }
                    }
                    cache.append_prefill(&k_kept, &v_kept, keep.len());
                    // positions continue from the ORIGINAL prompt length
                    cache.next_pos = t;
                    logits
                } else {
                    let shared = self.cache.create(id);
                    let mut cache = shared.lock().unwrap();
                    model.prefill(&prompt, &mut cache)
                }
            }
            Backend::Pjrt(rt) => {
                let g = rt
                    .manifest
                    .pick_bucket("prefill", 1, prompt.len())
                    .with_context(|| {
                        format!("no prefill bucket fits prompt of {}", prompt.len())
                    })?
                    .clone();
                let mut tokens = vec![0i32; g.batch * g.seq];
                for (i, &t) in prompt.iter().enumerate() {
                    tokens[i] = t as i32;
                }
                let mut plen = vec![1i32; g.batch];
                plen[0] = prompt.len() as i32;
                let out = rt.prefill(&g.name, &tokens, &plen)?;
                let cfg = &self.cfg;
                let k = split_prefill_kv(
                    &out.k, cfg.n_layers, g.batch, cfg.n_kv_heads, g.seq, cfg.head_dim, 0,
                );
                let v = split_prefill_kv(
                    &out.v, cfg.n_layers, g.batch, cfg.n_kv_heads, g.seq, cfg.head_dim, 0,
                );
                // keep only the valid region of the padded bucket
                let t = prompt.len();
                let (l, kv, dh) = (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim);
                let mut k_valid = Vec::with_capacity(l * kv * t * dh);
                let mut v_valid = Vec::with_capacity(l * kv * t * dh);
                for li in 0..l {
                    for h in 0..kv {
                        let off = (li * kv + h) * g.seq * dh;
                        k_valid.extend_from_slice(&k[off..off + t * dh]);
                        v_valid.extend_from_slice(&v[off..off + t * dh]);
                    }
                }
                let shared = self.cache.create(id);
                shared.lock().unwrap().append_prefill(&k_valid, &v_valid, t);
                out.logits[..self.cfg.vocab].to_vec()
            }
        };

        // first generated token comes from the prefill logits
        let tok = tr.req.sampler.sample(&logits, &mut self.rng);
        tr.generated.push(tok);
        tr.first_token_at = Some(Instant::now());
        self.metrics.decode_tokens += 1;
        self.metrics.ttft.record_secs(tr.arrived.elapsed().as_secs_f64());
        tr.state = RequestState::Decoding;
        Ok(())
    }

    // ----------------------------------------------------------- decode

    fn decode_iteration(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        let step_t = Instant::now();
        let ids: Vec<RequestId> = self.running.keys().cloned().collect();
        // collect (id, quantized cache len) for batching
        let mut seqs: Vec<(u64, usize)> = Vec::new();
        for &id in &ids {
            let tr = &self.running[&id];
            if tr.done() {
                continue;
            }
            let qlen = self.cache.get(id).map(|c| c.lock().unwrap().quantized_len()).unwrap_or(0);
            seqs.push((id, qlen));
        }

        let mut truncated: Vec<RequestId> = Vec::new();
        match &mut self.backend {
            Backend::Native(model) => {
                if let Some(pool) = self.pool.as_mut().filter(|_| seqs.len() > 1) {
                    // Thread-parallel path: fan balanced cache-length
                    // shards over the fixed pool.  Shards are disjoint, so
                    // every per-sequence lock the workers take is
                    // uncontended; the engine thread only rejoins at
                    // flush().
                    let shards = plan_decode_shards(&seqs, pool.width());
                    for (w, shard) in shards.iter().enumerate() {
                        for &id in shard {
                            let tr = &self.running[&id];
                            let cache = self.cache.get(id).context("cache missing")?;
                            pool.submit(
                                w,
                                DecodeTask {
                                    id,
                                    cache,
                                    last_token: *tr.generated.last().unwrap(),
                                    sampler: tr.req.sampler,
                                },
                            );
                        }
                    }
                    let mut results = std::mem::take(&mut self.step_results);
                    results.clear();
                    pool.flush(&mut results);
                    for r in &results {
                        let tr = self.running.get_mut(&r.id).unwrap();
                        tr.generated.push(r.token);
                        self.metrics.decode_tokens += 1;
                    }
                    self.step_results = results;
                } else {
                    for &(id, _) in &seqs {
                        let tr = self.running.get_mut(&id).unwrap();
                        let last = *tr.generated.last().unwrap();
                        let shared = self.cache.get(id).context("cache missing")?;
                        let mut cache = shared.lock().unwrap();
                        let logits = model.decode_step(last, &mut cache).to_vec();
                        drop(cache);
                        let tok = tr.req.sampler.sample(&logits, &mut self.rng);
                        tr.generated.push(tok);
                        self.metrics.decode_tokens += 1;
                    }
                }
                self.metrics.decode_steps += 1;
                self.metrics.decode_batch_sum += seqs.len() as u64;
            }
            Backend::Pjrt(rt) => {
                let (batches, overflow) =
                    plan_decode_batches(&rt.manifest, seqs.clone(), usize::MAX);
                truncated.extend(overflow);
                for b in &batches {
                    let cfg = &self.cfg;
                    let r_cap = cfg.resid;
                    let denses: Vec<_> = b
                        .ids
                        .iter()
                        .map(|&id| {
                            self.cache
                                .get(id)
                                .unwrap()
                                .lock()
                                .unwrap()
                                .export_dense(b.seq_cap, r_cap)
                        })
                        .collect();
                    let dense_refs: Vec<&_> = denses.iter().collect();
                    let mut ins = batch_dense(
                        &dense_refs,
                        cfg.n_layers,
                        cfg.n_kv_heads,
                        b.seq_cap,
                        r_cap,
                        cfg.head_dim,
                        cfg.group,
                        b.batch_cap,
                    );
                    for (lane, &id) in b.ids.iter().enumerate() {
                        let tr = &self.running[&id];
                        ins.tokens[lane] = *tr.generated.last().unwrap() as i32;
                        ins.positions[lane] =
                            self.cache.get(id).unwrap().lock().unwrap().next_pos as i32;
                    }
                    let out = rt.decode(&b.graph, &ins)?;
                    let (l, kv, dh, v) =
                        (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, cfg.vocab);
                    for (lane, &id) in b.ids.iter().enumerate() {
                        // de-batch new_k/new_v (L, B, Kv, dh) -> (L, Kv, dh)
                        let mut new_k = vec![0.0f32; l * kv * dh];
                        let mut new_v = vec![0.0f32; l * kv * dh];
                        for li in 0..l {
                            for h in 0..kv {
                                let src = ((li * b.batch_cap + lane) * kv + h) * dh;
                                let dst = (li * kv + h) * dh;
                                new_k[dst..dst + dh]
                                    .copy_from_slice(&out.new_k[src..src + dh]);
                                new_v[dst..dst + dh]
                                    .copy_from_slice(&out.new_v[src..src + dh]);
                            }
                        }
                        self.cache.get(id).unwrap().lock().unwrap().append_step(&new_k, &new_v);
                        let logits = &out.logits[lane * v..(lane + 1) * v];
                        let tr = self.running.get_mut(&id).unwrap();
                        let tok = tr.req.sampler.sample(logits, &mut self.rng);
                        tr.generated.push(tok);
                        self.metrics.decode_tokens += 1;
                    }
                    self.metrics.decode_steps += 1;
                    self.metrics.decode_batch_sum += b.ids.len() as u64;
                }
            }
        }
        self.metrics
            .per_token
            .record_secs(step_t.elapsed().as_secs_f64());

        // retire finished / truncated sequences
        let now_ids: Vec<RequestId> = self.running.keys().cloned().collect();
        for id in now_ids {
            let is_trunc = truncated.contains(&id);
            let finished = self.running[&id].done() || is_trunc;
            if finished {
                let mut tr = self.running.remove(&id).unwrap();
                tr.state = RequestState::Finished;
                tr.finished_at = Some(Instant::now());
                self.metrics.requests_finished += 1;
                self.metrics
                    .e2e
                    .record_secs(tr.arrived.elapsed().as_secs_f64());
                self.cache.release(id);
                done.push(Completion {
                    id,
                    prompt_len: tr.req.prompt.len(),
                    tokens: tr.generated.clone(),
                    ttft_s: tr.ttft(),
                    total_s: tr.total_latency(),
                    truncated: is_trunc,
                });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::tiny();
        cfg.n_layers = 2;
        cfg.vocab = 64;
        cfg.d_model = 32;
        cfg.n_heads = 4;
        cfg.n_kv_heads = 2;
        cfg.head_dim = 16;
        cfg.ffn = 48;
        cfg.group = 8;
        cfg.resid = 16;
        cfg
    }

    #[test]
    fn native_engine_completes_requests() {
        let mut eng = Engine::native_synthetic(tiny_cfg(), 1, 4.0, EngineOpts::default());
        for i in 0..3 {
            eng.submit(Request::greedy(i, vec![1, 2, 3, (i % 8) as u32 + 4], 6))
                .unwrap();
        }
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 3);
        for c in &done {
            assert_eq!(c.tokens.len(), 6);
            assert!(c.ttft_s.is_some());
            assert!(!c.truncated);
        }
        assert!(eng.idle());
        assert_eq!(eng.cache_report().sequences, 0, "caches released");
        assert_eq!(eng.metrics.requests_finished, 3);
        assert_eq!(eng.metrics.decode_tokens, 18);
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        let run = || {
            let mut eng =
                Engine::native_synthetic(tiny_cfg(), 2, 4.0, EngineOpts::default());
            eng.submit(Request::greedy(1, vec![5, 6, 7], 12)).unwrap();
            eng.run_to_completion().unwrap()[0].tokens.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn backpressure_rejects() {
        let mut opts = EngineOpts::default();
        opts.admission.max_queue = 1;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 3, 4.0, opts);
        eng.submit(Request::greedy(1, vec![1], 4)).unwrap();
        let r = eng.submit(Request::greedy(2, vec![1], 4));
        assert_eq!(r, Err(AdmitDecision::QueueFull));
        assert_eq!(eng.metrics.requests_rejected, 1);
    }

    #[test]
    fn snapkv_engine_compresses_long_prompts() {
        let mut opts = EngineOpts::default();
        opts.snapkv = Some(SnapKvOpts { budget: 16, window: 4 });
        let mut eng = Engine::native_synthetic(tiny_cfg(), 4, 4.0, opts);
        let prompt: Vec<u32> = (0..40).map(|i| (i % 60) as u32).collect();
        eng.submit(Request::greedy(1, prompt, 4)).unwrap();
        // after prefill, the cache holds only `budget` tokens
        eng.step().unwrap();
        let report = eng.cache_report();
        assert_eq!(report.tokens, 16 + 1, "budget + first decode step");
        eng.run_to_completion().unwrap();
    }

    #[test]
    fn parallel_decode_matches_inline_greedy() {
        // greedy decode is deterministic, so the pool path must produce
        // bit-identical rollouts to the inline path at any worker count
        let run = |workers: usize| {
            let mut opts = EngineOpts::default();
            opts.decode_workers = workers;
            let mut eng = Engine::native_synthetic(tiny_cfg(), 9, 4.0, opts);
            for i in 0..5 {
                eng.submit(Request::greedy(i, vec![1, 2, 3, (i % 8) as u32 + 4], 8))
                    .unwrap();
            }
            let mut done = eng.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
        };
        let inline = run(1);
        assert_eq!(inline, run(3));
        assert_eq!(inline, run(8), "more workers than sequences");
    }

    #[test]
    fn pool_width_reflects_opts() {
        let mut opts = EngineOpts::default();
        opts.decode_workers = 4;
        let eng = Engine::native_synthetic(tiny_cfg(), 10, 4.0, opts);
        assert_eq!(eng.decode_pool_width(), 4);
        let eng2 = Engine::native_synthetic(tiny_cfg(), 10, 4.0, EngineOpts::default());
        assert_eq!(eng2.decode_pool_width(), 1);
    }

    #[test]
    fn value_quantized_engine_runs() {
        let mut opts = EngineOpts::default();
        opts.value_bits = Some(2);
        let mut eng = Engine::native_synthetic(tiny_cfg(), 5, 4.0, opts);
        eng.submit(Request::greedy(1, (0..20).map(|i| i as u32).collect(), 8))
            .unwrap();
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 8);
    }
}
