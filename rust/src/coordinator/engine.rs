//! The serving engine: scheduler + cache + backend in one decode loop.
//!
//! `step()` is one scheduler iteration.  With chunked prefill OFF (the
//! legacy phase model) it admits up to `prefill_per_step` queued requests
//! and prefills each whole prompt inline, then decodes.  With
//! `EngineOpts::prefill_chunk > 0` (native backend) the engine is a
//! continuously-batched loop: admissions enter `Prefilling` with a
//! resumable cursor, each step grants at most one chunk's worth of
//! prefill tokens (FCFS across prefilling sequences, planned by
//! [`super::batcher::plan_prefill_chunks`]), and a decode iteration for
//! every `Decoding` sequence runs in the SAME step — so no running
//! sequence ever waits more than one chunk's compute for its next token.
//! Decode fans over the fixed [`DecodePool`] (thread-parallel over
//! balanced cache-length shards) or runs inline when `decode_workers <=
//! 1`, or batches into AOT shape buckets on the PJRT backend.
//!
//! The cache behind all of it is the refcounted group-page pool
//! (`kvcache::pool`): with `EngineOpts::prefix_cache` prompts attach to
//! already-pooled prefix pages and skip that prefill work, and with
//! `EngineOpts::cache_pages` bounding the pool the engine degrades by
//! LRU-reclaiming cached pages and then PREEMPTING the youngest decoding
//! sequence (requeue through chunked prefill + token replay) instead of
//! stalling or rejecting mid-flight work.

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::backpressure::{AdmissionPolicy, AdmitDecision, RejectReason, TenantBuckets};
use super::batcher::{pages_needed, plan_decode_batches, plan_decode_shards, plan_prefill_chunks};
use super::metrics::Metrics;
use super::pool::{DecodePool, DecodeTask, StepResult};
use super::request::{
    Completion, Event, FinishReason, Request, RequestId, RequestState, Tracked, TurnInfo,
};
use super::scheduler::{pick_preemption_victim, SchedMode, SchedulerPolicy, WfqState};
use crate::kvcache::eviction::{gather_rows, snapkv_select};
use crate::kvcache::tier::session::{decode_session, encode_session};
use crate::kvcache::{CacheManager, PagePool, SequenceCache, SharedSeq, TierConfig, TierRef};
use crate::model::sampling::{token_rng, Sampler};
use crate::model::{Model, ModelConfig, Weights};
use crate::quant::{select_kernel, DraftSpec, KernelKind};
use crate::runtime::marshal::{batch_dense, split_prefill_kv};
use crate::runtime::PjrtRuntime;
use crate::trace::{TraceKind, TraceRecorder};

// the per-request options (SnapKV override included) live with Request
pub use super::request::SnapKvOpts;

/// Compute backend: Rust-native model or PJRT-executed AOT graphs.
pub enum Backend {
    Native(Box<Model>),
    Pjrt(Box<PjrtRuntime>),
}

/// Disk-tier configuration (`--tier-dir`, `--tier-bytes`, `--snapshot`).
/// Attached AFTER construction via [`Engine::attach_tier`] so
/// [`EngineOpts`] stays `Copy`.
#[derive(Clone, Debug)]
pub struct TierOpts {
    /// Segment + snapshot directory for THIS engine (multi-worker servers
    /// give each engine its own subdirectory).
    pub dir: std::path::PathBuf,
    /// Demotion stops (plain eviction resumes) past this many segment
    /// bytes.
    pub max_bytes: u64,
    /// Persist the prefix index at shutdown (`Engine::snapshot_tier`).
    pub snapshot: bool,
}

/// Shared prefix-fabric configuration (`--fabric-dir` / `--fabric-peer`).
/// Attached AFTER construction via [`Engine::attach_fabric`], mirroring
/// [`TierOpts`].  Exactly one transport may be set; both `None` is a
/// caller error caught at attach time.
#[derive(Clone, Debug, Default)]
pub struct FabricOpts {
    /// shared segment directory every node of the fleet mounts
    pub dir: Option<std::path::PathBuf>,
    /// `host:port` of a designated peer backend to fetch from
    pub peer: Option<String>,
}

#[derive(Clone, Copy, Debug)]
pub struct EngineOpts {
    pub policy: SchedulerPolicy,
    pub admission: AdmissionPolicy,
    /// quantize values token-wise at this width (None = fp values)
    pub value_bits: Option<u32>,
    /// SnapKV prompt compression (native backend only)
    pub snapkv: Option<SnapKvOpts>,
    pub cache_budget_bytes: usize,
    /// Decode threads for the native backend: > 1 fans each decode
    /// iteration over a fixed worker pool (0 and 1 both mean inline).
    pub decode_workers: usize,
    /// Chunked prefill (native backend): prompts enter the cache this
    /// many tokens per engine step, interleaved with decode iterations —
    /// 0 disables chunking (whole prompt prefills inline, stalling the
    /// step).  Greedy rollouts are bit-identical at any chunk size.
    pub prefill_chunk: usize,
    /// With chunked prefill: finalize (quantize) full groups as chunks
    /// land instead of at end-of-prompt.  Cheaper residency for very long
    /// prompts, but later chunks attend through the LUT at the paper's
    /// quantization error, so rollouts are no longer bit-identical to the
    /// unchunked path.
    pub prefill_quantize_eagerly: bool,
    /// Physical page-pool capacity in group-pages (0 = unbounded).  When
    /// the pool runs dry mid-decode the engine reclaims refcount-zero
    /// cached prefix pages LRU, then preempts the youngest decoding
    /// sequence (releasing its pages and requeueing it through chunked
    /// prefill) instead of stalling.  Enforcement lives in the chunked
    /// scheduler: on non-chunked paths (whole-prompt prefill, SnapKV,
    /// PJRT) the cap only feeds accounting and is NOT enforced — the CLI
    /// rejects those combinations.
    pub cache_pages: usize,
    /// Prefix caching (chunked native engines only): prompts attach to
    /// already-pooled pages of any previously-served prompt sharing their
    /// prefix, refcounted, and skip prefilling those tokens.  Forces
    /// eager group finalization with a group-aligned chunk so shared and
    /// cold prefills run the identical computation — greedy decode is
    /// bit-identical with the flag on or off.
    pub prefix_cache: bool,
    /// Score-kernel backend for the native LUT QK path (`--kernel`).
    /// Availability of an explicit `Simd` choice is validated at the CLI
    /// boundary ([`crate::quant::select_kernel`]); `Auto` never fails.
    /// A pure performance knob: every kernel is bit-identical.
    pub kernel: KernelKind,
    /// Queued-request / prefill-grant ordering (`--sched`).  `Fcfs` (the
    /// default) is bit-identical to pre-WFQ builds; `Wfq` orders by
    /// per-tenant pass value so one tenant's flood cannot starve another.
    pub sched: SchedMode,
    /// Self-drafting speculative decoding (`--speculate K`, native
    /// backend): each decode iteration of an eligible request (greedy
    /// sampler, not replaying) proposes up to K tokens on the coarse
    /// truncated-code draft plane and verifies them in one exact batched
    /// LUT walk.  Greedy rollouts are bit-identical to `speculate = 0` —
    /// speculation only changes how many tokens one iteration emits.
    pub speculate: usize,
    /// Draft-plane width (`--draft-bits R,T`); `None` = half the exact
    /// plane's bits ([`DraftSpec::halved`]).  Ignored unless
    /// `speculate > 0`.  Must truncate (not exceed) the exact plane;
    /// validated at the CLI boundary.
    pub draft_bits: Option<(u32, u32)>,
    /// Request-lifecycle tracing (`--trace on`): record typed span
    /// events into a bounded per-engine ring ([`crate::trace`]).
    /// Observation-only — rollouts are byte-identical either way — and
    /// off by default, where its entire cost is one branch per site.
    pub trace: bool,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            policy: SchedulerPolicy::default(),
            admission: AdmissionPolicy::default(),
            value_bits: None,
            snapkv: None,
            cache_budget_bytes: usize::MAX,
            decode_workers: 0,
            prefill_chunk: 0,
            prefill_quantize_eagerly: false,
            cache_pages: 0,
            prefix_cache: false,
            kernel: KernelKind::Auto,
            sched: SchedMode::Fcfs,
            speculate: 0,
            draft_bits: None,
            trace: false,
        }
    }
}

/// Multi-tenant policy knobs (`--tenant-weight`, `--tenant-rate`,
/// `--tenant-burst`, `--tenant-pages`, `--session-ttl`).  Applied AFTER
/// construction via [`Engine::set_tenancy`] so [`EngineOpts`] stays
/// `Copy`; the all-default value changes nothing.
#[derive(Clone, Debug, Default)]
pub struct TenancyOpts {
    /// per-tenant WFQ weights (`name=N`); unlisted tenants weigh 1
    pub weights: HashMap<String, u32>,
    /// token-bucket refill in requests/s (0 = no rate limit)
    pub rate: f64,
    /// token-bucket burst capacity in requests
    pub burst: f64,
    /// prefix-cache pages below which a tenant's entries are never
    /// reclaimed to serve ANOTHER tenant's demand (0 = no reservation)
    pub reserve_pages: usize,
    /// demote an idle session's KV chain to the disk tier after this long
    pub session_ttl: Option<Duration>,
    /// per-tenant cap on reaped-session blob bytes in the disk tier
    /// (`--tenant-tier-bytes`; 0 = no per-tenant cap).  An over-cap
    /// tenant's reaps refuse — the session stays resident — while other
    /// tenants keep spilling under the shared `--tier-bytes` budget.
    pub tenant_tier_bytes: u64,
}

/// One conversation's engine-side state: the token history each turn's
/// prompt is rebuilt from, the live KV chain (kept between turns so the
/// next turn prefills only its new tokens), and the in-flight turn.
#[derive(Debug)]
struct Session {
    /// full conversation so far: every turn's tokens ++ its generation
    tokens: Vec<u32>,
    /// the conversation's cache, held across turns (chunked engines;
    /// whole-prompt engines re-prefill each turn and keep this `None`)
    cache: Option<SharedSeq>,
    /// turns are serialized per session: at most one in flight
    active: Option<RequestId>,
    /// when this session last saw a turn start or finish (the TTL clock)
    last_active: Instant,
    /// where the chain lives while reaped to the disk tier
    /// (`--session-ttl`); the next turn promotes it back
    tiered: Option<TierRef>,
    /// owning tenant (last turn's `Request::tenant`) — reaped blobs are
    /// charged to this name under `--tenant-tier-bytes`
    tenant: String,
}

impl Default for Session {
    fn default() -> Self {
        Session {
            tokens: Vec::new(),
            cache: None,
            active: None,
            last_active: Instant::now(),
            tiered: None,
            tenant: String::new(),
        }
    }
}

pub struct Engine {
    backend: Backend,
    pub cfg: ModelConfig,
    cache: CacheManager,
    queue: VecDeque<Tracked>,
    running: HashMap<RequestId, Tracked>,
    /// arrival order of sequences currently in `Prefilling` (chunked
    /// prefill grants are FCFS over this queue)
    prefill_order: VecDeque<RequestId>,
    /// id -> cache id (same value; kept for clarity)
    pub metrics: Metrics,
    opts: EngineOpts,
    /// streaming subscribers: request id -> event sink (dropped receivers
    /// are tolerated — events just fall on the floor)
    subs: HashMap<RequestId, Sender<Event>>,
    /// multi-turn conversations keyed by session id
    sessions: HashMap<u64, Session>,
    /// fixed thread pool for native decode (None = inline decode)
    pool: Option<DecodePool>,
    /// recycled gather buffer for pool results
    step_results: Vec<StepResult>,
    /// disk tier attached to the page pool (None = RAM-only pool)
    tier: Option<TierOpts>,
    /// prefix entries restored from a snapshot at attach time
    tier_restored: usize,
    /// weighted-fair queueing state (Some iff `opts.sched == Wfq`)
    wfq: Option<WfqState>,
    /// per-tenant token buckets (`--tenant-rate`; None = no rate limit)
    tenant_buckets: Option<TenantBuckets>,
    /// idle sessions older than this demote their chain to the disk tier
    session_ttl: Option<Duration>,
    /// per-tenant reaped-blob byte cap (`--tenant-tier-bytes`; 0 = none)
    tenant_tier_bytes: u64,
    /// lifecycle span recorder (disabled no-op unless `EngineOpts::trace`)
    trace: Arc<TraceRecorder>,
}

impl Engine {
    pub fn new(backend: Backend, cfg: ModelConfig, opts: EngineOpts) -> Self {
        let mut backend = backend;
        let mut opts = opts;
        if let Backend::Native(model) = &mut backend {
            // resolve --kernel ONCE, before the decode pool forks workers,
            // so every worker's LUT inherits the same backend.  An
            // explicit `simd` on unsupported hardware/builds is rejected
            // at the CLI boundary; library callers constructing EngineOpts
            // directly get the same strictness here.
            model.set_kernel(
                select_kernel(opts.kernel)
                    .expect("kernel availability is validated at the CLI boundary"),
            );
            if opts.speculate > 0 {
                // resolve the draft plane ONCE, before the decode pool
                // forks workers, so every worker carries the same draft
                // LUT (Model::fork propagates it)
                let draft = opts
                    .draft_bits
                    .map(|(r, t)| DraftSpec::new(r, t))
                    .unwrap_or_else(|| DraftSpec::halved(&cfg.polar_spec()));
                model
                    .set_draft(draft)
                    .expect("draft bits are validated at the CLI boundary");
            }
        }
        if opts.prefix_cache && opts.prefill_chunk > 0 {
            // Prefix sharing hands out QUANTIZED pages, so a prompt that
            // attaches to them must score the rest of its prefill exactly
            // the way a cold prefill would: eager finalization (cold runs
            // quantize as chunks land too) with the chunk aligned to the
            // group (chunk boundaries of shared and cold runs coincide).
            // Under those two constraints the shared path is bit-identical
            // to the cold path — see `adopt_prefix`.
            opts.prefill_quantize_eagerly = true;
            opts.prefill_chunk = opts.prefill_chunk.div_ceil(cfg.group) * cfg.group;
        }
        let trace = if opts.trace {
            Arc::new(TraceRecorder::new(true, TraceRecorder::DEFAULT_CAPACITY))
        } else {
            TraceRecorder::disabled()
        };
        if opts.trace {
            if let Backend::Native(model) = &mut backend {
                // install the recorder BEFORE the decode pool forks
                // workers, so every fork records into the same ring
                model.set_trace(trace.clone());
            }
        }
        let cache = CacheManager::new(cfg.cache_config(opts.value_bits), opts.cache_budget_bytes)
            .with_page_capacity(opts.cache_pages);
        if opts.trace {
            // the page pool (and the tier writer it later spawns) hold a
            // late-binding slot; fill it so promotions/demotions record
            cache.pool().set_trace(trace.clone());
        }
        // the pool shares the native model's weights; PJRT decode batches
        // inside the graph instead, so it never uses one
        let pool = match &backend {
            Backend::Native(model) if opts.decode_workers > 1 => {
                Some(DecodePool::new(model, opts.decode_workers))
            }
            _ => None,
        };
        Engine {
            backend,
            cfg,
            cache,
            queue: VecDeque::new(),
            running: HashMap::new(),
            prefill_order: VecDeque::new(),
            metrics: Metrics::new(),
            opts,
            subs: HashMap::new(),
            sessions: HashMap::new(),
            pool,
            step_results: Vec::new(),
            tier: None,
            tier_restored: 0,
            wfq: match opts.sched {
                SchedMode::Wfq => Some(WfqState::new(HashMap::new())),
                SchedMode::Fcfs => None,
            },
            tenant_buckets: None,
            session_ttl: None,
            tenant_tier_bytes: 0,
            trace,
        }
    }

    /// This engine's span recorder (the server drains it for the admin
    /// `trace` command and the Chrome export; disabled = records nothing).
    pub fn trace(&self) -> Arc<TraceRecorder> {
        self.trace.clone()
    }

    /// Apply the multi-tenant policy knobs.  Weights only matter under
    /// `--sched wfq`; a zero rate disables the token buckets; a `None`
    /// TTL disables session reaping.
    pub fn set_tenancy(&mut self, t: &TenancyOpts) {
        if self.wfq.is_some() && !t.weights.is_empty() {
            self.wfq = Some(WfqState::new(t.weights.clone()));
        }
        self.tenant_buckets =
            (t.rate > 0.0).then(|| TenantBuckets::new(t.rate, t.burst.max(1.0)));
        self.session_ttl = t.session_ttl;
        self.tenant_tier_bytes = t.tenant_tier_bytes;
        if t.reserve_pages > 0 {
            self.cache.pool().set_tenant_reserve(t.reserve_pages);
        }
    }

    /// The queued-request ordering in effect (server startup log).
    pub fn sched_mode(&self) -> SchedMode {
        self.opts.sched
    }

    /// The idle-session TTL in effect, if any (server startup log).
    pub fn session_ttl(&self) -> Option<Duration> {
        self.session_ttl
    }

    /// Attach the disk tier to this engine's page pool (requires prefix
    /// caching: the tier persists prefix-index pages).  Restores a
    /// snapshot left by an earlier process when one exists AND was
    /// written under the same model/codec config — the config
    /// fingerprint guards against warm-starting from another model's
    /// pages.  Returns the number of restored prefix entries.
    pub fn attach_tier(&mut self, t: &TierOpts) -> Result<usize> {
        if !self.prefix_caching() {
            bail!("the tier stores prefix-cache pages: enable prefix caching first");
        }
        if self.tier.is_some() {
            bail!("tier already attached");
        }
        let tag = config_fingerprint(&self.cfg, self.opts.value_bits);
        let restored = self
            .cache
            .pool()
            .attach_tier(TierConfig::new(t.dir.clone(), t.max_bytes, tag))?;
        self.tier = Some(t.clone());
        self.tier_restored = restored;
        Ok(restored)
    }

    /// The attached tier's options, if any (server startup log).
    pub fn tier(&self) -> Option<&TierOpts> {
        self.tier.as_ref()
    }

    /// Bind this engine's page pool to the shared prefix fabric
    /// (requires prefix caching, like [`Engine::attach_tier`]: the
    /// fabric moves prefix-index pages).  Records are namespaced by the
    /// same config fingerprint the tier uses, so a fleet member running
    /// different quant geometry can never poison the cache.  Returns the
    /// transport description for the startup log.
    pub fn attach_fabric(&mut self, f: &FabricOpts) -> Result<String> {
        if !self.prefix_caching() {
            bail!("the fabric moves prefix-cache pages: enable prefix caching first");
        }
        let tag = config_fingerprint(&self.cfg, self.opts.value_bits);
        let fabric: Arc<dyn crate::fabric::PrefixFabric> = match (&f.dir, &f.peer) {
            (Some(dir), None) => Arc::new(crate::fabric::DirFabric::new(dir, tag)?),
            (None, Some(peer)) => Arc::new(crate::fabric::PeerFabric::new(peer)),
            (Some(_), Some(_)) => bail!("--fabric-dir and --fabric-peer are exclusive"),
            (None, None) => bail!("fabric needs --fabric-dir or --fabric-peer"),
        };
        let desc = fabric.describe();
        self.cache.pool().set_fabric(Some(fabric), tag);
        Ok(desc)
    }

    /// Enable export-only fabric mode: this node answers peers'
    /// `{"peer":"fetch"}` requests out of its prefix index without
    /// fetching remotely itself.  A no-op when [`Engine::attach_fabric`]
    /// already bound a transport (the bind is once-only).
    pub fn enable_fabric_export(&self) {
        if self.prefix_caching() {
            let tag = config_fingerprint(&self.cfg, self.opts.value_bits);
            self.cache.pool().set_fabric(None, tag);
        }
    }

    /// Prefix entries restored from a snapshot at attach time.
    pub fn tier_restored(&self) -> usize {
        self.tier_restored
    }

    /// The shared page pool (tier counters, demotion hooks — tests,
    /// benches, and the server's introspection).
    pub fn page_pool(&self) -> &PagePool {
        self.cache.pool()
    }

    /// Persist the prefix index if a tier with `snapshot: true` is
    /// attached; `Ok(None)` when there is nothing to do.  Called by the
    /// server worker on graceful shutdown and by `generate` at exit.
    pub fn snapshot_tier(&self) -> Result<Option<(usize, u64)>> {
        match &self.tier {
            Some(t) if t.snapshot => self.cache.pool().snapshot().map(Some),
            _ => Ok(None),
        }
    }

    /// Decode parallelism of the native backend (1 = inline).
    pub fn decode_pool_width(&self) -> usize {
        self.pool.as_ref().map(|p| p.width()).unwrap_or(1)
    }

    /// Speculative draft length in effect (0 = plain decode; server
    /// startup log + admin `metrics` reply).
    pub fn speculate_k(&self) -> usize {
        match &self.backend {
            Backend::Native(_) => self.opts.speculate,
            Backend::Pjrt(_) => 0,
        }
    }

    /// The draft plane speculation runs on, if configured.
    pub fn draft_spec(&self) -> Option<DraftSpec> {
        match &self.backend {
            Backend::Native(m) => m.draft_spec(),
            Backend::Pjrt(_) => None,
        }
    }

    /// The score kernel actually running QK lookups ("scalar" / "simd";
    /// "pjrt-graph" when scoring happens inside the AOT graphs instead).
    /// Server startup log + admin `metrics` reply.
    pub fn kernel_name(&self) -> &'static str {
        match &self.backend {
            Backend::Native(m) => m.kernel_name(),
            Backend::Pjrt(_) => "pjrt-graph",
        }
    }

    /// Chunked-prefill grant size in effect (0 = whole-prompt prefill).
    pub fn prefill_chunk_size(&self) -> usize {
        if self.chunked_prefill() {
            self.opts.prefill_chunk
        } else {
            0
        }
    }

    /// Page-pool capacity in effect (0 = unbounded).
    pub fn cache_pages(&self) -> usize {
        if self.cache.pool().bounded() {
            self.cache.pool().capacity()
        } else {
            0
        }
    }

    /// True when prompts attach to shared prefix pages (chunked native
    /// engines with `EngineOpts::prefix_cache`).
    pub fn prefix_caching(&self) -> bool {
        self.opts.prefix_cache && self.chunked_prefill()
    }

    /// Native engine from synthetic weights (tests/benches).
    pub fn native_synthetic(cfg: ModelConfig, seed: u64, severity: f32, opts: EngineOpts) -> Self {
        let w = Weights::synthetic(&cfg, seed, severity);
        let model = Model::new(cfg.clone(), w);
        Engine::new(Backend::Native(Box::new(model)), cfg, opts)
    }

    /// PJRT engine from the artifact directory.
    pub fn pjrt_from_artifacts(dir: &Path, opts: EngineOpts) -> Result<Self> {
        let rt = PjrtRuntime::load(dir)?;
        let cfg = rt.manifest.config.clone();
        if opts.snapkv.is_some() {
            bail!("SnapKV prompt compression requires the native backend");
        }
        Ok(Engine::new(Backend::Pjrt(Box::new(rt)), cfg, opts))
    }

    /// Native engine using the artifact weights (bit-identical to PJRT).
    pub fn native_from_artifacts(dir: &Path, opts: EngineOpts) -> Result<Self> {
        let m = crate::runtime::Manifest::load(dir)?;
        let cfg = m.config.clone();
        let w = Weights::load(&dir.join(&m.weights.file), &m.weights.tensors, &cfg)?;
        let model = Model::new(cfg.clone(), w);
        Ok(Engine::new(Backend::Native(Box::new(model)), cfg, opts))
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    /// Lifecycle + generated-token count of a running request (None once
    /// finished or never admitted) — observability for tests and the
    /// server's introspection.
    pub fn progress(&self, id: RequestId) -> Option<(RequestState, usize)> {
        self.running.get(&id).map(|t| (t.state, t.generated.len()))
    }

    pub fn cache_report(&self) -> crate::kvcache::MemoryReport {
        self.cache.report()
    }

    /// Submit a request; rejects under backpressure (or an empty prompt,
    /// options this engine cannot honor, or an empty tenant bucket).
    pub fn submit(&mut self, req: Request) -> std::result::Result<(), RejectReason> {
        if let AdmitDecision::Reject(why) = self.admit_decision(&req, 0) {
            return Err(self.reject(&req.tenant, why));
        }
        if !self.bucket_admits(&req.tenant) {
            return Err(self.reject(&req.tenant, RejectReason::TenantThrottled));
        }
        self.metrics.requests_submitted += 1;
        self.metrics.tenant(&req.tenant).admitted += 1;
        self.trace.record(req.id, TraceKind::Admitted);
        self.queue.push_back(Tracked::new(req));
        Ok(())
    }

    /// Count a rejection (global + per-tenant) and hand the reason back,
    /// so rejection paths read `return Err(self.reject(tenant, why))`.
    fn reject(&mut self, tenant: &str, why: RejectReason) -> RejectReason {
        self.metrics.requests_rejected += 1;
        if why == RejectReason::TenantThrottled {
            self.metrics.tenant_throttled += 1;
            self.metrics.tenant(tenant).throttled += 1;
        }
        why
    }

    /// Spend one token from the tenant's bucket; vacuously true when no
    /// rate limit is configured.  The clock is the engine's own uptime,
    /// so refill arithmetic never sees wall-clock jumps.
    fn bucket_admits(&mut self, tenant: &str) -> bool {
        let now_s = self.metrics.started.elapsed().as_secs_f64();
        match self.tenant_buckets.as_mut() {
            Some(b) => b.try_admit(tenant, now_s),
            None => true,
        }
    }

    /// Would this request be admitted right now?  Checks option
    /// compatibility (per-request SnapKV needs a whole-prompt native
    /// engine) before the queue/memory policy.  `resident_tokens` is the
    /// prompt prefix ALREADY paid for in the pool's physical counters (a
    /// session turn's live chain) — charging it again would reject long
    /// conversations for memory their history no longer needs.
    fn admit_decision(&self, req: &Request, resident_tokens: usize) -> AdmitDecision {
        if let Some(sk) = req.gen.snapkv {
            let capable = matches!(self.backend, Backend::Native(_)) && !self.chunked_prefill();
            if !capable || sk.budget == 0 || sk.window == 0 || sk.window > sk.budget {
                return AdmitDecision::Reject(RejectReason::UnsupportedOptions);
            }
        }
        let expected =
            req.prompt.len().saturating_sub(resident_tokens) + req.gen.max_new_tokens;
        self.opts.admission.admit(self.queue.len(), &self.cache, req.prompt.len(), expected)
    }

    // ------------------------------------------------------- streaming

    /// Send `ev` to the request's subscriber, if any.  A dropped receiver
    /// (client went away) is not an error — generation continues and the
    /// remaining events fall on the floor.
    fn emit(subs: &HashMap<RequestId, Sender<Event>>, id: RequestId, ev: Event) {
        if let Some(tx) = subs.get(&id) {
            let _ = tx.send(ev);
        }
    }

    /// Sample the request's next token.  The full-softmax logprob (two
    /// extra O(vocab) passes) is only computed when a subscriber will
    /// actually see the token event AND the request asked for logprobs.
    fn sample_token(
        subs: &HashMap<RequestId, Sender<Event>>,
        tr: &Tracked,
        logits: &[f32],
    ) -> (u32, f32) {
        let mut rng = token_rng(tr.req.gen.seed, tr.generated.len());
        let sampler = tr.req.gen.sampler();
        if tr.req.gen.logprobs && subs.contains_key(&tr.req.id) {
            sampler.sample_with_logprob(logits, &mut rng)
        } else {
            (sampler.sample(logits, &mut rng), 0.0)
        }
    }

    /// Append a freshly sampled token and do every piece of per-token
    /// bookkeeping in ONE place: the inter-token-latency sample, the
    /// decode counter, and the streaming `Token` event.  (The caller
    /// still owns first-token extras: `first_token_at` + the TTFT hist.)
    fn record_token(
        metrics: &mut Metrics,
        subs: &HashMap<RequestId, Sender<Event>>,
        tr: &mut Tracked,
        token: u32,
        logprob: f32,
    ) {
        tr.generated.push(token);
        let index = tr.generated.len() - 1;
        let now = Instant::now();
        if let Some(prev) = tr.last_token_at {
            let dt = now.duration_since(prev).as_secs_f64();
            metrics.itl.record_secs(dt);
            metrics.tenant(&tr.req.tenant).itl.record_secs(dt);
        }
        tr.last_token_at = Some(now);
        metrics.decode_tokens += 1;
        metrics.tenant(&tr.req.tenant).decode_tokens += 1;
        Self::emit(subs, tr.req.id, Event::Token { id: tr.req.id, token, logprob, index });
    }

    /// Submit with a live event stream: `Admitted` on admission, a
    /// `PrefillProgress` per granted chunk, a `Token` the step each token
    /// is sampled (with its logprob), then the terminal `Done` — or a
    /// single `Rejected` if admission refuses.  Default [`GenOptions`]
    /// keep the streamed rollout bit-identical to the one-shot `submit`
    /// path: same engine, same math, the events are just visibility.
    ///
    /// [`GenOptions`]: super::request::GenOptions
    pub fn submit_streaming(&mut self, req: Request) -> Receiver<Event> {
        let (tx, rx) = channel();
        let _ = self.submit_with_events(req, tx);
        rx
    }

    /// [`Engine::submit_streaming`] with a caller-provided sink (the
    /// server wires the connection's channel straight in).
    pub fn submit_with_events(
        &mut self,
        req: Request,
        events: Sender<Event>,
    ) -> std::result::Result<(), RejectReason> {
        let id = req.id;
        match self.submit(req) {
            Ok(()) => {
                let _ = events.send(Event::Admitted { id });
                self.subs.insert(id, events);
                Ok(())
            }
            Err(why) => {
                let _ = events.send(Event::Rejected { id, reason: why });
                Err(why)
            }
        }
    }

    /// Cancel a queued or running request: its cache (pages and fp tails)
    /// is released immediately, `Done` with `FinishReason::Cancelled`
    /// (carrying the tokens generated so far) goes to any subscriber, and
    /// the completion is returned.  `None` if the id is not live.  A
    /// cancelled session turn keeps the conversation resumable: tokens
    /// fed so far become history and the partially-extended chain stays
    /// attached to the session.
    pub fn cancel(&mut self, id: RequestId) -> Option<Completion> {
        if let Some(pos) = self.queue.iter().position(|t| t.req.id == id) {
            let mut tr = self.queue.remove(pos).expect("position from iter");
            // a queued turn never ran: history is unchanged, and the
            // chain it took at submit goes straight back to the session
            if let Some(turn) = tr.turn {
                if let Some(sess) = self.sessions.get_mut(&turn.session) {
                    sess.active = None;
                    sess.last_active = Instant::now();
                    if let Some(chain) = tr.resume.take() {
                        sess.cache = Some(chain);
                    }
                }
            }
            return Some(self.finish_cancelled(tr));
        }
        let mut tr = self.running.remove(&id)?;
        self.prefill_order.retain(|&x| x != id);
        tr.state = RequestState::Finished;
        self.stash_session(&tr);
        self.cache.release(id);
        Some(self.finish_cancelled(tr))
    }

    fn finish_cancelled(&mut self, mut tr: Tracked) -> Completion {
        tr.finished_at = Some(Instant::now());
        self.metrics.requests_cancelled += 1;
        self.trace.record(
            tr.req.id,
            TraceKind::Done {
                finish_reason: FinishReason::Cancelled.as_str(),
                tokens: tr.generated.len() as u32,
            },
        );
        let c = Completion {
            id: tr.req.id,
            prompt_len: tr.req.prompt.len(),
            tokens: tr.generated.clone(),
            ttft_s: tr.ttft(),
            total_s: tr.total_latency(),
            truncated: false,
            rejected: false,
            reason: None,
            finish_reason: FinishReason::Cancelled,
        };
        if let Some(tx) = self.subs.remove(&tr.req.id) {
            let _ = tx.send(Event::Done(c.clone()));
        }
        c
    }

    // -------------------------------------------------------- sessions

    /// Open (or ensure) a conversation keyed `sid`.  Turns submitted via
    /// [`Engine::submit_turn`] share one KV chain; `end_session` frees it.
    pub fn open_session(&mut self, sid: u64) {
        self.sessions.entry(sid).or_default();
    }

    pub fn has_session(&self, sid: u64) -> bool {
        self.sessions.contains_key(&sid)
    }

    /// Tokens the session's live chain holds (tests/observability).
    pub fn session_cached_tokens(&self, sid: u64) -> Option<usize> {
        self.sessions.get(&sid)?.cache.as_ref().map(|c| c.lock().unwrap().len())
    }

    /// Close a conversation: cancels its in-flight turn (if any) and
    /// drops the session's KV chain — its pages return to the pool as
    /// soon as the last handle drops.  Returns false for an unknown sid.
    pub fn end_session(&mut self, sid: u64) -> bool {
        let Some(sess) = self.sessions.remove(&sid) else { return false };
        if let Some(active) = sess.active {
            // the session is already gone, so cancel() takes the plain
            // (non-stashing) path and the chain drops with `sess`
            self.cancel(active);
        }
        true
    }

    /// Submit the next turn of conversation `sid`.  `req.prompt` carries
    /// ONLY the turn's new tokens; the engine prepends the session
    /// history, and — on chunked engines — re-attaches the conversation's
    /// live chain so prefill runs only over the new tokens (plus the one
    /// still-unfed token of the previous turn).  Events flow to `events`
    /// exactly as for [`Engine::submit_with_events`]; the `Done`
    /// completion's tokens are THIS turn's generation.
    pub fn submit_turn(
        &mut self,
        sid: u64,
        req: Request,
        events: Sender<Event>,
    ) -> std::result::Result<(), RejectReason> {
        let resumable = self.chunked_prefill();
        if resumable {
            // a reaped session's chain comes back from the disk tier
            // BEFORE the resident-token read below, so admission charges
            // the warm-started turn exactly like an unreaped one
            self.promote_session(sid);
        }
        let id = req.id;
        // read session state WITHOUT creating an entry: a rejected turn
        // must not plant a zombie session the engine never cleans up
        let (history, resident, busy) = match self.sessions.get(&sid) {
            Some(sess) => (
                sess.tokens.clone(),
                // the resumed chain's tokens are already counted in the
                // pool's physical bytes; admission charges only the
                // turn's NEW footprint
                if resumable {
                    sess.cache.as_ref().map(|h| h.lock().unwrap().len()).unwrap_or(0)
                } else {
                    0
                },
                sess.active.is_some(),
            ),
            None => (Vec::new(), 0, false),
        };
        if busy {
            let why = self.reject(&req.tenant, RejectReason::SessionBusy);
            let _ = events.send(Event::Rejected { id, reason: why });
            return Err(why);
        }
        let new_tokens = req.prompt.len();
        let mut prompt = history;
        prompt.extend_from_slice(&req.prompt);
        let full = Request { id, session: Some(sid), tenant: req.tenant, prompt, gen: req.gen };
        if let AdmitDecision::Reject(why) = self.admit_decision(&full, resident) {
            let why = self.reject(&full.tenant, why);
            let _ = events.send(Event::Rejected { id, reason: why });
            return Err(why);
        }
        if !self.bucket_admits(&full.tenant) {
            let why = self.reject(&full.tenant, RejectReason::TenantThrottled);
            let _ = events.send(Event::Rejected { id, reason: why });
            return Err(why);
        }
        self.metrics.requests_submitted += 1;
        self.metrics.tenant(&full.tenant).admitted += 1;
        self.metrics.session_turns += 1;
        self.trace.record(id, TraceKind::Admitted);
        let mut tr = Tracked::new(full);
        // TAKE the chain (don't clone): while the turn is in flight the
        // Tracked owns the only session-side handle, so a preemption's
        // cache.reset actually returns the old chain's pages to the pool
        // instead of leaving them pinned by the Session
        let sess = self.sessions.entry(sid).or_default();
        sess.tenant = tr.req.tenant.clone();
        tr.resume = if resumable { sess.cache.take() } else { None };
        sess.active = Some(id);
        sess.last_active = Instant::now();
        tr.turn = Some(TurnInfo { session: sid, new_tokens });
        let _ = events.send(Event::Admitted { id });
        self.subs.insert(id, events);
        self.queue.push_back(tr);
        Ok(())
    }

    /// A finished (or cancelled mid-flight) session turn hands its state
    /// back to the session: history becomes prompt ++ generated, and — on
    /// chunked engines, which can resume — the live chain stays attached
    /// so the NEXT turn prefills only its own tokens.  Must run BEFORE
    /// the request's cache handle is released.
    fn stash_session(&mut self, tr: &Tracked) {
        let resumable = self.chunked_prefill();
        let Some(turn) = tr.turn else { return };
        let handle = if resumable { self.cache.get(tr.req.id) } else { None };
        let Some(sess) = self.sessions.get_mut(&turn.session) else { return };
        sess.active = None;
        sess.last_active = Instant::now();
        sess.tokens = tr.req.prompt.clone();
        sess.tokens.extend_from_slice(&tr.generated);
        sess.cache = handle;
    }

    /// Demote every idle session's KV chain to the disk tier once it has
    /// been untouched for `--session-ttl` (no-op without a TTL or a
    /// tier).  The chain is serialized PRIVATELY — a session's pages are
    /// cut at the conversation's own chunk boundaries, so they must never
    /// enter the shared prefix index — and the session keeps a `TierRef`,
    /// so the next turn warm-starts from disk instead of re-prefilling
    /// the whole history.  Returns the number of sessions reaped.
    pub fn reap_idle_sessions(&mut self) -> usize {
        let Some(ttl) = self.session_ttl else { return 0 };
        if self.tier.is_none() {
            return 0;
        }
        let tag = config_fingerprint(&self.cfg, self.opts.value_bits);
        let sids: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| {
                s.active.is_none() && s.cache.is_some() && s.last_active.elapsed() >= ttl
            })
            .map(|(&sid, _)| sid)
            .collect();
        let mut reaped = 0;
        for sid in sids {
            let Some((chain, tenant)) = self
                .sessions
                .get_mut(&sid)
                .and_then(|s| s.cache.take().map(|c| (c, s.tenant.clone())))
            else {
                continue;
            };
            let blob = encode_session(&chain.lock().unwrap(), tag);
            match self.cache.pool().session_spill(&blob, &tenant, self.tenant_tier_bytes) {
                Ok(r) => {
                    self.sessions.get_mut(&sid).unwrap().tiered = Some(r);
                    self.metrics.sessions_reaped += 1;
                    // background maintenance, not tied to a request (id 0)
                    self.trace.record(0, TraceKind::SessionReap { session: sid });
                    reaped += 1;
                    // `chain` drops here: the pages go back to the pool
                }
                Err(_) => {
                    // disk error or the tenant's `--tenant-tier-bytes`
                    // quota ran dry: keep the chain resident rather than
                    // silently forgetting the conversation's KV state
                    self.sessions.get_mut(&sid).unwrap().cache = Some(chain);
                }
            }
        }
        reaped
    }

    /// Bring a reaped session's chain back from the disk tier (no-op for
    /// live, unknown, or never-reaped sessions).  A blob that fails the
    /// checksum or config fingerprint is dropped: the turn falls back to
    /// a cold re-prefill of the history, which is correct, just slower.
    fn promote_session(&mut self, sid: u64) {
        let Some(sess) = self.sessions.get_mut(&sid) else { return };
        if sess.cache.is_some() || sess.active.is_some() || sess.tiered.is_none() {
            return;
        }
        let r = sess.tiered.take().expect("checked above");
        let tenant = sess.tenant.clone();
        let tag = config_fingerprint(&self.cfg, self.opts.value_bits);
        let Ok(bytes) = self.cache.pool().session_fetch(r, &tenant) else { return };
        let Ok(blob) = decode_session(&bytes, tag) else { return };
        // make room, best-effort: a shortfall means a transient overshoot
        // (same stance as the lone decoder), not a refused warm start
        let _ = self.cache.pool().try_free(blob.pages.len());
        let pool = self.cache.pool().clone();
        let pages = blob.pages.into_iter().map(|p| pool.adopt(p)).collect();
        let mut seq = SequenceCache::new_pooled(self.cache.config().clone(), pool);
        seq.adopt_pages(pages);
        seq.restore_tail(blob.tails, blob.next_pos);
        let sess = self.sessions.get_mut(&sid).expect("session checked above");
        sess.cache = Some(Arc::new(Mutex::new(seq)));
        sess.last_active = Instant::now();
        self.metrics.sessions_restored += 1;
        self.trace.record(0, TraceKind::SessionRestore { session: sid });
    }

    /// True when this engine runs the chunked-prefill continuous loop
    /// (native backend, `prefill_chunk > 0`; SnapKV needs whole-prompt
    /// importance, so it keeps the inline path).
    fn chunked_prefill(&self) -> bool {
        self.opts.prefill_chunk > 0
            && self.opts.snapkv.is_none()
            && matches!(self.backend, Backend::Native(_))
    }

    /// One scheduler iteration; returns completions.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        if self.session_ttl.is_some() {
            self.reap_idle_sessions();
        }
        let chunked = self.chunked_prefill();
        let plan = if chunked {
            let prefilling = self.prefill_order.len();
            let decoding = self.running.len() - prefilling;
            self.opts.policy.plan_chunked(self.queue.len(), prefilling, decoding)
        } else {
            self.opts.policy.plan(self.queue.len(), self.running.len())
        };
        if let Some(wfq) = self.wfq.as_mut() {
            // admission order = pass order: the least-served tenant's
            // request moves to the front of the wait queue
            wfq.reorder(self.queue.make_contiguous(), |t| t.req.tenant.as_str());
        }
        for _ in 0..plan.admit {
            let Some(mut tr) = self.queue.pop_front() else { break };
            self.metrics
                .queue_delay
                .record_secs(tr.arrived.elapsed().as_secs_f64());
            if chunked {
                tr.state = RequestState::Prefilling;
                if let Some(handle) = tr.resume.take() {
                    // session turn: the conversation's live chain IS this
                    // request's cache; prefill resumes after its tokens
                    let held = handle.lock().unwrap().len();
                    self.cache.insert(tr.req.id, handle);
                    tr.prefill_pos = held;
                    self.metrics.prefix_hits += 1;
                    self.metrics.prefix_tokens_reused += held as u64;
                    self.metrics.session_tokens_reused += held as u64;
                } else {
                    self.cache.create(tr.req.id);
                    if self.prefix_caching() {
                        self.adopt_prefix(&mut tr);
                    }
                }
                Self::emit(
                    &self.subs,
                    tr.req.id,
                    Event::PrefillProgress {
                        id: tr.req.id,
                        done: tr.prefill_pos,
                        total: tr.req.prompt.len(),
                    },
                );
                self.prefill_order.push_back(tr.req.id);
            } else {
                self.prefill_one(&mut tr)?;
            }
            self.running.insert(tr.req.id, tr);
        }
        if chunked && !self.prefill_order.is_empty() {
            self.prefill_chunk_phase()?;
        }
        let mut done = Vec::new();
        // the plan says decode MAY run; confirm against actual states
        // (chunked admissions can still be mid-prefill)
        if plan.decode && self.running.values().any(|t| t.state == RequestState::Decoding) {
            if chunked {
                // every decoding sequence must be able to cut its next
                // page; reclaim or preempt BEFORE the step so the append
                // path deep in the model never has to fail
                self.ensure_decode_pages();
            }
            self.decode_iteration(&mut done)?;
        }
        // paged-cache + tier gauges ride along on every step
        self.metrics.pages_in_use = self.cache.pool().pages_in_use() as u64;
        self.metrics.pages_evicted = self.cache.pool().pages_evicted();
        self.metrics.tier_hits = self.cache.pool().tier_hits();
        self.metrics.pages_demoted = self.cache.pool().pages_demoted();
        self.metrics.pages_promoted = self.cache.pool().pages_promoted();
        self.metrics.bytes_on_disk = self.cache.pool().bytes_on_disk();
        self.metrics.tier_session_bytes = self.cache.pool().session_bytes();
        Ok(done)
    }

    /// Run until every queued/running request finishes.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    // ---------------------------------------------------------- prefill

    /// Attach the longest already-pooled prefix of this prompt
    /// (refcounted page shares) and jump the prefill cursor past it.
    ///
    /// Bit-identity argument: pages only register under eager,
    /// group-aligned chunking with ALIGNED grants (prefix mode plans
    /// prefill chunks with `aligned = true`, so no sequence ever receives
    /// a partial leftover-budget grant) — page `g` is therefore a
    /// deterministic function of `prompt[..(g+1)*group]`, independent of
    /// concurrent traffic.  Adoption is additionally truncated to a CHUNK
    /// multiple — from there on, a shared prefill's chunk boundaries,
    /// cache state, and therefore every K/V it computes coincide exactly
    /// with the cold prefill's.  Greedy decode over the resulting cache
    /// is then the same computation either way.
    fn adopt_prefix(&mut self, tr: &mut Tracked) {
        let chunk = self.opts.prefill_chunk;
        let prompt = &tr.req.prompt;
        // always leave >= 1 token to prefill: the final chunk produces the
        // logits the first sampled token comes from
        let max_share = (prompt.len().saturating_sub(1) / chunk) * chunk;
        if max_share == 0 {
            return;
        }
        let group = self.cfg.group;
        // the traced variant attributes any tier promotion this lookup
        // triggers to the adopting request
        let mut pages =
            self.cache.pool().lookup_prefix_traced(prompt, group, max_share, tr.req.id);
        // truncate the hit to a chunk boundary (see above)
        pages.truncate((pages.len() * group / chunk) * chunk / group);
        if pages.is_empty() {
            return;
        }
        let shared = pages.iter().map(|p| p.tokens).sum::<usize>();
        let handle = self.cache.get(tr.req.id).expect("cache created at admission");
        handle.lock().unwrap().adopt_pages(pages);
        tr.prefill_pos = shared;
        self.metrics.prefix_hits += 1;
        self.metrics.prefix_tokens_reused += shared as u64;
    }

    /// Run this step's prefill-chunk grants: at most one chunk's worth of
    /// prompt tokens total (FCFS across prefilling sequences), so decode
    /// iterations never wait longer than one chunk's compute.  A sequence
    /// whose last chunk lands here samples its first token and moves to
    /// `Decoding` in the same step — unless it is recovering from a
    /// preemption, in which case its next tokens are already known and
    /// the decode phase replays them instead.
    fn prefill_chunk_phase(&mut self) -> Result<()> {
        let chunk = self.opts.prefill_chunk;
        let eager = self.opts.prefill_quantize_eagerly || self.prefix_caching();
        let group = self.cfg.group;
        let stalled = self.running.values().any(|t| t.state == RequestState::Decoding);
        let t0 = Instant::now();
        let mut remaining: Vec<(RequestId, usize)> = self
            .prefill_order
            .iter()
            .map(|&id| (id, self.running[&id].prefill_remaining()))
            .collect();
        if let Some(wfq) = self.wfq.as_mut() {
            // chunk grants follow pass order too, so a tenant mid-flood
            // yields the prefill budget to less-served tenants
            let running = &self.running;
            wfq.reorder(&mut remaining, |&(id, _)| running[&id].req.tenant.as_str());
        }
        // prefix mode demands ALIGNED grants: every sequence's chunk
        // boundaries must sit at fixed multiples of `chunk` regardless of
        // concurrent prefill traffic, or the eagerly quantized pages it
        // registers would not be a pure function of the token prefix
        let aligned = self.prefix_caching();
        for (gi, (id, take)) in
            plan_prefill_chunks(&remaining, chunk, chunk, aligned).into_iter().enumerate()
        {
            let shared = self.cache.get(id).context("prefilling sequence lost its cache")?;
            // page budget for what this grant will finalize: eager mode
            // cuts pages as the chunk lands, exact mode all at once on the
            // finishing flush.  If the pool can't cover it even after LRU
            // reclaim, skip the grant — decoders keep draining and free
            // pages — EXCEPT for the head-of-queue grant, which always
            // proceeds (transient overshoot beats a stall with nothing
            // decoding).
            {
                let cache = shared.lock().unwrap();
                let tr = &self.running[&id];
                let finishing = tr.prefill_pos + take == tr.req.prompt.len();
                let tokens_after = if eager || finishing { tr.prefill_pos + take } else { 0 };
                let need = pages_needed(tokens_after, cache.pages.len(), group);
                if need > 0 && !self.cache.pool().try_free(need) && gi > 0 {
                    continue;
                }
            }
            let chunk_t = Instant::now();
            let logits = {
                let Backend::Native(model) = &mut self.backend else {
                    bail!("chunked prefill requires the native backend");
                };
                let tr = &self.running[&id];
                let pos = tr.prefill_pos;
                // only the prompt's final chunk needs the lm_head pass,
                // and only when a first token will actually be sampled
                // (a preemption-recovery prefill never samples)
                let finishing = pos + take == tr.req.prompt.len();
                let mut cache = shared.lock().unwrap();
                model.prefill_chunk(
                    &tr.req.prompt[pos..pos + take],
                    pos,
                    &mut cache,
                    eager,
                    finishing && tr.generated.is_empty(),
                )
            };
            let chunk_elapsed = chunk_t.elapsed();
            let tr = self.running.get_mut(&id).unwrap();
            self.trace.record(
                id,
                TraceKind::PrefillChunk {
                    start: tr.prefill_pos as u32,
                    tokens: take as u32,
                    us: chunk_elapsed.as_micros() as u32,
                },
            );
            tr.prefill_pos += take;
            self.metrics.prefill_tokens += take as u64;
            self.metrics.prefill_chunks += 1;
            self.metrics.prefill_chunk_us.record_secs(chunk_elapsed.as_secs_f64());
            if let Some(wfq) = self.wfq.as_mut() {
                wfq.charge(&tr.req.tenant, take);
            }
            Self::emit(
                &self.subs,
                id,
                Event::PrefillProgress { id, done: tr.prefill_pos, total: tr.req.prompt.len() },
            );
            if tr.prefill_remaining() == 0 {
                if !eager {
                    // quantize full groups now, in append order — the same
                    // pages the unchunked path would have produced
                    shared.lock().unwrap().flush_groups();
                }
                if self.prefix_caching() {
                    // register the prompt's pages for future sharers, ONCE
                    // per prefill (per-chunk registration would re-hash the
                    // whole prefix every chunk — O(prompt²/chunk)).
                    // Idempotent, and generated-region pages never
                    // register: the token slice bound stops at the prompt.
                    let cache = shared.lock().unwrap();
                    let tr = &self.running[&id];
                    self.cache.pool().register_prefix_for(
                        &cache.pages,
                        &tr.req.prompt,
                        &tr.req.tenant,
                    );
                }
                let tr = self.running.get_mut(&id).unwrap();
                if tr.generated.is_empty() {
                    let (tok, lp) = Self::sample_token(&self.subs, tr, &logits);
                    Self::record_token(&mut self.metrics, &self.subs, tr, tok, lp);
                    tr.first_token_at = tr.last_token_at;
                    self.metrics.ttft.record_secs(tr.arrived.elapsed().as_secs_f64());
                }
                // else: preemption recovery — tokens already exist; the
                // decode phase replays them into the rebuilt cache
                tr.state = RequestState::Decoding;
            }
        }
        self.prefill_order
            .retain(|id| self.running.get(id).is_some_and(|t| t.state == RequestState::Prefilling));
        if stalled {
            self.metrics.decode_stall.record_secs(t0.elapsed().as_secs_f64());
        }
        Ok(())
    }

    // ------------------------------------------------- preemptive eviction

    /// Make sure every decoding sequence can cut the page its next append
    /// might need.  Shortfall order: reclaim LRU refcount-zero prefix
    /// pages, then preempt the youngest decoding sequence (release its
    /// pages, requeue it through chunked prefill) — repeatedly, until the
    /// demand fits or only one decoder remains (which is then allowed a
    /// transient overshoot rather than preempting itself forever).
    fn ensure_decode_pages(&mut self) {
        if !self.cache.pool().bounded() {
            return;
        }
        let group = self.cfg.group;
        loop {
            let mut decoding: Vec<(RequestId, Instant)> = Vec::new();
            let mut need = 0usize;
            for (&id, tr) in &self.running {
                if tr.state != RequestState::Decoding || tr.done() {
                    continue;
                }
                decoding.push((id, tr.arrived));
                if let Some(c) = self.cache.get(id) {
                    let c = c.lock().unwrap();
                    // a speculative burst appends up to `speculate + 1`
                    // tokens in one iteration (its window never crosses a
                    // group boundary, but it can land exactly ON one)
                    let lookahead = 1 + self.opts.speculate;
                    need += pages_needed(c.len() + lookahead, c.pages.len(), group);
                }
            }
            if need == 0 || self.cache.pool().try_free(need) {
                return;
            }
            if decoding.len() <= 1 {
                // preempting the only decoder cannot help anyone — let it
                // overshoot by its one page and keep making progress
                return;
            }
            let victim = pick_preemption_victim(&decoding).expect("nonempty");
            self.preempt(victim);
        }
    }

    /// Release the sequence's pages and send it back through chunked
    /// prefill.  Its generated tokens are kept: the recovery prefill
    /// rebuilds the prompt region (re-attaching any still-cached prefix
    /// pages for free), then the decode phase REPLAYS the generated
    /// tokens — feeding each known token without sampling — until the
    /// cache catches back up.  In exact (deferred) chunking mode the
    /// replayed computation is the original one, so the victim's final
    /// rollout is bit-identical to an unpreempted run.
    fn preempt(&mut self, id: RequestId) {
        let tr = self.running.get_mut(&id).expect("victim is running");
        debug_assert_eq!(tr.state, RequestState::Decoding);
        tr.state = RequestState::Prefilling;
        tr.prefill_pos = 0;
        if self.trace.enabled() {
            let pages = self
                .cache
                .get(id)
                .map(|c| c.lock().unwrap().pages.len())
                .unwrap_or(0);
            self.trace.record(id, TraceKind::PagePreempt { pages: pages as u32 });
        }
        self.cache.reset(id);
        if self.prefix_caching() {
            let mut tr = self.running.remove(&id).expect("victim is running");
            self.adopt_prefix(&mut tr);
            self.running.insert(id, tr);
        }
        self.prefill_order.push_back(id);
        self.metrics.preemptions += 1;
    }

    fn prefill_one(&mut self, tr: &mut Tracked) -> Result<()> {
        tr.state = RequestState::Prefilling;
        let id = tr.req.id;
        let prompt = tr.req.prompt.clone();
        self.metrics.prefill_tokens += prompt.len() as u64;
        if let Some(wfq) = self.wfq.as_mut() {
            wfq.charge(&tr.req.tenant, prompt.len());
        }

        // per-request SnapKV override beats the engine default; admission
        // already guaranteed this engine can honor it
        let snapkv = tr.req.gen.snapkv.or(self.opts.snapkv);
        let chunk_t = Instant::now();
        let logits = match &mut self.backend {
            Backend::Native(model) => {
                if let Some(sk) = snapkv {
                    let (logits, k, v, imp) =
                        model.prefill_kv_importance(&prompt, sk.window);
                    let keep = snapkv_select(&imp, sk.budget, sk.window);
                    self.metrics.snapkv_tokens_dropped += (prompt.len() - keep.len()) as u64;
                    let shared = self.cache.create(id);
                    let mut cache = shared.lock().unwrap();
                    let (l, kv, dh, t) =
                        (cache.cfg.n_layers, cache.cfg.n_kv_heads, cache.cfg.head_dim, prompt.len());
                    // gather kept rows per (layer, head) stream
                    let mut k_kept = Vec::with_capacity(l * kv * keep.len() * dh);
                    let mut v_kept = Vec::with_capacity(l * kv * keep.len() * dh);
                    for li in 0..l {
                        for h in 0..kv {
                            let off = (li * kv + h) * t * dh;
                            k_kept.extend(gather_rows(&k[off..off + t * dh], dh, &keep));
                            v_kept.extend(gather_rows(&v[off..off + t * dh], dh, &keep));
                        }
                    }
                    cache.append_prefill(&k_kept, &v_kept, keep.len());
                    // positions continue from the ORIGINAL prompt length
                    cache.next_pos = t;
                    logits
                } else {
                    let shared = self.cache.create(id);
                    let mut cache = shared.lock().unwrap();
                    model.prefill(&prompt, &mut cache)
                }
            }
            Backend::Pjrt(rt) => {
                let g = rt
                    .manifest
                    .pick_bucket("prefill", 1, prompt.len())
                    .with_context(|| {
                        format!("no prefill bucket fits prompt of {}", prompt.len())
                    })?
                    .clone();
                let mut tokens = vec![0i32; g.batch * g.seq];
                for (i, &t) in prompt.iter().enumerate() {
                    tokens[i] = t as i32;
                }
                let mut plen = vec![1i32; g.batch];
                plen[0] = prompt.len() as i32;
                let out = rt.prefill(&g.name, &tokens, &plen)?;
                let cfg = &self.cfg;
                let k = split_prefill_kv(
                    &out.k, cfg.n_layers, g.batch, cfg.n_kv_heads, g.seq, cfg.head_dim, 0,
                );
                let v = split_prefill_kv(
                    &out.v, cfg.n_layers, g.batch, cfg.n_kv_heads, g.seq, cfg.head_dim, 0,
                );
                // keep only the valid region of the padded bucket
                let t = prompt.len();
                let (l, kv, dh) = (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim);
                let mut k_valid = Vec::with_capacity(l * kv * t * dh);
                let mut v_valid = Vec::with_capacity(l * kv * t * dh);
                for li in 0..l {
                    for h in 0..kv {
                        let off = (li * kv + h) * g.seq * dh;
                        k_valid.extend_from_slice(&k[off..off + t * dh]);
                        v_valid.extend_from_slice(&v[off..off + t * dh]);
                    }
                }
                let shared = self.cache.create(id);
                shared.lock().unwrap().append_prefill(&k_valid, &v_valid, t);
                out.logits[..self.cfg.vocab].to_vec()
            }
        };

        // first generated token comes from the prefill logits
        tr.prefill_pos = prompt.len();
        // whole-prompt prefill is one big chunk as far as the trace goes
        let chunk_elapsed = chunk_t.elapsed();
        self.trace.record(
            id,
            TraceKind::PrefillChunk {
                start: 0,
                tokens: prompt.len() as u32,
                us: chunk_elapsed.as_micros() as u32,
            },
        );
        self.metrics.prefill_chunk_us.record_secs(chunk_elapsed.as_secs_f64());
        Self::emit(
            &self.subs,
            id,
            Event::PrefillProgress { id, done: prompt.len(), total: prompt.len() },
        );
        let (tok, lp) = Self::sample_token(&self.subs, tr, &logits);
        Self::record_token(&mut self.metrics, &self.subs, tr, tok, lp);
        tr.first_token_at = tr.last_token_at;
        self.metrics.ttft.record_secs(tr.arrived.elapsed().as_secs_f64());
        tr.state = RequestState::Decoding;
        Ok(())
    }

    // ----------------------------------------------------------- decode

    fn decode_iteration(&mut self, done: &mut Vec<Completion>) -> Result<()> {
        let step_t = Instant::now();
        let ids: Vec<RequestId> = self.running.keys().cloned().collect();
        // collect (id, quantized cache len) for batching; sequences still
        // prefilling (chunked mode) don't decode yet.  `feeds` carries the
        // token each sequence steps on: normally its last generated token,
        // but a sequence recovering from preemption REPLAYS its known
        // generated tokens (cache behind by k steps -> feed generated[fed]
        // without sampling) until the cache catches back up.
        let mut seqs: Vec<(u64, usize)> = Vec::new();
        let mut feeds: HashMap<RequestId, (u32, bool)> = HashMap::new();
        for &id in &ids {
            let tr = &self.running[&id];
            if tr.state != RequestState::Decoding || tr.done() {
                continue;
            }
            let Some(c) = self.cache.get(id) else { continue };
            let (qlen, next_pos) = {
                let c = c.lock().unwrap();
                (c.quantized_len(), c.next_pos)
            };
            let fed = next_pos - tr.req.prompt.len();
            let feed = if fed + 1 < tr.generated.len() {
                (tr.generated[fed], true) // replay: token known, no sample
            } else {
                debug_assert_eq!(fed + 1, tr.generated.len());
                (*tr.generated.last().unwrap(), false)
            };
            feeds.insert(id, feed);
            seqs.push((id, qlen));
        }

        // decoded sequence count this iteration (drives decode_steps /
        // decode_batch_sum identically on both backends)
        let mut decoded = 0usize;
        let mut truncated: HashSet<RequestId> = HashSet::new();
        match &mut self.backend {
            Backend::Native(model) => {
                if let Some(pool) = self.pool.as_mut().filter(|_| seqs.len() > 1) {
                    // Thread-parallel path: fan balanced cache-length
                    // shards over the fixed pool.  Shards are disjoint, so
                    // every per-sequence lock the workers take is
                    // uncontended; the engine thread only rejoins at
                    // flush().
                    let shards = plan_decode_shards(&seqs, pool.width());
                    for (w, shard) in shards.iter().enumerate() {
                        for &id in shard {
                            let tr = &self.running[&id];
                            let cache = self.cache.get(id).context("cache missing")?;
                            let (last_token, replay) = feeds[&id];
                            let sampler = tr.req.gen.sampler();
                            // speculation is greedy-only (verification
                            // compares argmax choices) and never runs
                            // during preemption replay (those tokens are
                            // already known)
                            let speculate = if !replay && sampler == Sampler::Greedy {
                                self.opts.speculate
                            } else {
                                0
                            };
                            pool.submit(
                                w,
                                DecodeTask {
                                    id,
                                    cache,
                                    last_token,
                                    sampler,
                                    // derived per token, so the sample is
                                    // shard-assignment-independent
                                    rng: token_rng(tr.req.gen.seed, tr.generated.len()),
                                    want_logprob: tr.req.gen.logprobs
                                        && self.subs.contains_key(&id),
                                    replay,
                                    speculate,
                                    max_emit: tr.req.gen.max_new_tokens - tr.generated.len(),
                                    stops: tr.req.gen.stop_tokens.clone(),
                                },
                            );
                        }
                    }
                    let mut results = std::mem::take(&mut self.step_results);
                    results.clear();
                    pool.flush(&mut results);
                    for r in &results {
                        if r.replay {
                            continue; // cache rebuilt; token already known
                        }
                        if r.drafted > 0 {
                            self.metrics.speculative_rounds += 1;
                            self.metrics.speculative_drafted += r.drafted as u64;
                            self.metrics.speculative_accepted += r.accepted as u64;
                        }
                        let tr = self.running.get_mut(&r.id).unwrap();
                        for &(tok, lp) in &r.tokens {
                            Self::record_token(&mut self.metrics, &self.subs, tr, tok, lp);
                        }
                        if let Some(wfq) = self.wfq.as_mut() {
                            wfq.charge(&tr.req.tenant, r.tokens.len());
                        }
                    }
                    self.step_results = results;
                } else {
                    for &(id, _) in &seqs {
                        let (feed, replay) = feeds[&id];
                        let shared = self.cache.get(id).context("cache missing")?;
                        let tr = &self.running[&id];
                        // same eligibility as the pooled path: greedy,
                        // not replaying, draft plane configured
                        if self.opts.speculate > 0
                            && !replay
                            && tr.req.gen.sampler() == Sampler::Greedy
                            && model.draft_spec().is_some()
                        {
                            let max_emit = tr.req.gen.max_new_tokens - tr.generated.len();
                            let stops = tr.req.gen.stop_tokens.clone();
                            let want_lp = tr.req.gen.logprobs && self.subs.contains_key(&id);
                            let t0 = self.trace.enabled().then(Instant::now);
                            if t0.is_some() {
                                // the model records the speculative round
                                // itself; key it to this request
                                model.set_trace_request(id);
                            }
                            let (out, pos) = {
                                let mut cache = shared.lock().unwrap();
                                let out = model.speculative_decode(
                                    feed,
                                    &mut cache,
                                    self.opts.speculate,
                                    max_emit,
                                    &stops,
                                    want_lp,
                                );
                                (out, cache.len())
                            };
                            if let Some(t0) = t0 {
                                self.trace.record(
                                    id,
                                    TraceKind::DecodeStep {
                                        pos: pos as u32,
                                        us: t0.elapsed().as_micros() as u32,
                                    },
                                );
                            }
                            if out.drafted > 0 {
                                self.metrics.speculative_rounds += 1;
                                self.metrics.speculative_drafted += out.drafted as u64;
                                self.metrics.speculative_accepted += out.accepted as u64;
                            }
                            let tr = self.running.get_mut(&id).unwrap();
                            for &(tok, lp) in &out.tokens {
                                Self::record_token(&mut self.metrics, &self.subs, tr, tok, lp);
                            }
                            if let Some(wfq) = self.wfq.as_mut() {
                                wfq.charge(&tr.req.tenant, out.tokens.len());
                            }
                            continue;
                        }
                        let t0 = self.trace.enabled().then(Instant::now);
                        let mut cache = shared.lock().unwrap();
                        let logits = model.decode_step(feed, &mut cache).to_vec();
                        let pos = cache.len();
                        drop(cache);
                        if replay {
                            continue; // cache rebuilt; token already known
                        }
                        if let Some(t0) = t0 {
                            self.trace.record(
                                id,
                                TraceKind::DecodeStep {
                                    pos: pos as u32,
                                    us: t0.elapsed().as_micros() as u32,
                                },
                            );
                        }
                        let tr = self.running.get_mut(&id).unwrap();
                        let (tok, lp) = Self::sample_token(&self.subs, tr, &logits);
                        Self::record_token(&mut self.metrics, &self.subs, tr, tok, lp);
                        if let Some(wfq) = self.wfq.as_mut() {
                            wfq.charge(&tr.req.tenant, 1);
                        }
                    }
                }
                decoded = seqs.len();
            }
            Backend::Pjrt(rt) => {
                let (batches, overflow) =
                    plan_decode_batches(&rt.manifest, seqs.clone(), usize::MAX);
                truncated.extend(overflow);
                for b in &batches {
                    let cfg = &self.cfg;
                    let r_cap = cfg.resid;
                    let denses: Vec<_> = b
                        .ids
                        .iter()
                        .map(|&id| {
                            self.cache
                                .get(id)
                                .unwrap()
                                .lock()
                                .unwrap()
                                .export_dense(b.seq_cap, r_cap)
                        })
                        .collect();
                    let dense_refs: Vec<&_> = denses.iter().collect();
                    let mut ins = batch_dense(
                        &dense_refs,
                        cfg.n_layers,
                        cfg.n_kv_heads,
                        b.seq_cap,
                        r_cap,
                        cfg.head_dim,
                        cfg.group,
                        b.batch_cap,
                    );
                    for (lane, &id) in b.ids.iter().enumerate() {
                        let tr = &self.running[&id];
                        ins.tokens[lane] = *tr.generated.last().unwrap() as i32;
                        ins.positions[lane] =
                            self.cache.get(id).unwrap().lock().unwrap().next_pos as i32;
                    }
                    // one graph execution serves the whole batch; each
                    // lane's span carries the shared batch duration
                    let t0 = self.trace.enabled().then(Instant::now);
                    let out = rt.decode(&b.graph, &ins)?;
                    let (l, kv, dh, v) =
                        (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, cfg.vocab);
                    for (lane, &id) in b.ids.iter().enumerate() {
                        // de-batch new_k/new_v (L, B, Kv, dh) -> (L, Kv, dh)
                        let mut new_k = vec![0.0f32; l * kv * dh];
                        let mut new_v = vec![0.0f32; l * kv * dh];
                        for li in 0..l {
                            for h in 0..kv {
                                let src = ((li * b.batch_cap + lane) * kv + h) * dh;
                                let dst = (li * kv + h) * dh;
                                new_k[dst..dst + dh]
                                    .copy_from_slice(&out.new_k[src..src + dh]);
                                new_v[dst..dst + dh]
                                    .copy_from_slice(&out.new_v[src..src + dh]);
                            }
                        }
                        self.cache.get(id).unwrap().lock().unwrap().append_step(&new_k, &new_v);
                        if let Some(t0) = t0 {
                            let pos = self.cache.get(id).unwrap().lock().unwrap().len();
                            self.trace.record(
                                id,
                                TraceKind::DecodeStep {
                                    pos: pos as u32,
                                    us: t0.elapsed().as_micros() as u32,
                                },
                            );
                        }
                        let logits = &out.logits[lane * v..(lane + 1) * v];
                        let tr = self.running.get_mut(&id).unwrap();
                        let (tok, lp) = Self::sample_token(&self.subs, tr, logits);
                        Self::record_token(&mut self.metrics, &self.subs, tr, tok, lp);
                        if let Some(wfq) = self.wfq.as_mut() {
                            wfq.charge(&tr.req.tenant, 1);
                        }
                    }
                    decoded += b.ids.len();
                }
            }
        }
        if decoded > 0 {
            // one decode iteration — however many bucket batches it took
            self.metrics.decode_steps += 1;
            self.metrics.decode_batch_sum += decoded as u64;
            self.metrics
                .per_token
                .record_secs(step_t.elapsed().as_secs_f64());
        }

        // retire finished / truncated sequences (never mid-prefill)
        let now_ids: Vec<RequestId> = self.running.keys().cloned().collect();
        for id in now_ids {
            let is_trunc = truncated.contains(&id);
            let tr = &self.running[&id];
            let finished = is_trunc || (tr.state == RequestState::Decoding && tr.done());
            if finished {
                let mut tr = self.running.remove(&id).unwrap();
                tr.state = RequestState::Finished;
                tr.finished_at = Some(Instant::now());
                self.metrics.requests_finished += 1;
                self.metrics.tenant(&tr.req.tenant).finished += 1;
                self.metrics
                    .e2e
                    .record_secs(tr.arrived.elapsed().as_secs_f64());
                // session turns hand their chain back BEFORE release
                self.stash_session(&tr);
                self.cache.release(id);
                let finish_reason = if is_trunc {
                    FinishReason::Length
                } else {
                    tr.done_reason().unwrap_or(FinishReason::Length)
                };
                self.trace.record(
                    id,
                    TraceKind::Done {
                        finish_reason: finish_reason.as_str(),
                        tokens: tr.generated.len() as u32,
                    },
                );
                let c = Completion {
                    id,
                    prompt_len: tr.req.prompt.len(),
                    tokens: tr.generated.clone(),
                    ttft_s: tr.ttft(),
                    total_s: tr.total_latency(),
                    truncated: is_trunc,
                    rejected: false,
                    reason: None,
                    finish_reason,
                };
                if let Some(tx) = self.subs.remove(&id) {
                    let _ = tx.send(Event::Done(c.clone()));
                }
                done.push(c);
            }
        }
        Ok(())
    }
}

/// Fingerprint of everything that determines a page's bit pattern: the
/// model geometry + codec spec + value width.  Two engines share a tier
/// snapshot only when their fingerprints match — adopting pages cut
/// under any other config would be silently wrong, not just lossy.
fn config_fingerprint(cfg: &ModelConfig, value_bits: Option<u32>) -> u64 {
    let fields = [
        cfg.vocab as u64,
        cfg.d_model as u64,
        cfg.n_layers as u64,
        cfg.n_heads as u64,
        cfg.n_kv_heads as u64,
        cfg.head_dim as u64,
        cfg.ffn as u64,
        cfg.rope_base.to_bits() as u64,
        cfg.group as u64,
        cfg.r_bits as u64,
        cfg.t_bits as u64,
        value_bits.map(|b| b as u64 + 1).unwrap_or(0),
    ];
    let mut bytes = Vec::with_capacity(fields.len() * 8);
    for v in fields {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    crate::kvcache::tier::serde::fnv1a(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::tiny();
        cfg.n_layers = 2;
        cfg.vocab = 64;
        cfg.d_model = 32;
        cfg.n_heads = 4;
        cfg.n_kv_heads = 2;
        cfg.head_dim = 16;
        cfg.ffn = 48;
        cfg.group = 8;
        cfg.resid = 16;
        cfg
    }

    #[test]
    fn native_engine_completes_requests() {
        let mut eng = Engine::native_synthetic(tiny_cfg(), 1, 4.0, EngineOpts::default());
        for i in 0..3 {
            eng.submit(Request::greedy(i, vec![1, 2, 3, (i % 8) as u32 + 4], 6))
                .unwrap();
        }
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 3);
        for c in &done {
            assert_eq!(c.tokens.len(), 6);
            assert!(c.ttft_s.is_some());
            assert!(!c.truncated);
        }
        assert!(eng.idle());
        assert_eq!(eng.cache_report().sequences, 0, "caches released");
        assert_eq!(eng.metrics.requests_finished, 3);
        assert_eq!(eng.metrics.decode_tokens, 18);
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        let run = || {
            let mut eng =
                Engine::native_synthetic(tiny_cfg(), 2, 4.0, EngineOpts::default());
            eng.submit(Request::greedy(1, vec![5, 6, 7], 12)).unwrap();
            eng.run_to_completion().unwrap()[0].tokens.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn traced_request_yields_ordered_lifecycle_and_identical_tokens() {
        let run = |trace: bool| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = 4;
            opts.trace = trace;
            let mut eng = Engine::native_synthetic(tiny_cfg(), 7, 4.0, opts);
            let prompt: Vec<u32> = (0..10).map(|i| (i * 3 % 64) as u32).collect();
            eng.submit(Request::greedy(21, prompt, 5)).unwrap();
            let tokens = eng.run_to_completion().unwrap()[0].tokens.clone();
            (tokens, eng.trace().drain())
        };
        let (plain, none) = run(false);
        assert!(none.is_empty(), "--trace off records nothing");
        let (traced, events) = run(true);
        assert_eq!(plain, traced, "tracing is observation-only");
        assert!(events.windows(2).all(|w| w[0].seq < w[1].seq), "drain is seq-ordered");
        let names: Vec<&str> =
            events.iter().filter(|e| e.request == 21).map(|e| e.kind.name()).collect();
        assert_eq!(names.first(), Some(&"admitted"));
        assert_eq!(names.last(), Some(&"done"));
        assert_eq!(
            names.iter().filter(|n| **n == "prefill_chunk").count(),
            3,
            "10 prompt tokens in grants of 4"
        );
        assert_eq!(
            names.iter().filter(|n| **n == "decode_step").count(),
            4,
            "first token comes from prefill; 4 decode iterations follow"
        );
        let idx = |n: &str| names.iter().position(|x| *x == n).unwrap();
        assert!(idx("admitted") < idx("prefill_chunk"));
        assert!(idx("prefill_chunk") < idx("decode_step"));
    }

    #[test]
    fn backpressure_rejects() {
        let mut opts = EngineOpts::default();
        opts.admission.max_queue = 1;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 3, 4.0, opts);
        eng.submit(Request::greedy(1, vec![1], 4)).unwrap();
        let r = eng.submit(Request::greedy(2, vec![1], 4));
        assert_eq!(r, Err(RejectReason::QueueFull));
        assert_eq!(eng.metrics.requests_rejected, 1);
    }

    #[test]
    fn snapkv_engine_compresses_long_prompts() {
        let mut opts = EngineOpts::default();
        opts.snapkv = Some(SnapKvOpts { budget: 16, window: 4 });
        let mut eng = Engine::native_synthetic(tiny_cfg(), 4, 4.0, opts);
        let prompt: Vec<u32> = (0..40).map(|i| (i % 60) as u32).collect();
        eng.submit(Request::greedy(1, prompt, 4)).unwrap();
        // after prefill, the cache holds only `budget` tokens
        eng.step().unwrap();
        let report = eng.cache_report();
        assert_eq!(report.tokens, 16 + 1, "budget + first decode step");
        eng.run_to_completion().unwrap();
    }

    #[test]
    fn parallel_decode_matches_inline_greedy() {
        // greedy decode is deterministic, so the pool path must produce
        // bit-identical rollouts to the inline path at any worker count
        let run = |workers: usize| {
            let mut opts = EngineOpts::default();
            opts.decode_workers = workers;
            let mut eng = Engine::native_synthetic(tiny_cfg(), 9, 4.0, opts);
            for i in 0..5 {
                eng.submit(Request::greedy(i, vec![1, 2, 3, (i % 8) as u32 + 4], 8))
                    .unwrap();
            }
            let mut done = eng.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
        };
        let inline = run(1);
        assert_eq!(inline, run(3));
        assert_eq!(inline, run(8), "more workers than sequences");
    }

    #[test]
    fn pool_width_reflects_opts() {
        let mut opts = EngineOpts::default();
        opts.decode_workers = 4;
        let eng = Engine::native_synthetic(tiny_cfg(), 10, 4.0, opts);
        assert_eq!(eng.decode_pool_width(), 4);
        let eng2 = Engine::native_synthetic(tiny_cfg(), 10, 4.0, EngineOpts::default());
        assert_eq!(eng2.decode_pool_width(), 1);
    }

    #[test]
    fn chunked_prefill_matches_unchunked_greedy_rollouts() {
        // Greedy decode output must be bit-identical with chunked prefill
        // on/off, at any chunk size and any decode-pool width.
        let run = |chunk: usize, workers: usize| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = chunk;
            opts.decode_workers = workers;
            let mut eng = Engine::native_synthetic(tiny_cfg(), 42, 4.0, opts);
            let prompts: Vec<Vec<u32>> = vec![
                vec![1, 2, 3],
                (0..17).map(|i| (i * 5 % 60) as u32).collect(),
                (0..40).map(|i| (i * 3 % 64) as u32).collect(),
                (0..9).map(|i| ((i + 7) % 64) as u32).collect(),
            ];
            for (i, p) in prompts.iter().enumerate() {
                eng.submit(Request::greedy(i as u64, p.clone(), 10)).unwrap();
            }
            let mut done = eng.run_to_completion().unwrap();
            assert_eq!(done.len(), 4);
            done.sort_by_key(|c| c.id);
            done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
        };
        let base = run(0, 1);
        for chunk in [1usize, 5, 8, 16, 64] {
            for workers in [1usize, 4] {
                assert_eq!(base, run(chunk, workers), "chunk={chunk} workers={workers}");
            }
        }
    }

    #[test]
    fn long_prefill_does_not_stall_running_decoders() {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 4;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 43, 4.0, opts);
        eng.submit(Request::greedy(1, vec![1, 2, 3], 64)).unwrap();
        // one step admits + prefills the short prompt (single chunk) and
        // runs its first decode iteration
        eng.step().unwrap();
        assert_eq!(eng.progress(1).unwrap(), (RequestState::Decoding, 2));
        // a long prompt arrives while 1 is decoding: 32 tokens at chunk 4
        // = 8 chunked steps, and sequence 1 must gain a token on EVERY
        // one of them (inter-token gap bounded by one chunk's compute)
        let long: Vec<u32> = (0..32).map(|i| (i % 64) as u32).collect();
        eng.submit(Request::greedy(2, long, 4)).unwrap();
        let mut interleaved_steps = 0;
        while eng.metrics.prefill_chunks < 1 + 8 {
            let (_, before) = eng.progress(1).unwrap();
            eng.step().unwrap();
            let (_, after) = eng.progress(1).unwrap();
            assert_eq!(after, before + 1, "decoder stalled behind a prefill chunk");
            interleaved_steps += 1;
        }
        assert_eq!(interleaved_steps, 8, "32-token prompt should take 8 chunks of 4");
        assert_eq!(eng.progress(2).unwrap().0, RequestState::Decoding);
        // the stall histogram saw every chunk that ran alongside decoders
        assert_eq!(eng.metrics.decode_stall.count(), 8);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(eng.metrics.requests_finished, 2);
    }

    #[test]
    fn eager_chunked_engine_completes() {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        opts.prefill_quantize_eagerly = true;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 44, 4.0, opts);
        let prompt: Vec<u32> = (0..30).map(|i| (i % 64) as u32).collect();
        eng.submit(Request::greedy(1, prompt, 6)).unwrap();
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 6);
        assert!(!done[0].rejected && !done[0].truncated);
        assert_eq!(eng.metrics.prefill_chunks, 4, "30 tokens at chunk 8");
    }

    #[test]
    fn empty_prompt_is_rejected_not_run() {
        let mut eng = Engine::native_synthetic(tiny_cfg(), 3, 4.0, EngineOpts::default());
        let r = eng.submit(Request::greedy(1, vec![], 4));
        assert_eq!(r, Err(RejectReason::EmptyPrompt));
        assert_eq!(eng.metrics.requests_rejected, 1);
        assert!(eng.idle(), "rejected request must not enter the queue");
    }

    #[test]
    fn rejected_completion_is_distinguishable_from_truncation() {
        let c = Completion::rejected(9, 5, RejectReason::QueueFull);
        assert!(c.rejected && !c.truncated);
        assert_eq!(c.reason, Some(RejectReason::QueueFull));
        assert_eq!(c.prompt_len, 5);
        assert!(c.tokens.is_empty());
    }

    #[test]
    fn decode_steps_count_iterations() {
        let mut eng = Engine::native_synthetic(tiny_cfg(), 6, 4.0, EngineOpts::default());
        eng.submit(Request::greedy(1, vec![1, 2, 3], 5)).unwrap();
        eng.run_to_completion().unwrap();
        // first token from prefill, then 4 decode iterations of batch 1
        assert_eq!(eng.metrics.decode_steps, 4);
        assert_eq!(eng.metrics.decode_batch_sum, 4);
        assert!((eng.metrics.mean_batch() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn prefix_cache_reuses_pages_and_keeps_greedy_rollouts_bit_identical() {
        // Requests served one after another on the SAME engine: with
        // prefix caching on, later prompts sharing a prefix must attach
        // to pooled pages (fewer prefill tokens) yet produce exactly the
        // tokens the prefix-off engine produces, at any pool width.
        let base: Vec<u32> = (0..32).map(|i| (i * 5 % 64) as u32).collect();
        let prompts: Vec<Vec<u32>> = vec![
            base.clone(),
            base.iter().cloned().chain([7, 9, 11]).collect(),
            base.iter().cloned().chain([3, 1]).collect(),
            (0..20).map(|i| (i * 11 % 64) as u32).collect(), // unrelated
        ];
        let run = |prefix: bool, workers: usize| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = 16; // multiple of group=8
            opts.prefill_quantize_eagerly = true; // prefix mode forces this anyway
            opts.prefix_cache = prefix;
            opts.decode_workers = workers;
            let mut eng = Engine::native_synthetic(tiny_cfg(), 77, 4.0, opts);
            let mut outs = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                eng.submit(Request::greedy(i as u64, p.clone(), 8)).unwrap();
                let done = eng.run_to_completion().unwrap();
                outs.push(done[0].tokens.clone());
            }
            (outs, eng.metrics.prefill_tokens, eng.metrics.prefix_hits)
        };
        let (cold, cold_tokens, cold_hits) = run(false, 1);
        assert_eq!(cold_hits, 0);
        for workers in [1usize, 4] {
            let (shared, shared_tokens, hits) = run(true, workers);
            assert_eq!(cold, shared, "workers={workers}: rollouts must be bit-identical");
            assert!(hits >= 2, "prompts 2 and 3 share prompt 1's prefix (hits {hits})");
            assert!(
                shared_tokens < cold_tokens,
                "shared prefill {shared_tokens} must skip tokens vs cold {cold_tokens}"
            );
        }
    }

    #[test]
    fn pool_exhaustion_preempts_youngest_and_both_complete_exactly() {
        // Two decoders under a pool too small for both to grow: the
        // younger one must be preempted (not rejected, not stalled), and
        // BOTH rollouts must match an unconstrained run bit-for-bit —
        // exact-mode recovery re-prefills the prompt and replays the
        // already-generated tokens.
        let run = |pages: usize| {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = 8; // exact (deferred) mode: bit-identical recovery
            opts.cache_pages = pages;
            let mut eng = Engine::native_synthetic(tiny_cfg(), 91, 4.0, opts);
            // group=8, streams=4: prompt 8 = 1 page each; 24 generated
            // tokens grow each sequence by 3 more pages
            eng.submit(Request::greedy(1, (0..8).map(|i| i as u32).collect(), 24)).unwrap();
            eng.step().unwrap(); // seq 1 prefilled + decoding before 2 arrives
            eng.submit(Request::greedy(2, (8..16).map(|i| i as u32).collect(), 24)).unwrap();
            let mut done = eng.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            assert_eq!(done.len(), 2);
            assert!(done.iter().all(|c| !c.rejected && !c.truncated));
            let preemptions = eng.metrics.preemptions;
            (done.into_iter().map(|c| c.tokens).collect::<Vec<_>>(), preemptions)
        };
        let (unconstrained, p0) = run(0);
        assert_eq!(p0, 0, "unbounded pool must never preempt");
        let (constrained, p) = run(4);
        assert!(p > 0, "4-page pool cannot hold two 4-page sequences without preempting");
        assert_eq!(unconstrained, constrained, "preemption must not change any rollout");
    }

    #[test]
    fn preempted_decoder_allows_transient_overshoot_when_alone() {
        // one decoder, pool of 1 page: it must finish by overshooting
        // (never self-preempt into a livelock)
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        opts.cache_pages = 1;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 92, 4.0, opts);
        eng.submit(Request::greedy(1, vec![1, 2, 3, 4], 20)).unwrap();
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 20);
        assert_eq!(eng.metrics.preemptions, 0, "a lone decoder never preempts itself");
    }

    #[test]
    fn streaming_events_mirror_the_one_shot_rollout() {
        // Same engine config, same prompt: the streamed Token events must
        // spell out exactly the tokens the one-shot path returns, in
        // order, ending in a Done carrying the same completion.
        let prompt = vec![1u32, 2, 3, 4, 5];
        let one_shot = {
            let mut eng = Engine::native_synthetic(tiny_cfg(), 11, 4.0, EngineOpts::default());
            eng.submit(Request::greedy(1, prompt.clone(), 6)).unwrap();
            eng.run_to_completion().unwrap()[0].tokens.clone()
        };
        let mut eng = Engine::native_synthetic(tiny_cfg(), 11, 4.0, EngineOpts::default());
        let rx = eng.submit_streaming(Request::greedy(1, prompt.clone(), 6));
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 1, "streaming requests still complete via step()");
        let events: Vec<Event> = rx.try_iter().collect();
        assert!(matches!(events[0], Event::Admitted { id: 1 }));
        let mut streamed = Vec::new();
        let mut finished = None;
        for ev in &events {
            match ev {
                Event::Token { token, index, logprob, .. } => {
                    assert_eq!(*index, streamed.len(), "token events arrive in order");
                    assert!(logprob.is_finite() && *logprob <= 0.0, "logprob {logprob}");
                    streamed.push(*token);
                }
                Event::Done(c) => finished = Some(c.clone()),
                _ => {}
            }
        }
        assert_eq!(streamed, one_shot, "streamed tokens == one-shot greedy rollout");
        let c = finished.expect("terminal Done event");
        assert_eq!(c.tokens, one_shot);
        assert_eq!(c.finish_reason, FinishReason::Length);
        assert!(matches!(events.last(), Some(Event::Done(_))), "Done is the last event");
    }

    #[test]
    fn rejected_streaming_submission_gets_a_rejected_event() {
        let mut opts = EngineOpts::default();
        opts.admission.max_queue = 0;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 12, 4.0, opts);
        let rx = eng.submit_streaming(Request::greedy(1, vec![1, 2], 4));
        let events: Vec<Event> = rx.try_iter().collect();
        assert_eq!(events.len(), 1);
        assert!(matches!(events[0], Event::Rejected { id: 1, reason: RejectReason::QueueFull }));
    }

    #[test]
    fn stop_tokens_finish_with_reason_stop() {
        // run greedily once to learn the rollout, then stop on the first
        // token that has no earlier duplicate (so the stop can only fire
        // there) and check the reason + truncation point
        let prompt = vec![3u32, 1, 4, 1, 5];
        let mut eng = Engine::native_synthetic(tiny_cfg(), 13, 4.0, EngineOpts::default());
        eng.submit(Request::greedy(1, prompt.clone(), 8)).unwrap();
        let free = eng.run_to_completion().unwrap()[0].tokens.clone();
        assert_eq!(free.len(), 8);
        let j = (1..free.len())
            .find(|&j| !free[..j].contains(&free[j]))
            .expect("rollout is a single repeated token; no valid stop probe");
        let stop = free[j];
        let mut eng = Engine::native_synthetic(tiny_cfg(), 13, 4.0, EngineOpts::default());
        let mut req = Request::greedy(2, prompt, 8);
        req.gen.stop_tokens = vec![stop];
        eng.submit(req).unwrap();
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].finish_reason, FinishReason::Stop);
        assert_eq!(done[0].tokens, free[..=j].to_vec(), "stop token is included");
    }

    #[test]
    fn cancel_frees_pages_mid_prefill_and_mid_decode() {
        // The cancellation leak check at engine level: counters must
        // return exactly to baseline (prefix cache off -> baseline 0).
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 14, 4.0, opts);
        let long: Vec<u32> = (0..40).map(|i| (i % 64) as u32).collect();

        // mid-prefill: one step grants a single 8-token chunk of 40
        let rx = eng.submit_streaming(Request::greedy(1, long.clone(), 16));
        eng.step().unwrap();
        assert_eq!(eng.progress(1).unwrap().0, RequestState::Prefilling);
        assert!(eng.cache_report().physical_bytes > 0, "prefill left bytes behind");
        let c = eng.cancel(1).expect("live request");
        assert_eq!(c.finish_reason, FinishReason::Cancelled);
        assert!(eng.idle());
        let r = eng.cache_report();
        assert_eq!(r.physical_bytes, 0, "cancel mid-prefill must free every byte");
        assert_eq!(eng.page_pool().pages_in_use(), 0);
        let events: Vec<Event> = rx.try_iter().collect();
        let cancelled_done = matches!(
            events.last(),
            Some(Event::Done(c)) if c.finish_reason == FinishReason::Cancelled
        );
        assert!(cancelled_done, "stream must end in Done(cancelled)");

        // mid-decode: let it sample a few tokens first
        eng.submit(Request::greedy(2, long, 16)).unwrap();
        eng.step().unwrap();
        while eng.progress(2).map(|(_, n)| n < 3).expect("request 2 is live") {
            eng.step().unwrap();
        }
        assert_eq!(eng.progress(2).unwrap().0, RequestState::Decoding);
        let c = eng.cancel(2).expect("live request");
        assert_eq!(c.finish_reason, FinishReason::Cancelled);
        assert_eq!(c.tokens.len(), 3, "partial generation is returned");
        assert!(eng.idle());
        assert_eq!(eng.cache_report().physical_bytes, 0, "mid-decode cancel leaks");
        assert_eq!(eng.page_pool().pages_in_use(), 0);
        assert_eq!(eng.metrics.requests_cancelled, 2);
        // cancelling a finished/unknown id is a no-op
        assert!(eng.cancel(2).is_none());
        assert!(eng.cancel(99).is_none());
    }

    #[test]
    fn session_turns_resume_the_kv_chain() {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 15, 4.0, opts);
        eng.open_session(7);
        let t1: Vec<u32> = (0..16).map(|i| (i * 3 % 64) as u32).collect();
        let (tx, rx1) = std::sync::mpsc::channel();
        eng.submit_turn(7, Request::greedy(1, t1.clone(), 16), tx).unwrap();
        let d1 = eng.run_to_completion().unwrap();
        assert_eq!(d1.len(), 1);
        let gen1 = d1[0].tokens.clone();
        drop(rx1);
        let prefill_t1 = eng.metrics.prefill_tokens;
        assert_eq!(prefill_t1, 16, "turn 1 prefills its whole prompt");
        // the chain stays alive between turns: prompt + all-but-last token
        assert_eq!(
            eng.session_cached_tokens(7).unwrap(),
            16 + gen1.len() - 1,
            "history chain held across turns"
        );
        // turn 2: only the new tokens (plus the one unfed token) prefill
        let (tx, _rx2) = std::sync::mpsc::channel();
        eng.submit_turn(7, Request::greedy(2, vec![9, 8, 7], 16), tx).unwrap();
        let d2 = eng.run_to_completion().unwrap();
        assert_eq!(d2.len(), 1);
        assert!(!d2[0].tokens.is_empty());
        let prefill_t2 = eng.metrics.prefill_tokens - prefill_t1;
        assert_eq!(prefill_t2, 3 + 1, "turn 2 prefills new tokens + the unfed one");
        assert!(eng.metrics.session_tokens_reused > 0);
        assert!(eng.metrics.prefix_tokens_reused > 0, "session reuse counts as prefix reuse");
        assert_eq!(eng.metrics.session_turns, 2);
        // ending the session releases the chain: pool back to baseline
        assert!(eng.end_session(7));
        assert_eq!(eng.page_pool().pages_in_use(), 0, "end_session frees the chain");
        assert_eq!(eng.cache_report().physical_bytes, 0);
        assert!(!eng.end_session(7), "double close is a no-op");
    }

    #[test]
    fn session_turns_are_not_charged_for_resident_history() {
        // Admission must charge a turn only for its NEW footprint: the
        // resumed chain is already in the pool's physical counters, and
        // double-charging it would reject every turn of a long
        // conversation under a finite budget.  Budget is calibrated
        // between "resident + incremental" (must admit) and "resident +
        // full-prompt estimate" (the old double-count, which rejected).
        let cfg = tiny_cfg();
        let t1: Vec<u32> = (0..16).map(|i| (i * 3 % 64) as u32).collect();
        let chain_bytes = {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = 8;
            let mut eng = Engine::native_synthetic(cfg.clone(), 19, 4.0, opts);
            let (tx, _rx) = std::sync::mpsc::channel();
            eng.submit_turn(9, Request::greedy(1, t1.clone(), 16), tx).unwrap();
            eng.run_to_completion().unwrap();
            eng.cache_report().physical_bytes
        };
        let mgr = CacheManager::new(cfg.cache_config(None), usize::MAX);
        let hist = 16 + 16; // turn-1 prompt + generation
        let est_incremental = mgr.estimate_bytes(3 + 1 + 8); // new + unfed + gen
        let est_full = mgr.estimate_bytes(hist + 3 + 8); // the double-count
        assert!(est_incremental < est_full);
        let budget = chain_bytes + (est_incremental + est_full) / 2;

        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        opts.cache_budget_bytes = budget;
        let mut eng = Engine::native_synthetic(cfg, 19, 4.0, opts);
        let (tx, _rx) = std::sync::mpsc::channel();
        eng.submit_turn(9, Request::greedy(1, t1, 16), tx).unwrap();
        eng.run_to_completion().unwrap();
        let (tx, _rx) = std::sync::mpsc::channel();
        let r = eng.submit_turn(9, Request::greedy(2, vec![1, 2, 3], 8), tx);
        assert_eq!(r, Ok(()), "resident history must not be double-charged at admission");
        eng.run_to_completion().unwrap();
    }

    #[test]
    fn session_rollouts_are_deterministic_across_engines() {
        // The same 3-turn conversation on two fresh engines produces
        // identical generations (greedy, chunked resume path).
        let run = || {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = 8;
            let mut eng = Engine::native_synthetic(tiny_cfg(), 16, 4.0, opts);
            let turns: Vec<Vec<u32>> = vec![
                (0..12).map(|i| (i * 5 % 64) as u32).collect(),
                vec![1, 2, 3],
                vec![60, 61],
            ];
            let mut outs = Vec::new();
            for (i, t) in turns.iter().enumerate() {
                let (tx, _rx) = std::sync::mpsc::channel();
                eng.submit_turn(5, Request::greedy(i as u64 + 1, t.clone(), 6), tx).unwrap();
                outs.push(eng.run_to_completion().unwrap()[0].tokens.clone());
            }
            outs
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn concurrent_turns_on_one_session_are_rejected() {
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 17, 4.0, opts);
        let (tx, _rx) = std::sync::mpsc::channel();
        eng.submit_turn(3, Request::greedy(1, vec![1, 2, 3], 4), tx).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        let r = eng.submit_turn(3, Request::greedy(2, vec![4], 4), tx);
        assert_eq!(r, Err(RejectReason::SessionBusy));
        let events: Vec<Event> = rx.try_iter().collect();
        assert!(matches!(events[0], Event::Rejected { reason: RejectReason::SessionBusy, .. }));
        eng.run_to_completion().unwrap();
        // first turn done: the session accepts the next turn again
        let (tx, _rx) = std::sync::mpsc::channel();
        eng.submit_turn(3, Request::greedy(3, vec![4], 4), tx).unwrap();
        eng.run_to_completion().unwrap();
    }

    #[test]
    fn per_request_snapkv_override_is_validated() {
        // chunked engines can't honor a SnapKV override
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        let mut eng = Engine::native_synthetic(tiny_cfg(), 18, 4.0, opts);
        let mut req = Request::greedy(1, (0..30).map(|i| i as u32).collect(), 4);
        req.gen.snapkv = Some(SnapKvOpts { budget: 16, window: 4 });
        assert_eq!(eng.submit(req), Err(RejectReason::UnsupportedOptions));
        // whole-prompt engines honor it per request
        let mut eng = Engine::native_synthetic(tiny_cfg(), 18, 4.0, EngineOpts::default());
        let mut req = Request::greedy(1, (0..30).map(|i| i as u32).collect(), 4);
        req.gen.snapkv = Some(SnapKvOpts { budget: 16, window: 4 });
        eng.submit(req).unwrap();
        eng.step().unwrap();
        assert_eq!(eng.cache_report().tokens, 16 + 1, "budget + first decode step");
        eng.run_to_completion().unwrap();
        assert_eq!(eng.metrics.snapkv_tokens_dropped, 30 - 16);
        // a bad window is rejected, not asserted deep in the model
        let mut req = Request::greedy(2, vec![1, 2, 3], 4);
        req.gen.snapkv = Some(SnapKvOpts { budget: 4, window: 9 });
        assert_eq!(eng.submit(req), Err(RejectReason::UnsupportedOptions));
    }

    #[test]
    fn value_quantized_engine_runs() {
        let mut opts = EngineOpts::default();
        opts.value_bits = Some(2);
        let mut eng = Engine::native_synthetic(tiny_cfg(), 5, 4.0, opts);
        eng.submit(Request::greedy(1, (0..20).map(|i| i as u32).collect(), 8))
            .unwrap();
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 8);
    }

    #[test]
    fn tenant_rate_limit_throttles_past_the_burst() {
        let mut eng = Engine::native_synthetic(tiny_cfg(), 21, 4.0, EngineOpts::default());
        let mut tenancy = TenancyOpts::default();
        // negligible refill: within this test only the burst matters
        tenancy.rate = 1e-6;
        tenancy.burst = 2.0;
        eng.set_tenancy(&tenancy);
        let mut req = |id: u64, tenant: &str| {
            let mut r = Request::greedy(id, vec![1, 2, 3], 4);
            r.tenant = tenant.to_string();
            r
        };
        assert_eq!(eng.submit(req(1, "flood")), Ok(()));
        assert_eq!(eng.submit(req(2, "flood")), Ok(()));
        assert_eq!(eng.submit(req(3, "flood")), Err(RejectReason::TenantThrottled));
        assert_eq!(eng.submit(req(4, "flood")), Err(RejectReason::TenantThrottled));
        // buckets are per tenant: another tenant still gets its burst
        assert_eq!(eng.submit(req(5, "calm")), Ok(()));
        assert_eq!(eng.metrics.tenant_throttled, 2);
        assert_eq!(eng.metrics.requests_rejected, 2);
        assert_eq!(eng.metrics.tenants["flood"].admitted, 2);
        assert_eq!(eng.metrics.tenants["flood"].throttled, 2);
        assert_eq!(eng.metrics.tenants["calm"].admitted, 1);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 3, "admitted requests still complete");
        assert_eq!(eng.metrics.tenants["flood"].finished, 2);
        let s = eng.metrics.summary();
        assert!(s.contains("tenant flood"), "throttled tenants surface in the summary: {s}");
    }

    #[test]
    fn wfq_single_tenant_rollouts_match_fcfs_bit_identically() {
        // with every request on one tenant, WFQ ordering is a stable
        // no-op: outputs must equal the FCFS engine's exactly, chunked or
        // not
        let run = |sched: SchedMode, chunk: usize| {
            let mut opts = EngineOpts::default();
            opts.sched = sched;
            opts.prefill_chunk = chunk;
            let mut eng = Engine::native_synthetic(tiny_cfg(), 23, 4.0, opts);
            let prompts: Vec<Vec<u32>> = vec![
                vec![1, 2, 3],
                (0..17).map(|i| (i * 5 % 60) as u32).collect(),
                (0..30).map(|i| (i * 3 % 64) as u32).collect(),
            ];
            for (i, p) in prompts.iter().enumerate() {
                eng.submit(Request::greedy(i as u64, p.clone(), 8)).unwrap();
            }
            let mut done = eng.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
        };
        for chunk in [0usize, 8] {
            assert_eq!(
                run(SchedMode::Fcfs, chunk),
                run(SchedMode::Wfq, chunk),
                "chunk={chunk}"
            );
        }
    }

    #[test]
    fn speculative_rollouts_match_plain_greedy_bit_identically() {
        // The tentpole invariant: --speculate K must not change a single
        // greedy token, at any K, draft width, worker count, or prefill
        // chunk size.  Speculation only changes how many tokens one
        // decode iteration emits.
        let prompts: Vec<Vec<u32>> = vec![
            vec![1, 2, 3],
            (0..17).map(|i| (i * 5 % 60) as u32).collect(),
            (0..40).map(|i| (i * 3 % 64) as u32).collect(),
        ];
        let run = |speculate: usize, draft: Option<(u32, u32)>, workers: usize, chunk: usize| {
            let mut opts = EngineOpts::default();
            opts.speculate = speculate;
            opts.draft_bits = draft;
            opts.decode_workers = workers;
            opts.prefill_chunk = chunk;
            let mut eng = Engine::native_synthetic(tiny_cfg(), 33, 4.0, opts);
            for (i, p) in prompts.iter().enumerate() {
                eng.submit(Request::greedy(i as u64, p.clone(), 12)).unwrap();
            }
            let mut done = eng.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            let toks: Vec<Vec<u32>> = done.into_iter().map(|c| c.tokens).collect();
            (toks, eng.metrics.speculative_rounds, eng.metrics.speculative_accepted)
        };
        let (base, rounds0, _) = run(0, None, 1, 0);
        assert_eq!(rounds0, 0, "speculate=0 must never count a round");
        for k in [2usize, 3] {
            for draft in [None, Some((4, 4)), Some((1, 1))] {
                for workers in [1usize, 4] {
                    for chunk in [0usize, 8] {
                        let (toks, rounds, accepted) = run(k, draft, workers, chunk);
                        assert_eq!(
                            base, toks,
                            "k={k} draft={draft:?} workers={workers} chunk={chunk}"
                        );
                        assert!(rounds > 0, "eligible greedy requests must speculate");
                        // with the draft EQUAL to the exact plane the
                        // proposal pass replays exact decode, so every
                        // draft verifies
                        if draft == Some((4, 4)) {
                            assert!(accepted > 0, "exact-width draft must accept");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn speculation_emits_more_tokens_than_decode_iterations() {
        // decode-steps-per-token < 1 is the whole point: with a draft as
        // wide as the exact plane every window verifies fully, so one
        // iteration emits several tokens.
        let mut opts = EngineOpts::default();
        opts.speculate = 3;
        opts.draft_bits = Some((4, 4));
        let mut eng = Engine::native_synthetic(tiny_cfg(), 34, 4.0, opts);
        eng.submit(Request::greedy(1, (0..16).map(|i| i as u32).collect(), 16)).unwrap();
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 16);
        let m = &eng.metrics;
        assert!(
            m.decode_steps < m.decode_tokens,
            "steps {} tokens {}: speculation must amortize iterations",
            m.decode_steps,
            m.decode_tokens
        );
        assert_eq!(m.speculative_drafted, m.speculative_accepted);
        let s = m.summary();
        assert!(s.contains("speculative"), "summary must surface speculation: {s}");
    }

    #[test]
    fn rejected_speculative_windows_unwind_to_pool_baseline() {
        // A 1,1-bit draft mispredicts constantly; every rejected window's
        // fork and unfed verification rows must fully unwind — after the
        // requests retire the pool is back to exactly zero.
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 8;
        opts.cache_pages = 64;
        opts.speculate = 3;
        opts.draft_bits = Some((1, 1));
        let mut eng = Engine::native_synthetic(tiny_cfg(), 35, 4.0, opts);
        for i in 0..3 {
            eng.submit(Request::greedy(i, (0..10).map(|j| ((j + i as usize) % 64) as u32).collect(), 20))
                .unwrap();
        }
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 3);
        assert!(done.iter().all(|c| c.tokens.len() == 20));
        assert!(eng.metrics.speculative_rounds > 0);
        assert_eq!(eng.page_pool().pages_in_use(), 0, "speculation leaked pages");
        assert_eq!(eng.cache_report().physical_bytes, 0, "speculation leaked resid bytes");
    }

    #[test]
    fn idle_session_reaps_to_tier_and_warm_restarts_bit_identically() {
        let dir = std::env::temp_dir()
            .join(format!("polarquant-engine-ttl-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let opts = || {
            let mut o = EngineOpts::default();
            o.prefill_chunk = 8;
            o.prefix_cache = true; // attach_tier requires it
            o
        };
        let t1: Vec<u32> = (0..19).map(|i| (i * 3 % 64) as u32).collect();
        let t2: Vec<u32> = vec![9, 8, 7];

        // baseline: same conversation, never reaped
        let (base1, base2, base_prefill2) = {
            let mut eng = Engine::native_synthetic(tiny_cfg(), 25, 4.0, opts());
            let (tx, _rx) = std::sync::mpsc::channel();
            eng.submit_turn(7, Request::greedy(1, t1.clone(), 12), tx).unwrap();
            let g1 = eng.run_to_completion().unwrap()[0].tokens.clone();
            let p1 = eng.metrics.prefill_tokens;
            let (tx, _rx) = std::sync::mpsc::channel();
            eng.submit_turn(7, Request::greedy(2, t2.clone(), 12), tx).unwrap();
            let g2 = eng.run_to_completion().unwrap()[0].tokens.clone();
            (g1, g2, eng.metrics.prefill_tokens - p1)
        };

        let mut eng = Engine::native_synthetic(tiny_cfg(), 25, 4.0, opts());
        eng.attach_tier(&TierOpts { dir: dir.clone(), max_bytes: u64::MAX, snapshot: false })
            .unwrap();
        let mut tenancy = TenancyOpts::default();
        tenancy.session_ttl = Some(Duration::from_secs(0));
        eng.set_tenancy(&tenancy);
        let (tx, _rx) = std::sync::mpsc::channel();
        eng.submit_turn(7, Request::greedy(1, t1.clone(), 12), tx).unwrap();
        let g1 = eng.run_to_completion().unwrap()[0].tokens.clone();
        assert_eq!(g1, base1);
        let p1 = eng.metrics.prefill_tokens;
        // TTL 0: the idle session's chain demotes on the next sweep
        assert_eq!(eng.reap_idle_sessions(), 1);
        assert_eq!(eng.metrics.sessions_reaped, 1);
        assert!(
            eng.session_cached_tokens(7).is_none(),
            "reaped chain must leave RAM"
        );
        assert!(eng.page_pool().bytes_on_disk() > 0);
        // a second sweep finds nothing
        assert_eq!(eng.reap_idle_sessions(), 0);
        // the next turn promotes the chain and continues bit-identically,
        // prefilling ONLY the new tokens (+ the one unfed token) — warm
        // start, not a cold re-prefill of the history
        let (tx, _rx) = std::sync::mpsc::channel();
        eng.submit_turn(7, Request::greedy(2, t2.clone(), 12), tx).unwrap();
        let g2 = eng.run_to_completion().unwrap()[0].tokens.clone();
        assert_eq!(g2, base2, "restored chain must decode bit-identically");
        assert_eq!(eng.metrics.sessions_restored, 1);
        assert_eq!(
            eng.metrics.prefill_tokens - p1,
            base_prefill2,
            "warm start prefills the same incremental tokens as never-reaped"
        );
        assert_eq!(eng.metrics.summary().contains("sessions reaped 1 (restored 1)"), true);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
