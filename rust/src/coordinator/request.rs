//! Request lifecycle: Queued -> Prefilling -> Decoding -> Finished — plus
//! the streaming contract around it: per-request [`GenOptions`], the
//! [`Event`] stream a submission can subscribe to, and the typed
//! [`FinishReason`] every [`Completion`] carries.

use std::time::Instant;

use super::backpressure::RejectReason;
use crate::kvcache::SharedSeq;
use crate::model::sampling::Sampler;

pub type RequestId = u64;

/// SnapKV prompt compression knobs (engine default or per-request
/// override — native whole-prompt-prefill engines only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapKvOpts {
    pub budget: usize,
    pub window: usize,
}

/// Per-request generation options.  The default is greedy decoding — the
/// exact computation `Request::greedy` always ran — so a v1 one-shot
/// request and a default-options streaming request are bit-identical.
#[derive(Clone, Debug, PartialEq)]
pub struct GenOptions {
    pub max_new_tokens: usize,
    /// 0.0 = greedy (argmax); > 0 samples from the tempered softmax
    pub temperature: f32,
    /// restrict sampling to the k most likely tokens (0 = full vocab)
    pub top_k: usize,
    /// nucleus sampling mass (>= 1.0 = off)
    pub top_p: f32,
    /// seeds the per-token RNG ([`crate::model::sampling::token_rng`]):
    /// identical (options, prompt, seed) give bit-identical rollouts at
    /// any decode-worker width
    pub seed: u64,
    /// generation stops when it emits any of these token ids (the stop
    /// token is included in the output)
    pub stop_tokens: Vec<u32>,
    /// compute each token's full-softmax logprob for the `Token` events
    /// (two extra O(vocab) passes per token; only paid when the request
    /// also has a subscriber).  The server enables this for streamed
    /// requests and leaves it off for one-shot ones, whose replies carry
    /// no logprobs anyway.
    pub logprobs: bool,
    /// per-request SnapKV override (None = the engine's default)
    pub snapkv: Option<SnapKvOpts>,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions {
            max_new_tokens: 16,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            stop_tokens: Vec::new(),
            logprobs: true,
            snapkv: None,
        }
    }
}

impl GenOptions {
    /// The sampler these options select.
    pub fn sampler(&self) -> Sampler {
        if self.temperature <= 0.0 {
            Sampler::Greedy
        } else {
            Sampler::Stochastic {
                temperature: self.temperature,
                top_k: self.top_k,
                top_p: self.top_p,
            }
        }
    }
}

/// Why a request stopped producing tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// emitted a `GenOptions::stop_tokens` id
    Stop,
    /// ran out of `max_new_tokens` budget (or outgrew every AOT bucket —
    /// see `Completion::truncated`)
    Length,
    /// cancelled via `Engine::cancel` while queued or running
    Cancelled,
    /// refused at admission; never ran (see `Completion::reason`)
    Rejected,
}

impl FinishReason {
    /// Stable wire-format label (the v2 protocol's `finish_reason`).
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Rejected => "rejected",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Prefilling,
    Decoding,
    Finished,
    Rejected,
}

/// The tenant every request without an explicit `tenant` frame field
/// belongs to — including every v1 request, so single-tenant deployments
/// see no behavior change.
pub const DEFAULT_TENANT: &str = "default";

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    /// optional session key for router affinity / engine KV reuse
    pub session: Option<u64>,
    /// tenant identity (wire v2 `tenant` field; absent -> "default") —
    /// drives weighted-fair scheduling, token-bucket admission, and
    /// per-tenant page quotas
    pub tenant: String,
    pub prompt: Vec<u32>,
    pub gen: GenOptions,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<u32>, gen: GenOptions) -> Self {
        Request { id, session: None, tenant: DEFAULT_TENANT.to_string(), prompt, gen }
    }

    /// Greedy request with default options (the v1 one-shot shape).
    pub fn greedy(id: RequestId, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Request::new(id, prompt, GenOptions { max_new_tokens, ..GenOptions::default() })
    }
}

/// The terminal reply for one request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub ttft_s: Option<f64>,
    pub total_s: Option<f64>,
    /// true if the sequence outgrew every AOT bucket and was cut short
    /// (`finish_reason` is `Length` in that case)
    pub truncated: bool,
    /// true if admission rejected the request outright (never ran);
    /// distinct from `truncated`, which means it RAN but was cut short
    pub rejected: bool,
    /// why admission rejected it (its wire label is
    /// [`RejectReason::as_str`])
    pub reason: Option<RejectReason>,
    /// why generation stopped: `Stop` | `Length` | `Cancelled` | `Rejected`
    pub finish_reason: FinishReason,
}

impl Completion {
    /// The reply a rejected request gets: no tokens, no timings, and an
    /// explicit reason so clients can tell backpressure from truncation.
    pub fn rejected(id: RequestId, prompt_len: usize, why: RejectReason) -> Self {
        Completion {
            id,
            prompt_len,
            tokens: Vec::new(),
            ttft_s: None,
            total_s: None,
            truncated: false,
            rejected: true,
            reason: Some(why),
            finish_reason: FinishReason::Rejected,
        }
    }
}

/// One frame of a streaming submission (`Engine::submit_streaming`).
/// Terminal events are `Done` and `Rejected`; everything else is
/// progress.  Token events carry the model's own (full-softmax) logprob.
#[derive(Clone, Debug)]
pub enum Event {
    /// the request passed admission and is queued
    Admitted { id: RequestId },
    /// `done` of `total` prompt tokens are in the cache (chunked prefill
    /// reports once per granted chunk; whole-prompt prefill once)
    PrefillProgress { id: RequestId, done: usize, total: usize },
    /// one generated token, emitted the step it was sampled
    Token { id: RequestId, token: u32, logprob: f32, index: usize },
    /// terminal: the request finished (any `FinishReason` but `Rejected`)
    Done(Completion),
    /// terminal: admission refused the request; no other event follows
    Rejected { id: RequestId, reason: RejectReason },
}

/// Which session a request is a turn of (engine-internal).
#[derive(Clone, Copy, Debug)]
pub struct TurnInfo {
    pub session: u64,
    /// tokens the client sent for THIS turn (the rest of `Request::prompt`
    /// is replayed conversation history)
    pub new_tokens: usize,
}

/// Book-keeping for a request inside the engine.
#[derive(Debug)]
pub struct Tracked {
    pub req: Request,
    pub state: RequestState,
    /// prompt tokens already prefilled — the resumable `Prefilling`
    /// cursor under chunked prefill (== prompt len once decoding)
    pub prefill_pos: usize,
    pub generated: Vec<u32>,
    pub arrived: Instant,
    pub first_token_at: Option<Instant>,
    /// when the latest token was emitted (drives the inter-token-latency
    /// histogram)
    pub last_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
    /// session-turn continuation: the conversation's live cache, adopted
    /// at admission so prefill resumes after the tokens it already holds
    pub resume: Option<SharedSeq>,
    /// set when this request is a session turn
    pub turn: Option<TurnInfo>,
}

impl Tracked {
    pub fn new(req: Request) -> Self {
        Tracked {
            req,
            state: RequestState::Queued,
            prefill_pos: 0,
            generated: Vec::new(),
            arrived: Instant::now(),
            first_token_at: None,
            last_token_at: None,
            finished_at: None,
            resume: None,
            turn: None,
        }
    }

    /// Prompt tokens still to prefill.
    pub fn prefill_remaining(&self) -> usize {
        self.req.prompt.len().saturating_sub(self.prefill_pos)
    }

    /// Why generation is complete, if it is: a stop token beats the
    /// budget when both hold on the same token.
    pub fn done_reason(&self) -> Option<FinishReason> {
        if let Some(last) = self.generated.last() {
            if self.req.gen.stop_tokens.contains(last) {
                return Some(FinishReason::Stop);
            }
        }
        if self.generated.len() >= self.req.gen.max_new_tokens {
            return Some(FinishReason::Length);
        }
        None
    }

    pub fn done(&self) -> bool {
        self.done_reason().is_some()
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at
            .map(|t| t.duration_since(self.arrived).as_secs_f64())
    }

    pub fn total_latency(&self) -> Option<f64> {
        self.finished_at
            .map(|t| t.duration_since(self.arrived).as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn done_on_budget() {
        let mut t = Tracked::new(Request::greedy(1, vec![1, 2], 3));
        assert!(!t.done());
        t.generated = vec![5, 6, 7];
        assert!(t.done());
        assert_eq!(t.done_reason(), Some(FinishReason::Length));
    }

    #[test]
    fn done_on_stop_token() {
        let mut req = Request::greedy(1, vec![1], 100);
        req.gen.stop_tokens = vec![0, 9];
        let mut t = Tracked::new(req);
        t.generated = vec![4, 9];
        assert!(t.done());
        assert_eq!(t.done_reason(), Some(FinishReason::Stop));
    }

    #[test]
    fn stop_beats_budget_on_the_same_token() {
        let mut req = Request::greedy(1, vec![1], 2);
        req.gen.stop_tokens = vec![7];
        let mut t = Tracked::new(req);
        t.generated = vec![3, 7];
        assert_eq!(t.done_reason(), Some(FinishReason::Stop));
    }

    #[test]
    fn default_options_are_greedy() {
        let g = GenOptions::default();
        assert_eq!(g.sampler(), Sampler::Greedy);
        let r = Request::greedy(1, vec![1], 8);
        assert_eq!(r.gen.max_new_tokens, 8);
        assert_eq!(r.gen.sampler(), Sampler::Greedy);
        let sampled = GenOptions { temperature: 0.7, top_k: 40, ..GenOptions::default() };
        assert!(matches!(sampled.sampler(), Sampler::Stochastic { .. }));
    }

    #[test]
    fn finish_reason_wire_labels_are_stable() {
        assert_eq!(FinishReason::Stop.as_str(), "stop");
        assert_eq!(FinishReason::Length.as_str(), "length");
        assert_eq!(FinishReason::Cancelled.as_str(), "cancelled");
        assert_eq!(FinishReason::Rejected.as_str(), "rejected");
    }
}
