//! Request lifecycle: Queued -> Prefilling -> Decoding -> Finished.

use std::time::Instant;

use crate::model::sampling::Sampler;

pub type RequestId = u64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Prefilling,
    Decoding,
    Finished,
    Rejected,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    /// optional session key for router affinity
    pub session: Option<u64>,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// stop generation at this token id (e.g. an EOS id), if any
    pub stop_token: Option<u32>,
}

impl Request {
    pub fn greedy(id: RequestId, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            session: None,
            prompt,
            max_new_tokens,
            sampler: Sampler::Greedy,
            stop_token: None,
        }
    }
}

/// Book-keeping for a request inside the engine.
#[derive(Debug)]
pub struct Tracked {
    pub req: Request,
    pub state: RequestState,
    /// prompt tokens already prefilled — the resumable `Prefilling`
    /// cursor under chunked prefill (== prompt len once decoding)
    pub prefill_pos: usize,
    pub generated: Vec<u32>,
    pub arrived: Instant,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
}

impl Tracked {
    pub fn new(req: Request) -> Self {
        Tracked {
            req,
            state: RequestState::Queued,
            prefill_pos: 0,
            generated: Vec::new(),
            arrived: Instant::now(),
            first_token_at: None,
            finished_at: None,
        }
    }

    /// Prompt tokens still to prefill.
    pub fn prefill_remaining(&self) -> usize {
        self.req.prompt.len().saturating_sub(self.prefill_pos)
    }

    pub fn done(&self) -> bool {
        if self.generated.len() >= self.req.max_new_tokens {
            return true;
        }
        if let (Some(stop), Some(&last)) = (self.req.stop_token, self.generated.last()) {
            return last == stop;
        }
        false
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at
            .map(|t| t.duration_since(self.arrived).as_secs_f64())
    }

    pub fn total_latency(&self) -> Option<f64> {
        self.finished_at
            .map(|t| t.duration_since(self.arrived).as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn done_on_budget() {
        let mut t = Tracked::new(Request::greedy(1, vec![1, 2], 3));
        assert!(!t.done());
        t.generated = vec![5, 6, 7];
        assert!(t.done());
    }

    #[test]
    fn done_on_stop_token() {
        let mut req = Request::greedy(1, vec![1], 100);
        req.stop_token = Some(0);
        let mut t = Tracked::new(req);
        t.generated = vec![4, 0];
        assert!(t.done());
    }
}
