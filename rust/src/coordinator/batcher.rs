//! Dynamic batching into the AOT shape buckets.
//!
//! AOT graphs have static shapes, so the batcher's job is the classic
//! TPU-serving one: group running sequences so that (batch, max cache len)
//! fits the smallest compiled bucket, padding the rest.  Sequences that
//! outgrow every bucket are surfaced so the scheduler can finish them on
//! the native backend (shape-unconstrained) instead of crashing.

use crate::runtime::Manifest;

/// One decode batch: request ids + the graph bucket that will run them.
#[derive(Clone, Debug, PartialEq)]
pub struct DecodeBatch {
    pub graph: String,
    pub batch_cap: usize,
    pub seq_cap: usize,
    pub ids: Vec<u64>,
}

/// Greedy bucket packing: sort by cache length descending, then fill the
/// smallest bucket that fits each prefix.
pub fn plan_decode_batches(
    manifest: &Manifest,
    mut seqs: Vec<(u64, usize)>, // (request id, quantized cache len)
    max_batches: usize,
) -> (Vec<DecodeBatch>, Vec<u64>) {
    let mut batches = Vec::new();
    let mut overflow = Vec::new();
    seqs.sort_by(|a, b| b.1.cmp(&a.1));

    let buckets = manifest.graphs_of_kind("decode");
    if buckets.is_empty() {
        return (batches, seqs.into_iter().map(|(id, _)| id).collect());
    }
    let max_seq_cap = buckets.iter().map(|g| g.seq).max().unwrap();

    let mut i = 0;
    while i < seqs.len() && batches.len() < max_batches {
        let (_, len) = seqs[i];
        if len > max_seq_cap {
            overflow.push(seqs[i].0);
            i += 1;
            continue;
        }
        // choose the bucket for this (longest-remaining) sequence
        let bucket = buckets
            .iter()
            .filter(|g| g.seq >= len)
            .min_by_key(|g| (g.seq, std::cmp::Reverse(g.batch)))
            .unwrap();
        // fill it with as many following sequences as fit
        let take = (seqs.len() - i).min(bucket.batch);
        let ids: Vec<u64> = seqs[i..i + take].iter().map(|&(id, _)| id).collect();
        batches.push(DecodeBatch {
            graph: bucket.name.clone(),
            batch_cap: bucket.batch,
            seq_cap: bucket.seq,
            ids,
        });
        i += take;
    }
    // anything left when max_batches hit also overflows to the caller
    overflow.extend(seqs[i..].iter().map(|&(id, _)| id));
    (batches, overflow)
}

/// One chunked-prefill grant: run `take` prompt tokens of request `id`
/// this engine step.
pub type PrefillGrant = (u64, usize);

/// Allocate this step's prefill token quota across the prefilling
/// sequences, FCFS in arrival order: each request gets at most one
/// `chunk`-sized slice, and the grants together never exceed `budget`
/// tokens — the engine's bound on how long a decode iteration can stall
/// behind prefill work.  With `budget == chunk` (the engine default) at
/// most one chunk's compute separates consecutive decode iterations.
///
/// `aligned` forbids PARTIAL grants (a sequence receiving less than
/// `min(chunk, rem)` because an earlier grant ate most of the budget):
/// planning stops instead.  The prefix-caching engine requires this —
/// page sharing is only sound if every sequence's chunk boundaries sit
/// at fixed multiples of `chunk`, independent of what else is
/// prefilling, so that an eagerly quantized page is a deterministic
/// function of the token prefix alone.  Leftover budget after a short
/// final chunk then goes unused, which costs a little utilization, never
/// correctness.
pub fn plan_prefill_chunks(
    remaining: &[(u64, usize)], // (request id, prompt tokens left) in arrival order
    chunk: usize,
    budget: usize,
    aligned: bool,
) -> Vec<PrefillGrant> {
    assert!(chunk > 0, "chunk size must be positive");
    let mut grants = Vec::new();
    let mut left = budget;
    for &(id, rem) in remaining {
        if left == 0 {
            break;
        }
        if rem == 0 {
            continue;
        }
        let take = rem.min(chunk);
        if aligned && take > left {
            break;
        }
        let take = take.min(left);
        grants.push((id, take));
        left -= take;
    }
    grants
}

/// Pages a sequence must be able to allocate before growing to
/// `tokens_after` total cache tokens, given it already holds
/// `pages_held` pages of `group` tokens each.  Drives the engine's
/// pool-capacity checks: one decode step's append needs a page exactly
/// when the residual is one token short of a group, and a prefill
/// chunk/flush needs pages for every full group it will finalize.
pub fn pages_needed(tokens_after: usize, pages_held: usize, group: usize) -> usize {
    (tokens_after / group).saturating_sub(pages_held)
}

/// Partition one decode step's sequences into `workers` shards balanced
/// by cache length (LPT greedy: longest first onto the lightest shard).
/// Per-token decode cost is dominated by walking the quantized pages, so
/// balancing summed cache length keeps the pool's slowest worker within
/// one sequence of the mean.  Returns `workers` id lists (some possibly
/// empty when there are fewer sequences than workers).
pub fn plan_decode_shards(seqs: &[(u64, usize)], workers: usize) -> Vec<Vec<u64>> {
    assert!(workers > 0);
    let mut order: Vec<usize> = (0..seqs.len()).collect();
    order.sort_by(|&a, &b| seqs[b].1.cmp(&seqs[a].1));
    let mut shards: Vec<Vec<u64>> = vec![Vec::new(); workers];
    let mut loads = vec![0usize; workers];
    for i in order {
        let w = (0..workers).min_by_key(|&w| (loads[w], w)).unwrap();
        // +1: even an empty cache costs a full model step (matmuls/FFN)
        loads[w] += seqs[i].1 + 1;
        shards[w].push(seqs[i].0);
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn packs_into_buckets() {
        let Some(m) = manifest() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        // tiny manifest has b1_s256, b4_s256, b1_s1024
        let seqs = vec![(1, 100), (2, 64), (3, 10), (4, 192), (5, 0)];
        let (batches, overflow) = plan_decode_batches(&m, seqs, 16);
        assert!(overflow.is_empty());
        let total: usize = batches.iter().map(|b| b.ids.len()).sum();
        assert_eq!(total, 5);
        for b in &batches {
            assert!(b.ids.len() <= b.batch_cap);
        }
        // the longest sequence must be in a bucket that fits it
        let first = &batches[0];
        assert!(first.seq_cap >= 192);
    }

    #[test]
    fn oversized_sequences_overflow() {
        let Some(m) = manifest() else {
            return;
        };
        let (batches, overflow) = plan_decode_batches(&m, vec![(9, 99_999)], 16);
        assert!(batches.is_empty());
        assert_eq!(overflow, vec![9]);
    }

    #[test]
    fn prefill_quota_is_fcfs_and_bounded() {
        // head request takes a full chunk; the rest of the budget spills
        // FCFS onto the next request
        let rem = vec![(1u64, 10usize), (2, 50), (3, 4)];
        let grants = plan_prefill_chunks(&rem, 8, 8, false);
        assert_eq!(grants, vec![(1, 8)]);
        // bigger budget: one chunk each until the budget runs out
        let grants = plan_prefill_chunks(&rem, 8, 20, false);
        assert_eq!(grants, vec![(1, 8), (2, 8), (3, 4)]);
        let total: usize = grants.iter().map(|&(_, t)| t).sum();
        assert!(total <= 20);
        // a short tail takes only what it needs
        let grants = plan_prefill_chunks(&[(7, 3)], 8, 8, false);
        assert_eq!(grants, vec![(7, 3)]);
        // finished entries are skipped, empty input is fine
        assert!(plan_prefill_chunks(&[(9, 0)], 8, 8, false).is_empty());
        assert!(plan_prefill_chunks(&[], 8, 8, false).is_empty());
    }

    #[test]
    fn aligned_planning_never_cuts_partial_chunks() {
        // head's short final chunk (5 of 8) leaves 3 budget: unaligned
        // planning would hand request 2 a misaligned 3-token grant;
        // aligned planning stops instead
        let rem = vec![(1u64, 5usize), (2, 50)];
        assert_eq!(plan_prefill_chunks(&rem, 8, 8, false), vec![(1, 5), (2, 3)]);
        assert_eq!(plan_prefill_chunks(&rem, 8, 8, true), vec![(1, 5)]);
        // full chunks still spill under a bigger budget
        assert_eq!(plan_prefill_chunks(&rem, 8, 16, true), vec![(1, 5), (2, 8)]);
        // a grant that IS the sequence's whole remainder stays allowed
        assert_eq!(plan_prefill_chunks(&[(9, 4)], 8, 8, true), vec![(9, 4)]);
    }

    #[test]
    fn shards_cover_all_ids_and_balance() {
        let seqs: Vec<(u64, usize)> = (0..13).map(|i| (i, (i as usize * 97) % 500)).collect();
        let shards = plan_decode_shards(&seqs, 4);
        assert_eq!(shards.len(), 4);
        let mut ids: Vec<u64> = shards.iter().flatten().copied().collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..13).collect::<Vec<u64>>());
        // LPT bound: max shard load <= mean + the largest single item
        let load = |s: &Vec<u64>| -> usize {
            s.iter().map(|id| seqs[*id as usize].1 + 1).sum()
        };
        let loads: Vec<usize> = shards.iter().map(load).collect();
        let total: usize = loads.iter().sum();
        let max_item = seqs.iter().map(|&(_, l)| l + 1).max().unwrap();
        let max_load = *loads.iter().max().unwrap();
        assert!(
            max_load <= total / 4 + max_item,
            "max {max_load} total {total} item {max_item}"
        );
    }

    #[test]
    fn pages_needed_counts_only_new_full_groups() {
        // decode growth: a page is needed exactly when the appended token
        // completes a group
        assert_eq!(pages_needed(16, 1, 8), 1);
        assert_eq!(pages_needed(15, 1, 8), 0);
        // prefill flush: all full groups at once, minus whatever a prefix
        // hit already attached
        assert_eq!(pages_needed(20, 0, 8), 2);
        assert_eq!(pages_needed(20, 2, 8), 0);
        // over-held (adopted more than the tokens ask) never underflows
        assert_eq!(pages_needed(8, 3, 8), 0);
    }

    #[test]
    fn shards_with_more_workers_than_seqs() {
        let shards = plan_decode_shards(&[(7, 10), (8, 2)], 5);
        assert_eq!(shards.iter().flatten().count(), 2);
        assert!(shards.iter().filter(|s| s.is_empty()).count() == 3);
    }

    #[test]
    fn respects_max_batches() {
        let Some(m) = manifest() else {
            return;
        };
        let seqs: Vec<(u64, usize)> = (0..20).map(|i| (i, 10)).collect();
        let (batches, overflow) = plan_decode_batches(&m, seqs, 1);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].ids.len() + overflow.len(), 20);
    }
}
