//! Dynamic batching into the AOT shape buckets.
//!
//! AOT graphs have static shapes, so the batcher's job is the classic
//! TPU-serving one: group running sequences so that (batch, max cache len)
//! fits the smallest compiled bucket, padding the rest.  Sequences that
//! outgrow every bucket are surfaced so the scheduler can finish them on
//! the native backend (shape-unconstrained) instead of crashing.

use crate::runtime::Manifest;

/// One decode batch: request ids + the graph bucket that will run them.
#[derive(Clone, Debug, PartialEq)]
pub struct DecodeBatch {
    pub graph: String,
    pub batch_cap: usize,
    pub seq_cap: usize,
    pub ids: Vec<u64>,
}

/// Greedy bucket packing: sort by cache length descending, then fill the
/// smallest bucket that fits each prefix.
pub fn plan_decode_batches(
    manifest: &Manifest,
    mut seqs: Vec<(u64, usize)>, // (request id, quantized cache len)
    max_batches: usize,
) -> (Vec<DecodeBatch>, Vec<u64>) {
    let mut batches = Vec::new();
    let mut overflow = Vec::new();
    seqs.sort_by(|a, b| b.1.cmp(&a.1));

    let buckets = manifest.graphs_of_kind("decode");
    if buckets.is_empty() {
        return (batches, seqs.into_iter().map(|(id, _)| id).collect());
    }
    let max_seq_cap = buckets.iter().map(|g| g.seq).max().unwrap();

    let mut i = 0;
    while i < seqs.len() && batches.len() < max_batches {
        let (_, len) = seqs[i];
        if len > max_seq_cap {
            overflow.push(seqs[i].0);
            i += 1;
            continue;
        }
        // choose the bucket for this (longest-remaining) sequence
        let bucket = buckets
            .iter()
            .filter(|g| g.seq >= len)
            .min_by_key(|g| (g.seq, std::cmp::Reverse(g.batch)))
            .unwrap();
        // fill it with as many following sequences as fit
        let take = (seqs.len() - i).min(bucket.batch);
        let ids: Vec<u64> = seqs[i..i + take].iter().map(|&(id, _)| id).collect();
        batches.push(DecodeBatch {
            graph: bucket.name.clone(),
            batch_cap: bucket.batch,
            seq_cap: bucket.seq,
            ids,
        });
        i += take;
    }
    // anything left when max_batches hit also overflows to the caller
    overflow.extend(seqs[i..].iter().map(|&(id, _)| id));
    (batches, overflow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn packs_into_buckets() {
        let Some(m) = manifest() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        // tiny manifest has b1_s256, b4_s256, b1_s1024
        let seqs = vec![(1, 100), (2, 64), (3, 10), (4, 192), (5, 0)];
        let (batches, overflow) = plan_decode_batches(&m, seqs, 16);
        assert!(overflow.is_empty());
        let total: usize = batches.iter().map(|b| b.ids.len()).sum();
        assert_eq!(total, 5);
        for b in &batches {
            assert!(b.ids.len() <= b.batch_cap);
        }
        // the longest sequence must be in a bucket that fits it
        let first = &batches[0];
        assert!(first.seq_cap >= 192);
    }

    #[test]
    fn oversized_sequences_overflow() {
        let Some(m) = manifest() else {
            return;
        };
        let (batches, overflow) = plan_decode_batches(&m, vec![(9, 99_999)], 16);
        assert!(batches.is_empty());
        assert_eq!(overflow, vec![9]);
    }

    #[test]
    fn respects_max_batches() {
        let Some(m) = manifest() else {
            return;
        };
        let seqs: Vec<(u64, usize)> = (0..20).map(|i| (i, 10)).collect();
        let (batches, overflow) = plan_decode_batches(&m, seqs, 1);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].ids.len() + overflow.len(), 20);
    }
}
