//! Minimal f32 tensor substrate.
//!
//! The coordinator, the Rust-native model, and the eval harness need a
//! small set of dense ops (matmul, softmax, norms, RoPE).  No ndarray
//! offline — this module implements exactly what the repo uses, with
//! row-major layout and explicit shapes, tuned enough (blocked matmul,
//! fused softmax) that the native backend is a fair comparator in benches.

pub mod ops;

pub use ops::*;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data/shape mismatch: {} vs {:?}",
            data.len(),
            shape
        );
        Tensor { data, shape: shape.to_vec() }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { data: vec![v; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let d = self.shape[1];
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let d = self.shape[1];
        &mut self.data[i * d..(i + 1) * d]
    }

    /// Reinterpret with a new shape (same numel).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.numel(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
        self
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &s)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < s, "index {x} out of bound {s} at dim {i}");
            off = off * s + x;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let o = self.offset(idx);
        &mut self.data[o]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(t.at(&[1, 2]), 6.0);
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.row(2), &[5.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![1.0], &[2, 2]);
    }
}
