//! Dense ops: matmul (blocked), vector math, softmax, RMS-norm, RoPE.
//!
//! RoPE here must match `ref.apply_rope` / `model.rope_rotate` exactly —
//! adjacent-pair formulation, `phi_i = base^(-2i/d)` — because the Rust
//! model's caches interoperate with the AOT graphs.

use super::Tensor;

/// out[m,n] = sum_k a[m,k] * b[k,n]  (row-major, blocked over k for cache
/// friendliness; good enough for the native backend's small matrices).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    let mut out = vec![0.0f32; m * n];
    matmul_into(&a.data, &b.data, m, k, n, &mut out);
    Tensor::new(out, &[m, n])
}

/// Core kernel: C += A(m,k) * B(k,n) with i-k-j loop order (B rows stream
/// through cache, C row stays hot).
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unroll: the hot path of the fp QK baseline.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// In-place numerically-stable softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    let mx = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// RMS norm: x * rsqrt(mean(x^2) + eps) * gamma   (matches model.rms_norm).
pub fn rms_norm(x: &[f32], gamma: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gamma.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * r * gamma[i];
    }
}

/// SiLU (x * sigmoid(x)).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RoPE pair frequencies phi_i = base^(-2i/d).
pub fn rope_freqs(head_dim: usize, base: f32) -> Vec<f32> {
    (0..head_dim / 2)
        .map(|i| base.powf(-2.0 * i as f32 / head_dim as f32))
        .collect()
}

/// Rotate adjacent pairs of `x` (len d) in place by angle pos*phi_j.
pub fn rope_rotate_inplace(x: &mut [f32], pos: u32, freqs: &[f32]) {
    debug_assert_eq!(x.len(), freqs.len() * 2);
    for (j, &phi) in freqs.iter().enumerate() {
        let ang = pos as f32 * phi;
        let (sin, cos) = ang.sin_cos();
        let xe = x[2 * j];
        let xo = x[2 * j + 1];
        x[2 * j] = xe * cos - xo * sin;
        x[2 * j + 1] = xe * sin + xo * cos;
    }
}

/// argmax index.
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..x.len() {
        if x[i] > x[best] {
            best = i;
        }
    }
    best
}

/// Mean squared error between slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Cosine similarity.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = dot(a, a) as f64;
    let nb = dot(b, b) as f64;
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot(a, b) as f64 / (na.sqrt() * nb.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::new(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rect() {
        // (1x3) @ (3x2)
        let a = Tensor::new(vec![1.0, 2.0, 3.0], &[1, 3]);
        let b = Tensor::new(vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0], &[3, 2]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![4.0, 5.0]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, -1e9];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x[3] < 1e-12);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn rope_preserves_norm() {
        let freqs = rope_freqs(8, 10000.0);
        let mut x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let n0 = dot(&x, &x);
        rope_rotate_inplace(&mut x, 17, &freqs);
        let n1 = dot(&x, &x);
        assert!((n0 - n1).abs() < 1e-3, "{n0} vs {n1}");
    }

    #[test]
    fn rope_zero_pos_is_identity() {
        let freqs = rope_freqs(4, 10000.0);
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        rope_rotate_inplace(&mut x, 0, &freqs);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rms_norm_unit_gamma() {
        let x = vec![3.0, 4.0];
        let gamma = vec![1.0, 1.0];
        let mut out = vec![0.0; 2];
        rms_norm(&x, &gamma, 1e-5, &mut out);
        let ms: f32 = (9.0 + 16.0) / 2.0;
        assert!((out[0] - 3.0 / ms.sqrt()).abs() < 1e-4);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..37).map(|i| (37 - i) as f32 * 0.25).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }
}
