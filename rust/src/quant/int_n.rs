//! Int-N baseline: plain token-wise asymmetric quantization (params per
//! token over channels).  Collapses under channel-wise outliers — the
//! failure mode PolarQuant is built to avoid (paper Table 1).

use super::pack::PackedCodes;
use super::{dequantize, qparams, quantize};

#[derive(Clone, Debug)]
pub struct IntEncoded {
    pub codes: PackedCodes,
    /// per-token zero point / scale
    pub z: Vec<f32>,
    pub s: Vec<f32>,
    pub bits: u32,
}

impl IntEncoded {
    pub fn tokens(&self) -> usize {
        self.z.len()
    }

    pub fn nbytes(&self) -> usize {
        self.codes.nbytes() + 2 * self.z.len() * std::mem::size_of::<f32>()
    }
}

/// bits/element incl. per-token fp16 zero+scale (32/d, paper §B).
pub fn bits_per_element(bits: u32, d: usize) -> f64 {
    bits as f64 + 32.0 / d as f64
}

pub fn encode(x: &[f32], d: usize, bits: u32) -> IntEncoded {
    let tokens = x.len() / d;
    assert_eq!(x.len(), tokens * d);
    let mut z = vec![0.0f32; tokens];
    let mut s = vec![0.0f32; tokens];
    let mut codes = vec![0u8; tokens * d];
    for n in 0..tokens {
        let row = &x[n * d..(n + 1) * d];
        let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let (zz, ss) = qparams(lo, hi, bits);
        z[n] = zz;
        s[n] = ss;
        for j in 0..d {
            codes[n * d + j] = quantize(row[j], zz, ss, bits);
        }
    }
    IntEncoded { codes: PackedCodes::from_codes(&codes, bits), z, s, bits }
}

pub fn decode(enc: &IntEncoded, d: usize) -> Vec<f32> {
    let codes = enc.codes.unpack();
    let mut out = Vec::with_capacity(codes.len());
    for n in 0..enc.tokens() {
        for j in 0..d {
            out.push(dequantize(codes[n * d + j], enc.z[n], enc.s[n]));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_within_half_cell() {
        let mut rng = Rng::new(41);
        let d = 24;
        let x = rng.normal_vec(10 * d);
        let enc = encode(&x, d, 4);
        let x_hat = decode(&enc, d);
        for n in 0..10 {
            for j in 0..d {
                assert!((x[n * d + j] - x_hat[n * d + j]).abs() <= enc.s[n] / 2.0 + 1e-5);
            }
        }
    }

    #[test]
    fn outliers_blow_up_the_scale() {
        // one huge channel makes the per-token scale coarse for everyone
        let mut rng = Rng::new(42);
        let d = 32;
        let mut x = rng.normal_vec(4 * d);
        for n in 0..4 {
            x[n * d] = 100.0;
        }
        let enc = encode(&x, d, 4);
        for n in 0..4 {
            assert!(enc.s[n] > 5.0, "scale should be dominated by the outlier");
        }
    }
}
