//! Quantization library: PolarQuant plus every baseline the paper
//! evaluates against (KIVI, Int-N, ZipCache, QJL) and the value-cache
//! codec, with real bit-packed storage and the LUT-accelerated QK path.
//!
//! Numerics contract (shared with `python/compile/kernels/ref.py` and
//! checked bit-for-bit by `rust/tests/goldens.rs`):
//!
//! * asymmetric min/max quantization:
//!     `z = min(x)`, `s = max((max-min)/2^bits, 1e-8)`,
//!     `code = clamp(floor((x-z)/s), 0, 2^bits-1)`,
//!     `deq  = (code + 1/2) * s + z`
//! * polar transform on post-RoPE keys, pairs `(2j, 2j+1)`:
//!     `rho = hypot(x, y)`, `theta = atan2(y, x) + pi` (stored in (0,2pi),
//!     shifted back by `-pi` at decode)
//! * group-wise over **tokens** (size g), params per (group, channel).

pub mod int_n;
pub mod kivi;
pub mod lut;
pub mod pack;
pub mod polar;
pub mod qjl;
pub mod spec;
pub mod value;
pub mod zipcache;

pub use lut::{
    select_kernel, simd_available, KernelKind, QkLut, ScalarKernel, ScoreKernel, SeqScoreJob,
    SimdKernel,
};
pub use polar::{DraftSpec, PolarEncoded, PolarGroup, PolarSpec};
pub use spec::{KeyCodec, QuantSpec};

/// Asymmetric quantization params for one channel over one token group.
#[inline]
pub fn qparams(min: f32, max: f32, bits: u32) -> (f32, f32) {
    let z = min;
    let s = ((max - min) / (1u32 << bits) as f32).max(1e-8);
    (z, s)
}

/// Quantize one value.
#[inline]
pub fn quantize(x: f32, z: f32, s: f32, bits: u32) -> u8 {
    let code = ((x - z) / s).floor();
    let hi = ((1u32 << bits) - 1) as f32;
    code.clamp(0.0, hi) as u8
}

/// Dequantize one code.
#[inline]
pub fn dequantize(code: u8, z: f32, s: f32) -> f32 {
    (code as f32 + 0.5) * s + z
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_roundtrip_within_half_cell() {
        let (z, s) = qparams(-2.0, 3.0, 4);
        for i in 0..=50 {
            let x = -2.0 + 5.0 * i as f32 / 50.0;
            let c = quantize(x, z, s, 4);
            let d = dequantize(c, z, s);
            assert!((x - d).abs() <= s / 2.0 + 1e-6, "x={x} d={d} s={s}");
        }
    }

    #[test]
    fn quant_clamps() {
        let (z, s) = qparams(0.0, 1.0, 2);
        assert_eq!(quantize(-5.0, z, s, 2), 0);
        assert_eq!(quantize(5.0, z, s, 2), 3);
    }

    #[test]
    fn degenerate_range_is_safe() {
        let (z, s) = qparams(1.5, 1.5, 4);
        assert_eq!(s, 1e-8);
        let c = quantize(1.5, z, s, 4);
        let d = dequantize(c, z, s);
        assert!((d - 1.5).abs() < 1e-6);
    }
}
