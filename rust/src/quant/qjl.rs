//! QJL baseline [Zandieh et al., 2024]: 1-bit quantized Johnson–
//! Lindenstrauss sketch of the keys.
//!
//! Keys are projected by a fixed Gaussian matrix `P (m x d)`; only the
//! *signs* of the projection plus the key norm are stored.  The inner
//! product is estimated by the sign-sketch identity
//! `E[sign(<p,k>)·<p,q>] = sqrt(2/pi)·<q,k>/||k||`, i.e.
//!
//! ```text
//! <q,k> ~= ||k|| · sqrt(pi/2) / m · Σ_i sign(<p_i,k>) · <p_i,q>
//! ```
//!
//! At `m = 3d` sign bits + one fp16 norm per token the budget matches the
//! paper's "QJL 3.13-bit" row.  No quantization constants are stored —
//! QJL's selling point — at the cost of a noisier estimator (visible in
//! Table 1 as mid-tier quality).

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct QjlSketcher {
    /// projection matrix, row-major (m x d)
    proj: Vec<f32>,
    pub m: usize,
    pub d: usize,
}

#[derive(Clone, Debug)]
pub struct QjlEncoded {
    /// sign bits, one u64 word per 64 projections, token-major
    signs: Vec<u64>,
    words_per_token: usize,
    pub norms: Vec<f32>,
}

impl QjlEncoded {
    pub fn tokens(&self) -> usize {
        self.norms.len()
    }

    pub fn nbytes(&self) -> usize {
        self.signs.len() * 8 + self.norms.len() * 2 // norms charged as fp16
    }
}

impl QjlSketcher {
    /// `bits_per_channel` ~ 3 reproduces the paper's QJL-3 budget.
    pub fn new(d: usize, bits_per_channel: usize, seed: u64) -> Self {
        let m = d * bits_per_channel;
        let mut rng = Rng::new(seed);
        let proj = rng.normal_vec(m * d);
        QjlSketcher { proj, m, d }
    }

    pub fn bits_per_element(&self) -> f64 {
        self.m as f64 / self.d as f64 + 16.0 / self.d as f64
    }

    pub fn encode(&self, k: &[f32]) -> QjlEncoded {
        let tokens = k.len() / self.d;
        let wpt = self.m.div_ceil(64);
        let mut signs = vec![0u64; tokens * wpt];
        let mut norms = vec![0.0f32; tokens];
        for n in 0..tokens {
            let row = &k[n * self.d..(n + 1) * self.d];
            norms[n] = crate::tensor::ops::dot(row, row).sqrt();
            for i in 0..self.m {
                let p = &self.proj[i * self.d..(i + 1) * self.d];
                if crate::tensor::ops::dot(p, row) >= 0.0 {
                    signs[n * wpt + i / 64] |= 1u64 << (i % 64);
                }
            }
        }
        QjlEncoded { signs, words_per_token: wpt, norms }
    }

    /// Estimated scores `<q, k_n>` for all cached tokens.
    pub fn scores(&self, q: &[f32], enc: &QjlEncoded, out: &mut Vec<f32>) {
        out.clear();
        // project the query once per call
        let pq: Vec<f32> = (0..self.m)
            .map(|i| crate::tensor::ops::dot(&self.proj[i * self.d..(i + 1) * self.d], q))
            .collect();
        let scale = (std::f32::consts::PI / 2.0).sqrt() / self.m as f32;
        for n in 0..enc.tokens() {
            let words = &enc.signs[n * enc.words_per_token..(n + 1) * enc.words_per_token];
            let mut acc = 0.0f32;
            for i in 0..self.m {
                let sign = if words[i / 64] >> (i % 64) & 1 == 1 { 1.0 } else { -1.0 };
                acc += sign * pq[i];
            }
            out.push(enc.norms[n] * scale * acc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::dot;
    use crate::util::rng::Rng;

    #[test]
    fn estimator_is_roughly_unbiased() {
        let d = 64;
        let sk = QjlSketcher::new(d, 8, 7); // generous m for the test
        let mut rng = Rng::new(71);
        let tokens = 32;
        let k = rng.normal_vec(tokens * d);
        let q = rng.normal_vec(d);
        let enc = sk.encode(&k);
        let mut est = Vec::new();
        sk.scores(&q, &enc, &mut est);
        // correlation between estimate and truth should be strong
        let truth: Vec<f32> = (0..tokens).map(|n| dot(&q, &k[n * d..(n + 1) * d])).collect();
        let corr = crate::tensor::ops::cosine(&est, &truth);
        assert!(corr > 0.8, "corr {corr}");
    }

    #[test]
    fn budget_matches_paper() {
        let sk = QjlSketcher::new(128, 3, 1);
        assert!((sk.bits_per_element() - 3.125).abs() < 1e-9);
    }
}
