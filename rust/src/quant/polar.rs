//! PolarQuant codec — the paper's contribution (§3.2).
//!
//! Post-RoPE key sub-vectors `(K[2j], K[2j+1])` are mapped to polar
//! coordinates; radius and angle are quantized asymmetrically (r / t bits)
//! group-wise over tokens with per-channel-pair params.  Storage is
//! bit-packed; the accelerated QK path lives in [`crate::quant::lut`].

use super::pack::PackedCodes;
use super::{qparams, quantize};

/// PolarQuant hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PolarSpec {
    pub r_bits: u32,
    pub t_bits: u32,
    pub group: usize,
}

impl PolarSpec {
    pub fn new(r_bits: u32, t_bits: u32, group: usize) -> Self {
        assert!((1..=8).contains(&r_bits) && (1..=8).contains(&t_bits));
        assert!(group > 0);
        PolarSpec { r_bits, t_bits, group }
    }

    /// Key-cache bits per *original element* (two elements per sub-vector)
    /// including fp16 zero/scale pairs for rho and theta per group per
    /// channel-pair: 4 * 16 bits over (group * 2) elements.
    pub fn bits_per_element(&self) -> f64 {
        (self.r_bits + self.t_bits) as f64 / 2.0 + 32.0 / self.group as f64
    }
}

/// A coarse *draft* plane derived from an exact [`PolarSpec`] by **code
/// truncation**: the draft code for a sub-vector is the stored exact code
/// with its low bits dropped (`c' = c >> shift`), and the draft dequant
/// point is the midpoint of the merged cell —
///
/// ```text
/// rho~'  = (c' + 1/2) · (s · 2^r_shift) + z      (same z, scale widened)
/// theta' = (c' + 1/2) · (ts · 2^t_shift) + tz − π
/// ```
///
/// so a draft plane is *derived*, never stored: pages keep only the exact
/// codes, and the shifted view is materialized at LUT staging time
/// ([`crate::quant::lut::QkLut::with_draft`]).  A draft pass therefore
/// costs zero extra quantization work and zero extra cache bytes — the
/// self-drafting property speculative decoding builds on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DraftSpec {
    pub r_bits: u32,
    pub t_bits: u32,
}

impl DraftSpec {
    pub fn new(r_bits: u32, t_bits: u32) -> Self {
        assert!((1..=8).contains(&r_bits) && (1..=8).contains(&t_bits));
        DraftSpec { r_bits, t_bits }
    }

    /// Parse a `R,T` flag value (`--draft-bits 2,2`).
    pub fn parse(s: &str) -> Result<Self, String> {
        let (r, t) = s
            .split_once(',')
            .ok_or_else(|| format!("draft bits '{s}': expected R,T"))?;
        let parse_bits = |v: &str, axis: &str| -> Result<u32, String> {
            let b: u32 = v
                .trim()
                .parse()
                .map_err(|_| format!("draft bits '{s}': bad {axis} '{v}'"))?;
            if (1..=8).contains(&b) {
                Ok(b)
            } else {
                Err(format!("draft bits '{s}': {axis} must be in 1..=8"))
            }
        };
        Ok(DraftSpec { r_bits: parse_bits(r, "radius bits")?, t_bits: parse_bits(t, "angle bits")? })
    }

    /// Default draft for an exact plane: half the bits, floor 1 — coarse
    /// enough for a cheap proxy, fine enough to keep score ordering.
    pub fn halved(exact: &PolarSpec) -> Self {
        DraftSpec::new((exact.r_bits / 2).max(1), (exact.t_bits / 2).max(1))
    }

    /// The right-shifts that turn exact codes into draft codes
    /// (`(r_shift, t_shift)`).  Errors unless `draft <= exact` on both
    /// axes — a draft plane can only drop bits the exact plane stored.
    pub fn shifts(&self, exact: &PolarSpec) -> Result<(u32, u32), String> {
        if self.r_bits > exact.r_bits || self.t_bits > exact.t_bits {
            return Err(format!(
                "draft bits r{}/t{} exceed the exact plane's r{}/t{}",
                self.r_bits, self.t_bits, exact.r_bits, exact.t_bits
            ));
        }
        Ok((exact.r_bits - self.r_bits, exact.t_bits - self.t_bits))
    }
}

/// One encoded token-group of one key stream (d/2 channel pairs).
///
/// Layout (pack v2): codes are CHANNEL-MAJOR planes (`j * tokens + n`) —
/// each channel pair's codes for the whole group form one contiguous,
/// byte-aligned lane, which is what the SIMD score kernel gathers from
/// and what lets rho dequantization broadcast one `(z, s)` pair down a
/// lane.  Params are per channel pair.  (Tier records written before the
/// layout bump stored token-major; `kvcache::tier::serde` transposes
/// them on promote.)
#[derive(Clone, Debug)]
pub struct PolarGroup {
    pub rho_codes: PackedCodes,
    pub theta_codes: PackedCodes,
    /// Combined (rho << t_bits | theta) codes, present when r+t <= 8.
    /// Same total bit count as the two separate streams, but the decode
    /// hot loop pays ONE unpack per sub-vector instead of two — the
    /// "byte-plane fusion" optimization recorded in EXPERIMENTS.md §Perf.
    pub combined: Option<PackedCodes>,
    pub rho_z: Vec<f32>,
    pub rho_s: Vec<f32>,
    pub theta_z: Vec<f32>,
    pub theta_s: Vec<f32>,
    /// tokens in this group (== spec.group for full groups)
    pub tokens: usize,
}

impl PolarGroup {
    /// Physical bytes (codes packed + params as fp32 here; the bit
    /// accounting in `spec.rs` charges fp16 as the paper does).
    ///
    /// Codes are counted ONCE: `combined` carries exactly the same r+t
    /// bits per sub-vector as the split rho/theta planes (it exists only
    /// so the decode hot loop pays one unpack instead of two); a
    /// production build would store just one of the two forms.
    pub fn nbytes(&self) -> usize {
        self.rho_codes.nbytes()
            + self.theta_codes.nbytes()
            + 4 * self.rho_z.len() * std::mem::size_of::<f32>()
    }
}

/// A whole encoded key stream: consecutive full groups.
#[derive(Clone, Debug, Default)]
pub struct PolarEncoded {
    pub groups: Vec<PolarGroup>,
}

impl PolarEncoded {
    pub fn tokens(&self) -> usize {
        self.groups.iter().map(|g| g.tokens).sum()
    }
}

/// Encode one full token group. `k` is row-major (tokens x d), post-RoPE.
pub fn encode_group(k: &[f32], d: usize, spec: &PolarSpec) -> PolarGroup {
    let tokens = k.len() / d;
    assert_eq!(k.len(), tokens * d);
    assert!(d % 2 == 0);
    let d2 = d / 2;

    // polar transform straight into channel-major planes: lane j holds
    // the whole group's values for channel pair j
    let mut rho = vec![0.0f32; tokens * d2];
    let mut theta = vec![0.0f32; tokens * d2];
    for n in 0..tokens {
        let row = &k[n * d..(n + 1) * d];
        for j in 0..d2 {
            let x = row[2 * j];
            let y = row[2 * j + 1];
            rho[j * tokens + n] = (x * x + y * y).sqrt();
            theta[j * tokens + n] = y.atan2(x) + std::f32::consts::PI;
        }
    }

    let mut rho_z = vec![0.0f32; d2];
    let mut rho_s = vec![0.0f32; d2];
    let mut theta_z = vec![0.0f32; d2];
    let mut theta_s = vec![0.0f32; d2];
    for j in 0..d2 {
        let (mut rmin, mut rmax) = (f32::INFINITY, f32::NEG_INFINITY);
        let (mut tmin, mut tmax) = (f32::INFINITY, f32::NEG_INFINITY);
        for n in 0..tokens {
            let r = rho[j * tokens + n];
            let t = theta[j * tokens + n];
            rmin = rmin.min(r);
            rmax = rmax.max(r);
            tmin = tmin.min(t);
            tmax = tmax.max(t);
        }
        let (z, s) = qparams(rmin, rmax, spec.r_bits);
        rho_z[j] = z;
        rho_s[j] = s;
        let (z, s) = qparams(tmin, tmax, spec.t_bits);
        theta_z[j] = z;
        theta_s[j] = s;
    }

    let mut rc = vec![0u8; tokens * d2];
    let mut tc = vec![0u8; tokens * d2];
    for j in 0..d2 {
        for n in 0..tokens {
            let i = j * tokens + n;
            rc[i] = quantize(rho[i], rho_z[j], rho_s[j], spec.r_bits);
            tc[i] = quantize(theta[i], theta_z[j], theta_s[j], spec.t_bits);
        }
    }

    let combined = if spec.r_bits + spec.t_bits <= 8 {
        let mixed: Vec<u8> = rc
            .iter()
            .zip(&tc)
            .map(|(&r, &t)| (r << spec.t_bits) | t)
            .collect();
        Some(PackedCodes::from_codes(&mixed, spec.r_bits + spec.t_bits))
    } else {
        None
    };
    PolarGroup {
        rho_codes: PackedCodes::from_codes(&rc, spec.r_bits),
        theta_codes: PackedCodes::from_codes(&tc, spec.t_bits),
        combined,
        rho_z,
        rho_s,
        theta_z,
        theta_s,
        tokens,
    }
}

/// Encode a multi-group stream (len must be a whole number of groups).
pub fn encode(k: &[f32], d: usize, spec: &PolarSpec) -> PolarEncoded {
    let tokens = k.len() / d;
    assert_eq!(tokens % spec.group, 0, "only full groups are encoded");
    let groups = (0..tokens / spec.group)
        .map(|g| {
            let start = g * spec.group * d;
            encode_group(&k[start..start + spec.group * d], d, spec)
        })
        .collect();
    PolarEncoded { groups }
}

/// Dequantize a group back to Cartesian keys (tokens x d), appending to `out`.
pub fn decode_group_into(g: &PolarGroup, d: usize, out: &mut Vec<f32>) {
    let d2 = d / 2;
    let rc = g.rho_codes.unpack();
    let tc = g.theta_codes.unpack();
    for n in 0..g.tokens {
        for j in 0..d2 {
            let i = j * g.tokens + n; // channel-major planes
            let rho = (rc[i] as f32 + 0.5) * g.rho_s[j] + g.rho_z[j];
            // -pi undoes the atan2(+pi) storage shift
            let th = (tc[i] as f32 + 0.5) * g.theta_s[j] + g.theta_z[j]
                - std::f32::consts::PI;
            out.push(rho * th.cos());
            out.push(rho * th.sin());
        }
    }
}

/// Dequantize a whole stream.
pub fn decode(enc: &PolarEncoded, d: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(enc.tokens() * d);
    for g in &enc.groups {
        decode_group_into(g, d, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{mse, rope_freqs, rope_rotate_inplace};
    use crate::util::rng::Rng;

    fn outlier_keys(rng: &mut Rng, tokens: usize, d: usize, severity: f32) -> Vec<f32> {
        let mut k = rng.normal_vec(tokens * d);
        let out_ch = rng.choose_distinct(d / 2, (d / 16).max(1));
        for n in 0..tokens {
            for &j in &out_ch {
                k[n * d + 2 * j] += severity;
            }
        }
        let freqs = rope_freqs(d, 10000.0);
        for n in 0..tokens {
            rope_rotate_inplace(&mut k[n * d..(n + 1) * d], n as u32, &freqs);
        }
        k
    }

    #[test]
    fn roundtrip_error_bounded_by_cells() {
        let mut rng = Rng::new(11);
        let spec = PolarSpec::new(4, 4, 16);
        let d = 32;
        let k = outlier_keys(&mut rng, 32, d, 8.0);
        let enc = encode(&k, d, &spec);
        let k_hat = decode(&enc, d);
        assert_eq!(k_hat.len(), k.len());
        for (gi, g) in enc.groups.iter().enumerate() {
            for n in 0..g.tokens {
                let t = gi * spec.group + n;
                for j in 0..d / 2 {
                    let dx = k[t * d + 2 * j] - k_hat[t * d + 2 * j];
                    let dy = k[t * d + 2 * j + 1] - k_hat[t * d + 2 * j + 1];
                    let err = (dx * dx + dy * dy).sqrt();
                    let x = k[t * d + 2 * j];
                    let y = k[t * d + 2 * j + 1];
                    let rho = (x * x + y * y).sqrt();
                    let bound = g.rho_s[j] / 2.0 + (rho + g.rho_s[j] / 2.0) * g.theta_s[j] / 2.0;
                    assert!(err <= bound + 1e-4, "err {err} bound {bound}");
                }
            }
        }
    }

    #[test]
    fn polar_beats_tokenwise_under_outliers() {
        // Figure-2 claim at the Rust layer.
        let mut rng = Rng::new(5);
        let d = 64;
        let spec = PolarSpec::new(4, 4, 32);
        let k = outlier_keys(&mut rng, 128, d, 20.0);
        let enc = encode(&k, d, &spec);
        let k_hat = decode(&enc, d);
        let err_polar = mse(&k, &k_hat);

        let tok = super::super::int_n::encode(&k, d, 4);
        let k_tok = super::super::int_n::decode(&tok, d);
        let err_tok = mse(&k, &k_tok);
        assert!(
            err_polar < 0.5 * err_tok,
            "polar {err_polar} vs tokenwise {err_tok}"
        );
    }

    #[test]
    fn bits_accounting() {
        let spec = PolarSpec::new(4, 4, 128);
        assert!((spec.bits_per_element() - 4.25).abs() < 1e-9);
        let spec = PolarSpec::new(3, 3, 128);
        assert!((spec.bits_per_element() - 3.25).abs() < 1e-9);
    }

    #[test]
    fn draft_spec_shifts_and_validation() {
        let exact = PolarSpec::new(4, 4, 16);
        assert_eq!(DraftSpec::new(2, 3).shifts(&exact), Ok((2, 1)));
        assert_eq!(DraftSpec::new(4, 4).shifts(&exact), Ok((0, 0)));
        assert!(DraftSpec::new(5, 4).shifts(&exact).is_err());
        assert!(DraftSpec::new(4, 5).shifts(&exact).is_err());
        assert_eq!(DraftSpec::halved(&exact), DraftSpec::new(2, 2));
        assert_eq!(DraftSpec::halved(&PolarSpec::new(1, 2, 16)), DraftSpec::new(1, 1));
        assert_eq!(DraftSpec::parse("2,3"), Ok(DraftSpec::new(2, 3)));
        assert_eq!(DraftSpec::parse(" 1 , 8 "), Ok(DraftSpec::new(1, 8)));
        assert!(DraftSpec::parse("2").is_err());
        assert!(DraftSpec::parse("0,3").is_err());
        assert!(DraftSpec::parse("2,nine").is_err());
    }

    #[test]
    fn multi_group_layout() {
        let mut rng = Rng::new(3);
        let spec = PolarSpec::new(3, 5, 8);
        let d = 16;
        let k = rng.normal_vec(24 * d);
        let enc = encode(&k, d, &spec);
        assert_eq!(enc.groups.len(), 3);
        assert_eq!(enc.tokens(), 24);
        // group 1 encoded independently == slicing input
        let g1 = encode_group(&k[8 * d..16 * d], d, &spec);
        assert_eq!(enc.groups[1].rho_codes, g1.rho_codes);
        assert_eq!(enc.groups[1].theta_codes, g1.theta_codes);
    }
}
