//! ZipCache baseline [He et al., 2024]: channel-separable token-wise key
//! quantization — each channel is normalized by sqrt(max |.|) over the
//! window before per-token quantization.  Softens (but does not solve) the
//! outlier problem; the paper's Table 1 shows it collapsing on
//! outlier-heavy ("qwen-like") key distributions.

use super::int_n::{self, IntEncoded};

#[derive(Clone, Debug)]
pub struct ZipEncoded {
    pub inner: IntEncoded,
    /// per-channel normalizer sqrt(max |k[:, j]|)
    pub channel_norm: Vec<f32>,
}

impl ZipEncoded {
    pub fn nbytes(&self) -> usize {
        self.inner.nbytes() + self.channel_norm.len() * std::mem::size_of::<f32>()
    }
}

pub fn encode(k: &[f32], d: usize, bits: u32) -> ZipEncoded {
    let tokens = k.len() / d;
    assert_eq!(k.len(), tokens * d);
    let mut norm = vec![0.0f32; d];
    for j in 0..d {
        let mut mx = 0.0f32;
        for n in 0..tokens {
            mx = mx.max(k[n * d + j].abs());
        }
        norm[j] = mx.max(1e-8).sqrt();
    }
    let mut kn = vec![0.0f32; k.len()];
    for n in 0..tokens {
        for j in 0..d {
            kn[n * d + j] = k[n * d + j] / norm[j];
        }
    }
    ZipEncoded { inner: int_n::encode(&kn, d, bits), channel_norm: norm }
}

pub fn decode(enc: &ZipEncoded, d: usize) -> Vec<f32> {
    let mut out = int_n::decode(&enc.inner, d);
    let tokens = out.len() / d;
    for n in 0..tokens {
        for j in 0..d {
            out[n * d + j] *= enc.channel_norm[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::mse;
    use crate::util::rng::Rng;

    #[test]
    fn zip_beats_plain_int_under_outliers() {
        let mut rng = Rng::new(51);
        let d = 32;
        let mut k = rng.normal_vec(64 * d);
        for n in 0..64 {
            k[n * d + 4] += 30.0; // channel outlier
        }
        let zip = decode(&encode(&k, d, 4), d);
        let int = int_n::decode(&int_n::encode(&k, d, 4), d);
        assert!(mse(&k, &zip) < mse(&k, &int));
    }

    #[test]
    fn roundtrip_reasonable_without_outliers() {
        let mut rng = Rng::new(52);
        let d = 16;
        let k = rng.normal_vec(32 * d);
        let k_hat = decode(&encode(&k, d, 6), d);
        assert!(mse(&k, &k_hat) < 1e-2);
    }
}
