//! Unified codec façade: every key-cache quantizer from the paper's
//! evaluation behind one enum, with the paper's bit accounting (§B).
//! The eval harness and the table benches sweep over [`QuantSpec`]s.

use super::{int_n, kivi, lut::QkLut, polar, qjl, zipcache};

/// A key-cache quantization method + hyper-parameters.
#[derive(Clone, Debug)]
pub enum QuantSpec {
    /// full precision (fp16-equivalent baseline; we compute in f32)
    Fp16,
    /// PolarQuant_rt with group size g
    Polar { r_bits: u32, t_bits: u32, group: usize },
    /// KIVI-N channel-wise with group size g
    Kivi { bits: u32, group: usize },
    /// token-wise Int-N
    Int { bits: u32 },
    /// ZipCache-N channel-separable token-wise
    Zip { bits: u32 },
    /// QJL sign sketch with m = bpc * d projections
    Qjl { bits_per_channel: usize },
}

impl QuantSpec {
    /// Paper-style label, e.g. "PolarQuant44".
    pub fn label(&self) -> String {
        match self {
            QuantSpec::Fp16 => "Bf16".into(),
            QuantSpec::Polar { r_bits, t_bits, .. } => {
                format!("PolarQuant{r_bits}{t_bits}")
            }
            QuantSpec::Kivi { bits, .. } => format!("KIVI-{bits}"),
            QuantSpec::Int { bits } => format!("Int-{bits}"),
            QuantSpec::Zip { bits } => format!("ZipCache-{bits}"),
            QuantSpec::Qjl { .. } => "QJL".into(),
        }
    }

    /// Token-group granularity this codec encodes at, if group-wise.
    pub fn group_size(&self) -> Option<usize> {
        match self {
            QuantSpec::Polar { group, .. } | QuantSpec::Kivi { group, .. } => Some(*group),
            _ => None,
        }
    }

    /// The default self-drafting plane for speculative decoding: Polar
    /// codecs expose a code-truncated coarse view ([`polar::DraftSpec`],
    /// half the bits of the exact plane, floor 1) derived from the SAME
    /// stored codes — no second quantization pass, no extra bytes.  Other
    /// codecs store no truncatable code plane and return `None`.
    pub fn default_draft(&self) -> Option<polar::DraftSpec> {
        match self {
            QuantSpec::Polar { r_bits, t_bits, group } => Some(polar::DraftSpec::halved(
                &polar::PolarSpec::new(*r_bits, *t_bits, *group),
            )),
            _ => None,
        }
    }

    /// Average bits per key element including quantization constants
    /// (paper §B; d = head dim).
    pub fn bits_per_element(&self, d: usize) -> f64 {
        match self {
            QuantSpec::Fp16 => 16.0,
            QuantSpec::Polar { r_bits, t_bits, group } => {
                (r_bits + t_bits) as f64 / 2.0 + 32.0 / *group as f64
            }
            QuantSpec::Kivi { bits, group } => *bits as f64 + 32.0 / *group as f64,
            QuantSpec::Int { bits } | QuantSpec::Zip { bits } => {
                *bits as f64 + 32.0 / d as f64
            }
            QuantSpec::Qjl { bits_per_channel } => {
                *bits_per_channel as f64 + 16.0 / d as f64
            }
        }
    }

    /// Encode a (tokens x d) post-RoPE key block.  For group-wise codecs,
    /// `tokens` must be a whole number of groups (the cache manager
    /// guarantees this; eval workloads are sized accordingly).
    pub fn encode(&self, k: &[f32], d: usize) -> EncodedKeys {
        match self {
            QuantSpec::Fp16 => EncodedKeys::Fp(k.to_vec(), d),
            QuantSpec::Polar { r_bits, t_bits, group } => {
                let spec = polar::PolarSpec::new(*r_bits, *t_bits, *group);
                EncodedKeys::Polar(polar::encode(k, d, &spec), spec, d)
            }
            QuantSpec::Kivi { bits, group } => {
                let spec = kivi::KiviSpec::new(*bits, *group);
                EncodedKeys::Kivi(kivi::encode(k, d, &spec), spec, d)
            }
            QuantSpec::Int { bits } => EncodedKeys::Int(int_n::encode(k, d, *bits), d),
            QuantSpec::Zip { bits } => EncodedKeys::Zip(zipcache::encode(k, d, *bits), d),
            QuantSpec::Qjl { bits_per_channel } => {
                let sk = qjl::QjlSketcher::new(d, *bits_per_channel, QJL_SEED);
                let enc = sk.encode(k);
                EncodedKeys::Qjl(Box::new(sk), enc)
            }
        }
    }
}

const QJL_SEED: u64 = 0x514a_4c5f_5345_4544; // "QJL_SEED"

/// An encoded key block, decodable / scorable uniformly.
pub enum EncodedKeys {
    Fp(Vec<f32>, usize),
    Polar(polar::PolarEncoded, polar::PolarSpec, usize),
    Kivi(kivi::KiviEncoded, kivi::KiviSpec, usize),
    Int(int_n::IntEncoded, usize),
    Zip(zipcache::ZipEncoded, usize),
    Qjl(Box<qjl::QjlSketcher>, qjl::QjlEncoded),
}

impl EncodedKeys {
    pub fn tokens(&self) -> usize {
        match self {
            EncodedKeys::Fp(k, d) => k.len() / d,
            EncodedKeys::Polar(e, _, _) => e.tokens(),
            EncodedKeys::Kivi(e, _, _) => e.tokens(),
            EncodedKeys::Int(e, _) => e.tokens(),
            EncodedKeys::Zip(e, _) => e.inner.tokens(),
            EncodedKeys::Qjl(_, e) => e.tokens(),
        }
    }

    /// Dequantized (approximate) keys, (tokens x d) row-major.
    pub fn decode(&self) -> Vec<f32> {
        match self {
            EncodedKeys::Fp(k, _) => k.clone(),
            EncodedKeys::Polar(e, _, d) => polar::decode(e, *d),
            EncodedKeys::Kivi(e, _, d) => kivi::decode(e, *d),
            EncodedKeys::Int(e, d) => int_n::decode(e, *d),
            EncodedKeys::Zip(e, d) => zipcache::decode(e, *d),
            EncodedKeys::Qjl(_, _) => {
                panic!("QJL is score-only: it stores a sketch, not keys")
            }
        }
    }

    /// QK scores of `q` against every cached token, via each method's own
    /// decode path (LUT for Polar, dequant-then-dot for KIVI, ...).
    pub fn scores(&self, q: &[f32], out: &mut Vec<f32>) {
        match self {
            EncodedKeys::Fp(k, d) => {
                out.clear();
                for n in 0..k.len() / d {
                    out.push(crate::tensor::ops::dot(q, &k[n * d..(n + 1) * d]));
                }
            }
            EncodedKeys::Polar(e, spec, d) => {
                let mut lut = QkLut::new(*spec, *d, 1);
                lut.scores(q, e, out);
            }
            EncodedKeys::Kivi(e, spec, d) => {
                let mut qk = kivi::KiviQk::new(*spec, *d);
                qk.scores(q, e, out);
            }
            EncodedKeys::Int(e, d) => {
                let k_hat = int_n::decode(e, *d);
                out.clear();
                for n in 0..e.tokens() {
                    out.push(crate::tensor::ops::dot(q, &k_hat[n * d..(n + 1) * d]));
                }
            }
            EncodedKeys::Zip(e, d) => {
                let k_hat = zipcache::decode(e, *d);
                out.clear();
                for n in 0..e.inner.tokens() {
                    out.push(crate::tensor::ops::dot(q, &k_hat[n * d..(n + 1) * d]));
                }
            }
            EncodedKeys::Qjl(sk, e) => sk.scores(q, e, out),
        }
    }

    /// Physical bytes at rest.
    pub fn nbytes(&self) -> usize {
        match self {
            EncodedKeys::Fp(k, _) => k.len() * 2, // charged as fp16
            EncodedKeys::Polar(e, _, _) => e.groups.iter().map(|g| g.nbytes()).sum(),
            EncodedKeys::Kivi(e, _, _) => e.groups.iter().map(|g| g.nbytes()).sum(),
            EncodedKeys::Int(e, _) => e.nbytes(),
            EncodedKeys::Zip(e, _) => e.nbytes(),
            EncodedKeys::Qjl(_, e) => e.nbytes(),
        }
    }
}

/// Legacy alias used around the eval harness.
pub type KeyCodec = QuantSpec;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn labels_match_paper() {
        assert_eq!(
            QuantSpec::Polar { r_bits: 4, t_bits: 4, group: 128 }.label(),
            "PolarQuant44"
        );
        assert_eq!(QuantSpec::Kivi { bits: 2, group: 32 }.label(), "KIVI-2");
    }

    #[test]
    fn bit_budgets_match_table1() {
        let d = 128;
        // Table 1 "Bits" column
        assert!((QuantSpec::Int { bits: 4 }.bits_per_element(d) - 4.25).abs() < 1e-9);
        assert!(
            (QuantSpec::Polar { r_bits: 4, t_bits: 4, group: 128 }.bits_per_element(d)
                - 4.25)
                .abs()
                < 1e-9
        );
        assert!((QuantSpec::Kivi { bits: 4, group: 128 }.bits_per_element(d) - 4.25).abs() < 1e-9);
        assert!((QuantSpec::Kivi { bits: 2, group: 32 }.bits_per_element(d) - 3.0).abs() < 1e-9);
        assert!(
            (QuantSpec::Qjl { bits_per_channel: 3 }.bits_per_element(d) - 3.125).abs() < 1e-9
        );
    }

    #[test]
    fn default_draft_is_polar_only() {
        let p = QuantSpec::Polar { r_bits: 4, t_bits: 4, group: 64 };
        assert_eq!(p.default_draft(), Some(polar::DraftSpec::new(2, 2)));
        let p = QuantSpec::Polar { r_bits: 1, t_bits: 3, group: 64 };
        assert_eq!(p.default_draft(), Some(polar::DraftSpec::new(1, 1)));
        assert_eq!(QuantSpec::Kivi { bits: 4, group: 64 }.default_draft(), None);
        assert_eq!(QuantSpec::Fp16.default_draft(), None);
    }

    #[test]
    fn all_codecs_score_consistently_with_decode() {
        let mut rng = Rng::new(99);
        let d = 32;
        let k = rng.normal_vec(64 * d);
        let q = rng.normal_vec(d);
        for spec in [
            QuantSpec::Fp16,
            QuantSpec::Polar { r_bits: 4, t_bits: 4, group: 16 },
            QuantSpec::Kivi { bits: 4, group: 16 },
            QuantSpec::Int { bits: 4 },
            QuantSpec::Zip { bits: 4 },
        ] {
            let enc = spec.encode(&k, d);
            let mut scores = Vec::new();
            enc.scores(&q, &mut scores);
            let k_hat = enc.decode();
            for n in 0..enc.tokens() {
                let want = crate::tensor::ops::dot(&q, &k_hat[n * d..(n + 1) * d]);
                assert!(
                    (scores[n] - want).abs() < 5e-4 * (1.0 + want.abs()),
                    "{}: {} vs {}",
                    spec.label(),
                    scores[n],
                    want
                );
            }
        }
    }

    #[test]
    fn memory_ordering_matches_bit_budget() {
        let mut rng = Rng::new(100);
        let d = 128;
        let k = rng.normal_vec(256 * d);
        let fp = QuantSpec::Fp16.encode(&k, d).nbytes();
        let p44 = QuantSpec::Polar { r_bits: 4, t_bits: 4, group: 128 }.encode(&k, d).nbytes();
        let p33 = QuantSpec::Polar { r_bits: 3, t_bits: 3, group: 128 }.encode(&k, d).nbytes();
        assert!(p44 < fp / 3, "p44 {p44} fp {fp}");
        assert!(p33 < p44);
    }
}
