//! Bit-packing for 1..=8-bit codes.
//!
//! Codes are stored little-endian within a contiguous bitstream; this is
//! the at-rest representation in the KV-cache pages (the memory-accounting
//! numbers in Table 4 are physical, not analytic).  The hot QK path
//! unpacks one token-group at a time into a scratch `u8` buffer — the
//! unpack cost is part of what the Fig-3 benches measure.

/// Packed code buffer: `n` codes of `bits` bits each.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedCodes {
    pub bits: u32,
    pub n: usize,
    data: Vec<u8>,
}

impl PackedCodes {
    pub fn from_codes(codes: &[u8], bits: u32) -> Self {
        assert!((1..=8).contains(&bits));
        let total_bits = codes.len() * bits as usize;
        let mut data = vec![0u8; total_bits.div_ceil(8)];
        let mask = ((1u16 << bits) - 1) as u8;
        for (i, &c) in codes.iter().enumerate() {
            debug_assert_eq!(c & !mask, 0, "code {c} exceeds {bits} bits");
            let bit = i * bits as usize;
            let byte = bit / 8;
            let off = bit % 8;
            let v = (c & mask) as u16;
            data[byte] |= (v << off) as u8;
            if off + bits as usize > 8 {
                data[byte + 1] |= (v >> (8 - off)) as u8;
            }
        }
        PackedCodes { bits, n: codes.len(), data }
    }

    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.n);
        let bits = self.bits as usize;
        let bit = i * bits;
        let byte = bit / 8;
        let off = bit % 8;
        let lo = self.data[byte] as u16;
        let hi = if byte + 1 < self.data.len() {
            self.data[byte + 1] as u16
        } else {
            0
        };
        let v = (lo | (hi << 8)) >> off;
        (v as u8) & (((1u16 << bits) - 1) as u8)
    }

    /// Unpack all codes into `out` (len >= n).
    pub fn unpack_into(&self, out: &mut [u8]) {
        assert!(out.len() >= self.n);
        for i in 0..self.n {
            out[i] = self.get(i);
        }
    }

    pub fn unpack(&self) -> Vec<u8> {
        let mut v = vec![0u8; self.n];
        self.unpack_into(&mut v);
        v
    }

    /// Physical storage in bytes.
    pub fn nbytes(&self) -> usize {
        self.data.len()
    }

    /// The raw little-endian bitstream — the at-rest form the tiered page
    /// store serializes verbatim (`kvcache::tier::serde`).
    pub fn as_bytes(&self) -> &[u8] {
        &self.data
    }

    /// Rebuild a packed buffer from its serialized parts.  The byte
    /// length must be exactly what `n` codes of `bits` bits occupy —
    /// anything else means a corrupt or truncated record, and the caller
    /// (the tier codec) must treat it as such, never panic.
    pub fn from_raw(bits: u32, n: usize, data: Vec<u8>) -> Result<Self, String> {
        if !(1..=8).contains(&bits) {
            return Err(format!("packed codes: bits {bits} out of range 1..=8"));
        }
        let want = (n * bits as usize).div_ceil(8);
        if data.len() != want {
            return Err(format!(
                "packed codes: {} bytes for {n} codes of {bits} bits (want {want})",
                data.len()
            ));
        }
        Ok(PackedCodes { bits, n, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Rng::new(9);
        for bits in 1..=8u32 {
            let n = 257; // deliberately not byte-aligned
            let codes: Vec<u8> = (0..n)
                .map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8)
                .collect();
            let p = PackedCodes::from_codes(&codes, bits);
            assert_eq!(p.unpack(), codes, "bits={bits}");
            // random access agrees
            for _ in 0..50 {
                let i = rng.below(n);
                assert_eq!(p.get(i), codes[i]);
            }
        }
    }

    #[test]
    fn packing_is_tight() {
        let codes = vec![7u8; 100];
        let p = PackedCodes::from_codes(&codes, 3);
        assert_eq!(p.nbytes(), (100 * 3 + 7) / 8);
    }

    #[test]
    fn raw_bytes_roundtrip_and_length_validation() {
        let codes: Vec<u8> = (0..37).map(|i| (i % 8) as u8).collect();
        let p = PackedCodes::from_codes(&codes, 3);
        let rebuilt = PackedCodes::from_raw(3, p.n, p.as_bytes().to_vec()).unwrap();
        assert_eq!(rebuilt, p);
        assert_eq!(rebuilt.unpack(), codes);
        // wrong length / wrong bit width are rejected, not mis-decoded
        assert!(PackedCodes::from_raw(3, p.n + 1, p.as_bytes().to_vec()).is_err());
        assert!(PackedCodes::from_raw(0, p.n, p.as_bytes().to_vec()).is_err());
        assert!(PackedCodes::from_raw(9, p.n, p.as_bytes().to_vec()).is_err());
    }

    #[test]
    fn cross_byte_boundary() {
        // 5-bit codes straddle byte boundaries constantly
        let codes: Vec<u8> = (0..64).map(|i| (i % 32) as u8).collect();
        let p = PackedCodes::from_codes(&codes, 5);
        assert_eq!(p.unpack(), codes);
    }
}
