//! Code packing for 1..=8-bit codes — layout v2 ("lane" layout).
//!
//! **v2 (current, the only writer).**  Codes live in fixed-width,
//! byte-aligned lanes: one nibble per code for widths 1..=4, one whole
//! byte for widths 5..=8.  A code never straddles a byte boundary, so
//! random access is a constant shift+mask and a bulk unpack is a memcpy
//! (byte lanes) or a tight nibble loop — which is what lets the SIMD
//! score kernel in [`crate::quant::lut`] turn a staged lane directly
//! into gather indices.  The paper's headline r4/t4 config pays zero
//! padding (4-bit planes fill nibbles exactly; the fused 8-bit plane
//! fills bytes exactly); odd widths trade a little padding for the
//! aligned access.
//!
//! **v1 (legacy, decode-only).**  The tight little-endian bitstream this
//! module packed before the layout bump.  Tier segments written by older
//! builds embed it verbatim (`kvcache::tier::serde` PAGE_VERSION 1), so
//! the v1 decoder is kept: `get`/`unpack` decode it bit-exactly, and the
//! tier codec converts promoted v1 records to v2 lanes on read.

/// Physical layout of a packed buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodeLayout {
    /// tight little-endian bitstream — legacy tier records (decode-only)
    V1Bitstream,
    /// byte-aligned lanes: nibble per code (bits <= 4), byte per code
    /// (bits 5..=8)
    V2Lanes,
}

/// Packed code buffer: `n` codes of `bits` bits each.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedCodes {
    pub bits: u32,
    pub n: usize,
    layout: CodeLayout,
    data: Vec<u8>,
}

/// Bytes `n` codes of `bits` bits occupy in the v2 lane layout.
pub fn lane_nbytes(bits: u32, n: usize) -> usize {
    if bits <= 4 {
        n.div_ceil(2)
    } else {
        n
    }
}

impl PackedCodes {
    /// Pack into the v2 lane layout (the only writer).
    pub fn from_codes(codes: &[u8], bits: u32) -> Self {
        assert!((1..=8).contains(&bits));
        let mask = ((1u16 << bits) - 1) as u8;
        let data = if bits <= 4 {
            let mut data = vec![0u8; codes.len().div_ceil(2)];
            for (i, &c) in codes.iter().enumerate() {
                debug_assert_eq!(c & !mask, 0, "code {c} exceeds {bits} bits");
                data[i >> 1] |= (c & mask) << ((i & 1) * 4);
            }
            data
        } else {
            for &c in codes {
                debug_assert_eq!(c & !mask, 0, "code {c} exceeds {bits} bits");
            }
            codes.to_vec()
        };
        PackedCodes { bits, n: codes.len(), layout: CodeLayout::V2Lanes, data }
    }

    /// Pack into the legacy v1 bitstream (test fixtures for pre-bump tier
    /// records; production code never writes v1).
    pub fn from_codes_v1(codes: &[u8], bits: u32) -> Self {
        assert!((1..=8).contains(&bits));
        let total_bits = codes.len() * bits as usize;
        let mut data = vec![0u8; total_bits.div_ceil(8)];
        let mask = ((1u16 << bits) - 1) as u8;
        for (i, &c) in codes.iter().enumerate() {
            debug_assert_eq!(c & !mask, 0, "code {c} exceeds {bits} bits");
            let bit = i * bits as usize;
            let byte = bit / 8;
            let off = bit % 8;
            let v = (c & mask) as u16;
            data[byte] |= (v << off) as u8;
            if off + bits as usize > 8 {
                data[byte + 1] |= (v >> (8 - off)) as u8;
            }
        }
        PackedCodes { bits, n: codes.len(), layout: CodeLayout::V1Bitstream, data }
    }

    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.n);
        match self.layout {
            CodeLayout::V2Lanes => {
                if self.bits <= 4 {
                    let mask = ((1u16 << self.bits) - 1) as u8;
                    (self.data[i >> 1] >> ((i & 1) * 4)) & mask
                } else {
                    self.data[i]
                }
            }
            CodeLayout::V1Bitstream => {
                let bits = self.bits as usize;
                let bit = i * bits;
                let byte = bit / 8;
                let off = bit % 8;
                let lo = self.data[byte] as u16;
                let hi = if byte + 1 < self.data.len() {
                    self.data[byte + 1] as u16
                } else {
                    0
                };
                let v = (lo | (hi << 8)) >> off;
                (v as u8) & (((1u16 << bits) - 1) as u8)
            }
        }
    }

    /// Unpack all codes into `out` (len >= n).
    pub fn unpack_into(&self, out: &mut [u8]) {
        assert!(out.len() >= self.n);
        if self.layout == CodeLayout::V2Lanes && self.bits > 4 {
            out[..self.n].copy_from_slice(&self.data);
            return;
        }
        for i in 0..self.n {
            out[i] = self.get(i);
        }
    }

    pub fn unpack(&self) -> Vec<u8> {
        let mut v = vec![0u8; self.n];
        self.unpack_into(&mut v);
        v
    }

    /// Physical storage in bytes.
    pub fn nbytes(&self) -> usize {
        self.data.len()
    }

    pub fn layout(&self) -> CodeLayout {
        self.layout
    }

    /// The raw lane bytes — the at-rest form the tiered page store
    /// serializes verbatim (`kvcache::tier::serde`, PAGE_VERSION 2).
    pub fn as_bytes(&self) -> &[u8] {
        &self.data
    }

    /// Rebuild a v2 packed buffer from its serialized parts.  The byte
    /// length must be exactly what `n` codes of `bits` bits occupy in
    /// the lane layout — anything else means a corrupt or truncated
    /// record, and the caller (the tier codec) must treat it as such,
    /// never panic.
    pub fn from_raw(bits: u32, n: usize, data: Vec<u8>) -> Result<Self, String> {
        if !(1..=8).contains(&bits) {
            return Err(format!("packed codes: bits {bits} out of range 1..=8"));
        }
        let want = lane_nbytes(bits, n);
        if data.len() != want {
            return Err(format!(
                "packed codes: {} bytes for {n} codes of {bits} bits (want {want})",
                data.len()
            ));
        }
        // nibble lanes: an odd count leaves the final high nibble unused;
        // reject set bits there so records stay canonical (re-encode of a
        // decoded page is byte-identical)
        if bits <= 4 {
            if n % 2 == 1 {
                if let Some(&last) = data.last() {
                    if last >> 4 != 0 {
                        return Err("packed codes: set bits in unused trailing nibble".into());
                    }
                }
            }
            if bits < 4 {
                let lane = ((1u16 << bits) - 1) as u8;
                let mask = !(lane | (lane << 4));
                if data.iter().any(|&b| b & mask != 0) {
                    return Err(format!("packed codes: set bits beyond width {bits}"));
                }
            }
        }
        Ok(PackedCodes { bits, n, layout: CodeLayout::V2Lanes, data })
    }

    /// Rebuild a LEGACY v1 bitstream from its serialized parts (tier
    /// records with PAGE_VERSION 1).  Length must match the tight
    /// bitstream size.
    pub fn from_raw_v1(bits: u32, n: usize, data: Vec<u8>) -> Result<Self, String> {
        if !(1..=8).contains(&bits) {
            return Err(format!("packed codes: bits {bits} out of range 1..=8"));
        }
        let want = (n * bits as usize).div_ceil(8);
        if data.len() != want {
            return Err(format!(
                "packed codes (v1): {} bytes for {n} codes of {bits} bits (want {want})",
                data.len()
            ));
        }
        Ok(PackedCodes { bits, n, layout: CodeLayout::V1Bitstream, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_codes(rng: &mut Rng, n: usize, bits: u32) -> Vec<u8> {
        (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect()
    }

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Rng::new(9);
        for bits in 1..=8u32 {
            let n = 257; // deliberately odd: exercises the trailing nibble
            let codes = random_codes(&mut rng, n, bits);
            let p = PackedCodes::from_codes(&codes, bits);
            assert_eq!(p.unpack(), codes, "bits={bits}");
            // random access agrees
            for _ in 0..50 {
                let i = rng.below(n);
                assert_eq!(p.get(i), codes[i]);
            }
        }
    }

    #[test]
    fn v1_bitstream_still_decodes() {
        // the legacy layout (tier records written pre-bump) must keep
        // decoding bit-exactly, including cross-byte straddles
        let mut rng = Rng::new(10);
        for bits in 1..=8u32 {
            let n = 129;
            let codes = random_codes(&mut rng, n, bits);
            let v1 = PackedCodes::from_codes_v1(&codes, bits);
            assert_eq!(v1.layout(), CodeLayout::V1Bitstream);
            assert_eq!(v1.nbytes(), (n * bits as usize).div_ceil(8), "v1 is tight");
            assert_eq!(v1.unpack(), codes, "bits={bits}");
            for _ in 0..50 {
                let i = rng.below(n);
                assert_eq!(v1.get(i), codes[i], "bits={bits} i={i}");
            }
            // both layouts agree code-for-code
            let v2 = PackedCodes::from_codes(&codes, bits);
            assert_eq!(v1.unpack(), v2.unpack());
        }
    }

    #[test]
    fn lanes_are_byte_aligned() {
        // sub-nibble widths round up to a nibble, 5..8 to a byte: the
        // price of never straddling a byte boundary
        let codes = vec![7u8; 100];
        assert_eq!(PackedCodes::from_codes(&codes, 3).nbytes(), 50);
        assert_eq!(PackedCodes::from_codes(&codes, 4).nbytes(), 50);
        let codes = vec![17u8; 100];
        assert_eq!(PackedCodes::from_codes(&codes, 5).nbytes(), 100);
        assert_eq!(PackedCodes::from_codes(&codes, 8).nbytes(), 100);
        // odd count: the final high nibble is padding
        assert_eq!(PackedCodes::from_codes(&[1, 2, 3], 4).nbytes(), 2);
    }

    #[test]
    fn raw_bytes_roundtrip_and_length_validation() {
        let codes: Vec<u8> = (0..37).map(|i| (i % 8) as u8).collect();
        let p = PackedCodes::from_codes(&codes, 3);
        let rebuilt = PackedCodes::from_raw(3, p.n, p.as_bytes().to_vec()).unwrap();
        assert_eq!(rebuilt, p);
        assert_eq!(rebuilt.unpack(), codes);
        // wrong length / wrong bit width are rejected, not mis-decoded
        assert!(PackedCodes::from_raw(3, p.n + 2, p.as_bytes().to_vec()).is_err());
        assert!(PackedCodes::from_raw(0, p.n, p.as_bytes().to_vec()).is_err());
        assert!(PackedCodes::from_raw(9, p.n, p.as_bytes().to_vec()).is_err());
        // non-canonical padding bits are rejected too
        let mut noisy = p.as_bytes().to_vec();
        *noisy.last_mut().unwrap() |= 0xf0; // 37 codes -> high nibble unused
        assert!(PackedCodes::from_raw(3, p.n, noisy).is_err());
        // and the v1 reader validates against the TIGHT length
        let v1 = PackedCodes::from_codes_v1(&codes, 3);
        assert_eq!(
            PackedCodes::from_raw_v1(3, v1.n, v1.as_bytes().to_vec()).unwrap().unpack(),
            codes
        );
        assert!(PackedCodes::from_raw_v1(3, v1.n + 1, v1.as_bytes().to_vec()).is_err());
    }

    #[test]
    fn byte_lane_bulk_unpack_is_identity() {
        let codes: Vec<u8> = (0..64).map(|i| (i % 32) as u8).collect();
        let p = PackedCodes::from_codes(&codes, 5);
        assert_eq!(p.as_bytes(), &codes[..], "5..8-bit lanes store codes verbatim");
        assert_eq!(p.unpack(), codes);
    }
}
