//! The paper's decoding acceleration (§3.3 / Appendix A): query–key inner
//! products against a PolarQuant cache via a per-channel lookup table.
//!
//! For a decode-step query `q` and one token group with theta params
//! `(tz, ts)`, the dequantized partial product at channel pair `j` takes
//! one of `2^t` values:
//!
//! ```text
//! LUT[j][c] = q[2j]·cos(th(c;j)) + q[2j+1]·sin(th(c;j)),
//! th(c;j)   = (c + 1/2)·ts[j] + tz[j] − π
//! score(n)  = Σ_j rho~(n,j) · LUT[j][theta_code(n,j)]
//! ```
//!
//! The trig to build the table is O(d/2 · 2^t) per group — *independent of
//! group size* — after which each cached token costs one gather + two
//! mul-adds per pair, versus KIVI's dequant-then-dot at two mul-adds per
//! *element* plus the dequant.  GQA amplifies the win: the `cos/sin` basis
//! is shared across all query heads attached to a kv head
//! ([`QkLut::scores_multi`]), which is how the paper's Triton kernel
//! amortizes LUT construction across the head group.

use super::polar::{PolarEncoded, PolarGroup, PolarSpec};

/// Scratch + result buffers for repeated LUT QK calls (allocation-free at
/// steady state — see EXPERIMENTS.md §Perf).
pub struct QkLut {
    spec: PolarSpec,
    d2: usize,
    /// cos/sin basis for the current group: [2 * d2 * levels]
    basis: Vec<f32>,
    /// per-head tables: [heads * d2 * levels]
    lut: Vec<f32>,
    /// unpacked codes for the current group
    rho_scratch: Vec<u8>,
    theta_scratch: Vec<u8>,
    /// dequantized rho values
    rho_deq: Vec<f32>,
}

impl QkLut {
    pub fn new(spec: PolarSpec, d: usize, max_heads: usize) -> Self {
        let d2 = d / 2;
        let levels = 1usize << spec.t_bits;
        QkLut {
            spec,
            d2,
            basis: vec![0.0; 2 * d2 * levels],
            lut: vec![0.0; max_heads * d2 * levels],
            rho_scratch: vec![0; spec.group * d2],
            theta_scratch: vec![0; spec.group * d2],
            rho_deq: vec![0.0; spec.group * d2],
        }
    }

    pub fn spec(&self) -> &PolarSpec {
        &self.spec
    }

    /// Build the shared cos/sin basis for one group (trig happens ONCE per
    /// group regardless of how many query heads score against it).
    fn build_basis(&mut self, g: &PolarGroup) {
        let levels = 1usize << self.spec.t_bits;
        for j in 0..self.d2 {
            let (tz, ts) = (g.theta_z[j], g.theta_s[j]);
            for c in 0..levels {
                let th = (c as f32 + 0.5) * ts + tz - std::f32::consts::PI;
                let (sin, cos) = th.sin_cos();
                self.basis[(j * levels + c) * 2] = cos;
                self.basis[(j * levels + c) * 2 + 1] = sin;
            }
        }
    }

    /// Combine the basis with `heads` queries into per-head LUTs.
    fn build_luts(&mut self, qs: &[&[f32]]) {
        let levels = 1usize << self.spec.t_bits;
        for (h, q) in qs.iter().enumerate() {
            debug_assert_eq!(q.len(), self.d2 * 2);
            let lut = &mut self.lut[h * self.d2 * levels..(h + 1) * self.d2 * levels];
            for j in 0..self.d2 {
                let qx = q[2 * j];
                let qy = q[2 * j + 1];
                for c in 0..levels {
                    let cos = self.basis[(j * levels + c) * 2];
                    let sin = self.basis[(j * levels + c) * 2 + 1];
                    lut[j * levels + c] = qx * cos + qy * sin;
                }
            }
        }
    }

    /// Unpack codes + dequantize rho for one group.
    fn stage_group(&mut self, g: &PolarGroup) {
        g.rho_codes.unpack_into(&mut self.rho_scratch);
        g.theta_codes.unpack_into(&mut self.theta_scratch);
        for n in 0..g.tokens {
            for j in 0..self.d2 {
                let idx = n * self.d2 + j;
                self.rho_deq[idx] =
                    (self.rho_scratch[idx] as f32 + 0.5) * g.rho_s[j] + g.rho_z[j];
            }
        }
    }

    /// Scores for MULTIPLE query heads sharing one kv stream (GQA).
    ///
    /// `out[h]` receives `enc.tokens()` scores for query `qs[h]`.
    pub fn scores_multi(&mut self, qs: &[&[f32]], enc: &PolarEncoded, out: &mut [Vec<f32>]) {
        self.scores_groups(qs, &enc.groups, out);
    }

    /// Core kernel over borrowed groups — generic over any in-order group
    /// source, so the paged kvcache's per-stream view
    /// ([`crate::kvcache::StreamView::key_groups`], one group per shared
    /// page) feeds it directly, with no contiguous `Vec<PolarGroup>` (and
    /// no `PolarEncoded` clone) materialized on the decode hot path.
    /// Plain slices still work (`&[PolarGroup]` iterates by reference).
    ///
    /// Fast path (r+t <= 8): the group's combined (rho<<t | theta) codes
    /// are unpacked ONCE into a byte scratch; rho is dequantized into a
    /// staging row shared by all heads; the per-head loop is a pure
    /// gather+fma over that row.  See EXPERIMENTS.md §Perf for the
    /// before/after.
    pub fn scores_groups<'g, I>(&mut self, qs: &[&[f32]], groups: I, out: &mut [Vec<f32>])
    where
        I: IntoIterator<Item = &'g PolarGroup>,
    {
        assert_eq!(qs.len(), out.len());
        assert!(qs.len() * self.d2 * (1 << self.spec.t_bits) <= self.lut.len());
        for o in out.iter_mut() {
            o.clear();
        }
        let levels = 1usize << self.spec.t_bits;
        let t_mask = (levels - 1) as u8;
        let t_bits = self.spec.t_bits;
        for g in groups {
            self.build_basis(g);
            self.build_luts(qs);
            if let Some(combined) = &g.combined {
                // fused path: one unpack, split codes inline, stage rho
                combined.unpack_into(&mut self.theta_scratch);
                for n in 0..g.tokens {
                    let row = n * self.d2;
                    for j in 0..self.d2 {
                        let b = self.theta_scratch[row + j];
                        let rc = (b >> t_bits) as f32;
                        self.rho_deq[row + j] = (rc + 0.5) * g.rho_s[j] + g.rho_z[j];
                    }
                }
                for (h, o) in out.iter_mut().enumerate() {
                    let lut = &self.lut[h * self.d2 * levels..(h + 1) * self.d2 * levels];
                    for n in 0..g.tokens {
                        let row = n * self.d2;
                        let codes = &self.theta_scratch[row..row + self.d2];
                        let rho = &self.rho_deq[row..row + self.d2];
                        // iterator-fused gather+fma: chunks_exact lets the
                        // compiler hoist bounds checks out of the loop
                        let mut acc = 0.0f32;
                        for ((lut_j, &code), &rho_j) in
                            lut.chunks_exact(levels).zip(codes).zip(rho)
                        {
                            acc += rho_j * lut_j[(code & t_mask) as usize];
                        }
                        o.push(acc);
                    }
                }
            } else {
                // general path (r+t > 8): separate unpacks
                self.stage_group(g);
                for (h, o) in out.iter_mut().enumerate() {
                    let lut = &self.lut[h * self.d2 * levels..(h + 1) * self.d2 * levels];
                    for n in 0..g.tokens {
                        let row = n * self.d2;
                        let mut acc = 0.0f32;
                        for j in 0..self.d2 {
                            let code = self.theta_scratch[row + j] as usize;
                            acc += self.rho_deq[row + j] * lut[j * levels + code];
                        }
                        o.push(acc);
                    }
                }
            }
        }
    }

    /// Single-head convenience wrapper.
    pub fn scores(&mut self, q: &[f32], enc: &PolarEncoded, out: &mut Vec<f32>) {
        let mut tmp = [std::mem::take(out)];
        self.scores_multi(&[q], enc, &mut tmp);
        *out = std::mem::take(&mut tmp[0]);
    }

    /// Blocked MULTI-SEQUENCE entry point: one decode step's worth of QK
    /// scoring for a whole batch of sequences sharing this scratch.
    ///
    /// `out[s][h]` receives the scores of sequence `s`, query head `h`.
    /// Each sequence's cos/sin basis is built once per group and shared by
    /// all of its GQA query heads; across sequences the LUT/basis/unpack
    /// scratch is reused, so a caller can score a whole shard of
    /// sequences with zero allocation at steady state.  The
    /// `decode_batch` bench and the batch-equivalence proptests drive
    /// this wrapper; [`crate::coordinator::pool::DecodePool`] workers
    /// reach the same inner [`QkLut::scores_groups`] kernel through
    /// `Model::decode_step`, one sequence at a time.
    pub fn scores_batch(&mut self, jobs: &[SeqScoreJob<'_>], out: &mut [Vec<Vec<f32>>]) {
        assert_eq!(jobs.len(), out.len());
        for (job, o) in jobs.iter().zip(out.iter_mut()) {
            self.scores_groups(job.qs, job.groups, o);
        }
    }
}

/// One sequence's slice of a batched decode step: its GQA query heads and
/// a borrowed view of its cached key groups.
pub struct SeqScoreJob<'a> {
    /// query rows, one per query head attached to this kv stream
    pub qs: &'a [&'a [f32]],
    /// the sequence's finalized (quantized) key groups
    pub groups: &'a [PolarGroup],
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::polar;
    use crate::tensor::ops::dot;
    use crate::util::rng::Rng;

    #[test]
    fn lut_matches_dequant_matmul() {
        let mut rng = Rng::new(21);
        let d = 32;
        for (r, t, g) in [(4, 4, 16), (3, 3, 8), (5, 2, 16), (2, 5, 8)] {
            let spec = PolarSpec::new(r, t, g);
            let k = rng.normal_vec(4 * g * d);
            let enc = polar::encode(&k, d, &spec);
            let k_hat = polar::decode(&enc, d);
            let q = rng.normal_vec(d);
            let mut lut = QkLut::new(spec, d, 1);
            let mut scores = Vec::new();
            lut.scores(&q, &enc, &mut scores);
            assert_eq!(scores.len(), 4 * g);
            for n in 0..scores.len() {
                let want = dot(&q, &k_hat[n * d..(n + 1) * d]);
                assert!(
                    (scores[n] - want).abs() < 2e-4 * (1.0 + want.abs()),
                    "n={n}: {} vs {}",
                    scores[n],
                    want
                );
            }
        }
    }

    #[test]
    fn batch_matches_per_sequence() {
        let mut rng = Rng::new(23);
        let d = 32;
        let spec = PolarSpec::new(4, 4, 16);
        let hq = 2;
        // three sequences of different lengths
        let encs: Vec<_> = [2usize, 3, 1]
            .iter()
            .map(|&gs| polar::encode(&rng.normal_vec(gs * 16 * d), d, &spec))
            .collect();
        let qs: Vec<Vec<Vec<f32>>> = (0..encs.len())
            .map(|_| (0..hq).map(|_| rng.normal_vec(d)).collect())
            .collect();
        let qrefs: Vec<Vec<&[f32]>> = qs
            .iter()
            .map(|sq| sq.iter().map(|q| q.as_slice()).collect())
            .collect();
        let jobs: Vec<SeqScoreJob> = encs
            .iter()
            .zip(&qrefs)
            .map(|(e, q)| SeqScoreJob { qs: q, groups: &e.groups })
            .collect();

        let mut lut = QkLut::new(spec, d, hq);
        let mut batched: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); hq]; encs.len()];
        lut.scores_batch(&jobs, &mut batched);

        for (s, enc) in encs.iter().enumerate() {
            let mut single = vec![Vec::new(); hq];
            lut.scores_multi(&qrefs[s], enc, &mut single);
            assert_eq!(batched[s], single, "sequence {s}");
            assert_eq!(batched[s][0].len(), enc.tokens());
        }
    }

    #[test]
    fn multi_head_matches_single() {
        let mut rng = Rng::new(22);
        let d = 64;
        let spec = PolarSpec::new(4, 4, 32);
        let k = rng.normal_vec(2 * 32 * d);
        let enc = polar::encode(&k, d, &spec);
        let q0 = rng.normal_vec(d);
        let q1 = rng.normal_vec(d);
        let q2 = rng.normal_vec(d);

        let mut lut = QkLut::new(spec, d, 4);
        let mut multi = vec![Vec::new(), Vec::new(), Vec::new()];
        lut.scores_multi(&[&q0, &q1, &q2], &enc, &mut multi);
        for (h, q) in [&q0, &q1, &q2].iter().enumerate() {
            let mut single = Vec::new();
            lut.scores(q, &enc, &mut single);
            assert_eq!(multi[h], single, "head {h}");
        }
    }
}
