//! The paper's decoding acceleration (§3.3 / Appendix A): query–key inner
//! products against a PolarQuant cache via a per-channel lookup table.
//!
//! For a decode-step query `q` and one token group with theta params
//! `(tz, ts)`, the dequantized partial product at channel pair `j` takes
//! one of `2^t` values:
//!
//! ```text
//! LUT[j][c] = q[2j]·cos(th(c;j)) + q[2j+1]·sin(th(c;j)),
//! th(c;j)   = (c + 1/2)·ts[j] + tz[j] − π
//! score(n)  = Σ_j rho~(n,j) · LUT[j][theta_code(n,j)]
//! ```
//!
//! The trig to build the table is O(d/2 · 2^t) per group — *independent of
//! group size* — after which each cached token costs one gather + two
//! mul-adds per pair, versus KIVI's dequant-then-dot at two mul-adds per
//! *element* plus the dequant.  GQA amplifies the win: the `cos/sin` basis
//! is shared across all query heads attached to a kv head
//! ([`QkLut::scores_multi`]), which is how the paper's Triton kernel
//! amortizes LUT construction across the head group.
//!
//! # Kernels
//!
//! The gather+accumulate inner loop is behind the [`ScoreKernel`] trait:
//! [`ScalarKernel`] is the portable baseline, [`SimdKernel`] is an AVX2
//! gather kernel compiled under `--features simd` (x86_64 only, runtime
//! `avx2` detection, scalar fallback otherwise — offline CI builds
//! without the feature).  Both operate on the staged channel-major lanes
//! from pack layout v2: codes as `[d2 × tokens]` u8 planes, rho
//! dequantized into matching f32 lanes.  The SIMD kernel vectorizes
//! ACROSS TOKENS — eight accumulators, each summing its token's partial
//! products in the same ascending-`j` order as the scalar kernel, with
//! mul-then-add (never FMA-contracted) — so the two kernels are
//! **bit-identical**, fused and general paths alike.  Every public entry
//! point (`scores`, `scores_multi`, `scores_groups`, `scores_batch`) is
//! a thin shim over the same staged walk + kernel dispatch.

use super::polar::{DraftSpec, PolarEncoded, PolarGroup, PolarSpec};

/// Which score kernel to use (`--kernel`, [`select_kernel`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// the SIMD kernel when compiled in (`--features simd`) and the CPU
    /// supports AVX2, else scalar
    #[default]
    Auto,
    Scalar,
    Simd,
}

impl KernelKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(KernelKind::Auto),
            "scalar" => Ok(KernelKind::Scalar),
            "simd" => Ok(KernelKind::Simd),
            _ => Err(format!("unknown kernel '{s}' (expected auto|scalar|simd)")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KernelKind::Auto => "auto",
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
        }
    }
}

/// The gather+accumulate inner loop of the LUT score path.
///
/// Implementations accumulate one staged group into `out` (len ==
/// `tokens`, already holding the running sums — zeros for a fresh group):
///
/// ```text
/// out[n] += Σ_j rho[j·tokens + n] · lut[j·levels + (codes[j·tokens + n] & t_mask)]
/// ```
///
/// `codes` and `rho` are channel-major planes (`[d2 × tokens]`); `lut` is
/// one head's table (`[d2 × levels]`).  The mask strips the rho bits off
/// fused `(rho << t_bits) | theta` codes and is a no-op on plain theta
/// codes, so one signature serves both staging paths.
///
/// CONTRACT: every implementation must perform, per token, the exact
/// same f32 operation sequence (ascending `j`, mul then add) — kernels
/// are interchangeable bit-for-bit, which is what lets `--kernel` be a
/// pure performance knob with no effect on greedy decode output.
pub trait ScoreKernel: Send + Sync {
    fn name(&self) -> &'static str;

    #[allow(clippy::too_many_arguments)]
    fn accumulate(
        &self,
        lut: &[f32],
        levels: usize,
        t_mask: u8,
        d2: usize,
        tokens: usize,
        codes: &[u8],
        rho: &[f32],
        out: &mut [f32],
    );
}

/// Portable baseline: lane-at-a-time over channel planes.  The `j`-outer
/// loop order keeps the code/rho access contiguous; each token's partial
/// sums still land in ascending-`j` order (the bit-exactness contract).
pub struct ScalarKernel;

impl ScoreKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn accumulate(
        &self,
        lut: &[f32],
        levels: usize,
        t_mask: u8,
        d2: usize,
        tokens: usize,
        codes: &[u8],
        rho: &[f32],
        out: &mut [f32],
    ) {
        debug_assert!(out.len() == tokens && codes.len() >= d2 * tokens);
        for j in 0..d2 {
            let lut_j = &lut[j * levels..(j + 1) * levels];
            let lane_c = &codes[j * tokens..(j + 1) * tokens];
            let lane_r = &rho[j * tokens..(j + 1) * tokens];
            for n in 0..tokens {
                out[n] += lane_r[n] * lut_j[(lane_c[n] & t_mask) as usize];
            }
        }
    }
}

/// AVX2 gather kernel: eight tokens per iteration, `vpgatherdps` against
/// the per-channel LUT rows.  Requires `--features simd`; without it (or
/// off x86_64, or on a CPU without AVX2) it falls back to the scalar
/// kernel, so a `SimdKernel` handle is always safe to call.
pub struct SimdKernel;

impl ScoreKernel for SimdKernel {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn accumulate(
        &self,
        lut: &[f32],
        levels: usize,
        t_mask: u8,
        d2: usize,
        tokens: usize,
        codes: &[u8],
        rho: &[f32],
        out: &mut [f32],
    ) {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if is_x86_feature_detected!("avx2") {
            debug_assert!(out.len() == tokens && codes.len() >= d2 * tokens);
            // SAFETY: avx2 verified above; slice bounds checked by the
            // debug assert and re-derived inside from the same lengths
            unsafe { avx2::accumulate(lut, levels, t_mask, d2, tokens, codes, rho, out) };
            return;
        }
        ScalarKernel.accumulate(lut, levels, t_mask, d2, tokens, codes, rho, out)
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use std::arch::x86_64::*;

    /// Eight tokens per vector: lane `i` accumulates token `n0+i`'s score
    /// in ascending-`j` order with mul-then-add — the same per-token f32
    /// sequence as [`super::ScalarKernel`], hence bit-identical output.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments, clippy::missing_safety_doc)]
    pub unsafe fn accumulate(
        lut: &[f32],
        levels: usize,
        t_mask: u8,
        d2: usize,
        tokens: usize,
        codes: &[u8],
        rho: &[f32],
        out: &mut [f32],
    ) {
        let mask = _mm256_set1_epi32(t_mask as i32);
        let mut n0 = 0usize;
        while n0 + 8 <= tokens {
            let mut acc = _mm256_loadu_ps(out.as_ptr().add(n0));
            for j in 0..d2 {
                let lane = j * tokens + n0;
                // 8 code bytes -> 8 i32 gather indices into this
                // channel's LUT row
                let c8 = _mm_loadl_epi64(codes.as_ptr().add(lane) as *const __m128i);
                let idx = _mm256_and_si256(_mm256_cvtepu8_epi32(c8), mask);
                let vals = _mm256_i32gather_ps::<4>(lut.as_ptr().add(j * levels), idx);
                let r8 = _mm256_loadu_ps(rho.as_ptr().add(lane));
                // mul + add, NOT fma: matches scalar rounding exactly
                acc = _mm256_add_ps(acc, _mm256_mul_ps(r8, vals));
            }
            _mm256_storeu_ps(out.as_mut_ptr().add(n0), acc);
            n0 += 8;
        }
        // ragged tail: same ascending-j per-token sequence, scalar
        for j in 0..d2 {
            for n in n0..tokens {
                out[n] += rho[j * tokens + n]
                    * lut[j * levels + (codes[j * tokens + n] & t_mask) as usize];
            }
        }
    }
}

static SCALAR: ScalarKernel = ScalarKernel;
static SIMD: SimdKernel = SimdKernel;

/// True when the SIMD kernel would actually run vectorized: compiled with
/// `--features simd` on x86_64 AND the CPU reports AVX2.
pub fn simd_available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// Resolve a [`KernelKind`] to a kernel.  `Simd` errors when the
/// vectorized path cannot run (strict `--kernel simd` semantics); `Auto`
/// silently falls back to scalar.
pub fn select_kernel(kind: KernelKind) -> Result<&'static dyn ScoreKernel, String> {
    match kind {
        KernelKind::Scalar => Ok(&SCALAR),
        KernelKind::Simd => {
            if simd_available() {
                Ok(&SIMD)
            } else if cfg!(feature = "simd") {
                Err("kernel 'simd': CPU has no AVX2 support".into())
            } else {
                Err("kernel 'simd': binary built without the `simd` feature \
                     (rebuild with `cargo build --release --features simd`)"
                    .into())
            }
        }
        KernelKind::Auto => {
            Ok(if simd_available() { &SIMD as &dyn ScoreKernel } else { &SCALAR })
        }
    }
}

/// The `Auto` kernel — never fails.
pub fn default_kernel() -> &'static dyn ScoreKernel {
    select_kernel(KernelKind::Auto).expect("auto kernel selection is infallible")
}

/// Touch the next group's code plane and params while the current one is
/// scored.  Groups on the decode path come one per `Arc<Page>`, so the
/// walk is a pointer chase across the heap — without the prefetch every
/// group boundary stalls on a cold line.  Only the head of the plane is
/// prefetched; the hardware prefetcher streams the rest once the lane
/// walk starts.
#[inline]
fn prefetch_group(g: &PolarGroup) {
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        let bytes = g.combined.as_ref().unwrap_or(&g.theta_codes).as_bytes();
        let mut off = 0usize;
        while off < bytes.len().min(512) {
            // SAFETY: in-bounds pointer; prefetch has no memory effects
            unsafe { _mm_prefetch::<_MM_HINT_T0>(bytes.as_ptr().add(off) as *const i8) };
            off += 64;
        }
        unsafe {
            _mm_prefetch::<_MM_HINT_T0>(g.rho_z.as_ptr() as *const i8);
            _mm_prefetch::<_MM_HINT_T0>(g.theta_z.as_ptr() as *const i8);
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = g;
}

/// Scratch + result buffers for repeated LUT QK calls (allocation-free at
/// steady state — see EXPERIMENTS.md §Perf).
pub struct QkLut {
    spec: PolarSpec,
    /// Right-shifts applied to the STORED codes at staging time
    /// (`(0, 0)` = exact plane).  A nonzero shift makes this a DRAFT
    /// scorer: codes are truncated per [`DraftSpec`] while staging, the
    /// basis spans `2^(t_bits - t_shift)` merged angle cells, and rho
    /// dequantizes with its scale widened by `2^r_shift` — the same pages
    /// serve two precisions with no second quantization pass.
    r_shift: u32,
    t_shift: u32,
    d2: usize,
    /// cos/sin basis for the current group: [2 * d2 * levels]
    basis: Vec<f32>,
    /// per-head tables: [heads * d2 * levels]
    lut: Vec<f32>,
    /// unpacked code planes for the current group (channel-major)
    rho_scratch: Vec<u8>,
    theta_scratch: Vec<u8>,
    /// dequantized rho lanes (channel-major)
    rho_deq: Vec<f32>,
    /// the gather+accumulate backend (kernels are stateless statics)
    kernel: &'static dyn ScoreKernel,
}

impl QkLut {
    pub fn new(spec: PolarSpec, d: usize, max_heads: usize) -> Self {
        QkLut::with_kernel(spec, d, max_heads, default_kernel())
    }

    /// Build with an explicit [`ScoreKernel`] (see [`select_kernel`]).
    pub fn with_kernel(
        spec: PolarSpec,
        d: usize,
        max_heads: usize,
        kernel: &'static dyn ScoreKernel,
    ) -> Self {
        let d2 = d / 2;
        let levels = 1usize << spec.t_bits;
        QkLut {
            spec,
            r_shift: 0,
            t_shift: 0,
            d2,
            basis: vec![0.0; 2 * d2 * levels],
            lut: vec![0.0; max_heads * d2 * levels],
            rho_scratch: vec![0; spec.group * d2],
            theta_scratch: vec![0; spec.group * d2],
            rho_deq: vec![0.0; spec.group * d2],
            kernel,
        }
    }

    /// Build a DRAFT scorer over the SAME stored groups the exact LUT
    /// reads: codes are truncated (right-shifted) to `draft`'s bit widths
    /// while staging, per the code-truncation math on [`DraftSpec`].
    /// Scores are bit-identical to what a plain LUT would produce over a
    /// cache re-quantized at the draft widths with the merged-cell params
    /// (`s · 2^shift`, same zero) — see `draft_matches_truncated_requant`.
    pub fn with_draft(
        spec: PolarSpec,
        draft: DraftSpec,
        d: usize,
        max_heads: usize,
        kernel: &'static dyn ScoreKernel,
    ) -> Result<Self, String> {
        let (r_shift, t_shift) = draft.shifts(&spec)?;
        let mut lut = QkLut::with_kernel(spec, d, max_heads, kernel);
        lut.r_shift = r_shift;
        lut.t_shift = t_shift;
        Ok(lut)
    }

    pub fn spec(&self) -> &PolarSpec {
        &self.spec
    }

    /// True when this scorer reads a truncated (draft) view of the codes.
    pub fn is_draft(&self) -> bool {
        self.r_shift != 0 || self.t_shift != 0
    }

    /// Effective angle levels: `2^(t_bits - t_shift)` (draft planes merge
    /// `2^t_shift` exact cells per level).
    fn levels(&self) -> usize {
        1usize << (self.spec.t_bits - self.t_shift)
    }

    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    pub fn set_kernel(&mut self, kernel: &'static dyn ScoreKernel) {
        self.kernel = kernel;
    }

    /// Build the shared cos/sin basis for one group (trig happens ONCE per
    /// group regardless of how many query heads score against it).
    fn build_basis(&mut self, g: &PolarGroup) {
        let levels = self.levels();
        // draft planes widen the angle step by the merged-cell factor
        // (exact: t_shift == 0, step_scale == 1.0 and this is a no-op)
        let step_scale = (1u32 << self.t_shift) as f32;
        for j in 0..self.d2 {
            let (tz, ts) = (g.theta_z[j], g.theta_s[j] * step_scale);
            for c in 0..levels {
                let th = (c as f32 + 0.5) * ts + tz - std::f32::consts::PI;
                let (sin, cos) = th.sin_cos();
                self.basis[(j * levels + c) * 2] = cos;
                self.basis[(j * levels + c) * 2 + 1] = sin;
            }
        }
    }

    /// Combine the basis with `heads` queries into per-head LUTs.
    fn build_luts(&mut self, qs: &[&[f32]]) {
        let levels = self.levels();
        for (h, q) in qs.iter().enumerate() {
            debug_assert_eq!(q.len(), self.d2 * 2);
            let lut = &mut self.lut[h * self.d2 * levels..(h + 1) * self.d2 * levels];
            for j in 0..self.d2 {
                let qx = q[2 * j];
                let qy = q[2 * j + 1];
                for c in 0..levels {
                    let cos = self.basis[(j * levels + c) * 2];
                    let sin = self.basis[(j * levels + c) * 2 + 1];
                    lut[j * levels + c] = qx * cos + qy * sin;
                }
            }
        }
    }

    /// Stage one group for the kernel: unpack its code plane(s) into the
    /// channel-major byte scratch and dequantize rho into the f32 lanes
    /// shared by every head.  Fused groups (r+t <= 8) pay ONE unpack —
    /// the combined bytes serve directly as (masked) theta gather indices
    /// while rho is split off arithmetically; general groups (r+t > 8)
    /// unpack the two planes separately.  Either way the kernel sees the
    /// same staged shape.
    fn stage_group(&mut self, g: &PolarGroup) {
        let plane = g.tokens * self.d2;
        if self.theta_scratch.len() < plane {
            self.theta_scratch.resize(plane, 0);
            self.rho_scratch.resize(plane, 0);
            self.rho_deq.resize(plane, 0.0);
        }
        let t_bits = self.spec.t_bits;
        if self.is_draft() {
            return self.stage_group_draft(g);
        }
        if let Some(combined) = &g.combined {
            combined.unpack_into(&mut self.theta_scratch);
            for j in 0..self.d2 {
                let (z, s) = (g.rho_z[j], g.rho_s[j]);
                let lane = j * g.tokens;
                for n in 0..g.tokens {
                    let rc = (self.theta_scratch[lane + n] >> t_bits) as f32;
                    self.rho_deq[lane + n] = (rc + 0.5) * s + z;
                }
            }
        } else {
            g.theta_codes.unpack_into(&mut self.theta_scratch);
            g.rho_codes.unpack_into(&mut self.rho_scratch);
            for j in 0..self.d2 {
                let (z, s) = (g.rho_z[j], g.rho_s[j]);
                let lane = j * g.tokens;
                for n in 0..g.tokens {
                    self.rho_deq[lane + n] =
                        (self.rho_scratch[lane + n] as f32 + 0.5) * s + z;
                }
            }
        }
    }

    /// Draft staging: derive the truncated code plane from the stored
    /// exact codes while unpacking.  Unlike the exact fused path (which
    /// leaves fused bytes in the scratch and lets the kernel's `t_mask`
    /// strip the rho bits), draft staging must REWRITE the staged theta
    /// bytes — the shifted draft index can't be recovered by a mask alone
    /// once rho bits sit above it — so the kernel sees pure codes
    /// `< 2^(t_bits - t_shift)` on both layouts.  Rho dequantizes at the
    /// merged-cell midpoint: `(c >> r_shift + 1/2) · (s · 2^r_shift) + z`.
    fn stage_group_draft(&mut self, g: &PolarGroup) {
        let t_bits = self.spec.t_bits;
        let (r_shift, t_shift) = (self.r_shift, self.t_shift);
        let t_mask_full = ((1u32 << t_bits) - 1) as u8;
        let s_scale = (1u32 << r_shift) as f32;
        if let Some(combined) = &g.combined {
            combined.unpack_into(&mut self.theta_scratch);
            for j in 0..self.d2 {
                let (z, s) = (g.rho_z[j], g.rho_s[j] * s_scale);
                let lane = j * g.tokens;
                for n in 0..g.tokens {
                    let byte = self.theta_scratch[lane + n];
                    let rc = ((byte >> t_bits) >> r_shift) as f32;
                    self.theta_scratch[lane + n] = (byte & t_mask_full) >> t_shift;
                    self.rho_deq[lane + n] = (rc + 0.5) * s + z;
                }
            }
        } else {
            g.theta_codes.unpack_into(&mut self.theta_scratch);
            g.rho_codes.unpack_into(&mut self.rho_scratch);
            for j in 0..self.d2 {
                let (z, s) = (g.rho_z[j], g.rho_s[j] * s_scale);
                let lane = j * g.tokens;
                for n in 0..g.tokens {
                    self.theta_scratch[lane + n] >>= t_shift;
                    let rc = (self.rho_scratch[lane + n] >> r_shift) as f32;
                    self.rho_deq[lane + n] = (rc + 0.5) * s + z;
                }
            }
        }
    }

    /// Scores for MULTIPLE query heads sharing one kv stream (GQA).
    ///
    /// `out[h]` receives `enc.tokens()` scores for query `qs[h]`.
    /// Thin shim over [`QkLut::scores_groups`].
    pub fn scores_multi(&mut self, qs: &[&[f32]], enc: &PolarEncoded, out: &mut [Vec<f32>]) {
        self.scores_groups(qs, &enc.groups, out);
    }

    /// Core staged walk over borrowed groups — generic over any in-order
    /// group source, so the paged kvcache's per-stream view
    /// ([`crate::kvcache::StreamView::key_groups`], one group per shared
    /// page) feeds it directly, with no contiguous `Vec<PolarGroup>` (and
    /// no `PolarEncoded` clone) materialized on the decode hot path.
    /// Plain slices still work (`&[PolarGroup]` iterates by reference).
    ///
    /// Per group: build the basis + per-head LUTs, stage the code planes
    /// once (shared by all heads), then hand each head's table to the
    /// selected [`ScoreKernel`].  While a group is being scored the NEXT
    /// group's code plane is software-prefetched — the paged walk is a
    /// pointer chase across `Arc<Page>`s otherwise.
    pub fn scores_groups<'g, I>(&mut self, qs: &[&[f32]], groups: I, out: &mut [Vec<f32>])
    where
        I: IntoIterator<Item = &'g PolarGroup>,
    {
        assert_eq!(qs.len(), out.len());
        let levels = self.levels();
        assert!(qs.len() * self.d2 * levels <= self.lut.len());
        for o in out.iter_mut() {
            o.clear();
        }
        let t_mask = (levels - 1) as u8;
        let kernel = self.kernel;
        let mut it = groups.into_iter().peekable();
        while let Some(g) = it.next() {
            if let Some(next) = it.peek() {
                prefetch_group(next);
            }
            self.build_basis(g);
            self.build_luts(qs);
            self.stage_group(g);
            let plane = g.tokens * self.d2;
            for (h, o) in out.iter_mut().enumerate() {
                let lut = &self.lut[h * self.d2 * levels..(h + 1) * self.d2 * levels];
                let base = o.len();
                o.resize(base + g.tokens, 0.0);
                kernel.accumulate(
                    lut,
                    levels,
                    t_mask,
                    self.d2,
                    g.tokens,
                    &self.theta_scratch[..plane],
                    &self.rho_deq[..plane],
                    &mut o[base..],
                );
            }
        }
    }

    /// Batched speculative VERIFICATION: score `k` proposed decode
    /// positions against one kv stream's cached groups in a single staged
    /// walk.
    ///
    /// `qs` holds every query row of every proposed position
    /// (position-major: `qs[p * heads + h]`); each group's basis build and
    /// code staging is paid ONCE for all k positions × all GQA heads — the
    /// amortization the exact LUT already gives one position's head group,
    /// stretched across the whole speculation window.  `out` follows
    /// `qs`'s order.  Per-head accumulation never depends on the other
    /// queries in the batch (`ScoreKernel` contract), so each position's
    /// scores are bit-identical to scoring it alone — the property that
    /// lets speculative greedy decode verify drafts against sequential
    /// output token-for-token.
    pub fn verify_batch<'g, I>(&mut self, qs: &[&[f32]], groups: I, out: &mut [Vec<f32>])
    where
        I: IntoIterator<Item = &'g PolarGroup>,
    {
        self.scores_groups(qs, groups, out);
    }

    /// Single-head convenience wrapper (shim over the kernel walk).
    pub fn scores(&mut self, q: &[f32], enc: &PolarEncoded, out: &mut Vec<f32>) {
        let mut tmp = [std::mem::take(out)];
        self.scores_multi(&[q], enc, &mut tmp);
        *out = std::mem::take(&mut tmp[0]);
    }

    /// Blocked MULTI-SEQUENCE entry point: one decode step's worth of QK
    /// scoring for a whole batch of sequences sharing this scratch.
    ///
    /// `out[s][h]` receives the scores of sequence `s`, query head `h`.
    /// Each sequence's cos/sin basis is built once per group and shared by
    /// all of its GQA query heads; across sequences the LUT/basis/unpack
    /// scratch is reused, so a caller can score a whole shard of
    /// sequences with zero allocation at steady state.  The
    /// `decode_batch` bench and the batch-equivalence proptests drive
    /// this wrapper; [`crate::coordinator::pool::DecodePool`] workers
    /// reach the same staged kernel walk through `Model::decode_step`,
    /// one sequence at a time.
    pub fn scores_batch(&mut self, jobs: &[SeqScoreJob<'_>], out: &mut [Vec<Vec<f32>>]) {
        assert_eq!(jobs.len(), out.len());
        for (job, o) in jobs.iter().zip(out.iter_mut()) {
            self.scores_groups(job.qs, job.groups, o);
        }
    }
}

/// One sequence's slice of a batched decode step: its GQA query heads and
/// a borrowed view of its cached key groups.
pub struct SeqScoreJob<'a> {
    /// query rows, one per query head attached to this kv stream
    pub qs: &'a [&'a [f32]],
    /// the sequence's finalized (quantized) key groups
    pub groups: &'a [PolarGroup],
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::polar;
    use crate::tensor::ops::dot;
    use crate::util::rng::Rng;

    #[test]
    fn lut_matches_dequant_matmul() {
        let mut rng = Rng::new(21);
        let d = 32;
        for (r, t, g) in [(4, 4, 16), (3, 3, 8), (5, 2, 16), (2, 5, 8)] {
            let spec = PolarSpec::new(r, t, g);
            let k = rng.normal_vec(4 * g * d);
            let enc = polar::encode(&k, d, &spec);
            let k_hat = polar::decode(&enc, d);
            let q = rng.normal_vec(d);
            let mut lut = QkLut::new(spec, d, 1);
            let mut scores = Vec::new();
            lut.scores(&q, &enc, &mut scores);
            assert_eq!(scores.len(), 4 * g);
            for n in 0..scores.len() {
                let want = dot(&q, &k_hat[n * d..(n + 1) * d]);
                assert!(
                    (scores[n] - want).abs() < 2e-4 * (1.0 + want.abs()),
                    "n={n}: {} vs {}",
                    scores[n],
                    want
                );
            }
        }
    }

    #[test]
    fn batch_matches_per_sequence() {
        let mut rng = Rng::new(23);
        let d = 32;
        let spec = PolarSpec::new(4, 4, 16);
        let hq = 2;
        // three sequences of different lengths
        let encs: Vec<_> = [2usize, 3, 1]
            .iter()
            .map(|&gs| polar::encode(&rng.normal_vec(gs * 16 * d), d, &spec))
            .collect();
        let qs: Vec<Vec<Vec<f32>>> = (0..encs.len())
            .map(|_| (0..hq).map(|_| rng.normal_vec(d)).collect())
            .collect();
        let qrefs: Vec<Vec<&[f32]>> = qs
            .iter()
            .map(|sq| sq.iter().map(|q| q.as_slice()).collect())
            .collect();
        let jobs: Vec<SeqScoreJob> = encs
            .iter()
            .zip(&qrefs)
            .map(|(e, q)| SeqScoreJob { qs: q, groups: &e.groups })
            .collect();

        let mut lut = QkLut::new(spec, d, hq);
        let mut batched: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); hq]; encs.len()];
        lut.scores_batch(&jobs, &mut batched);

        for (s, enc) in encs.iter().enumerate() {
            let mut single = vec![Vec::new(); hq];
            lut.scores_multi(&qrefs[s], enc, &mut single);
            assert_eq!(batched[s], single, "sequence {s}");
            assert_eq!(batched[s][0].len(), enc.tokens());
        }
    }

    #[test]
    fn multi_head_matches_single() {
        let mut rng = Rng::new(22);
        let d = 64;
        let spec = PolarSpec::new(4, 4, 32);
        let k = rng.normal_vec(2 * 32 * d);
        let enc = polar::encode(&k, d, &spec);
        let q0 = rng.normal_vec(d);
        let q1 = rng.normal_vec(d);
        let q2 = rng.normal_vec(d);

        let mut lut = QkLut::new(spec, d, 4);
        let mut multi = vec![Vec::new(), Vec::new(), Vec::new()];
        lut.scores_multi(&[&q0, &q1, &q2], &enc, &mut multi);
        for (h, q) in [&q0, &q1, &q2].iter().enumerate() {
            let mut single = Vec::new();
            lut.scores(q, &enc, &mut single);
            assert_eq!(multi[h], single, "head {h}");
        }
    }

    #[test]
    fn draft_matches_truncated_requant() {
        // A draft LUT over the EXACT stored plane must score bit-identically
        // to a plain LUT over a cache whose codes were explicitly truncated
        // (c >> shift) with merged-cell params (s * 2^shift, same zero) —
        // the DraftSpec contract, on both the fused and the general layout.
        use super::super::pack::PackedCodes;
        use super::super::polar::DraftSpec;
        let mut rng = Rng::new(31);
        let d = 32;
        for (r, t, dr, dt) in [(4u32, 4u32, 2u32, 2u32), (5, 5, 2, 3), (4, 4, 4, 4), (3, 6, 1, 2)]
        {
            let spec = PolarSpec::new(r, t, 16);
            let draft = DraftSpec::new(dr, dt);
            let (rs, ts) = draft.shifts(&spec).unwrap();
            let k = rng.normal_vec(2 * 16 * d);
            let enc = polar::encode(&k, d, &spec);

            // explicit truncated re-encoding of every group
            let coarse_spec = PolarSpec::new(dr, dt, 16);
            let coarse_groups: Vec<PolarGroup> = enc
                .groups
                .iter()
                .map(|g| {
                    let rc: Vec<u8> =
                        g.rho_codes.unpack().iter().map(|&c| c >> rs).collect();
                    let tc: Vec<u8> =
                        g.theta_codes.unpack().iter().map(|&c| c >> ts).collect();
                    let combined = (dr + dt <= 8).then(|| {
                        let mixed: Vec<u8> = rc
                            .iter()
                            .zip(&tc)
                            .map(|(&r, &t)| (r << dt) | t)
                            .collect();
                        PackedCodes::from_codes(&mixed, dr + dt)
                    });
                    PolarGroup {
                        rho_codes: PackedCodes::from_codes(&rc, dr),
                        theta_codes: PackedCodes::from_codes(&tc, dt),
                        combined,
                        rho_z: g.rho_z.clone(),
                        rho_s: g.rho_s.iter().map(|&s| s * (1u32 << rs) as f32).collect(),
                        theta_z: g.theta_z.clone(),
                        theta_s: g.theta_s.iter().map(|&s| s * (1u32 << ts) as f32).collect(),
                        tokens: g.tokens,
                    }
                })
                .collect();

            let q = rng.normal_vec(d);
            let mut draft_lut =
                QkLut::with_draft(spec, draft, d, 1, default_kernel()).unwrap();
            assert_eq!(draft_lut.is_draft(), rs != 0 || ts != 0);
            let mut via_shift = vec![Vec::new()];
            draft_lut.scores_groups(&[&q], &enc.groups, &mut via_shift);

            let mut plain_lut = QkLut::new(coarse_spec, d, 1);
            let mut via_requant = vec![Vec::new()];
            plain_lut.scores_groups(&[&q], &coarse_groups[..], &mut via_requant);

            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(
                bits(&via_shift[0]),
                bits(&via_requant[0]),
                "r{r}t{t} -> r{dr}t{dt}"
            );
        }
    }

    #[test]
    fn verify_batch_matches_per_position() {
        // k positions' heads scored through one walk == each position
        // scored alone, bit-for-bit (the speculative verification entry).
        let mut rng = Rng::new(33);
        let d = 32;
        let hq = 2;
        let positions = 3;
        let spec = PolarSpec::new(4, 4, 16);
        let enc = polar::encode(&rng.normal_vec(3 * 16 * d), d, &spec);
        let qs: Vec<Vec<f32>> =
            (0..positions * hq).map(|_| rng.normal_vec(d)).collect();
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();

        let mut lut = QkLut::new(spec, d, positions * hq);
        let mut batched = vec![Vec::new(); positions * hq];
        lut.verify_batch(&qrefs, &enc.groups, &mut batched);

        let mut solo_lut = QkLut::new(spec, d, hq);
        for p in 0..positions {
            let mut solo = vec![Vec::new(); hq];
            solo_lut.scores_multi(&qrefs[p * hq..(p + 1) * hq], &enc, &mut solo);
            for h in 0..hq {
                assert_eq!(batched[p * hq + h], solo[h], "pos {p} head {h}");
            }
        }
    }

    #[test]
    fn kernel_selection_surface() {
        assert_eq!(KernelKind::parse("auto"), Ok(KernelKind::Auto));
        assert_eq!(KernelKind::parse("scalar"), Ok(KernelKind::Scalar));
        assert_eq!(KernelKind::parse("simd"), Ok(KernelKind::Simd));
        assert!(KernelKind::parse("gpu").is_err());
        assert_eq!(select_kernel(KernelKind::Scalar).unwrap().name(), "scalar");
        // strict semantics: explicit simd errors when it cannot vectorize
        match select_kernel(KernelKind::Simd) {
            Ok(k) => {
                assert!(simd_available());
                assert_eq!(k.name(), "simd");
            }
            Err(e) => {
                assert!(!simd_available());
                assert!(e.contains("simd"), "{e}");
            }
        }
        // auto never fails and reports the kernel it picked
        let auto = select_kernel(KernelKind::Auto).unwrap();
        assert_eq!(auto.name(), if simd_available() { "simd" } else { "scalar" });
    }

    #[test]
    fn scalar_and_selected_kernels_agree_bitwise() {
        // unit-level smoke of the ScoreKernel contract (the cross-kernel
        // proptest in tests/proptests.rs covers random shapes): whatever
        // Auto resolves to must match the scalar kernel bit-for-bit on
        // both the fused and the general staging path.
        let mut rng = Rng::new(77);
        let d = 32;
        for (r, t) in [(4u32, 4u32), (5, 5)] {
            let spec = PolarSpec::new(r, t, 16);
            let enc = polar::encode(&rng.normal_vec(3 * 16 * d), d, &spec);
            let q = rng.normal_vec(d);
            let mut scalar_lut =
                QkLut::with_kernel(spec, d, 1, select_kernel(KernelKind::Scalar).unwrap());
            let mut auto_lut = QkLut::new(spec, d, 1);
            let (mut a, mut b) = (Vec::new(), Vec::new());
            scalar_lut.scores(&q, &enc, &mut a);
            auto_lut.scores(&q, &enc, &mut b);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a), bits(&b), "r{r} t{t}");
        }
    }
}
