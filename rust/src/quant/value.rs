//! Value-cache codec: token-wise asymmetric quantization (KIVI's value
//! path; used by PolarQuant for Table 7's "+ value quant" rows) plus a
//! fused weighted-sum kernel for the attention `w @ V` product that never
//! materializes dequantized values.

use super::int_n::{self, IntEncoded};

pub type ValueEncoded = IntEncoded;

pub fn encode(v: &[f32], d: usize, bits: u32) -> ValueEncoded {
    int_n::encode(v, d, bits)
}

pub fn decode(enc: &ValueEncoded, d: usize) -> Vec<f32> {
    int_n::decode(enc, d)
}

/// out[j] += Σ_n w[n] · deq(v[n, j])
///
/// Using deq = (c + ½)s_n + z_n:
///   out += Σ_n (w_n·s_n)·codes[n, :]  +  (Σ_n w_n·(z_n + ½ s_n)) · 1
/// so each element costs one mul-add on the u8 code — the value-side
/// analogue of the paper's post-multiplication dequantization idea.
pub fn weighted_sum_into(w: &[f32], enc: &ValueEncoded, d: usize, out: &mut [f32]) {
    assert_eq!(out.len(), d);
    assert!(w.len() <= enc.tokens());
    let codes = enc.codes.unpack(); // one pass; page-sized in practice
    let mut bias = 0.0f32;
    for (n, &wn) in w.iter().enumerate() {
        if wn == 0.0 {
            continue;
        }
        let ws = wn * enc.s[n];
        bias += wn * (enc.z[n] + 0.5 * enc.s[n]);
        let row = &codes[n * d..(n + 1) * d];
        for j in 0..d {
            out[j] += ws * row[j] as f32;
        }
    }
    for o in out.iter_mut() {
        *o += bias;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fused_weighted_sum_matches_decode_path() {
        let mut rng = Rng::new(61);
        let d = 32;
        let tokens = 20;
        let v = rng.normal_vec(tokens * d);
        let enc = encode(&v, d, 4);
        let v_hat = decode(&enc, d);
        let mut w: Vec<f32> = (0..tokens).map(|_| rng.uniform() as f32).collect();
        let sum: f32 = w.iter().sum();
        for x in w.iter_mut() {
            *x /= sum;
        }
        let mut fused = vec![0.0f32; d];
        weighted_sum_into(&w, &enc, d, &mut fused);
        let mut direct = vec![0.0f32; d];
        for n in 0..tokens {
            for j in 0..d {
                direct[j] += w[n] * v_hat[n * d + j];
            }
        }
        for j in 0..d {
            assert!((fused[j] - direct[j]).abs() < 1e-4, "{} vs {}", fused[j], direct[j]);
        }
    }

    #[test]
    fn two_bit_values_keep_attention_output_close() {
        // Table 7's claim in miniature: 2-bit V barely moves the output.
        let mut rng = Rng::new(62);
        let d = 64;
        let tokens = 128;
        let v = rng.normal_vec(tokens * d);
        let enc = encode(&v, d, 2);
        let mut w = vec![1.0f32 / tokens as f32; tokens];
        w[0] = 0.5; // a heavy hitter
        let sum: f32 = w.iter().sum();
        for x in w.iter_mut() {
            *x /= sum;
        }
        let mut got = vec![0.0f32; d];
        weighted_sum_into(&w, &enc, d, &mut got);
        let mut want = vec![0.0f32; d];
        for n in 0..tokens {
            for j in 0..d {
                want[j] += w[n] * v[n * d + j];
            }
        }
        let err = crate::tensor::ops::mse(&got, &want);
        let mag = crate::tensor::ops::mse(&want, &vec![0.0; d]);
        // 2-bit quantization: error well under the signal (cos-sim stays
        // high); Table 7 shows the task-level effect is negligible.
        assert!(err < 0.3 * mag.max(1e-6), "err {err} mag {mag}");
        assert!(crate::tensor::ops::cosine(&got, &want) > 0.9);
    }
}
