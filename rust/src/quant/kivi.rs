//! KIVI baseline [Liu et al., ICML'24]: channel-wise asymmetric key
//! quantization — params per (token-group, channel) — and the
//! dequantize-then-multiply QK path the paper's Fig. 3 compares against.

use super::pack::PackedCodes;
use super::{dequantize, qparams, quantize};

#[derive(Clone, Copy, Debug)]
pub struct KiviSpec {
    pub bits: u32,
    pub group: usize,
}

impl KiviSpec {
    pub fn new(bits: u32, group: usize) -> Self {
        KiviSpec { bits, group }
    }

    /// bits/element incl. fp16 zero+scale per channel per group (paper §B).
    pub fn bits_per_element(&self) -> f64 {
        self.bits as f64 + 32.0 / self.group as f64
    }
}

/// One encoded token group: codes token-major (tokens x d), params per
/// channel.
#[derive(Clone, Debug)]
pub struct KiviGroup {
    pub codes: PackedCodes,
    pub z: Vec<f32>,
    pub s: Vec<f32>,
    pub tokens: usize,
}

impl KiviGroup {
    pub fn nbytes(&self) -> usize {
        self.codes.nbytes() + 2 * self.z.len() * std::mem::size_of::<f32>()
    }
}

#[derive(Clone, Debug, Default)]
pub struct KiviEncoded {
    pub groups: Vec<KiviGroup>,
}

impl KiviEncoded {
    pub fn tokens(&self) -> usize {
        self.groups.iter().map(|g| g.tokens).sum()
    }
}

pub fn encode_group(k: &[f32], d: usize, spec: &KiviSpec) -> KiviGroup {
    let tokens = k.len() / d;
    assert_eq!(k.len(), tokens * d);
    let mut z = vec![0.0f32; d];
    let mut s = vec![0.0f32; d];
    for j in 0..d {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for n in 0..tokens {
            let v = k[n * d + j];
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let (zz, ss) = qparams(lo, hi, spec.bits);
        z[j] = zz;
        s[j] = ss;
    }
    let mut codes = vec![0u8; tokens * d];
    for n in 0..tokens {
        for j in 0..d {
            codes[n * d + j] = quantize(k[n * d + j], z[j], s[j], spec.bits);
        }
    }
    KiviGroup { codes: PackedCodes::from_codes(&codes, spec.bits), z, s, tokens }
}

pub fn encode(k: &[f32], d: usize, spec: &KiviSpec) -> KiviEncoded {
    let tokens = k.len() / d;
    assert_eq!(tokens % spec.group, 0);
    KiviEncoded {
        groups: (0..tokens / spec.group)
            .map(|g| encode_group(&k[g * spec.group * d..(g + 1) * spec.group * d], d, spec))
            .collect(),
    }
}

pub fn decode_group_into(g: &KiviGroup, d: usize, out: &mut Vec<f32>) {
    let codes = g.codes.unpack();
    for n in 0..g.tokens {
        for j in 0..d {
            out.push(dequantize(codes[n * d + j], g.z[j], g.s[j]));
        }
    }
}

pub fn decode(enc: &KiviEncoded, d: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(enc.tokens() * d);
    for g in &enc.groups {
        decode_group_into(g, d, out.as_mut());
    }
    out
}

/// Dequantize-then-dot QK: the faithful reproduction of KIVI's decode
/// kernel (materializes each dequantized key row, then dots).  Scratch
/// buffers live in the struct so the hot loop is allocation-free.
pub struct KiviQk {
    #[allow(dead_code)]
    spec: KiviSpec,
    d: usize,
    code_scratch: Vec<u8>,
    row: Vec<f32>,
}

impl KiviQk {
    pub fn new(spec: KiviSpec, d: usize) -> Self {
        KiviQk { spec, d, code_scratch: vec![0; spec.group * d], row: vec![0.0; d] }
    }

    pub fn scores(&mut self, q: &[f32], enc: &KiviEncoded, out: &mut Vec<f32>) {
        out.clear();
        for g in &enc.groups {
            g.codes.unpack_into(&mut self.code_scratch);
            for n in 0..g.tokens {
                let codes = &self.code_scratch[n * self.d..(n + 1) * self.d];
                for j in 0..self.d {
                    self.row[j] = (codes[j] as f32 + 0.5) * g.s[j] + g.z[j];
                }
                out.push(crate::tensor::ops::dot(q, &self.row));
            }
        }
    }

    /// Algebraic shortcut (ablation, not the paper's baseline): fold q into
    /// the scales once per group — score(n) = Σ_j code·(s_j·q_j) + const.
    /// This shows how much of KIVI's gap is implementation, not method.
    pub fn scores_folded(&mut self, q: &[f32], enc: &KiviEncoded, out: &mut Vec<f32>) {
        out.clear();
        for g in &enc.groups {
            g.codes.unpack_into(&mut self.code_scratch);
            let mut c0 = 0.0f32;
            for j in 0..self.d {
                self.row[j] = g.s[j] * q[j];
                c0 += (g.z[j] + 0.5 * g.s[j]) * q[j];
            }
            for n in 0..g.tokens {
                let codes = &self.code_scratch[n * self.d..(n + 1) * self.d];
                let mut acc = c0;
                for j in 0..self.d {
                    acc += codes[j] as f32 * self.row[j];
                }
                out.push(acc);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::dot;
    use crate::util::rng::Rng;

    #[test]
    fn qk_matches_decode_then_dot() {
        let mut rng = Rng::new(31);
        let d = 32;
        let spec = KiviSpec::new(4, 16);
        let k = rng.normal_vec(48 * d);
        let enc = encode(&k, d, &spec);
        let k_hat = decode(&enc, d);
        let q = rng.normal_vec(d);
        let mut qk = KiviQk::new(spec, d);
        let mut scores = Vec::new();
        qk.scores(&q, &enc, &mut scores);
        let mut folded = Vec::new();
        qk.scores_folded(&q, &enc, &mut folded);
        for n in 0..48 {
            let want = dot(&q, &k_hat[n * d..(n + 1) * d]);
            assert!((scores[n] - want).abs() < 2e-4 * (1.0 + want.abs()));
            assert!((folded[n] - want).abs() < 5e-4 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn roundtrip_within_half_cell() {
        let mut rng = Rng::new(32);
        let d = 16;
        let spec = KiviSpec::new(3, 8);
        let k = rng.normal_vec(16 * d);
        let enc = encode(&k, d, &spec);
        let k_hat = decode(&enc, d);
        for (gi, g) in enc.groups.iter().enumerate() {
            for n in 0..g.tokens {
                let t = gi * spec.group + n;
                for j in 0..d {
                    let err = (k[t * d + j] - k_hat[t * d + j]).abs();
                    assert!(err <= g.s[j] / 2.0 + 1e-5);
                }
            }
        }
    }

    #[test]
    fn bits_accounting() {
        assert!((KiviSpec::new(4, 128).bits_per_element() - 4.25).abs() < 1e-9);
        assert!((KiviSpec::new(2, 32).bits_per_element() - 3.0).abs() < 1e-9);
    }
}
