//! Fabric transfer record: one prefix-index entry (hash-chain link plus
//! its page) as a self-contained checksummed blob.
//!
//! The inner page bytes are the unmodified tier codec output
//! ([`crate::kvcache::tier::serde::encode_page`]), so any page a node
//! can demote to disk it can also publish to the fabric — bit-exact on
//! both paths.  The envelope adds everything a *remote* consumer needs
//! to verify the entry before trusting it:
//!
//! ```text
//! u32 magic "PQFB"   u16 version (1)
//! u64 tag            # config fingerprint — model + quant geometry
//! u64 parent         # parent chain hash (ROOT_HASH at depth 0)
//! u32 ntoks          # token ids covered by this page
//! ntoks * u32 toks
//! u32 page_len       # tier-codec page record
//! page_len bytes
//! u64 fnv1a-64 checksum over every preceding byte
//! ```
//!
//! Verification order on fetch: outer checksum, magic/version, tag
//! (wrong-config records are *rejected*, not decoded), then the page
//! codec's own checksum + bounds checks.  The consumer additionally
//! re-derives the chain hash from `(parent, toks)` and compares token
//! counts, so a record filed under the wrong hash — or a hash collision
//! — degrades to a miss, never a wrong cache entry.

use anyhow::{ensure, Result};

use crate::kvcache::tier::serde::{decode_page, encode_page, fnv1a, put_u32, put_u64};
use crate::kvcache::Page;

pub const FABRIC_MAGIC: u32 = 0x5051_4642; // "PQFB"
pub const FABRIC_VERSION: u16 = 1;

/// A decoded + envelope-verified fabric record.  The *semantic* checks
/// (chain hash, token count vs page) are the pool's job — it owns the
/// hash function and the entry it is about to admit.
pub struct FabricRecord {
    pub parent: u64,
    pub toks: Vec<u32>,
    pub page: Page,
}

/// Serialize one prefix entry for publication.
pub fn encode_record(tag: u64, parent: u64, toks: &[u32], page: &Page) -> Vec<u8> {
    let body = encode_page(page);
    let mut buf = Vec::with_capacity(38 + 4 * toks.len() + body.len());
    put_u32(&mut buf, FABRIC_MAGIC);
    buf.extend_from_slice(&FABRIC_VERSION.to_le_bytes());
    put_u64(&mut buf, tag);
    put_u64(&mut buf, parent);
    put_u32(&mut buf, toks.len() as u32);
    for &t in toks {
        put_u32(&mut buf, t);
    }
    put_u32(&mut buf, body.len() as u32);
    buf.extend_from_slice(&body);
    let sum = fnv1a(&buf);
    put_u64(&mut buf, sum);
    buf
}

/// Parse and verify one fetched record against the local config
/// fingerprint.  Every corruption mode — torn bytes, bad magic, a peer
/// running different quant geometry, a damaged inner page — is an `Err`
/// the pool turns into a clean miss.
pub fn decode_record(buf: &[u8], want_tag: u64) -> Result<FabricRecord> {
    ensure!(buf.len() >= 4 + 2 + 8 + 8 + 4 + 4 + 8, "fabric record too short ({} bytes)", buf.len());
    let (body, tail) = buf.split_at(buf.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().unwrap());
    ensure!(fnv1a(body) == want, "fabric record checksum mismatch");

    let mut c = crate::kvcache::tier::serde::Cur::new(body);
    let magic = c.u32()?;
    ensure!(magic == FABRIC_MAGIC, "fabric record bad magic {magic:#x}");
    let version = c.u16()?;
    ensure!(version == FABRIC_VERSION, "fabric record version {version} (reader handles v{FABRIC_VERSION})");
    let tag = c.u64()?;
    ensure!(
        tag == want_tag,
        "fabric record config fingerprint {tag:#x} != local {want_tag:#x}"
    );
    let parent = c.u64()?;
    let ntoks = c.u32()? as usize;
    ensure!(ntoks > 0, "fabric record: empty token run");
    let toks = c.u32s(ntoks)?;
    let page_len = c.u32()? as usize;
    let page = decode_page(c.take(page_len)?)?;
    ensure!(c.done(), "fabric record: trailing bytes");
    Ok(FabricRecord { parent, toks, page })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::stream::GroupValues;
    use crate::quant::polar::{self, PolarSpec};
    use crate::util::rng::Rng;

    fn page(seed: u64) -> Page {
        let spec = PolarSpec::new(4, 4, 4);
        let d = 8;
        let mut rng = Rng::new(seed);
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..2 {
            keys.push(polar::encode_group(&rng.normal_vec(4 * d), d, &spec));
            vals.push(GroupValues::Fp(rng.normal_vec(4 * d)));
        }
        Page::new(keys, vals, 4)
    }

    #[test]
    fn roundtrip_preserves_envelope_and_page() {
        let p = page(7);
        let toks = vec![11u32, 12, 13, 14];
        let enc = encode_record(0xDEAD_BEEF, 0x1234, &toks, &p);
        let rec = decode_record(&enc, 0xDEAD_BEEF).expect("decode");
        assert_eq!(rec.parent, 0x1234);
        assert_eq!(rec.toks, toks);
        assert_eq!(
            crate::kvcache::tier::serde::encode_page(&rec.page),
            crate::kvcache::tier::serde::encode_page(&p),
            "inner page survives bit-exactly"
        );
    }

    #[test]
    fn wrong_config_fingerprint_is_rejected() {
        let enc = encode_record(1, 0, &[5, 6, 7, 8], &page(8));
        let err = decode_record(&enc, 2).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
    }

    #[test]
    fn corruption_is_rejected_not_panicking() {
        let enc = encode_record(9, 3, &[1, 2, 3, 4], &page(9));
        for i in [0usize, 6, 20, enc.len() / 2, enc.len() - 9, enc.len() - 1] {
            let mut bad = enc.clone();
            bad[i] ^= 0x5A;
            assert!(decode_record(&bad, 9).is_err(), "flip at byte {i} accepted");
        }
        for cut in [0usize, 10, enc.len() / 2, enc.len() - 1] {
            assert!(decode_record(&enc[..cut], 9).is_err(), "truncation to {cut} accepted");
        }
        let mut long = enc.clone();
        long.push(0);
        assert!(decode_record(&long, 9).is_err(), "trailing byte accepted");
    }
}
