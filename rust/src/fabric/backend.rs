//! Fabric transports: shared directory and peer fetch.
//!
//! Both move opaque [`super::record`] bytes; neither interprets them.
//! The pool verifies everything after the fact, so these stay simple —
//! a failed read, a half-written file, or a lying peer costs one fetch
//! and degrades to a cold prefill.

use std::fs;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::util::json::{self, Value};

use super::PrefixFabric;

/// How long a peer fetch may take before the pool gives up and cold
/// prefills.  Generous against disk reads, tight against a hung node.
const PEER_IO_TIMEOUT: Duration = Duration::from_secs(2);
/// Refuse absurd peer-advertised lengths before allocating.
const MAX_RECORD_BYTES: usize = 256 << 20;

/// Shared segment directory — the simplest fabric: every node mounts
/// the same directory (NFS or a shared volume in CI) and publishes one
/// file per prefix chain hash, namespaced by config fingerprint so
/// differently-configured fleets can share a mount without ever reading
/// each other's records.
pub struct DirFabric {
    dir: PathBuf,
    tag: u64,
}

impl DirFabric {
    pub fn new(dir: &Path, tag: u64) -> std::io::Result<Self> {
        fs::create_dir_all(dir)?;
        Ok(DirFabric { dir: dir.to_path_buf(), tag })
    }

    fn path(&self, hash: u64) -> PathBuf {
        self.dir.join(format!("fb-{:016x}-{hash:016x}.page", self.tag))
    }
}

impl PrefixFabric for DirFabric {
    fn fetch(&self, hash: u64) -> Option<Vec<u8>> {
        fs::read(self.path(hash)).ok()
    }

    fn publish(&self, hash: u64, record: &[u8]) -> bool {
        let dst = self.path(hash);
        if dst.exists() {
            return false; // records are content-addressed; first write wins
        }
        // tmp + rename so a concurrent reader never sees a torn record
        // (the checksum would catch it anyway, but a clean rename avoids
        // burning the fetch on a transient)
        let tmp = self.dir.join(format!(
            "fb-{:016x}-{hash:016x}.tmp-{}",
            self.tag,
            std::process::id()
        ));
        let ok = fs::write(&tmp, record).is_ok() && fs::rename(&tmp, &dst).is_ok();
        if !ok {
            let _ = fs::remove_file(&tmp);
        }
        ok
    }

    fn describe(&self) -> String {
        format!("dir:{}", self.dir.display())
    }
}

/// Designated-peer fetch over the JSON-lines admin channel: one
/// connection per fetch (backend sessions are connection-independent,
/// and fetches are rare — only cold prefix misses reach here).
///
/// ```text
/// -> {"peer": "fetch", "hash": "<decimal u64 string>"}
/// <- {"peer": "fetch", "len": N}   # N == 0 means miss
/// <- N raw bytes
/// ```
///
/// The hash rides as a decimal *string*: JSON numbers are f64 on this
/// wire and would silently round hashes above 2^53.
pub struct PeerFabric {
    addr: String,
}

impl PeerFabric {
    pub fn new(addr: &str) -> Self {
        PeerFabric { addr: addr.to_string() }
    }

    fn try_fetch(&self, hash: u64) -> std::io::Result<Option<Vec<u8>>> {
        let stream = TcpStream::connect(&self.addr)?;
        stream.set_read_timeout(Some(PEER_IO_TIMEOUT))?;
        stream.set_write_timeout(Some(PEER_IO_TIMEOUT))?;
        let mut writer = stream.try_clone()?;
        writer.write_all(
            format!("{{\"peer\":\"fetch\",\"hash\":\"{hash}\"}}\n").as_bytes(),
        )?;
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let bad = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
        let header: Value =
            json::parse(line.trim()).map_err(|e| bad(&format!("peer header: {e}")))?;
        let len = header
            .get("len")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| bad("peer header missing len"))?;
        if len == 0 {
            return Ok(None);
        }
        if len > MAX_RECORD_BYTES {
            return Err(bad(&format!("peer advertised absurd record ({len} bytes)")));
        }
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf)?;
        Ok(Some(buf))
    }
}

impl PrefixFabric for PeerFabric {
    fn fetch(&self, hash: u64) -> Option<Vec<u8>> {
        self.try_fetch(hash).ok().flatten()
    }

    fn publish(&self, _hash: u64, _record: &[u8]) -> bool {
        false // peers serve their own pool; nothing to push
    }

    fn describe(&self) -> String {
        format!("peer:{}", self.addr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pq-fabric-{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn dir_fabric_roundtrips_and_first_write_wins() {
        let dir = tmp("roundtrip");
        let f = DirFabric::new(&dir, 0xABCD).unwrap();
        assert!(f.fetch(7).is_none(), "cold directory misses");
        assert!(f.publish(7, b"record-one"));
        assert_eq!(f.fetch(7).as_deref(), Some(b"record-one".as_ref()));
        assert!(!f.publish(7, b"record-two"), "re-publish is a no-op");
        assert_eq!(f.fetch(7).as_deref(), Some(b"record-one".as_ref()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn dir_fabric_namespaces_by_config_tag() {
        let dir = tmp("tag");
        let a = DirFabric::new(&dir, 1).unwrap();
        let b = DirFabric::new(&dir, 2).unwrap();
        assert!(a.publish(9, b"from-a"));
        assert!(b.fetch(9).is_none(), "other fingerprint must not see the record");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn peer_fabric_survives_a_dead_address() {
        // nothing listens here: fetch must be a miss, not a hang or panic
        let f = PeerFabric::new("127.0.0.1:1");
        assert!(f.fetch(42).is_none());
        assert!(!f.publish(42, b"x"));
    }
}
