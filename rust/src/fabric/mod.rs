//! Multi-node serving fabric: front-tier routing + a shared prefix
//! cache over tier segments.
//!
//! PolarQuant pages are compact (packed polar codes instead of fp16 KV),
//! which makes *moving* a cached prefix between nodes dramatically
//! cheaper than re-prefilling it — the cross-node corollary of the
//! disk tier's economics.  This subsystem scales the single-process
//! server out:
//!
//! * [`ring`] — consistent-hash ring: sessions and prefix keys map to
//!   backend nodes by name, stable under node add/remove (only ~1/N of
//!   keys move), with health applied as a *skip* so placements return
//!   to their home node when it recovers.
//! * [`record`] — the transfer codec: one prefix entry (parent chain
//!   hash + token run + tier-codec page bytes) as a checksummed,
//!   config-fingerprinted blob.  Corrupt or mismatched fetches decode
//!   to `Err` and degrade to a cold prefill — never a wrong cache.
//! * [`backend`] — the two fetch/publish transports behind
//!   [`PrefixFabric`]: a shared segment *directory* (`--fabric-dir`,
//!   one file per chain hash, atomic tmp+rename publication) and a
//!   designated *peer* (`--fabric-peer`, a `{"peer":"fetch"}` frame on
//!   the JSON-lines admin channel followed by raw record bytes).
//! * [`front`] — the `route` front tier: speaks wire v2 to clients
//!   (streaming, sessions, cancel, tenants pass through), places
//!   sessions on backends via the ring, proxies frames, tracks node
//!   health by heartbeat, honors draining, and hedges slow requests
//!   onto a second node with the loser cancelled mid-stream.
//!
//! The pool side lives in [`crate::kvcache::pool`]: `lookup_prefix`, on
//! a local+tier miss, asks the attached fabric for the chain hash and
//! admits the page only after full verification (checksum, config tag,
//! parent hash, exact token run).

pub mod backend;
pub mod front;
pub mod record;
pub mod ring;

use std::sync::atomic::{AtomicU64, Ordering};

pub use backend::{DirFabric, PeerFabric};
pub use front::{route, FrontHandle, FrontOpts};
pub use record::{decode_record, encode_record, FabricRecord};
pub use ring::HashRing;

/// A remote source of prefix-cache pages.  Implementations are dumb
/// byte transports — all verification happens in the pool, so a
/// misbehaving fabric can cost a fetch, never correctness.
pub trait PrefixFabric: Send + Sync {
    /// Raw record bytes for `hash`, or `None` on a miss / transport error.
    fn fetch(&self, hash: u64) -> Option<Vec<u8>>;
    /// Offer a freshly registered prefix entry to the fabric.  Returns
    /// whether the record was actually published (already-present and
    /// fetch-only transports return `false`).
    fn publish(&self, hash: u64, record: &[u8]) -> bool;
    /// Human-readable transport description for startup logs.
    fn describe(&self) -> String;
}

/// Shared fabric counters, surfaced through admin metrics / Prometheus
/// as `fabric_*`.
#[derive(Debug, Default)]
pub struct FabricCounters {
    /// prefix lookups that were satisfied by a fabric fetch
    pub hits: AtomicU64,
    /// pages admitted from the fabric (== hits while records carry one page)
    pub pages: AtomicU64,
    /// fetched records rejected by verification (corrupt, wrong config,
    /// wrong chain) — each one degraded to a cold prefill
    pub rejected: AtomicU64,
    /// records this node published to the fabric
    pub published: AtomicU64,
    /// raw record bytes fetched (hit or rejected)
    pub bytes_fetched: AtomicU64,
}

impl FabricCounters {
    pub fn get(&self, c: &AtomicU64) -> u64 {
        c.load(Ordering::Relaxed)
    }

    pub fn bump(c: &AtomicU64, by: u64) {
        c.fetch_add(by, Ordering::Relaxed);
    }
}
