//! Consistent-hash ring for session / prefix placement.
//!
//! Each node contributes `vnodes` points hashed from its *name* alone
//! (`fnv1a("addr#i")`), so the key→node mapping depends only on the set
//! of node names: adding or removing a node moves ~1/N of the keyspace
//! and never reshuffles keys between surviving nodes.  Health is not
//! baked into the ring — callers pass an `ok` predicate to [`HashRing::pick`]
//! so a key's *home* node stays stable while the node is merely skipped
//! (drained / unhealthy), and placements return home when it recovers.

use crate::kvcache::tier::serde::fnv1a;

/// Immutable point set over a fixed node list.
#[derive(Debug, Clone)]
pub struct HashRing {
    nodes: Vec<String>,
    /// (point hash, node index), sorted by hash.  Ties are broken by
    /// node index so construction order never matters.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// Build a ring over `nodes` with `vnodes` points per node.
    pub fn new(nodes: &[String], vnodes: usize) -> Self {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(nodes.len() * vnodes);
        for (i, n) in nodes.iter().enumerate() {
            for v in 0..vnodes {
                let label = format!("{n}#{v}");
                points.push((fnv1a(label.as_bytes()), i));
            }
        }
        points.sort_unstable();
        HashRing { nodes: nodes.to_vec(), points }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node_name(&self, idx: usize) -> &str {
        &self.nodes[idx]
    }

    /// Index of the first ring point at or clockwise-after `key`.
    fn start(&self, key: u64) -> usize {
        match self.points.binary_search_by(|&(h, _)| h.cmp(&key)) {
            Ok(i) => i,
            Err(i) => i % self.points.len().max(1),
        }
    }

    /// The key's home node, ignoring health.
    pub fn node_for(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        Some(self.points[self.start(key)].1)
    }

    /// First node clockwise from `key` that satisfies `ok`.  Walking the
    /// ring (rather than re-hashing) keeps the fallback deterministic
    /// and returns the key to its home node once `ok(home)` again.
    pub fn pick(&self, key: u64, ok: impl Fn(usize) -> bool) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let start = self.start(key);
        let n = self.points.len();
        let mut seen = vec![false; self.nodes.len()];
        for step in 0..n {
            let (_, node) = self.points[(start + step) % n];
            if seen[node] {
                continue;
            }
            seen[node] = true;
            if ok(node) {
                return Some(node);
            }
        }
        None
    }

    /// Like [`HashRing::pick`] but skipping `not` — the hedge target:
    /// the next distinct healthy node clockwise from the key.
    pub fn pick_distinct(
        &self,
        key: u64,
        not: usize,
        ok: impl Fn(usize) -> bool,
    ) -> Option<usize> {
        self.pick(key, |n| n != not && ok(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("node-{i}:7000")).collect()
    }

    #[test]
    fn empty_ring_places_nothing() {
        let ring = HashRing::new(&[], 32);
        assert!(ring.is_empty());
        assert_eq!(ring.node_for(42), None);
        assert_eq!(ring.pick(42, |_| true), None);
    }

    #[test]
    fn single_node_takes_everything() {
        let ring = HashRing::new(&names(1), 8);
        for k in 0..64u64 {
            assert_eq!(ring.node_for(k.wrapping_mul(0x9E37_79B9_7F4A_7C15)), Some(0));
        }
    }

    #[test]
    fn placement_is_deterministic_and_roughly_balanced() {
        let ring = HashRing::new(&names(4), 64);
        let mut counts = [0usize; 4];
        for k in 0..4096u64 {
            let key = fnv1a(&k.to_le_bytes());
            let a = ring.node_for(key).unwrap();
            let b = ring.node_for(key).unwrap();
            assert_eq!(a, b, "same key, same node");
            counts[a] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                c > 4096 / 4 / 4,
                "node {i} got {c}/4096 keys — badly unbalanced ring"
            );
        }
    }

    #[test]
    fn pick_skips_unhealthy_then_returns_home() {
        let ring = HashRing::new(&names(3), 32);
        let key = fnv1a(b"session-77");
        let home = ring.node_for(key).unwrap();
        let detour = ring.pick(key, |n| n != home).unwrap();
        assert_ne!(detour, home, "detour must avoid the down node");
        // once the home node is healthy again the key goes straight back
        assert_eq!(ring.pick(key, |_| true), Some(home));
    }

    #[test]
    fn pick_distinct_never_returns_the_excluded_node() {
        let ring = HashRing::new(&names(3), 32);
        for k in 0..256u64 {
            let key = fnv1a(&k.to_le_bytes());
            let first = ring.node_for(key).unwrap();
            let second = ring.pick_distinct(key, first, |_| true).unwrap();
            assert_ne!(first, second);
        }
    }

    #[test]
    fn removing_a_node_only_moves_its_own_keys() {
        let all = names(4);
        let full = HashRing::new(&all, 64);
        let mut three = all.clone();
        three.remove(2);
        let reduced = HashRing::new(&three, 64);
        for k in 0..2048u64 {
            let key = fnv1a(&k.to_le_bytes());
            let before = full.node_for(key).unwrap();
            if before == 2 {
                continue; // the removed node's keys may land anywhere
            }
            let after = reduced.node_for(key).unwrap();
            assert_eq!(
                full.node_name(before),
                reduced.node_name(after),
                "key {k} moved between surviving nodes"
            );
        }
    }
}
