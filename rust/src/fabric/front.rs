//! The `route` front tier: one process that speaks wire v2 to clients
//! and fans requests out to N backend `serve` processes.
//!
//! Placement is consistent hashing ([`super::ring`]): sessions hash by
//! their front-assigned session id, sessionless requests by a prefix of
//! their prompt tokens (so identical system prompts land on the same
//! backend and share its prefix cache).  Health is heartbeat-driven —
//! a `{"admin":"ping"}` per node per interval — and applied only to NEW
//! placements: a draining or flapping node keeps serving its existing
//! sessions (that is the drain contract) while new work routes around
//! it.
//!
//! Ids are front-owned.  Backends allocate request ids and session ids
//! independently, so two backends WILL collide; the front therefore
//! allocates its own id per request (and session ids from `1 << 40`,
//! above the backends' `1 << 32` range) and rewrites the `id` /
//! `session` fields on every frame crossing it.  Clients never see a
//! backend-local id.
//!
//! Hedging: a streaming sessionless request that produces no progress
//! within `--hedge-after-ms` is re-dispatched to the next distinct
//! healthy node on the ring.  The first attempt to deliver a token /
//! done / rejected frame wins and owns the client stream; the loser is
//! cancelled via the ordinary wire-v2 cancel frame and drained
//! silently.  `admitted` / `prefill` progress frames are suppressed for
//! hedged requests (both attempts would emit them; clients treat them
//! as informational), so the client sees exactly one coherent stream.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::router::Router;
use crate::kvcache::tier::serde::fnv1a;
use crate::util::json::{self, num, obj, Value};

use super::ring::HashRing;

/// Tokens of the prompt prefix that drive sessionless placement: enough
/// to bucket by system prompt, short enough that divergent tails still
/// colocate.
const PLACEMENT_PREFIX: usize = 32;
/// Backend session ids start at `1 << 32`; front ids live far above.
const FRONT_SID_BASE: u64 = 1 << 40;
const CONNECT_TIMEOUT: Duration = Duration::from_millis(500);
const PROBE_TIMEOUT: Duration = Duration::from_secs(2);

#[derive(Clone, Debug)]
pub struct FrontOpts {
    /// listen address for clients
    pub addr: String,
    /// backend `serve` addresses
    pub backends: Vec<String>,
    /// re-dispatch a stalled streaming request after this long
    pub hedge_after: Option<Duration>,
    /// node health probe interval
    pub heartbeat: Duration,
    /// ring points per backend
    pub vnodes: usize,
}

struct Node {
    addr: String,
    healthy: AtomicBool,
    draining: AtomicBool,
}

/// One live proxied attempt: where cancels for it go.
struct Attempt {
    writer: Mutex<TcpStream>,
    /// backend-assigned request id, learned from the `admitted` frame
    /// (0 = not yet known; backends start ids at 1)
    backend_id: AtomicU64,
}

impl Attempt {
    fn cancel(&self) {
        let bid = self.backend_id.load(Ordering::Relaxed);
        if bid != 0 {
            let mut w = self.writer.lock().unwrap();
            let _ = writeln!(w, "{{\"v\":2,\"cancel\":{bid}}}");
        }
    }
}

/// Per-request cancel fan-out: the client's cancel frame reaches every
/// attempt (primary + hedge) that has learned its backend id; attempts
/// that learn theirs later check the flag then.
struct Inflight {
    cancel_requested: AtomicBool,
    attempts: Mutex<Vec<Arc<Attempt>>>,
}

/// Hedge coordination: the first attempt to deliver substantive output
/// claims the slot and owns the client stream.
struct Race {
    winner: OnceLock<usize>,
    progressed: AtomicBool,
}

struct SessionRoute {
    node: usize,
    backend_sid: u64,
}

struct FrontState {
    ring: HashRing,
    nodes: Vec<Node>,
    /// load accounting + sticky front-session map, same policy object
    /// the in-process server uses — here the ring picks the node and
    /// [`Router::route_to`] records the placement
    router: Mutex<Router>,
    sessions: Mutex<HashMap<u64, SessionRoute>>,
    next_sid: AtomicU64,
    next_id: AtomicU64,
    stop: AtomicBool,
    hedge_after: Option<Duration>,
    requests_proxied: AtomicU64,
    hedges_fired: AtomicU64,
    hedges_won: AtomicU64,
}

impl FrontState {
    fn placeable(&self, n: usize) -> bool {
        self.nodes[n].healthy.load(Ordering::Relaxed)
            && !self.nodes[n].draining.load(Ordering::Relaxed)
    }
}

/// A running front tier.
pub struct FrontHandle {
    pub addr: String,
    state: Arc<FrontState>,
    listener_thread: Option<JoinHandle<()>>,
    heartbeat_thread: Option<JoinHandle<()>>,
}

impl FrontHandle {
    fn join(&mut self) {
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.heartbeat_thread.take() {
            let _ = t.join();
        }
    }

    /// Signal shutdown and join the listener + heartbeat threads.
    pub fn stop(mut self) {
        self.state.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(&self.addr); // poke accept()
        self.join();
    }

    /// Block until a client sends `{"admin":"shutdown"}`.
    pub fn wait(mut self) {
        self.join();
    }
}

/// Start the front tier.  Returns once the listener is bound; backends
/// may still be starting — the heartbeat marks them healthy as they
/// come up, and a failed dispatch marks a node down immediately.
pub fn route(opts: FrontOpts) -> Result<FrontHandle> {
    anyhow::ensure!(!opts.backends.is_empty(), "route needs at least one backend");
    let listener = TcpListener::bind(&opts.addr).context("bind front tier")?;
    let local = listener.local_addr()?.to_string();
    let nodes: Vec<Node> = opts
        .backends
        .iter()
        .map(|a| Node {
            addr: a.clone(),
            // optimistic start: the first heartbeat (or first failed
            // dispatch) corrects
            healthy: AtomicBool::new(true),
            draining: AtomicBool::new(false),
        })
        .collect();
    let state = Arc::new(FrontState {
        ring: HashRing::new(&opts.backends, opts.vnodes.max(1)),
        nodes,
        router: Mutex::new(Router::new(opts.backends.len())),
        sessions: Mutex::new(HashMap::new()),
        next_sid: AtomicU64::new(FRONT_SID_BASE),
        next_id: AtomicU64::new(0),
        stop: AtomicBool::new(false),
        hedge_after: opts.hedge_after,
        requests_proxied: AtomicU64::new(0),
        hedges_fired: AtomicU64::new(0),
        hedges_won: AtomicU64::new(0),
    });

    let hb_state = state.clone();
    let interval = opts.heartbeat;
    let heartbeat_thread = std::thread::spawn(move || heartbeat_loop(&hb_state, interval));

    let ln_state = state.clone();
    let front_addr = local.clone();
    let listener_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if ln_state.stop.load(Ordering::Relaxed) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let st = ln_state.clone();
            let fa = front_addr.clone();
            std::thread::spawn(move || {
                let _ = handle_client(stream, &st, &fa);
            });
        }
    });

    Ok(FrontHandle {
        addr: local,
        state,
        listener_thread: Some(listener_thread),
        heartbeat_thread: Some(heartbeat_thread),
    })
}

// ------------------------------------------------------------ heartbeat

/// One ping round-trip: `Some(draining)` when the node answered.
fn probe(addr: &str) -> Option<bool> {
    let sock = addr.to_socket_addrs().ok()?.next()?;
    let stream = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT).ok()?;
    stream.set_read_timeout(Some(PROBE_TIMEOUT)).ok()?;
    stream.set_write_timeout(Some(PROBE_TIMEOUT)).ok()?;
    let mut w = stream.try_clone().ok()?;
    writeln!(w, "{{\"admin\":\"ping\"}}").ok()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).ok()?;
    let v = json::parse(line.trim()).ok()?;
    if !v.get("ok").and_then(|b| b.as_bool()).unwrap_or(false) {
        return None;
    }
    Some(v.get("draining").and_then(|b| b.as_bool()).unwrap_or(false))
}

fn heartbeat_loop(state: &FrontState, interval: Duration) {
    loop {
        for node in &state.nodes {
            if state.stop.load(Ordering::Relaxed) {
                return;
            }
            match probe(&node.addr) {
                Some(draining) => {
                    if !node.healthy.swap(true, Ordering::Relaxed) {
                        eprintln!("[route] backend {} is healthy", node.addr);
                    }
                    if node.draining.swap(draining, Ordering::Relaxed) != draining {
                        eprintln!(
                            "[route] backend {} {}",
                            node.addr,
                            if draining { "is draining" } else { "stopped draining" }
                        );
                    }
                }
                None => {
                    if node.healthy.swap(false, Ordering::Relaxed) {
                        eprintln!("[route] backend {} is DOWN", node.addr);
                    }
                }
            }
        }
        // sleep in slices so stop() doesn't wait out a long interval
        let deadline = Instant::now() + interval;
        while Instant::now() < deadline {
            if state.stop.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
    }
}

// ------------------------------------------------------- frame plumbing

type SharedStream = Arc<Mutex<TcpStream>>;

fn write_line(out: &SharedStream, v: &Value) -> std::io::Result<()> {
    let mut s = out.lock().unwrap();
    writeln!(s, "{}", json::write(v))
}

fn error_frame(msg: &str) -> Value {
    obj(vec![("error", json::s(msg))])
}

/// Overwrite one object field (no-op on non-objects).
fn set_field(v: &mut Value, key: &str, val: Value) {
    if let Value::Obj(m) = v {
        m.insert(key.to_string(), val);
    }
}

/// The v2 rejection the front emits when it cannot reach any backend.
/// Shaped like an engine rejection so clients need no special casing;
/// the reason label is front-specific.
fn unavailable_frame(front_id: u64, v1: bool) -> Value {
    if v1 {
        obj(vec![
            ("id", num(front_id as f64)),
            ("prompt_len", num(0.0)),
            ("tokens", Value::Arr(Vec::new())),
            ("truncated", Value::Bool(false)),
            ("rejected", Value::Bool(true)),
            ("finish_reason", json::s("rejected")),
            ("reason", json::s("node_unavailable")),
        ])
    } else {
        obj(vec![
            ("v", num(2.0)),
            ("event", json::s("rejected")),
            ("id", num(front_id as f64)),
            ("reason", json::s("node_unavailable")),
        ])
    }
}

/// Placement key for a sessionless request: hash of the prompt's first
/// [`PLACEMENT_PREFIX`] tokens, so shared system prompts colocate.
fn prompt_key(prompt: &[u32]) -> u64 {
    let mut bytes = Vec::with_capacity(4 * PLACEMENT_PREFIX.min(prompt.len()));
    for t in prompt.iter().take(PLACEMENT_PREFIX) {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    fnv1a(&bytes)
}

fn sid_key(sid: u64) -> u64 {
    fnv1a(&sid.to_le_bytes())
}

/// Open a fresh connection to a backend (backend sessions are
/// connection-independent, so per-request connections are correct; they
/// are also what keeps the front a thin pass-through with no pooled
/// stream multiplexing to get wrong).
fn connect_backend(state: &FrontState, node: usize) -> Option<TcpStream> {
    let addr = &state.nodes[node].addr;
    let sock = addr.to_socket_addrs().ok()?.next()?;
    match TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT) {
        Ok(s) => Some(s),
        Err(_) => {
            // dispatch is the fastest health detector there is
            if state.nodes[node].healthy.swap(false, Ordering::Relaxed) {
                eprintln!("[route] backend {addr} is DOWN (dispatch failed)");
            }
            None
        }
    }
}

/// One request/reply exchange on a fresh backend connection.
fn backend_roundtrip(state: &FrontState, node: usize, frame: &Value) -> Option<Value> {
    let stream = connect_backend(state, node)?;
    stream.set_read_timeout(Some(PROBE_TIMEOUT)).ok()?;
    let mut w = stream.try_clone().ok()?;
    writeln!(w, "{}", json::write(frame)).ok()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).ok()?;
    json::parse(line.trim()).ok()
}

// ------------------------------------------------------- client handler

type ConnRequests = Arc<Mutex<HashMap<u64, Arc<Inflight>>>>;

fn handle_client(stream: TcpStream, state: &Arc<FrontState>, front_addr: &str) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let out: SharedStream = Arc::new(Mutex::new(stream));
    let my_requests: ConnRequests = Arc::new(Mutex::new(HashMap::new()));
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                write_line(&out, &error_frame(&e.0))?;
                continue;
            }
        };
        if let Some(cmd) = v.get("admin").and_then(|a| a.as_str()) {
            handle_front_admin(cmd, state, &out, front_addr)?;
            if cmd == "shutdown" {
                return Ok(());
            }
            continue;
        }
        match v.usize_or("v", 1) {
            1 => handle_request(v, true, state, &out, &my_requests),
            2 => handle_v2(v, state, &out, &my_requests)?,
            other => write_line(&out, &error_frame(&format!(
                "unsupported protocol version {other} (this router speaks v1 and v2)"
            )))?,
        }
    }
}

fn handle_front_admin(
    cmd: &str,
    state: &Arc<FrontState>,
    out: &SharedStream,
    front_addr: &str,
) -> Result<()> {
    match cmd {
        "ping" => write_line(out, &obj(vec![
            ("admin", json::s("ping")),
            ("ok", Value::Bool(true)),
            ("role", json::s("route")),
        ]))?,
        "shutdown" => {
            state.stop.store(true, Ordering::Relaxed);
            let _ = TcpStream::connect(front_addr); // unblock accept()
            write_line(out, &obj(vec![
                ("admin", json::s("shutdown")),
                ("ok", Value::Bool(true)),
            ]))?;
        }
        "metrics" => {
            let sessions = state.sessions.lock().unwrap();
            let mut per_node: Vec<usize> = vec![0; state.nodes.len()];
            for r in sessions.values() {
                per_node[r.node] += 1;
            }
            drop(sessions);
            let router = state.router.lock().unwrap();
            let backends: Vec<Value> = state
                .nodes
                .iter()
                .enumerate()
                .map(|(i, n)| obj(vec![
                    ("addr", json::s(&n.addr)),
                    ("healthy", Value::Bool(n.healthy.load(Ordering::Relaxed))),
                    ("draining", Value::Bool(n.draining.load(Ordering::Relaxed))),
                    ("load", num(router.load(i) as f64)),
                    ("sessions", num(per_node[i] as f64)),
                ]))
                .collect();
            drop(router);
            write_line(out, &obj(vec![
                ("admin", json::s("metrics")),
                ("ok", Value::Bool(true)),
                ("role", json::s("route")),
                ("requests_proxied",
                 num(state.requests_proxied.load(Ordering::Relaxed) as f64)),
                ("hedges_fired", num(state.hedges_fired.load(Ordering::Relaxed) as f64)),
                ("hedges_won", num(state.hedges_won.load(Ordering::Relaxed) as f64)),
                ("backends", Value::Arr(backends)),
            ]))?;
        }
        other => write_line(out, &obj(vec![
            ("ok", Value::Bool(false)),
            ("error", json::s(&format!(
                "unknown admin command '{other}' (the front tier answers ping/metrics/\
                 shutdown; query backends directly for engine counters)"
            ))),
        ]))?,
    }
    Ok(())
}

fn handle_v2(
    v: Value,
    state: &Arc<FrontState>,
    out: &SharedStream,
    my_requests: &ConnRequests,
) -> Result<()> {
    // -- session open ---------------------------------------------------
    if v.get("open_session").and_then(|b| b.as_bool()).unwrap_or(false) {
        let fail = |out: &SharedStream, why: &str| write_line(out, &obj(vec![
            ("v", num(2.0)),
            ("event", json::s("session")),
            ("ok", Value::Bool(false)),
            ("error", json::s(why)),
        ]));
        let fsid = state.next_sid.fetch_add(1, Ordering::Relaxed);
        let Some(node) = state.ring.pick(sid_key(fsid), |n| state.placeable(n)) else {
            return fail(out, "no healthy backend accepts new sessions").map_err(Into::into);
        };
        let open = obj(vec![("v", num(2.0)), ("open_session", Value::Bool(true))]);
        let reply = backend_roundtrip(state, node, &open);
        let backend_sid = reply
            .as_ref()
            .filter(|r| r.get("ok").and_then(|b| b.as_bool()).unwrap_or(false))
            .and_then(|r| r.get("session").and_then(|s| s.as_i64()))
            .map(|s| s as u64);
        let Some(backend_sid) = backend_sid else {
            return fail(out, "backend refused the session").map_err(Into::into);
        };
        state.sessions.lock().unwrap().insert(fsid, SessionRoute { node, backend_sid });
        {
            // record the sticky placement; the open itself is not an
            // in-flight request, so balance the load count right away
            let mut router = state.router.lock().unwrap();
            router.route_to(Some(fsid), node);
            router.complete(node);
        }
        write_line(out, &obj(vec![
            ("v", num(2.0)),
            ("event", json::s("session")),
            ("session", num(fsid as f64)),
            ("ok", Value::Bool(true)),
        ]))?;
        return Ok(());
    }
    // -- cancel ---------------------------------------------------------
    if let Some(front_id) = v.get("cancel").and_then(|c| c.as_usize()) {
        // fire-and-forget, mirroring the backend contract: the answer is
        // the request's own terminal frame
        if let Some(inflight) = my_requests.lock().unwrap().get(&(front_id as u64)) {
            inflight.cancel_requested.store(true, Ordering::Relaxed);
            for a in inflight.attempts.lock().unwrap().iter() {
                a.cancel();
            }
        }
        return Ok(());
    }
    // -- session close --------------------------------------------------
    if v.get("close").and_then(|b| b.as_bool()).unwrap_or(false) {
        let Some(fsid) = v.get("session").and_then(|s| s.as_i64()).map(|s| s as u64) else {
            write_line(out, &error_frame("close needs a session id"))?;
            return Ok(());
        };
        if let Some(route) = state.sessions.lock().unwrap().remove(&fsid) {
            let close = obj(vec![
                ("v", num(2.0)),
                ("session", num(route.backend_sid as f64)),
                ("close", Value::Bool(true)),
            ]);
            let _ = backend_roundtrip(state, route.node, &close);
        }
        state.router.lock().unwrap().end_session(fsid);
        // idempotent like the backend: closing an unknown session is ok
        write_line(out, &obj(vec![
            ("v", num(2.0)),
            ("event", json::s("session_closed")),
            ("session", num(fsid as f64)),
            ("ok", Value::Bool(true)),
        ]))?;
        return Ok(());
    }
    // -- generate / turn ------------------------------------------------
    if tokens_of(&v, "turn").is_none() && tokens_of(&v, "prompt").is_none() {
        write_line(out, &error_frame(
            "expected one of prompt, turn, cancel, open_session, close",
        ))?;
        return Ok(());
    }
    handle_request(v, false, state, out, my_requests);
    Ok(())
}

fn tokens_of(v: &Value, key: &str) -> Option<Vec<u32>> {
    v.get(key)
        .and_then(|p| p.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_usize()).map(|x| x as u32).collect())
}

/// Place + proxy one generate request (v1 one-shot or v2 prompt/turn).
/// Spawns a coordinator thread so the connection loop keeps reading
/// (that is what makes client cancels reachable mid-stream).
fn handle_request(
    mut v: Value,
    v1: bool,
    state: &Arc<FrontState>,
    out: &SharedStream,
    my_requests: &ConnRequests,
) {
    let front_id = state.next_id.fetch_add(1, Ordering::Relaxed) + 1;
    let session = v.get("session").and_then(|s| s.as_i64()).map(|s| s as u64);
    let streaming = !v1 && v.get("stream").and_then(|b| b.as_bool()).unwrap_or(false);

    // ---- placement
    let node = if let Some(fsid) = session {
        if v1 {
            // v1 session ids are client-chosen affinity keys, not
            // front-allocated: place them by ring so the same key is
            // sticky across connections, no rewrite needed
            state.ring.pick(sid_key(fsid), |n| state.placeable(n))
        } else {
            let sessions = state.sessions.lock().unwrap();
            let Some(route) = sessions.get(&fsid) else {
                drop(sessions);
                let _ = write_line(out, &error_frame(&format!("unknown session {fsid}")));
                return;
            };
            // existing sessions stay on their node even while it drains —
            // that IS the drain semantic (finish in-flight, refuse new)
            set_field(&mut v, "session", num(route.backend_sid as f64));
            Some(route.node)
        }
    } else {
        let prompt = tokens_of(&v, "prompt").unwrap_or_default();
        state.ring.pick(prompt_key(&prompt), |n| state.placeable(n))
    };
    let Some(node) = node else {
        let _ = write_line(out, &unavailable_frame(front_id, v1));
        return;
    };

    state.requests_proxied.fetch_add(1, Ordering::Relaxed);
    let inflight = Arc::new(Inflight {
        cancel_requested: AtomicBool::new(false),
        attempts: Mutex::new(Vec::new()),
    });
    my_requests.lock().unwrap().insert(front_id, inflight.clone());

    // hedging applies to streaming sessionless requests only: session
    // turns are pinned to their node, and non-streaming replies give the
    // front no admitted frame to cancel the loser with
    let hedge_after = match (streaming, session) {
        (true, None) => state.hedge_after,
        _ => None,
    };
    let race = hedge_after.map(|_| Arc::new(Race {
        winner: OnceLock::new(),
        progressed: AtomicBool::new(false),
    }));

    let st = state.clone();
    let out = out.clone();
    let requests = my_requests.clone();
    std::thread::spawn(move || {
        st.router.lock().unwrap().route_to(session, node);
        let mut handles = Vec::new();
        {
            let (st, v, out, inflight) = (st.clone(), v.clone(), out.clone(), inflight.clone());
            let race = race.clone();
            handles.push(std::thread::spawn(move || {
                relay_attempt(&st, node, v, front_id, &out, &inflight, race.as_deref(), 0)
            }));
        }
        if let (Some(after), Some(race)) = (hedge_after, race.as_ref()) {
            // watch for progress until the hedge deadline
            let deadline = Instant::now() + after;
            while Instant::now() < deadline
                && race.winner.get().is_none()
                && !race.progressed.load(Ordering::Relaxed)
            {
                std::thread::sleep(Duration::from_millis(1));
            }
            if race.winner.get().is_none() && !race.progressed.load(Ordering::Relaxed) {
                let key = prompt_key(&tokens_of(&v, "prompt").unwrap_or_default());
                if let Some(second) =
                    st.ring.pick_distinct(key, node, |n| st.placeable(n))
                {
                    st.hedges_fired.fetch_add(1, Ordering::Relaxed);
                    st.router.lock().unwrap().route_to(None, second);
                    let (st2, out2, inflight2) = (st.clone(), out.clone(), inflight.clone());
                    let race2 = race.clone();
                    handles.push(std::thread::spawn(move || {
                        relay_attempt(
                            &st2, second, v, front_id, &out2, &inflight2, Some(&race2), 1,
                        )
                    }));
                }
            }
        }
        let mut delivered = false;
        for h in handles {
            delivered |= h.join().unwrap_or(false);
        }
        if let Some(race) = race.as_ref() {
            if race.winner.get() == Some(&1) {
                st.hedges_won.fetch_add(1, Ordering::Relaxed);
            }
        }
        if !delivered {
            // every attempt died before reaching a terminal frame
            let _ = write_line(&out, &unavailable_frame(front_id, v1));
        }
        requests.lock().unwrap().remove(&front_id);
    });
}

/// Proxy one attempt: send the (rewritten) request on a fresh backend
/// connection and relay frames to the client until the terminal frame.
/// Returns whether a terminal frame was delivered to the client.
///
/// With a `race`, the first attempt to produce a token / done / rejected
/// claims the stream; the loser cancels its backend copy and drains
/// silently.  Progress frames (`admitted` / `prefill`) are suppressed
/// in race mode from BOTH attempts, so the client sees one stream.
#[allow(clippy::too_many_arguments)]
fn relay_attempt(
    state: &FrontState,
    node: usize,
    request: Value,
    front_id: u64,
    out: &SharedStream,
    inflight: &Inflight,
    race: Option<&Race>,
    attempt: usize,
) -> bool {
    let finish = |delivered: bool| {
        state.router.lock().unwrap().complete(node);
        delivered
    };
    let Some(stream) = connect_backend(state, node) else {
        return finish(false);
    };
    let Ok(write_half) = stream.try_clone() else {
        return finish(false);
    };
    let att = Arc::new(Attempt {
        writer: Mutex::new(write_half),
        backend_id: AtomicU64::new(0),
    });
    inflight.attempts.lock().unwrap().push(att.clone());
    {
        let mut w = att.writer.lock().unwrap();
        if writeln!(w, "{}", json::write(&request)).is_err() {
            return finish(false);
        }
    }
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut delivered = false;
    let mut lost = false;
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break, // backend went away
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let Ok(mut frame) = json::parse(trimmed) else { continue };
        let event = frame.get("event").and_then(|e| e.as_str()).unwrap_or("").to_string();
        let backend_error = frame.get("error").is_some();
        // learn the backend id as soon as the backend names it, and honor
        // a cancel that raced ahead of it
        if let Some(bid) = frame.get("id").and_then(|i| i.as_i64()) {
            if att.backend_id.swap(bid as u64, Ordering::Relaxed) == 0
                && inflight.cancel_requested.load(Ordering::Relaxed)
            {
                att.cancel();
            }
        }
        let terminal = matches!(event.as_str(), "done" | "rejected")
            || backend_error
            || (event.is_empty() && frame.get("tokens").is_some()); // v1 reply
        let progress = matches!(event.as_str(), "admitted" | "prefill");
        if let Some(race) = race {
            if progress {
                race.progressed.store(true, Ordering::Relaxed);
                continue; // suppressed: the winner's stream must be unique
            }
            if race.winner.get().is_none() {
                let _ = race.winner.set(attempt);
            }
            if race.winner.get() != Some(&attempt) {
                if !lost {
                    lost = true;
                    att.cancel(); // stop burning the losing backend
                }
                if terminal {
                    break; // drained to the end, nothing forwarded
                }
                continue;
            }
        }
        set_field(&mut frame, "id", num(front_id as f64));
        if write_line(out, &frame).is_err() {
            // client went away: cancel the backend copy and stop
            att.cancel();
            break;
        }
        if terminal {
            delivered = true;
            break;
        }
    }
    finish(delivered)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_key_buckets_by_prefix() {
        let sys: Vec<u32> = (100..164).collect();
        let mut a = sys.clone();
        a.extend([1, 2, 3]);
        let mut b = sys.clone();
        b.extend([9, 8, 7, 6]);
        // identical 32-token prefixes colocate even with divergent tails
        assert_eq!(prompt_key(&a), prompt_key(&b));
        let mut c = sys;
        c[0] += 1;
        assert_ne!(prompt_key(&a), prompt_key(&c));
    }

    #[test]
    fn set_field_rewrites_in_place() {
        let mut v = json::parse(r#"{"v":2,"event":"token","id":3,"token":42}"#).unwrap();
        set_field(&mut v, "id", num(900.0));
        assert_eq!(v.usize_or("id", 0), 900);
        assert_eq!(v.usize_or("token", 0), 42, "other fields untouched");
    }

    #[test]
    fn unavailable_frames_match_both_protocols() {
        let v1 = unavailable_frame(7, true);
        assert_eq!(v1.get("rejected").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(v1.str_or("reason", ""), "node_unavailable");
        let v2 = unavailable_frame(7, false);
        assert_eq!(v2.str_or("event", ""), "rejected");
        assert_eq!(v2.usize_or("id", 0), 7);
    }

    #[test]
    fn front_session_ids_clear_backend_range() {
        assert!(FRONT_SID_BASE > (1u64 << 32) + (1 << 31), "front sids must never collide");
    }

    #[test]
    fn route_refuses_an_empty_backend_list() {
        let opts = FrontOpts {
            addr: "127.0.0.1:0".into(),
            backends: Vec::new(),
            hedge_after: None,
            heartbeat: Duration::from_secs(1),
            vnodes: 16,
        };
        assert!(route(opts).is_err());
    }

    #[test]
    fn probe_of_a_dead_address_is_none() {
        assert_eq!(probe("127.0.0.1:1"), None);
    }
}
